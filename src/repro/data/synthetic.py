"""Synthetic corpora shaped like the paper's datasets (Table 3).

BigANN-style: uint8-quantized SIFT-like vectors (clustered GMM so graphs
have non-trivial structure).  DEEP-style: float32 unit-norm descriptors.
Scaled to CPU-budget N; the statistical shape (clustered, anisotropic)
is what matters for graph behaviour.
"""
from __future__ import annotations

import numpy as np


def _gmm(n: int, dim: int, n_clusters: int, rng: np.random.Generator, spread: float = 0.35):
    centers = rng.normal(0.0, 1.0, size=(n_clusters, dim))
    assign = rng.integers(0, n_clusters, size=n)
    x = centers[assign] + rng.normal(0.0, spread, size=(n, dim))
    return x.astype(np.float32), assign


def make_bigann_like(n: int, dim: int = 128, seed: int = 0, n_clusters: int = 64):
    """uint8-range clustered vectors (stored float32 for compute)."""
    rng = np.random.default_rng(seed)
    x, _ = _gmm(n, dim, n_clusters, rng)
    x = x - x.min()
    x = x / x.max() * 255.0
    return np.round(x).astype(np.float32)


def make_deep_like(n: int, dim: int = 96, seed: int = 0, n_clusters: int = 64):
    """Unit-norm float descriptors (DEEP-style)."""
    rng = np.random.default_rng(seed)
    x, _ = _gmm(n, dim, n_clusters, rng)
    x /= np.linalg.norm(x, axis=1, keepdims=True) + 1e-9
    return x.astype(np.float32)


def make_queries(corpus: np.ndarray, n_queries: int, seed: int = 1, noise: float = 0.05):
    """Queries drawn near corpus points (realistic ANN workload)."""
    rng = np.random.default_rng(seed)
    idx = rng.choice(corpus.shape[0], size=n_queries, replace=False)
    scale = np.abs(corpus).mean() * noise
    q = corpus[idx] + rng.normal(0.0, scale, size=(n_queries, corpus.shape[1]))
    return q.astype(np.float32)


def make_zipfian_queries(
    corpus: np.ndarray,
    n_queries: int,
    *,
    n_centers: int = 32,
    alpha: float = 1.1,
    seed: int = 1,
    noise: float = 0.05,
    mask: np.ndarray | None = None,
):
    """Skewed production-style workload: queries cluster around a few hot
    corpus points with Zipf(alpha) popularity.

    Center k (of ``n_centers`` points drawn from ``mask``-selected rows,
    or the whole corpus) is chosen with probability ∝ 1/(k+1)^alpha, so
    a handful of regions receive most of the traffic — the regime where
    an adaptive cache beats a static, filter-blind hot set.
    """
    rng = np.random.default_rng(seed)
    pool = np.flatnonzero(mask) if mask is not None else np.arange(corpus.shape[0])
    if pool.size == 0:
        raise ValueError("make_zipfian_queries: mask selects no corpus rows")
    if n_centers <= 0:
        raise ValueError(f"make_zipfian_queries: n_centers must be > 0, got {n_centers}")
    centers = rng.choice(pool, size=min(n_centers, pool.size), replace=False)
    w = 1.0 / np.arange(1, centers.size + 1) ** alpha
    p = w / w.sum()
    picks = centers[rng.choice(centers.size, size=n_queries, p=p)]
    scale = np.abs(corpus).mean() * noise
    q = corpus[picks] + rng.normal(0.0, scale, size=(n_queries, corpus.shape[1]))
    return q.astype(np.float32)
