"""Synthetic corpora shaped like the paper's datasets (Table 3).

BigANN-style: uint8-quantized SIFT-like vectors (clustered GMM so graphs
have non-trivial structure).  DEEP-style: float32 unit-norm descriptors.
Scaled to CPU-budget N; the statistical shape (clustered, anisotropic)
is what matters for graph behaviour.
"""
from __future__ import annotations

import numpy as np


def _gmm(n: int, dim: int, n_clusters: int, rng: np.random.Generator, spread: float = 0.35):
    centers = rng.normal(0.0, 1.0, size=(n_clusters, dim))
    assign = rng.integers(0, n_clusters, size=n)
    x = centers[assign] + rng.normal(0.0, spread, size=(n, dim))
    return x.astype(np.float32), assign


def make_bigann_like(n: int, dim: int = 128, seed: int = 0, n_clusters: int = 64):
    """uint8-range clustered vectors (stored float32 for compute)."""
    rng = np.random.default_rng(seed)
    x, _ = _gmm(n, dim, n_clusters, rng)
    x = x - x.min()
    x = x / x.max() * 255.0
    return np.round(x).astype(np.float32)


def make_deep_like(n: int, dim: int = 96, seed: int = 0, n_clusters: int = 64):
    """Unit-norm float descriptors (DEEP-style)."""
    rng = np.random.default_rng(seed)
    x, _ = _gmm(n, dim, n_clusters, rng)
    x /= np.linalg.norm(x, axis=1, keepdims=True) + 1e-9
    return x.astype(np.float32)


def make_queries(corpus: np.ndarray, n_queries: int, seed: int = 1, noise: float = 0.05):
    """Queries drawn near corpus points (realistic ANN workload)."""
    rng = np.random.default_rng(seed)
    idx = rng.choice(corpus.shape[0], size=n_queries, replace=False)
    scale = np.abs(corpus).mean() * noise
    q = corpus[idx] + rng.normal(0.0, scale, size=(n_queries, corpus.shape[1]))
    return q.astype(np.float32)
