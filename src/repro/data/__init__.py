from repro.data.synthetic import (
    make_bigann_like,
    make_deep_like,
    make_queries,
    make_zipfian_queries,
)
from repro.data.labels import (
    uniform_labels,
    zipf_labels,
    kmeans_correlated_labels,
    norm_bin_attribute,
    multilabel_tags,
)
from repro.data.groundtruth import filtered_ground_truth

__all__ = [
    "make_bigann_like",
    "make_deep_like",
    "make_queries",
    "make_zipfian_queries",
    "uniform_labels",
    "zipf_labels",
    "kmeans_correlated_labels",
    "norm_bin_attribute",
    "multilabel_tags",
    "filtered_ground_truth",
]
