"""Label/metadata generators matching the paper's evaluation settings.

  * uniform 10-class        (BigANN-100M / DEEP-100M, §5.1)
  * Zipf(alpha)             (§5.4.5 skewed labels)
  * k-means correlated(a)   (§5.4.6 spatial label correlation)
  * L2-norm equal-freq bins (§5.4.7 range predicates)
  * power-law multi-tags    (§5.2.5 YFCC-style subset predicates)
"""
from __future__ import annotations

import numpy as np


def uniform_labels(n: int, n_classes: int = 10, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, n_classes, size=n).astype(np.int32)


def zipf_labels(n: int, n_classes: int = 10, alpha: float = 1.0, seed: int = 0) -> np.ndarray:
    """Class c gets mass ∝ 1/(c+1)^alpha. Paper: top class 34%, rarest 3.4%."""
    rng = np.random.default_rng(seed)
    w = 1.0 / np.arange(1, n_classes + 1) ** alpha
    p = w / w.sum()
    return rng.choice(n_classes, size=n, p=p).astype(np.int32)


def kmeans_correlated_labels(
    vectors: np.ndarray, n_classes: int = 10, alpha: float = 1.0, seed: int = 0,
    iters: int = 10,
) -> np.ndarray:
    """alpha=0: random labels; alpha=1: label of the nearest k-means center.

    In-between: each node keeps its cluster label with prob alpha, else a
    uniform random label — selectivity stays ~1/n_classes at every alpha.
    """
    rng = np.random.default_rng(seed)
    n = vectors.shape[0]
    # lightweight k-means
    centers = vectors[rng.choice(n, n_classes, replace=False)].copy()
    for _ in range(iters):
        d = ((vectors[:, None, :] - centers[None, :, :]) ** 2).sum(-1) if n <= 20000 else None
        if d is None:  # chunked for big corpora
            assign = np.empty(n, dtype=np.int64)
            for s in range(0, n, 16384):
                blk = vectors[s : s + 16384]
                dd = ((blk[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
                assign[s : s + 16384] = dd.argmin(1)
        else:
            assign = d.argmin(1)
        for c in range(n_classes):
            m = assign == c
            if m.any():
                centers[c] = vectors[m].mean(0)
    keep = rng.random(n) < alpha
    rand = rng.integers(0, n_classes, size=n)
    return np.where(keep, assign, rand).astype(np.int32)


def norm_bin_attribute(vectors: np.ndarray, n_bins: int = 10):
    """Returns (continuous attribute, equal-frequency bin edges).

    The attribute is the vector's L2 norm; bins are equal-frequency so one
    bin ≈ 1/n_bins selectivity (§5.4.7).
    """
    norms = np.linalg.norm(vectors, axis=1)
    edges = np.quantile(norms, np.linspace(0.0, 1.0, n_bins + 1))
    edges[0] -= 1e-6
    edges[-1] += 1e-6
    return norms.astype(np.float32), edges.astype(np.float32)


def multilabel_tags(
    n: int, vocab: int = 2048, mean_tags: float = 6.0, zipf_alpha: float = 1.2, seed: int = 0
):
    """YFCC-like power-law tag assignment. Returns list-of-lists.

    Tag t has popularity ∝ 1/(t+1)^alpha; nodes draw Poisson(mean_tags)
    tags. The top tag covers tens of percent of nodes; most are rare —
    matching §5.2.5's description.
    """
    rng = np.random.default_rng(seed)
    w = 1.0 / np.arange(1, vocab + 1) ** zipf_alpha
    p = w / w.sum()
    counts = np.maximum(rng.poisson(mean_tags, size=n), 1)
    out = []
    for c in counts:
        out.append(np.unique(rng.choice(vocab, size=c, p=p)).tolist())
    return out


def multilabel_queries(
    tag_lists, n_queries: int, n_tags: tuple[int, int] = (1, 2), seed: int = 1
):
    """Query tag sets sampled from real node tag sets (so selectivity > 0)."""
    rng = np.random.default_rng(seed)
    out = []
    n = len(tag_lists)
    for _ in range(n_queries):
        node = rng.integers(0, n)
        tags = tag_lists[node]
        k = min(len(tags), rng.integers(n_tags[0], n_tags[1] + 1))
        out.append(sorted(rng.choice(tags, size=k, replace=False).tolist()))
    return out
