"""Exact filtered k-NN ground truth (for Recall@k)."""
from __future__ import annotations

import numpy as np


def filtered_ground_truth(
    corpus: np.ndarray,
    queries: np.ndarray,
    match_mask: np.ndarray,  # (B, N) bool or (N,) bool
    k: int = 10,
    block: int = 8192,
) -> np.ndarray:
    """Brute-force top-k among matching nodes. Returns (B, k) int32, -1 pad."""
    b = queries.shape[0]
    n = corpus.shape[0]
    if match_mask.ndim == 1:
        match_mask = np.broadcast_to(match_mask[None, :], (b, n))
    best_d = np.full((b, k), np.inf, dtype=np.float64)
    best_i = np.full((b, k), -1, dtype=np.int64)
    q_sq = (queries.astype(np.float64) ** 2).sum(1)[:, None]
    for s in range(0, n, block):
        blk = corpus[s : s + block].astype(np.float64)
        d = q_sq - 2.0 * queries.astype(np.float64) @ blk.T + (blk**2).sum(1)[None, :]
        d = np.where(match_mask[:, s : s + block], d, np.inf)
        cat_d = np.concatenate([best_d, d], axis=1)
        cat_i = np.concatenate(
            [best_i, np.broadcast_to(np.arange(s, s + blk.shape[0])[None, :], d.shape)], axis=1
        )
        sel = np.argpartition(cat_d, kth=k - 1, axis=1)[:, :k]
        best_d = np.take_along_axis(cat_d, sel, axis=1)
        best_i = np.take_along_axis(cat_i, sel, axis=1)
        order = np.argsort(best_d, axis=1)
        best_d = np.take_along_axis(best_d, order, axis=1)
        best_i = np.take_along_axis(best_i, order, axis=1)
    best_i[~np.isfinite(best_d)] = -1
    return best_i.astype(np.int32)
