"""LM token pipeline: synthetic corpus, packing, deterministic sharded batches.

Streams are pure functions of (seed, step): a restart replays the exact
batch sequence with no loader state to checkpoint (fault-tolerance
contract).  The synthetic corpus is a Zipf-distributed Markov-ish token
source — enough structure for loss curves to move.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1


def _zipf_probs(vocab: int, alpha: float):
    w = 1.0 / np.arange(1, vocab + 1) ** alpha
    return w / w.sum()


def batch_at_step(cfg: TokenStreamConfig, step: int):
    """Deterministic batch for `step`: {'tokens', 'targets'} (B, T) int32."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    p = _zipf_probs(min(cfg.vocab_size, 65536), cfg.zipf_alpha)
    base = rng.choice(len(p), size=(cfg.global_batch, cfg.seq_len + 1), p=p)
    # inject local structure: every 8th token repeats its predecessor
    base[:, 1::8] = base[:, 0:-1:8]
    base = base % cfg.vocab_size
    return {
        "tokens": base[:, :-1].astype(np.int32),
        "targets": base[:, 1:].astype(np.int32),
    }


def stream(cfg: TokenStreamConfig, start_step: int = 0):
    step = start_step
    while True:
        yield step, batch_at_step(cfg, step)
        step += 1
