from repro.distributed.sharding import (
    Layout,
    make_layout,
    lshard,
    param_pspec,
    store_pspec,
    tree_pspecs,
)

__all__ = [
    "Layout",
    "make_layout",
    "lshard",
    "param_pspec",
    "store_pspec",
    "tree_pspecs",
]
