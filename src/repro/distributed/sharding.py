"""Logical-axis sharding rules (MaxText-style) for every execution layout.

Models annotate parameters and activations with *logical* axis names
("embed", "ffn", "act_seq", ...).  A ``Layout`` maps logical names to mesh
axes for one execution mode; changing a layout changes the distribution of
the whole model without touching model code — this is the knob the §Perf
hillclimb turns.

Layouts
-------
* ``train`` / ``prefill`` — 2D data x sequence parallelism: activations
  sharded (batch -> data, seq -> model); compute params replicated
  (gathered per scanned layer from their ZeRO-sharded storage); expert
  weights sharded over ``model`` (EP).  Even on all chips for every arch
  (no head-divisibility constraints).
* ``decode`` — row/column tensor parallelism over ``model`` via the
  d_model axis (exact for all archs since every d_model % 16 == 0), with
  the KV cache sharded over *sequence* on ``model`` (flash-decode with a
  distributed softmax).
* ``long`` — decode with batch=1: cache sequence sharded over
  (data x model); batch unsharded.

Storage specs ("ZeRO"): parameters and optimizer state are stored fully
sharded over all free mesh axes (greedy largest-divisible-dim placement);
the per-layer gather back to the compute spec happens inside the scan
body, so peak memory holds one layer's gathered params, and XLA overlaps
the gather with the previous layer's compute.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Activation logical axes.  "act_kv_seq"/"act_full_seq" are deliberately
# unmapped (None) in the 2D layouts: constraining to them forces the
# all-gather that materializes full-length K/V (or a full sequence for
# strictly-sequential recurrences).  "act_lru" channel-shards linear
# recurrences instead of sequence-sharding them.
_ACT_RULES = {
    "train": {"act_batch": ("data",), "act_seq": ("model",), "act_lru": ("model",),
              "experts": ("model",)},
    "prefill": {"act_batch": ("data",), "act_seq": ("model",), "act_lru": ("model",),
                "experts": ("model",)},
    "decode": {"act_batch": ("data",), "cache_seq": ("model",), "embed": ("model",),
               "experts": ("model",)},
    "long": {"cache_seq": ("data", "model"), "embed": ("model",), "experts": ("model",)},
}
# Layout variants (per-arch overrides): "dp_only" folds the model axis into
# batch parallelism — used by archs with strictly-sequential recurrences
# (xLSTM's sLSTM) where sequence sharding cannot apply.
_ACT_RULES_DP_ONLY = {
    "train": {"act_batch": ("data", "model")},
    "prefill": {"act_batch": ("data",), "act_seq": ("model",), "act_lru": ("model",)},
}
# Parameter logical axes (compute specs)
_PARAM_RULES = {
    "train": {"experts": ("model",)},
    "prefill": {"experts": ("model",)},
    "decode": {"embed": ("model",), "experts": ("model",)},
    "long": {"embed": ("model",), "experts": ("model",)},
}


@dataclasses.dataclass(frozen=True)
class Layout:
    kind: str  # train | prefill | decode | long | None
    mesh: Mesh | None
    multi_pod: bool = False
    variant: str = "default"  # default | dp_only

    # ---- rule lookup -------------------------------------------------------
    def _expand(self, axes_map: dict, name: str):
        got = axes_map.get(name)
        if got is None:
            return None
        if self.multi_pod:
            # pod joins the batch-parallel group in train/prefill/decode,
            # and the sequence shard group in long-context decode.  The
            # dp_only variant already folds `model` into batch (256-way);
            # global_batch=256 cannot split 512 ways, so pod stays out of
            # the activation sharding there (batch-bound arch — DESIGN §5).
            if (name == "act_batch" and self.kind in ("train", "prefill", "decode")
                    and self.variant != "dp_only"):
                got = ("pod",) + tuple(got)
            if name == "cache_seq" and self.kind == "long":
                got = ("pod",) + tuple(got)
        return tuple(got)

    def act_axes(self, name: str):
        if self.kind is None:
            return None
        rules = _ACT_RULES[self.kind]
        if self.variant == "dp_only" and self.kind in _ACT_RULES_DP_ONLY:
            rules = _ACT_RULES_DP_ONLY[self.kind]
        return self._expand(rules, name)

    def param_axes(self, name: str):
        if self.kind is None:
            return None
        return self._expand(_PARAM_RULES[self.kind], name)


def make_layout(
    kind: str | None, mesh: Mesh | None, multi_pod: bool = False,
    variant: str = "default",
) -> Layout:
    return Layout(kind=kind, mesh=mesh, multi_pod=multi_pod, variant=variant)


NULL_LAYOUT = Layout(kind=None, mesh=None)


def _dedup(spec_list):
    """A mesh axis may appear only once in a PartitionSpec; keep first use."""
    seen: set = set()
    out = []
    for entry in spec_list:
        if entry is None:
            out.append(None)
            continue
        entry = tuple(a for a in entry if a not in seen)
        seen.update(entry)
        out.append(entry if entry else None)
    return out


def _spec(layout: Layout, names, lookup) -> P:
    return P(*_dedup([lookup(n) for n in names]))


def lshard(x: jax.Array, layout: Layout | None, names) -> jax.Array:
    """Constrain activation x to the layout's mapping of logical `names`."""
    if layout is None or layout.mesh is None or layout.kind is None:
        return x
    assert x.ndim == len(names), (x.shape, names)
    spec = _spec(layout, names, layout.act_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(layout.mesh, spec))


def param_pspec(names, layout: Layout) -> P:
    """Compute-time PartitionSpec for a parameter with logical `names`."""
    if layout.mesh is None or layout.kind is None:
        return P()
    return _spec(layout, names, layout.param_axes)


def store_pspec(shape, names, layout: Layout) -> P:
    """Storage (ZeRO) spec: compute spec + free mesh axes greedily placed on
    the largest divisible dims. Applies to master params / optimizer state."""
    if layout.mesh is None or layout.kind is None:
        return P()
    base = _dedup([layout.param_axes(n) for n in names])
    used = {a for entry in base if entry for a in entry}
    free = [a for a in layout.mesh.axis_names if a not in used]
    axis_sizes = dict(zip(layout.mesh.axis_names, layout.mesh.devices.shape))
    # current shard factor per dim
    factor = [int(np.prod([axis_sizes[a] for a in (entry or ())])) for entry in base]
    spec = [list(entry) if entry else [] for entry in base]
    for ax in free:
        s = axis_sizes[ax]
        # choose the largest dim divisible by factor*s
        cand = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in cand:
            if shape[i] % (factor[i] * s) == 0 and shape[i] // (factor[i] * s) >= 1:
                spec[i].append(ax)
                factor[i] *= s
                break
    return P(*_dedup([tuple(e) if e else None for e in spec]))


def tree_pspecs(axes_tree, params_tree, layout: Layout, stored: bool):
    """Map (axes pytree, params pytree) -> PartitionSpec pytree."""

    def one(axes, leaf):
        if stored:
            return store_pspec(np.shape(leaf), axes, layout)
        return param_pspec(axes, layout)

    return jax.tree.map(
        one, axes_tree, params_tree,
        is_leaf=lambda a: isinstance(a, tuple) and all(isinstance(x, (str, type(None))) for x in a),
    )


def tree_shardings(axes_tree, params_tree, layout: Layout, stored: bool):
    if layout.mesh is None:
        return None
    specs = tree_pspecs(axes_tree, params_tree, layout, stored)
    return jax.tree.map(lambda s: NamedSharding(layout.mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
