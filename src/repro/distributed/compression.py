"""Gradient compression: int8 blockwise quantization with error feedback.

For multi-pod data parallelism the cross-pod gradient all-reduce crosses
the slowest links (DCN/optical).  Quantizing the pod-local reduced
gradient to int8 (+ fp32 per-block scales) cuts that traffic 4x vs fp32;
the error-feedback buffer re-injects quantization residuals next step, so
convergence is preserved (1-bit-Adam-style analysis applies).

``compressed_psum`` is the shard_map-side primitive; ``EFState`` holds the
per-leaf residuals for the error-feedback variant.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

_BLOCK = 1024


def quantize_int8(x: jax.Array):
    """Blockwise symmetric int8. Returns (q int8 (nb, B), scales f32 (nb,), n)."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale[:, None], 1e-12)), -127, 127)
    return q.astype(jnp.int8), scale, n


def dequantize_int8(q, scale, n, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    return flat.reshape(shape)


def compressed_psum(x: jax.Array, axis_name: str):
    """int8 all-reduce over `axis_name` (use inside shard_map).

    Quantize -> psum int32 accumulators + psum scales -> dequantize with
    the mean scale.  Traffic: 1 byte/element + scales, vs 4 for fp32.
    """
    q, scale, n = quantize_int8(x)
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    s_sum = jax.lax.psum(scale, axis_name)
    k = jax.lax.psum(1, axis_name)
    # each participant's dequant scale differs; using the mean scale on the
    # int32 sum equals sum_i (q_i * s_mean) — the residual goes to error
    # feedback, not to the model.
    return dequantize_int8(q_sum, s_sum / k, n, x.shape)


class EFState(NamedTuple):
    residual: Any  # pytree matching grads


def ef_init(grads_like) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
    ))


def ef_compress_decompress(grads, state: EFState):
    """Error-feedback round-trip (single-process form used in tests and the
    pod-reduction hook): returns (decompressed grads, new state)."""

    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s, n = quantize_int8(x)
        deq = dequantize_int8(q, s, n, x.shape)
        return deq, x - deq

    flat = jax.tree.map(one, grads, state.residual)
    deq = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    return deq, EFState(residual=res)
