"""Fault tolerance & scale-out policy.

Mechanisms implemented in this framework (and how they compose at
1000+ nodes):

1. **Checkpoint/restart** — ``repro.checkpoint``: atomic, topology-free,
   async.  On any node failure the job restarts from the last manifest;
   restore re-shards to whatever mesh the restarted job has (elastic
   re-mesh), so a 2-pod job can resume as 1-pod degraded or 4-pod scaled.

2. **Deterministic data resume** — ``repro.data.tokens`` streams are pure
   functions of (seed, step), so a restart replays the exact batch
   sequence with no data-loader state to persist.

3. **Straggler mitigation** — ``StepWatchdog`` below: bounded step
   wall-time; on trip, the runner snapshots (async checkpoint already in
   flight), excludes the slow host from the next mesh (smaller ``data``
   axis), and restarts.  Because layouts only name logical axes, a
   re-meshed restart needs no model changes.  (In SPMD there is no
   per-step partial repair — exclusion-and-restart is how production TPU
   fleets handle persistent stragglers.)

4. **Gradient compression** — ``repro.distributed.compression``: int8
   blockwise quantization with error feedback for the cross-pod gradient
   reduction (the slowest link in multi-pod DP).

5. **Compute/comm overlap** — per-layer ZeRO gathers ride inside the layer
   scan, so XLA's latency-hiding scheduler overlaps each layer's weight
   all-gather with the previous layer's compute; verified in the
   dry-run HLO (EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class StepWatchdog:
    """Bounded step wall-time with an escalation callback.

    >>> wd = StepWatchdog(limit_s=120.0, on_trip=handle_straggler)
    >>> for step in range(n):
    ...     with wd:
    ...         run_step()
    """

    limit_s: float
    on_trip: callable = None
    trips: int = 0
    history_len: int = 64

    def __post_init__(self):
        self._hist: list[float] = []

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        dt = time.perf_counter() - self._t0
        self._hist = (self._hist + [dt])[-self.history_len :]
        if dt > self.limit_s:
            self.trips += 1
            if self.on_trip is not None:
                self.on_trip(dt)
        return False

    @property
    def p50(self) -> float:
        h = sorted(self._hist)
        return h[len(h) // 2] if h else 0.0

    def adaptive_limit(self, factor: float = 3.0) -> float:
        """Straggler threshold as a multiple of the median step time."""
        return max(self.limit_s, factor * self.p50)


def exclude_and_remesh(all_hosts: list, bad_hosts: set, per_host_devices: int = 4):
    """Plan the post-failure mesh: drop bad hosts, shrink the data axis to
    the largest power-of-two slice that the remaining devices support.
    Returns (kept_hosts, new_data_axis)."""
    kept = [h for h in all_hosts if h not in bad_hosts]
    n_dev = len(kept) * per_host_devices
    data = 1
    while data * 2 <= n_dev // 16:  # keep model axis at 16
        data *= 2
    return kept, data
