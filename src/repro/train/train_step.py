"""Training step: value_and_grad over the scanned model, global-norm clip,
warmup-cosine schedule, pluggable optimizer (AdamW / Adafactor / 8-bit).

Mixed precision: master params are fp32, stored ZeRO-sharded over all free
mesh axes; matmuls cast weights to bf16 lazily inside the scan body, so
the per-layer all-gather moves bf16 (half the bytes) and only one layer's
gathered weights are live at a time.  Gradients are reduced at the storage
sharding (reduce-scatter inserted by the partitioner through the scan's
transpose).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import Layout, store_pspec, tree_pspecs
from repro.models.transformer import lm_loss
from repro.optim import OptConfig, clip_by_global_norm, opt_init, opt_update, warmup_cosine
from repro.optim.adamw import AdafactorState, AdamWState


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    peak_lr: float = 3e-4
    warmup: int = 2000
    total_steps: int = 100_000
    opt: OptConfig = OptConfig()


class TrainState(NamedTuple):
    params: Any  # fp32 master
    opt: Any
    step: jax.Array


def make_train_state(key, cfg: ModelConfig, hp: TrainHParams):
    from repro.models.transformer import init_model

    params, _ = init_model(key, cfg)
    return TrainState(params=params, opt=opt_init(params, hp.opt), step=jnp.zeros((), jnp.int32))


def make_train_step(cfg: ModelConfig, layout: Layout, hp: TrainHParams,
                    grad_specs=None):
    """grad_specs: optional PartitionSpec tree (the ZeRO storage specs).

    Constraining gradients to their storage shard *before* the global-norm
    clip lets the partitioner lower the gradient reduction as
    reduce-scatter into the shard (norm = partial-square-sums + scalar
    psum) instead of all-reducing full replicated gradients just to slice
    them afterwards — ~2x cross-chip gradient traffic (§Perf iteration B).
    Disable with REPRO_GRAD_SHARD=0 for A/B comparison.
    """
    import os

    use_grad_shard = os.environ.get("REPRO_GRAD_SHARD", "1") == "1"

    def train_step(state: TrainState, batch):
        def loss_fn(p):
            return lm_loss(p, cfg, layout, batch)

        loss, grads = jax.value_and_grad(loss_fn)(state.params)
        if grad_specs is not None and layout.mesh is not None and use_grad_shard:
            from jax.sharding import NamedSharding, PartitionSpec as P

            flat_g, treedef = jax.tree.flatten(grads)
            flat_s = jax.tree.flatten(
                grad_specs, is_leaf=lambda s: isinstance(s, P))[0]
            grads = jax.tree.unflatten(treedef, [
                jax.lax.with_sharding_constraint(g, NamedSharding(layout.mesh, s))
                for g, s in zip(flat_g, flat_s)
            ])
        grads, gnorm = clip_by_global_norm(grads, hp.opt.clip_norm)
        lr = warmup_cosine(
            state.step, peak_lr=hp.peak_lr, warmup=hp.warmup, total=hp.total_steps
        )
        new_params, new_opt = opt_update(grads, state.opt, state.params, lr, hp.opt)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return TrainState(params=new_params, opt=new_opt, step=state.step + 1), metrics

    return train_step


# ---------------------------------------------------------------------------
# sharding specs for the full train state
# ---------------------------------------------------------------------------

def _axes_is_leaf(t):
    return isinstance(t, tuple) and all(isinstance(x, (str, type(None))) for x in t)


def make_train_state_specs(params_struct, axes, layout: Layout, opt_name: str):
    """PartitionSpec tree matching TrainState(params, opt, step)."""
    p_specs = tree_pspecs(axes, params_struct, layout, stored=True)

    def spec_for(leaf_struct, leaf_axes, drop: str):
        shape = leaf_struct.shape
        if drop == "last":
            shape, leaf_axes = shape[:-1], leaf_axes[:-1]
        elif drop == "col":
            shape = leaf_struct.shape
            leaf_axes = leaf_axes
        return store_pspec(shape, leaf_axes, layout)

    if opt_name == "adamw":
        opt_specs = AdamWState(step=jax.sharding.PartitionSpec(), m=p_specs, v=p_specs)
    elif opt_name == "adafactor":
        def vr_spec(struct, ax):
            if len(struct.shape) >= 2:
                return store_pspec(struct.shape[:-1], ax[:-1], layout)
            return store_pspec(struct.shape, ax, layout)

        def vc_spec(struct, ax):
            if len(struct.shape) >= 2:
                return store_pspec(struct.shape[:-2] + struct.shape[-1:],
                                   ax[:-2] + ax[-1:], layout)
            return jax.sharding.PartitionSpec()

        vr = _map_params_axes(vr_spec, params_struct, axes)
        vc = _map_params_axes(vc_spec, params_struct, axes)
        opt_specs = AdafactorState(step=jax.sharding.PartitionSpec(), vr=vr, vc=vc)
    else:  # adamw8bit: block-flattened states — store replicated (feature mode)
        rep = jax.tree.map(lambda _: jax.sharding.PartitionSpec(), params_struct)
        from repro.optim.adamw import Adam8State

        opt_specs = Adam8State(
            step=jax.sharding.PartitionSpec(), m_q=rep, m_s=rep,
            v_q=jax.tree.map(lambda s: s, rep), v_s=jax.tree.map(lambda s: s, rep),
        )
    return TrainState(params=p_specs, opt=opt_specs, step=jax.sharding.PartitionSpec())


def _map_params_axes(fn, params_tree, axes_tree):
    """tree.map over (param leaves, axes tuples) where axes tuples are leaves."""
    flat_p, treedef = jax.tree.flatten(params_tree)
    flat_a = jax.tree.flatten(axes_tree, is_leaf=_axes_is_leaf)[0]
    return jax.tree.unflatten(treedef, [fn(p, a) for p, a in zip(flat_p, flat_a)])
