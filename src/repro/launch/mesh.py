"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — required for the dry-run's 512 placeholder
devices to be configured before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist locally (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
