"""Training launcher: --arch <id> [--smoke] with checkpoint/restart.

Production path: build the mesh, make the layout, jit the train step with
ZeRO state shardings, stream deterministic batches, checkpoint async.
On CPU (tests/examples) the same code runs with a local mesh or none.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointConfig, Checkpointer
from repro.configs import get_config, get_smoke_config
from repro.configs.base import TRAIN_4K, ShapeConfig
from repro.data.tokens import TokenStreamConfig, batch_at_step
from repro.distributed.sharding import NULL_LAYOUT, make_layout
from repro.models import transformer as tfm
from repro.optim import OptConfig
from repro.train.train_step import TrainHParams, TrainState, make_train_step
from repro.optim import opt_init


def run(arch: str, *, smoke: bool = False, steps: int = 50, seq_len: int = 128,
        batch: int = 8, ckpt_dir: str | None = None, lr: float = 3e-4,
        log_every: int = 10):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    import dataclasses
    if smoke:
        cfg = dataclasses.replace(cfg, dtype="float32")
    layout = NULL_LAYOUT  # single-host run; production uses make_layout("train", mesh)
    hp = TrainHParams(peak_lr=lr, warmup=max(steps // 10, 1), total_steps=steps,
                      opt=OptConfig(name="adamw"))

    params, _ = tfm.init_model(jax.random.PRNGKey(0), cfg)
    state = TrainState(params=params, opt=opt_init(params, hp.opt),
                       step=jnp.zeros((), jnp.int32))
    step_fn = jax.jit(make_train_step(cfg, layout, hp))

    ckpt = Checkpointer(CheckpointConfig(directory=ckpt_dir)) if ckpt_dir else None
    start = 0
    if ckpt and ckpt.latest_step() is not None:
        state = ckpt.restore(state)
        start = int(state.step)
        print(f"resumed from step {start}")

    ds = TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                           global_batch=batch, seed=0)
    losses = []
    t0 = time.perf_counter()
    for step in range(start, steps):
        batch_np = batch_at_step(ds, step)
        state, metrics = step_fn(state, jax.tree.map(jnp.asarray, batch_np))
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({time.perf_counter()-t0:.1f}s)", flush=True)
        if ckpt and step and step % 50 == 0:
            ckpt.save(step + 1, state)
    if ckpt:
        ckpt.save(steps, state, blocking=True)
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    losses = run(args.arch, smoke=args.smoke, steps=args.steps,
                 seq_len=args.seq_len, batch=args.batch, ckpt_dir=args.ckpt_dir)
    print(f"final loss: {losses[-1]:.4f} (first: {losses[0]:.4f})")


if __name__ == "__main__":
    main()
