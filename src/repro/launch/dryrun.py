import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds abstract inputs (ShapeDtypeStruct — no
allocation), the layout's in/out shardings, then ``.lower().compile()``
on the production mesh and records:

  * ``memory_analysis()``  — per-device bytes (proves the cell fits),
  * ``cost_analysis()``    — HLO FLOPs / bytes for the roofline,
  * collective bytes       — parsed from the partitioned HLO text
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute, per-device output-shape accounting; see
    ``collective_bytes``).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, shapes_for
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import Layout, make_layout, tree_pspecs
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tfm
from repro.models import zoo
from repro.optim import OptConfig, opt_init
from repro.serve.decode import cache_pspecs, make_serve_step
from repro.serve.prefill import make_prefill_step
from repro.train.train_step import (
    TrainHParams,
    TrainState,
    make_train_state_specs,
    make_train_step,
)

# Per-arch optimizer: adafactor for the 400B MoE so fp32 Adam moments never
# exceed a v5e's 16 GB HBM even at 256 chips.
OPT_FOR = {"llama4-maverick-400b-a17b": "adafactor"}

# Per-arch training layout variant (DESIGN.md §5)
VARIANT_FOR = {"xlstm-350m": "dp_only"}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4, "u64": 8,
    "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
}

_COLL_RE = re.compile(
    r"=\s+([^=]+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device communicated bytes by collective kind (ring estimates:
    all-gather/all-to-all/permute ~ out bytes; all-reduce ~ 2x out;
    reduce-scatter ~ out x (group-1), conservatively group from
    replica_groups when printed, else 1x)."""
    out: dict[str, float] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_str)
        g = 1
        gm = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
        if gm:
            g = gm.group(1).count(",") + 1
        else:
            gm2 = re.search(r"replica_groups=\[\d+,(\d+)\]", line)
            if gm2:
                g = int(gm2.group(1))
        if kind == "all-reduce":
            nbytes = 2 * nbytes * (g - 1) / max(g, 1)
        elif kind == "reduce-scatter":
            nbytes = nbytes * max(g - 1, 1)
        elif kind in ("all-gather", "all-to-all"):
            nbytes = nbytes * (g - 1) / max(g, 1) if g > 1 else nbytes
        out[kind] = out.get(kind, 0.0) + nbytes
        counts[kind] = counts.get(kind, 0) + 1
    out["_counts"] = counts
    return out


def _cost_dict(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions: older
    releases return a list with one dict per computation, newer ones the
    dict itself (or None when analysis is unavailable)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    return cost or {}


def _bf16_struct(tree):
    def conv(s):
        dt = jnp.bfloat16 if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype
        return jax.ShapeDtypeStruct(s.shape, dt)

    return jax.tree.map(conv, tree)


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def input_shardings(cfg: ModelConfig, shape: ShapeConfig, layout: Layout):
    mesh = layout.mesh
    ab = layout.act_axes("act_batch")
    if shape.kind in ("train", "prefill"):
        tok = P(ab, layout.act_axes("act_seq"))
        out = {"tokens": tok}
        if shape.kind == "train":
            out["targets"] = tok
        if cfg.frontend == "vision_stub":
            out["prefix_embeds"] = P(ab, layout.act_axes("act_seq"), None)
        return _ns(mesh, out)
    # decode
    return _ns(mesh, {
        "tokens": P(ab, None),
        "caches": cache_pspecs(cfg, layout),
        "pos": P(),
    })


def lower_cell(arch: str, shape: ShapeConfig, *, multi_pod: bool = False,
               donate: bool = False):
    """Returns (lowered, compiled, report dict, hlo text).

    donate=True donates the train state / decode caches so XLA updates
    them in place (no defensive copies) — a §Perf iteration knob.
    """
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(mesh.devices.shape))
    kind = {"train": "train", "prefill": "prefill", "decode": "decode"}[shape.kind]
    if shape.kind == "decode" and shape.name == "long_500k":
        kind = "long"
    variant = VARIANT_FOR.get(arch, "default") if shape.kind == "train" else "default"
    layout = make_layout(kind, mesh, multi_pod=multi_pod, variant=variant)

    params_struct = jax.eval_shape(
        lambda k: tfm.init_model(k, cfg)[0], jax.random.PRNGKey(0)
    )
    axes = zoo.param_axes(cfg)
    specs = zoo.input_specs(cfg, shape)
    in_sh = input_shardings(cfg, shape, layout)

    t0 = time.perf_counter()
    if shape.kind == "train":
        opt_name = OPT_FOR.get(arch, "adamw")
        hp = TrainHParams(opt=OptConfig(name=opt_name))
        state_struct = jax.eval_shape(
            lambda p: TrainState(
                params=p, opt=opt_init(p, hp.opt), step=jnp.zeros((), jnp.int32)
            ),
            params_struct,
        )
        state_specs = make_train_state_specs(params_struct, axes, layout, opt_name)
        state_sh = _ns(mesh, state_specs)
        metrics_sh = {k: NamedSharding(mesh, P()) for k in ("loss", "grad_norm", "lr")}
        step = make_train_step(cfg, layout, hp, grad_specs=state_specs.params)
        jitted = jax.jit(step, in_shardings=(state_sh, in_sh),
                         out_shardings=(state_sh, metrics_sh),
                         donate_argnums=(0,) if donate else ())
        lowered = jitted.lower(state_struct, specs)
    elif shape.kind == "prefill":
        p_bf16 = _bf16_struct(params_struct)
        p_specs = tree_pspecs(axes, params_struct, layout, stored=True)
        p_sh = _ns(mesh, p_specs)
        step = make_prefill_step(cfg, layout)
        jitted = jax.jit(step, in_shardings=(p_sh, in_sh))
        lowered = jitted.lower(p_bf16, specs)
    else:  # decode
        p_bf16 = _bf16_struct(params_struct)
        if os.environ.get("REPRO_W_INT8", "0") == "1":
            # w8a16 serving: int8 weights + per-channel scales
            from repro.models.layers import quantize_axes, quantize_tree

            p_bf16 = jax.eval_shape(lambda p: quantize_tree(p, axes), p_bf16)
            axes = quantize_axes(axes)
            params_struct = p_bf16
        p_specs = tree_pspecs(axes, params_struct, layout, stored=False)
        p_sh = _ns(mesh, p_specs)
        c_sh = _ns(mesh, cache_pspecs(cfg, layout))
        ab = layout.act_axes("act_batch")
        out_sh = {
            "logits": NamedSharding(mesh, P(ab, None, None)),
            "next_tokens": NamedSharding(mesh, P(ab, None)),
            "caches": c_sh,
        }
        step = make_serve_step(cfg, layout)
        jitted = jax.jit(step, in_shardings=(p_sh, in_sh["caches"], in_sh["tokens"],
                                             NamedSharding(mesh, P())),
                         out_shardings=out_sh,
                         donate_argnums=(1,) if donate else ())
        lowered = jitted.lower(p_bf16, specs["caches"], specs["tokens"], specs["pos"])
    t_lower = time.perf_counter() - t0

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    cost = _cost_dict(compiled)
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    from repro.launch.hlo_analysis import analyze

    loop_aware = analyze(hlo)

    report = {
        "arch": arch,
        "shape": shape.name,
        "mesh": list(mesh.devices.shape),
        "multi_pod": multi_pod,
        "n_devices": n_dev,
        "layout": kind,
        "variant": variant,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # raw backend numbers (while bodies counted once — see hlo_analysis)
        "flops_per_device_raw": float(cost.get("flops", -1)),
        "bytes_accessed_per_device_raw": float(cost.get("bytes accessed", -1)),
        # loop-aware numbers parsed from the partitioned HLO
        "flops_per_device": loop_aware["flops"],
        "hbm_bytes_per_device": loop_aware["hbm_bytes"],
        "collective_bytes_per_device": loop_aware["collective_bytes"],
        "collective_bytes_total": loop_aware["collective_total"],
        "collective_counts": loop_aware["collective_counts"],
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            report[attr] = getattr(mem, attr, None)
    return lowered, compiled, report, hlo


def lower_retrieval(*, multi_pod: bool = False, n: int = 100_000_000,
                    dim: int = 128, batch: int = 256, mode: str = "gate"):
    """Dry-run the distributed GateANN retrieve step at BigANN-100M scale."""
    from repro.core.distributed_search import DistSearchConfig, make_retrieve_step

    mesh = make_production_mesh(multi_pod=multi_pod)
    rows = -(-n // 16)  # records sharded over model within a data group
    cfg = DistSearchConfig(search_l=100, beam_width=8, n_hops=48, mode=mode)
    step = make_retrieve_step(mesh, cfg, rows_per_shard=rows, multi_pod=multi_pod)
    C, K, R, RMAX = 32, 256, 96, 16
    s = jax.ShapeDtypeStruct
    args_struct = (
        s((batch, dim), jnp.float32),  # queries
        s((batch, C, K), jnp.float32),  # lut
        s((n, C), jnp.int32),  # codes  (int8 logical; int32 for take) — Table 2
        s((n, RMAX), jnp.int32),  # neighbor store
        s((n,), jnp.int32),  # labels
        s((rows * 16, dim), jnp.float32),  # record vectors (padded)
        s((rows * 16, R), jnp.int32),  # record adjacency
        s((), jnp.int32),  # entry
        s((batch,), jnp.int32),  # targets
    )
    t0 = time.perf_counter()
    lowered = step.lower(*args_struct)
    compiled = lowered.compile()
    cost = _cost_dict(compiled)
    from repro.launch.hlo_analysis import analyze

    loop_aware = analyze(compiled.as_text())
    report = {
        "arch": f"gateann-retrieval-{mode}",
        "shape": f"bigann{n//1_000_000}m_b{batch}",
        "mesh": list(mesh.devices.shape),
        "multi_pod": multi_pod,
        "compile_s": round(time.perf_counter() - t0, 1),
        "flops_per_device_raw": float(cost.get("flops", -1)),
        "bytes_accessed_per_device_raw": float(cost.get("bytes accessed", -1)),
        "flops_per_device": loop_aware["flops"],
        "hbm_bytes_per_device": loop_aware["hbm_bytes"],
        "collective_bytes_per_device": loop_aware["collective_bytes"],
        "collective_bytes_total": loop_aware["collective_total"],
        "collective_counts": loop_aware["collective_counts"],
        "n_hops": cfg.n_hops,
        "beam_width": cfg.beam_width,
    }
    mem = compiled.memory_analysis()
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes"):
            report[attr] = getattr(mem, attr, None)
    return report, compiled.as_text()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS) + [None])
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--retrieval", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    if args.retrieval:
        os.makedirs(args.out, exist_ok=True)
        for mp in ([False, True] if args.both_meshes else [args.multi_pod]):
            for mode in ("gate", "post"):
                tag = f"gateann-retrieval-{mode}__{'2x16x16' if mp else '16x16'}"
                print(f"=== {tag}", flush=True)
                rep, hlo = lower_retrieval(multi_pod=mp, mode=mode)
                print(f"    flops/dev={rep['flops_per_device']:.3e} "
                      f"coll={rep['collective_bytes_per_device']}", flush=True)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rep, f, indent=2)
                import gzip

                with gzip.open(os.path.join(args.out, tag + ".hlo.gz"), "wt") as f:
                    f.write(hlo)
        return

    os.makedirs(args.out, exist_ok=True)
    archs = list(ARCH_IDS) if args.all or args.arch is None else [args.arch]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            if args.shape and shape.name != args.shape:
                continue
            for mp in meshes:
                tag = f"{arch}__{shape.name}__{'2x16x16' if mp else '16x16'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"skip (exists): {tag}")
                    continue
                print(f"=== {tag}", flush=True)
                try:
                    _, compiled, report, hlo = lower_cell(arch, shape, multi_pod=mp)
                    print(f"    flops/dev={report['flops_per_device']:.3e} "
                          f"coll/dev={report['collective_bytes_total']:.3e} "
                          f"compile={report['compile_s']}s", flush=True)
                    with open(path, "w") as f:
                        json.dump(report, f, indent=2)
                    import gzip

                    with gzip.open(path.replace(".json", ".hlo.gz"), "wt") as f:
                        f.write(hlo)
                except Exception as e:  # noqa: BLE001 — record and continue
                    failures.append(tag)
                    print(f"    FAILED: {e}")
                    traceback.print_exc()
                    with open(os.path.join(args.out, tag + ".FAILED"), "w") as f:
                        f.write(traceback.format_exc())
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
