"""Compiled-HLO analysis: loop-aware FLOPs and collective bytes.

XLA's ``cost_analysis()`` counts a while-loop body **once**, so any model
scanned over layers under-reports by ~n_layers.  This module parses the
partitioned optimized HLO text instead:

  * builds the computation call graph (fusions, calls, while bodies),
  * extracts while trip counts from the loop-condition constants,
  * counts dot/convolution FLOPs per computation from operand shapes,
  * sums collective bytes (ring-model per-device traffic) per computation,

then folds multiplicities down the call graph.  Everything is derived
from the dry-run's compiled artifact, per the roofline contract.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4, "u64": 8,
    "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}

# computation headers start at column 0, contain ") -> ", and end with "{"
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?(%?[\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_SHAPE_TOK = re.compile(r"(\w+)\[([\d,]*)\]")
_CALLS = re.compile(r"calls=(%[\w\.\-]+)")
_TO_APPLY = re.compile(r"to_apply=(%[\w\.\-]+)")
_WHILE = re.compile(r"condition=(%[\w\.\-]+),\s*body=(%[\w\.\-]+)")
_CONST = re.compile(r"constant\((\d+)\)")
_GROUPS = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS2 = re.compile(r"replica_groups=\[\d+,(\d+)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_dims(shape_str: str):
    """First dtype[dims] token -> (dtype, [dims])."""
    m = _SHAPE_TOK.search(shape_str)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def _all_shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_TOK.finditer(shape_str):
        if m.group(1) not in _DTYPE_BYTES:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


@dataclasses.dataclass
class Computation:
    name: str
    flops: float = 0.0
    hbm_bytes: float = 0.0  # operands+outputs of top-level (unfused) ops
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_counts: dict = dataclasses.field(default_factory=lambda: defaultdict(int))
    calls: list = dataclasses.field(default_factory=list)  # (callee, multiplier)
    max_const: int = 1  # for trip-count extraction when used as a condition


# ops whose operands+outputs move HBM bytes at module level; fused
# computations' internals are free (counted at the fusion call site).
_MEM_OPS = {
    "fusion", "dot", "convolution", "copy", "reduce", "sort", "scatter",
    "gather", "dynamic-update-slice", "dynamic-slice", "transpose", "reshape",
    "broadcast", "concatenate", "slice", "pad", "convert", "select",
    "add", "multiply", "subtract", "divide", "exponential", "rsqrt", "tanh",
    "custom-call", "iota", "compare", "maximum", "minimum",
} | set(COLLECTIVES)
_FREE_OPS = {"get-tuple-element", "tuple", "bitcast", "parameter", "constant",
             "after-all", "partition-id", "replica-id"}
_OPERANDS = re.compile(r"\((%[\w\.\-]+(?:,\s*%[\w\.\-]+)*)\)")


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    symtab: dict[str, str] = {}
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line) if (line and not raw[0].isspace()) else None
        if hdr and line.endswith("{"):
            name = hdr.group(1)
            if not name.startswith("%"):
                name = "%" + name
            cur = Computation(name=name)
            comps[name] = cur
            if raw.startswith("ENTRY"):
                entry = name
            symtab = {}
            # header params: "%comp (p0: f32[..], p1: (s32[], ...)) -> ..."
            for pm in re.finditer(r"([\w\.\-]+):\s*([\w\[\]\{\},\s]+?)(?=,\s*[\w\.\-]+:|\)\s*->)", line):
                symtab["%" + pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            cm = _CONST.search(line)
            if cm:
                cur.max_const = max(cur.max_const, int(cm.group(1)))
            continue
        name, shape_str, op = m.group(1), m.group(2), m.group(3)
        symtab[name] = shape_str
        cm = _CONST.search(line)
        if cm:
            cur.max_const = max(cur.max_const, int(cm.group(1)))

        # HBM byte accounting (top-level ops; fused internals are free)
        base_op = op.replace("-start", "").replace("-done", "")
        if base_op in _MEM_OPS and op not in ("while", "call", "conditional"):
            if base_op in ("dynamic-slice", "gather"):
                # reads only the sliced/gathered region ~= output bytes
                nbytes = 2 * _all_shape_bytes(shape_str)
            elif base_op in ("dynamic-update-slice", "scatter"):
                # rw of the updated region; the aliased buffer is untouched
                om = _OPERANDS.search(line)
                upd = 0
                if om:
                    parts = [p.strip() for p in om.group(1).split(",")]
                    if len(parts) >= 2:
                        upd = _all_shape_bytes(symtab.get(parts[1], ""))
                nbytes = 2 * upd if upd else _all_shape_bytes(shape_str)
            else:
                nbytes = _all_shape_bytes(shape_str)
                om = _OPERANDS.search(line)
                if om:
                    for opnd in om.group(1).split(","):
                        nbytes += _all_shape_bytes(symtab.get(opnd.strip(), ""))
            cur.hbm_bytes += nbytes

        if op == "dot":
            flops = _dot_flops(line, shape_str, symtab)
            cur.flops += flops
        elif op in ("convolution",):
            # rare here; approximate with output x kernel contraction
            cur.flops += 2 * _all_shape_bytes(shape_str)  # coarse
        elif op in COLLECTIVES or any(op.startswith(c) for c in COLLECTIVES):
            base = op.replace("-start", "").replace("-done", "")
            if base.endswith("-done"):
                continue
            if op.endswith("-done"):
                continue
            nbytes = _all_shape_bytes(shape_str)
            g = 1
            gm = _GROUPS.search(line)
            if gm:
                g = gm.group(1).count(",") + 1
            else:
                gm2 = _GROUPS2.search(line)
                if gm2:
                    g = int(gm2.group(1))
            if base == "all-reduce":
                traffic = 2 * nbytes * (g - 1) / max(g, 1)
            elif base == "reduce-scatter":
                traffic = nbytes * max(g - 1, 1)
            elif base in ("all-gather", "all-to-all"):
                traffic = nbytes * (g - 1) / max(g, 1) if g > 1 else nbytes
            else:  # collective-permute
                traffic = nbytes
            cur.coll_bytes += traffic
            cur.coll_by_kind[base] += traffic
            cur.coll_counts[base] += 1
        elif op == "fusion":
            cm2 = _CALLS.search(line)
            if cm2:
                cur.calls.append((cm2.group(1), 1))
        elif op == "while":
            wm = _WHILE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                cur.calls.append(("__while__" + body + "|" + cond, 1))
        elif op in ("call", "conditional", "async-start"):
            cm2 = _TO_APPLY.search(line) or _CALLS.search(line)
            if cm2:
                cur.calls.append((cm2.group(1), 1))
        # reduce/sort/map to_apply bodies: negligible flops, skipped
    comps["__entry__"] = comps.get(entry, Computation("__entry__"))
    return comps


def _dot_flops(line: str, out_shape: str, symtab: dict) -> float:
    _, out_dims = _shape_dims(out_shape)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    # contraction size from lhs operand shape + contracting dims
    ops = re.search(r"\(([^)]*)\)", line)
    lhs_name = ops.group(1).split(",")[0].strip() if ops else None
    lhs_shape = symtab.get(lhs_name, "")
    _, lhs_dims = _shape_dims(lhs_shape)
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    contract = 1
    if cm and lhs_dims:
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def fold(comps: dict[str, Computation]) -> dict:
    """Fold flops/collectives down the call graph with loop multiplicities."""
    memo: dict[str, tuple] = {}

    def visit(name: str, depth=0):
        if name in memo:
            return memo[name]
        if depth > 50 or name not in comps:
            return 0.0, 0.0, defaultdict(float), defaultdict(int)
        c = comps[name]
        flops = c.flops
        hbm = c.hbm_bytes
        coll = defaultdict(float, c.coll_by_kind)
        counts = defaultdict(int, c.coll_counts)
        for callee, mult in c.calls:
            if callee.startswith("__while__"):
                body, cond = callee[9:].split("|")
                trips = comps[cond].max_const if cond in comps else 1
                bf, bh, bc, bn = visit(body, depth + 1)
                cf, ch, cc, cn = visit(cond, depth + 1)
                flops += trips * (bf + cf)
                hbm += trips * (bh + ch)
                for k, v in bc.items():
                    coll[k] += trips * v
                for k, v in bn.items():
                    counts[k] += trips * v
            else:
                f2, h2, c2, n2 = visit(callee, depth + 1)
                flops += mult * f2
                # fusion internals don't move HBM bytes — only the fusion
                # op itself (already counted at the call site)
                if not callee.startswith("%fused") and "fused" not in callee:
                    hbm += mult * h2
                for k, v in c2.items():
                    coll[k] += mult * v
                for k, v in n2.items():
                    counts[k] += mult * v
        memo[name] = (flops, hbm, coll, counts)
        return memo[name]

    flops, hbm, coll, counts = visit("__entry__")
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "collective_bytes": dict(coll),
        "collective_total": sum(coll.values()),
        "collective_counts": dict(counts),
    }


def analyze(hlo_text: str) -> dict:
    return fold(parse_hlo(hlo_text))
