"""Model assembly: pattern-unit scan, decode, loss.

Layers are grouped into the config's repeating ``pattern_unit``; training
and prefill ``lax.scan`` over the stacked units (small HLO, fast compiles,
per-layer ZeRO gather inside the loop) with gradient rematerialization,
and any leftover layers (n_layers % unit) run unrolled.  Decoding unrolls
all layers so per-layer caches can be heterogeneous (ring buffers for
sliding-window attention, recurrent states for RG-LRU/xLSTM, full-length
KV for global attention).

The cross-entropy never materializes full fp32 logits: it streams over
vocab chunks with a running log-sum-exp (``chunked_xent``), which bounds
loss memory for 256k-vocab models at any batch x seq.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import Layout, lshard
from repro.models import attention as attn
from repro.models import moe as moem
from repro.models import rglru as rglrum
from repro.models import xlstm as xlstmm
from repro.models.layers import ffn, init_ffn, init_norm, rms_norm


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

def init_layer(key, kind: str, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    if kind in ("attn", "moe"):
        p["norm1"], a["norm1"] = init_norm(cfg.d_model)
        p["attn"], a["attn"] = attn.init_attention(ks[0], cfg)
        p["norm2"], a["norm2"] = init_norm(cfg.d_model)
        if kind == "moe":
            p["moe"], a["moe"] = moem.init_moe(ks[1], cfg)
        elif cfg.d_ff:
            p["ffn"], a["ffn"] = init_ffn(ks[1], cfg.d_model, cfg.d_ff)
    elif kind == "rglru":
        p["norm1"], a["norm1"] = init_norm(cfg.d_model)
        p["rglru"], a["rglru"] = rglrum.init_rglru(ks[0], cfg)
        p["norm2"], a["norm2"] = init_norm(cfg.d_model)
        p["ffn"], a["ffn"] = init_ffn(ks[1], cfg.d_model, cfg.d_ff)
    elif kind == "mlstm":
        p["norm1"], a["norm1"] = init_norm(cfg.d_model)
        p["mlstm"], a["mlstm"] = xlstmm.init_mlstm(ks[0], cfg)
    elif kind == "slstm":
        p["norm1"], a["norm1"] = init_norm(cfg.d_model)
        p["slstm"], a["slstm"] = xlstmm.init_slstm(ks[0], cfg)
    else:
        raise ValueError(kind)
    return p, a


def apply_layer_train(
    params, x, positions, kind: str, window: int | None, cfg: ModelConfig,
    layout: Layout, *, collect_kv: bool,
):
    """Returns (x, aux_scalar, kv_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    kv_out = None
    if kind in ("attn", "moe"):
        h = rms_norm(x, params["norm1"], cfg.norm_eps)
        h, kv = attn.attn_train(params["attn"], h, positions, cfg, layout, window=window)
        if collect_kv:
            kv_out = kv
        x = x + h
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        if kind == "moe":
            h2, auxd = moem.moe_ffn(params["moe"], h2, cfg, layout)
            aux = aux + auxd["moe_aux"] + auxd["moe_zloss"]
        elif cfg.d_ff:
            h2 = ffn(h2, params["ffn"], cfg.act, layout)
        else:
            h2 = jnp.zeros_like(x)
        x = x + h2
    elif kind == "rglru":
        h = rms_norm(x, params["norm1"], cfg.norm_eps)
        x = x + rglrum.rglru_train(params["rglru"], h, cfg, layout)
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        x = x + ffn(h2, params["ffn"], cfg.act, layout)
    elif kind == "mlstm":
        h = rms_norm(x, params["norm1"], cfg.norm_eps)
        x = x + xlstmm.mlstm_train(params["mlstm"], h, cfg, layout)
    elif kind == "slstm":
        h = rms_norm(x, params["norm1"], cfg.norm_eps)
        x = x + xlstmm.slstm_train(params["slstm"], h, cfg, layout)
    x = lshard(x, layout, ("act_batch", "act_seq", "embed"))
    return x, aux, kv_out


def apply_layer_decode(params, x, cache, pos, kind, window, cfg, layout):
    """Returns (x, new_cache)."""
    if kind in ("attn", "moe"):
        h = rms_norm(x, params["norm1"], cfg.norm_eps)
        h, new_cache = attn.attn_decode(
            params["attn"], h, cache, pos, cfg, layout, window=window
        )
        x = x + h
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        if kind == "moe":
            h2, _ = moem.moe_ffn(params["moe"], h2, cfg, layout, group_by_batch=True)
        elif cfg.d_ff:
            h2 = ffn(h2, params["ffn"], cfg.act, layout)
        else:
            h2 = jnp.zeros_like(x)
        x = x + h2
    elif kind == "rglru":
        h = rms_norm(x, params["norm1"], cfg.norm_eps)
        h, new_cache = rglrum.rglru_decode(params["rglru"], h, cache, cfg, layout)
        x = x + h
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        x = x + ffn(h2, params["ffn"], cfg.act, layout)
    elif kind == "mlstm":
        h = rms_norm(x, params["norm1"], cfg.norm_eps)
        h, new_cache = xlstmm.mlstm_decode(params["mlstm"], h, cache, cfg, layout)
        x = x + h
    elif kind == "slstm":
        h = rms_norm(x, params["norm1"], cfg.norm_eps)
        h, new_cache = xlstmm.slstm_decode(params["slstm"], h, cache, cfg, layout)
        x = x + h
    else:
        raise ValueError(kind)
    return x, new_cache


# ---------------------------------------------------------------------------
# whole-model init
# ---------------------------------------------------------------------------

def init_model(key, cfg: ModelConfig):
    """Returns (params, axes). Layer stacks: params['units'][pos] has a
    leading n_units axis; leftovers are individual layers."""
    n_unit = len(cfg.pattern_unit)
    keys = jax.random.split(key, cfg.n_layers + 3)
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}

    # N(0, 1/d): the input path re-scales by sqrt(d) (gemma convention) and a
    # tied unembedding then yields unit-variance logits.
    params["embed"] = jax.random.normal(
        keys[-1], (cfg.vocab_size, cfg.d_model), jnp.float32
    ) / np.sqrt(cfg.d_model)
    axes["embed"] = ("vocab", "embed")
    params["final_norm"], axes["final_norm"] = init_norm(cfg.d_model)
    if not cfg.tie_embeddings:
        params["unembed"] = (
            jax.random.normal(keys[-2], (cfg.d_model, cfg.vocab_size), jnp.float32)
            / np.sqrt(cfg.d_model)
        )
        axes["unembed"] = ("embed", "vocab")

    units_p, units_a = {}, {}
    if cfg.n_units:
        for pos, kind in enumerate(cfg.pattern_unit):
            unit_keys = jnp.stack(
                [keys[u * n_unit + pos] for u in range(cfg.n_units)]
            )
            stacked_p, one_a = jax.vmap(
                lambda k, _kind=kind: init_layer(k, _kind, cfg)[0]
            )(unit_keys), init_layer(keys[pos], kind, cfg)[1]
            units_p[str(pos)] = stacked_p
            units_a[str(pos)] = jax.tree.map(
                lambda t: ("layers",) + t, one_a,
                is_leaf=lambda t: isinstance(t, tuple) and all(
                    isinstance(x, (str, type(None))) for x in t
                ),
            )
    params["units"] = units_p
    axes["units"] = units_a

    left_p, left_a = [], []
    kinds = cfg.layer_kinds
    for i in range(cfg.n_units * n_unit, cfg.n_layers):
        p, a = init_layer(keys[i], kinds[i], cfg)
        left_p.append(p)
        left_a.append(a)
    params["leftover"] = left_p
    axes["leftover"] = left_a
    return params, axes


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg: ModelConfig, layout: Layout, batch: dict, dtype):
    """Token embedding (+ stub frontend prefix). Returns (x, positions)."""
    tokens = batch["tokens"]  # (B, T)
    x = params["embed"].astype(dtype)[tokens] * jnp.asarray(np.sqrt(cfg.d_model), dtype)
    if cfg.frontend == "vision_stub" and "prefix_embeds" in batch:
        x = jnp.concatenate([batch["prefix_embeds"].astype(dtype), x], axis=1)
    b, t = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    x = lshard(x, layout, ("act_batch", "act_seq", "embed"))
    return x, positions


def forward_train(params, cfg: ModelConfig, layout: Layout, batch: dict, *,
                  collect_kv: bool = False, remat: bool = True):
    """Returns (hidden (B,T,D), aux scalar, caches list|None)."""
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x, positions = _embed_inputs(params, cfg, layout, batch, dtype)
    unit = cfg.pattern_unit
    windows = cfg.attn_windows
    caches = []

    import os as _os

    cast_early = _os.environ.get("REPRO_CAST_EARLY", "1") == "1"
    if cast_early and dtype != jnp.float32:
        # Cast fp32 masters to bf16 *outside* the scan, on the stacked
        # (ZeRO-sharded) arrays: the convert is elementwise and
        # sharding-preserving, so the per-layer all-gather the scan body
        # triggers moves bf16 (half the bytes), and the scan transpose
        # reduce-scatters bf16 gradients.  (Casting inside the body CSEs
        # with linear()'s lazy cast and changes nothing — measured, §Perf.)
        params = dict(params)
        params["units"] = jax.tree.map(
            lambda a: a.astype(dtype) if a.dtype == jnp.float32 else a,
            params["units"],
        )
        params["leftover"] = jax.tree.map(
            lambda a: a.astype(dtype) if a.dtype == jnp.float32 else a,
            params["leftover"],
        )

    def unit_body(x, unit_params):
        aux = jnp.zeros((), jnp.float32)
        kvs = []
        for pos, kind in enumerate(unit):
            x, a, kv = apply_layer_train(
                unit_params[str(pos)], x, positions, kind,
                windows[pos % len(windows)], cfg, layout,
                collect_kv=collect_kv,
            )
            aux = aux + a
            if collect_kv and kv is not None:
                kvs.append(kv)
        return x, (aux, tuple(kvs))

    if cfg.n_units:
        body = unit_body
        if remat:
            # REPRO_REMAT=dots keeps matmul outputs (no recompute of dots in
            # the backward pass: ~8ND -> ~6ND compute) at the cost of
            # activation memory; "full" recomputes everything.
            if _os.environ.get("REPRO_REMAT", "full") == "dots":
                policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                body = jax.checkpoint(unit_body, prevent_cse=False, policy=policy)
            else:
                body = jax.checkpoint(unit_body, prevent_cse=False)
        x, (auxs, kv_stacks) = jax.lax.scan(body, x, params["units"])
        aux_total = jnp.sum(auxs)
        if collect_kv:
            caches.append(kv_stacks)
    else:
        aux_total = jnp.zeros((), jnp.float32)

    kinds = cfg.layer_kinds
    all_windows = cfg.layer_windows
    for i, lp in enumerate(params["leftover"]):
        li = cfg.n_units * len(unit) + i
        x, a, kv = apply_layer_train(
            lp, x, positions, kinds[li], all_windows[li], cfg, layout,
            collect_kv=collect_kv,
        )
        aux_total = aux_total + a
        if collect_kv and kv is not None:
            caches.append(kv)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_total, (caches if collect_kv else None)


def unembed_matrix(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"].T  # (D, V)
    return params["unembed"]


def chunked_xent(hidden, w_unembed, targets, *, chunk_v: int = 32_768,
                 ignore_id: int = -1):
    """Streaming cross-entropy over vocab chunks (no full fp32 logits).

    hidden (B, T, D), w_unembed (D, V), targets (B, T) -> (loss_sum, n_valid).
    """
    b, t, d = hidden.shape
    v = w_unembed.shape[1]
    chunk_v = min(chunk_v, v)
    n_chunks = -(-v // chunk_v)
    pad_v = n_chunks * chunk_v - v
    wt = w_unembed
    if pad_v:
        wt = jnp.pad(wt, ((0, 0), (0, pad_v)))
    wt = wt.reshape(d, n_chunks, chunk_v).transpose(1, 0, 2)  # (Nc, D, Cv)

    def step(carry, inputs):
        m, s, tgt = carry  # running max (B,T), sumexp (B,T), target logit (B,T)
        wc, base = inputs
        logits = jax.lax.dot_general(
            hidden, wc.astype(hidden.dtype), (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (B, T, Cv) f32
        if pad_v:
            in_range = (base + jnp.arange(chunk_v)) < v
            logits = jnp.where(in_range[None, None, :], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        s = s * jnp.exp(m - m_new) + jnp.exp(logits - m_new[..., None]).sum(-1)
        local = targets - base
        hit = (local >= 0) & (local < chunk_v)
        got = jnp.take_along_axis(
            logits, jnp.clip(local, 0, chunk_v - 1)[..., None], axis=-1
        )[..., 0]
        tgt = jnp.where(hit, got, tgt)
        return (m_new, s, tgt), None

    m0 = jnp.full((b, t), -1e30, jnp.float32)
    s0 = jnp.zeros((b, t), jnp.float32)
    tgt0 = jnp.zeros((b, t), jnp.float32)
    bases = jnp.arange(n_chunks) * chunk_v
    (m, s, tgt), _ = jax.lax.scan(step, (m0, s0, tgt0), (wt, bases))
    logz = m + jnp.log(jnp.maximum(s, 1e-30))
    nll = logz - tgt  # (B, T)
    valid = targets != ignore_id
    loss_sum = jnp.sum(jnp.where(valid, nll, 0.0))
    return loss_sum, jnp.sum(valid)


def lm_loss(params, cfg: ModelConfig, layout: Layout, batch: dict):
    """Mean next-token NLL + MoE aux. batch: tokens (B,T), targets (B,T)."""
    hidden, aux, _ = forward_train(params, cfg, layout, batch)
    targets = batch["targets"]
    if cfg.frontend == "vision_stub" and "prefix_embeds" in batch:
        # no loss on the visual prefix
        pad = jnp.full(batch["prefix_embeds"].shape[:2], -1, targets.dtype)
        targets = jnp.concatenate([pad, targets], axis=1)
    loss_sum, n_valid = chunked_xent(hidden, unembed_matrix(params, cfg), targets)
    return loss_sum / jnp.maximum(n_valid, 1) + aux


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------

def layer_params_for(params, cfg: ModelConfig, i: int):
    """Slice layer i's params out of the stacked/leftover structure."""
    n_unit = len(cfg.pattern_unit)
    if i < cfg.n_units * n_unit:
        u, pos = divmod(i, n_unit)
        return jax.tree.map(lambda a: a[u], params["units"][str(pos)])
    return params["leftover"][i - cfg.n_units * n_unit]


def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Per-layer decode caches (heterogeneous)."""
    caches = []
    for kind, window in zip(cfg.layer_kinds, cfg.layer_windows):
        if kind in ("attn", "moe"):
            length = min(window, max_len) if window else max_len
            caches.append(attn.make_cache(cfg, batch, length, dtype))
        elif kind == "rglru":
            caches.append(rglrum.make_rglru_state(cfg, batch, dtype))
        elif kind == "mlstm":
            caches.append(xlstmm.make_mlstm_state(cfg, batch, dtype))
        elif kind == "slstm":
            caches.append(xlstmm.make_slstm_state(cfg, batch))
    return caches


def forward_decode(params, cfg: ModelConfig, layout: Layout, tokens, caches, pos):
    """One decode step. tokens (B, 1); pos () int32. Returns (logits, caches)."""
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = params["embed"].astype(dtype)[tokens] * jnp.asarray(np.sqrt(cfg.d_model), dtype)
    x = lshard(x, layout, ("act_batch", "act_seq", "embed"))
    new_caches = []
    for i in range(cfg.n_layers):
        lp = layer_params_for(params, cfg, i)
        x, nc = apply_layer_decode(
            lp, x, caches[i], pos, cfg.layer_kinds[i], cfg.layer_windows[i], cfg, layout
        )
        new_caches.append(nc)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jax.lax.dot_general(
        x, unembed_matrix(params, cfg).astype(dtype), (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return logits, new_caches


def forward_prefill(params, cfg: ModelConfig, layout: Layout, batch: dict):
    """Full-sequence forward collecting KV; returns (last_logits, kv_caches)."""
    hidden, _, caches = forward_train(params, cfg, layout, batch, collect_kv=True)
    last = hidden[:, -1:, :]
    dtype = hidden.dtype
    logits = jax.lax.dot_general(
        last, unembed_matrix(params, cfg).astype(dtype), (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return logits, caches
