from repro.models import transformer, zoo
from repro.models.transformer import (
    forward_train,
    forward_decode,
    forward_prefill,
    init_model,
    init_caches,
    lm_loss,
)
