"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory with recurrent gate coupling) — arXiv:2405.04517.

mLSTM — training/prefill use the *stabilized parallel form* (exact,
attention-like quadratic with a gate-derived decay matrix); decoding uses
the recurrent matrix-memory update:

    C_t = f_t C_{t-1} + i_t v_t k_t^T ,  n_t = f_t n_{t-1} + i_t k_t
    h_t = (C_t q_t) / max(|n_t . q_t|, exp(-m_t))       (stabilized)

sLSTM — strictly sequential (h_{t-1} enters the gates through per-head
recurrent matrices), so training runs a ``lax.scan`` over time; the
training layout for this arch is pure data parallelism (DESIGN.md §5).

Both blocks carry their own projections (config d_ff = 0): mLSTM up-projects
x2 (conv -> q,k from the conv path, v from the pre-conv path), sLSTM is
followed by a 4/3-factor GeGLU FFN, per the paper's block diagrams.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import Layout, lshard
from repro.models.layers import init_linear, init_norm, linear, rms_norm

NEG_INF = jnp.float32(-1e30)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig):
    d = cfg.d_model
    inner = 2 * d
    h, dh = cfg.n_heads, cfg.head_dim  # qk head dim from config
    dv = inner // h  # value head dim
    ks = jax.random.split(key, 9)
    p, a = {}, {}
    p["w_up"], a["w_up"] = init_linear(ks[0], d, inner, ("embed",), ("inner",))
    p["w_gate"], a["w_gate"] = init_linear(ks[1], d, inner, ("embed",), ("inner",))
    p["conv_w"] = 0.01 * jax.random.normal(ks[2], (cfg.conv_width, inner), jnp.float32)
    a["conv_w"] = ("conv", "inner")
    p["conv_b"] = jnp.zeros((inner,), jnp.float32)
    a["conv_b"] = ("inner",)
    p["wq"], a["wq"] = init_linear(ks[3], inner, (h, dh), ("inner",), ("heads", "head_dim"))
    p["wk"], a["wk"] = init_linear(ks[4], inner, (h, dh), ("inner",), ("heads", "head_dim"))
    p["w_i"], a["w_i"] = init_linear(ks[5], inner, h, ("inner",), ("heads",))
    p["w_f"], a["w_f"] = init_linear(ks[6], inner, h, ("inner",), ("heads",))
    # forget-gate bias init: strongly positive so f ~ 1 early
    p["w_f"]["b"] = jnp.linspace(3.0, 6.0, h)
    a["w_f"]["b"] = ("heads",)
    p["norm"], a["norm"] = init_norm(inner)
    p["w_out"], a["w_out"] = init_linear(ks[7], inner, d, ("inner",), ("embed",))
    return p, a


def _mlstm_qkvif(params, x, cfg: ModelConfig):
    """x (B,T,D) -> q,k (B,T,H,dh), v (B,T,H,dv), log_i, log_f (B,T,H) f32."""
    inner = 2 * cfg.d_model
    h = cfg.n_heads
    up = linear(x, params["w_up"])  # (B, T, inner) — v path (pre-conv)
    conv, _ = _causal_conv(up, params["conv_w"], params["conv_b"])
    conv = jax.nn.silu(conv)
    q = linear(conv, params["wq"]) * (cfg.head_dim**-0.5)
    k = linear(conv, params["wk"])
    b, t = x.shape[:2]
    v = up.reshape(b, t, h, inner // h)
    log_i = linear(conv, params["w_i"], dtype=jnp.float32)
    log_f = jax.nn.log_sigmoid(linear(conv, params["w_f"], dtype=jnp.float32))
    return q, k, v, log_i, log_f, up


def _causal_conv(x, conv_w, conv_b, history=None):
    cw = conv_w.shape[0]
    if history is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = history.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * conv_w[i].astype(x.dtype) for i in range(cw))
    return out + conv_b.astype(x.dtype), xp[:, -(cw - 1) :, :]


def mlstm_train(params, x, cfg: ModelConfig, layout: Layout):
    """Stabilized parallel form. x (B, T, D) -> (B, T, D)."""
    b, t, d = x.shape
    q, k, v, log_i, log_f, up = _mlstm_qkvif(params, x, cfg)
    # decay matrix: D~[s, u] = cum_f[s] - cum_f[u] + log_i[u] for u <= s
    cum_f = jnp.cumsum(log_f, axis=1)  # (B, T, H)
    dmat = (
        cum_f[:, :, None, :] - cum_f[:, None, :, :] + log_i[:, None, :, :]
    )  # (B, Ts, Tu, H)
    causal = jnp.tril(jnp.ones((t, t), bool))
    dmat = jnp.where(causal[None, :, :, None], dmat, NEG_INF)
    m = jnp.max(dmat, axis=2, keepdims=True)  # (B, T, 1, H) row stabilizer
    dexp = jnp.exp(dmat - m)
    scores = jnp.einsum("bshd,buhd->bsuh", q.astype(jnp.float32), k.astype(jnp.float32))
    sd = scores * dexp
    norm = jnp.maximum(jnp.abs(sd.sum(axis=2)), jnp.exp(-m[:, :, 0, :]))  # (B, T, H)
    hidden = jnp.einsum("bsuh,buhv->bshv", sd, v.astype(jnp.float32)) / norm[..., None]
    hidden = hidden.reshape(b, t, 2 * d).astype(x.dtype)
    hidden = rms_norm(hidden, params["norm"], cfg.norm_eps)
    hidden = hidden * jax.nn.silu(linear(x, params["w_gate"]))
    return linear(hidden, params["w_out"])


def make_mlstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    h, dh = cfg.n_heads, cfg.head_dim
    dv = 2 * cfg.d_model // h
    return {
        "c": jnp.zeros((batch, h, dh, dv), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, 2 * cfg.d_model), dtype),
    }


def mlstm_decode(params, x, state, cfg: ModelConfig, layout: Layout):
    """One-token recurrent step."""
    b = x.shape[0]
    inner = 2 * cfg.d_model
    h = cfg.n_heads
    up = linear(x, params["w_up"])
    conv, conv_hist = _causal_conv(up, params["conv_w"], params["conv_b"], state["conv"])
    conv = jax.nn.silu(conv)
    q = (linear(conv, params["wq"]) * (cfg.head_dim**-0.5))[:, 0]  # (B, H, dh)
    k = linear(conv, params["wk"])[:, 0]
    v = up.reshape(b, 1, h, inner // h)[:, 0]  # (B, H, dv)
    log_i = linear(conv, params["w_i"], dtype=jnp.float32)[:, 0]  # (B, H)
    log_f = jax.nn.log_sigmoid(linear(conv, params["w_f"], dtype=jnp.float32))[:, 0]

    m_new = jnp.maximum(log_f + state["m"], log_i)
    f_s = jnp.exp(log_f + state["m"] - m_new)  # (B, H)
    i_s = jnp.exp(log_i - m_new)
    kf, vf, qf = k.astype(jnp.float32), v.astype(jnp.float32), q.astype(jnp.float32)
    c = f_s[..., None, None] * state["c"] + i_s[..., None, None] * (
        kf[..., :, None] * vf[..., None, :]
    )  # (B, H, dh, dv)
    n = f_s[..., None] * state["n"] + i_s[..., None] * kf
    num = jnp.einsum("bhd,bhdv->bhv", qf, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), jnp.exp(-m_new))
    hidden = (num / den[..., None]).reshape(b, 1, inner).astype(x.dtype)
    hidden = rms_norm(hidden, params["norm"], cfg.norm_eps)
    hidden = hidden * jax.nn.silu(linear(x, params["w_gate"]))
    out = linear(hidden, params["w_out"])
    return out, {"c": c, "n": n, "m": m_new, "conv": conv_hist}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 11)
    p, a = {}, {}
    for idx, gate in enumerate(("i", "f", "z", "o")):
        p[f"w_{gate}"], a[f"w_{gate}"] = init_linear(
            ks[idx], d, (h, dh), ("embed",), ("heads", "head_dim"), bias=True
        )
        p[f"r_{gate}"] = (1.0 / jnp.sqrt(dh)) * jax.random.normal(
            ks[4 + idx], (h, dh, dh), jnp.float32
        )
        a[f"r_{gate}"] = ("heads", "head_dim", "head_dim")
    p["w_f"]["b"] = jnp.full((h, dh), 3.0)  # forget bias
    p["norm"], a["norm"] = init_norm(d)
    # post-block GeGLU FFN, projection factor 4/3 (paper block diagram)
    f = int(round(4 * d * 4 / 3 / 64)) * 64
    from repro.models.layers import init_ffn

    p["ffn"], a["ffn"] = init_ffn(ks[9], d, f)
    return p, a


def _slstm_step(params, carry, gates_t):
    """carry: (c, n, h, m) each (B, H, dh); gates_t: preactivations (B,H,dh,4)."""
    c, n, h_prev, m = carry
    rec = lambda g: jnp.einsum("bhd,hde->bhe", h_prev, params[f"r_{g}"].astype(h_prev.dtype))
    zi = gates_t[..., 0] + rec("i")
    zf = gates_t[..., 1] + rec("f")
    zz = gates_t[..., 2] + rec("z")
    zo = gates_t[..., 3] + rec("o")
    # stabilized exponential gating
    log_f = jax.nn.log_sigmoid(zf)
    m_new = jnp.maximum(log_f + m, zi)
    i_s = jnp.exp(zi - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * jnp.tanh(zz)
    n_new = f_s * n + i_s
    h_new = jax.nn.sigmoid(zo) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_train(params, x, cfg: ModelConfig, layout: Layout):
    """Sequential scan over T. x (B, T, D) -> (B, T, D)."""
    b, t, d = x.shape
    h, dh = cfg.n_heads, d // cfg.n_heads
    gates = jnp.stack(
        [linear(x, params[f"w_{g}"], dtype=jnp.float32) for g in ("i", "f", "z", "o")],
        axis=-1,
    )  # (B, T, H, dh, 4)
    c0 = jnp.zeros((b, h, dh), jnp.float32)
    m0 = jnp.full((b, h, dh), -1e30, jnp.float32)
    (c, n, hh, m), hs = jax.lax.scan(
        lambda carry, g: _slstm_step(params, carry, g),
        (c0, c0, c0, m0),
        gates.transpose(1, 0, 2, 3, 4),
    )
    out = hs.transpose(1, 0, 2, 3).reshape(b, t, d).astype(x.dtype)
    out = rms_norm(out, params["norm"], cfg.norm_eps)
    from repro.models.layers import ffn

    return out + ffn(out, params["ffn"], "gelu", layout)


def make_slstm_state(cfg: ModelConfig, batch: int):
    h, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, h, dh), -1e30, jnp.float32)}


def slstm_decode(params, x, state, cfg: ModelConfig, layout: Layout):
    b, _, d = x.shape
    gates = jnp.stack(
        [linear(x, params[f"w_{g}"], dtype=jnp.float32)[:, 0] for g in ("i", "f", "z", "o")],
        axis=-1,
    )  # (B, H, dh, 4)
    carry = (state["c"], state["n"], state["h"], state["m"])
    (c, n, hh, m), h_new = _slstm_step(params, carry, gates)
    out = h_new.reshape(b, 1, d).astype(x.dtype)
    out = rms_norm(out, params["norm"], cfg.norm_eps)
    from repro.models.layers import ffn

    out = out + ffn(out, params["ffn"], "gelu", layout)
    return out, {"c": c, "n": n, "h": hh, "m": m}
