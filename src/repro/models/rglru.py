"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Block structure (arXiv:2402.19427): two branches from the residual input —
a gate branch (linear -> GeLU) and a recurrent branch (linear -> temporal
conv1d -> RG-LRU); their product is projected back to d_model.

RG-LRU recurrence (elementwise over channels):
    r_t = sigmoid(W_a xi_t)                       (recurrence gate)
    i_t = sigmoid(W_x xi_t)                       (input gate)
    log a_t = -c * softplus(Lambda) * r_t         (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * xi_t)

Training uses ``jax.lax.associative_scan`` over time.  Because the
recurrence is elementwise over channels, the layout *channel-shards* it
("act_lru" -> model) and replicates time — sequence sharding cannot apply
to a recurrence, and this keeps per-chip work exactly even (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import Layout, lshard
from repro.models.layers import init_linear, linear

_C = 8.0


def init_rglru(key, cfg: ModelConfig):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    p["w_rec"], a["w_rec"] = init_linear(ks[0], d, w, ("embed",), ("lru",))
    p["w_gate"], a["w_gate"] = init_linear(ks[1], d, w, ("embed",), ("lru",))
    p["w_out"], a["w_out"] = init_linear(ks[2], w, d, ("lru",), ("embed",))
    p["w_a"], a["w_a"] = init_linear(ks[3], w, w, ("lru",), ("lru",))
    p["w_i"], a["w_i"] = init_linear(ks[4], w, w, ("lru",), ("lru",))
    # Lambda init so a ~ U[0.9, 0.999]^(1/c) region (Griffin appendix)
    u = jax.random.uniform(ks[5], (w,), jnp.float32, 0.9, 0.999)
    p["lam"] = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # inverse softplus
    a["lam"] = ("lru",)
    p["conv_w"] = 0.01 * jax.random.normal(ks[5], (cfg.conv_width, w), jnp.float32)
    a["conv_w"] = ("conv", "lru")
    p["conv_b"] = jnp.zeros((w,), jnp.float32)
    a["conv_b"] = ("lru",)
    return p, a


def _conv1d(x, conv_w, conv_b, history=None):
    """Causal temporal conv. x (B, T, W); history (B, cw-1, W) or None."""
    cw = conv_w.shape[0]
    if history is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = history.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * conv_w[i].astype(x.dtype) for i in range(cw)
    )
    return out + conv_b.astype(x.dtype), xp[:, -(cw - 1) :, :]


def _gates(params, xi):
    r = jax.nn.sigmoid(linear(xi, params["w_a"], dtype=jnp.float32))
    i = jax.nn.sigmoid(linear(xi, params["w_i"], dtype=jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, beta * i * xi.astype(jnp.float32)


def rglru_train(params, x, cfg: ModelConfig, layout: Layout):
    """x (B, T, D) -> (B, T, D). Channel-sharded associative scan over T."""
    xi = linear(x, params["w_rec"])  # (B, T, W)
    xi = lshard(xi, layout, ("act_batch", "act_full_seq", "act_lru"))
    gate = jax.nn.gelu(linear(x, params["w_gate"]))
    gate = lshard(gate, layout, ("act_batch", "act_full_seq", "act_lru"))
    xi, _ = _conv1d(xi, params["conv_w"], params["conv_b"])
    a, b = _gates(params, xi)  # (B, T, W) f32 each

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = (h.astype(x.dtype) * gate)
    h = lshard(h, layout, ("act_batch", "act_full_seq", "act_lru"))
    out = linear(h, params["w_out"])
    return lshard(out, layout, ("act_batch", "act_seq", "embed"))


def make_rglru_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }


def rglru_decode(params, x, state, cfg: ModelConfig, layout: Layout):
    """One-token step. x (B, 1, D), state {h (B, W), conv (B, cw-1, W)}."""
    xi = linear(x, params["w_rec"])
    gate = jax.nn.gelu(linear(x, params["w_gate"]))
    xi, conv_hist = _conv1d(xi, params["conv_w"], params["conv_b"], history=state["conv"])
    a, b = _gates(params, xi)  # (B, 1, W)
    h = a[:, 0] * state["h"] + b[:, 0]
    out = linear((h[:, None, :].astype(x.dtype) * gate), params["w_out"])
    return out, {"h": h, "conv": conv_hist}
