"""Mixture-of-Experts FFN with expert parallelism (dropped-token, TPU-style).

Mesh-TF / MaxText design: per-sequence capacity, one-hot dispatch/combine
einsums, experts sharded over the ``model`` axis (EP).  The dispatch
einsum contracts the token axes (sharded batch x seq) against the expert
axis (sharded model) — the SPMD partitioner lowers this to the expert
all-to-all.  Top-k routing with capacity dropping; an auxiliary
load-balancing loss (Switch-style) and router z-loss are returned to the
trainer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import Layout, lshard
from repro.models.layers import _act, init_linear, linear


def init_moe(key, cfg: ModelConfig):
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p, a = {}, {}
    p["router"], a["router"] = init_linear(ks[0], d, e, ("embed",), ("experts",))
    scale = 1.0 / jnp.sqrt(d)
    p["w_gate"] = scale * jax.random.normal(ks[1], (e, d, ff), jnp.float32)
    a["w_gate"] = ("experts", "embed", "ffn")
    p["w_up"] = scale * jax.random.normal(ks[2], (e, d, ff), jnp.float32)
    a["w_up"] = ("experts", "embed", "ffn")
    p["w_down"] = (1.0 / jnp.sqrt(ff)) * jax.random.normal(ks[3], (e, ff, d), jnp.float32)
    a["w_down"] = ("experts", "ffn", "embed")
    if cfg.n_shared_experts:
        from repro.models.layers import init_ffn

        p["shared"], a["shared"] = init_ffn(ks[4], d, cfg.moe_d_ff * cfg.n_shared_experts)
    return p, a


def moe_ffn(params, x, cfg: ModelConfig, layout: Layout, *, group_by_batch: bool = False):
    """x (B, T, D) -> (out (B, T, D), aux_losses dict).

    Capacity groups: per sequence for train/prefill (T tokens/group); the
    whole batch for decode (T == 1 -> group_by_batch=True).
    """
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    if group_by_batch:
        xg = x.reshape(1, b * t, d)
    else:
        xg = x
    g, s, _ = xg.shape
    cap = max(int(s * k * cfg.capacity_factor / e), 1)

    logits = linear(xg, params["router"], dtype=jnp.float32)  # (G, S, E)
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k gates; renormalized over the selected experts
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (G, S, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # expert one-hot (G, S, K, E) and per-expert positions via cumsum over S
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (G, S, K, E)
    flat = onehot.reshape(g, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat  # slots used before this (token, k)
    pos = pos.reshape(g, s, k, e)
    in_cap = (pos < cap) & (onehot > 0)
    pos = jnp.where(in_cap, pos, 0).astype(jnp.int32)
    cap_onehot = jax.nn.one_hot(pos, cap, dtype=x.dtype)  # (G, S, K, E, C)

    dispatch = (cap_onehot * in_cap[..., None].astype(x.dtype)).sum(2)  # (G, S, E, C)
    combine = (cap_onehot * (gate_vals[..., None] * in_cap.astype(jnp.float32))[..., None]
               ).sum(2).astype(x.dtype)  # (G, S, E, C)

    xe = jnp.einsum("gsd,gsec->gecd", xg, dispatch)  # expert inputs
    xe = lshard(xe, layout, ("act_group", "experts", "moe_cap", "embed"))
    h = _act(cfg.act)(
        jnp.einsum("gecd,edf->gecf", xe, params["w_gate"].astype(x.dtype))
    ) * jnp.einsum("gecd,edf->gecf", xe, params["w_up"].astype(x.dtype))
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"].astype(x.dtype))
    ye = lshard(ye, layout, ("act_group", "experts", "moe_cap", "embed"))
    out = jnp.einsum("gecd,gsec->gsd", ye, combine).reshape(b, t, d)

    if cfg.n_shared_experts:
        from repro.models.layers import ffn

        out = out + ffn(x, params["shared"], cfg.act, layout)

    # Switch load-balance loss + router z-loss
    frac_tokens = jnp.mean(onehot.sum(2), axis=(0, 1))  # (E,) fraction routed
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * mean_prob) * cfg.router_aux_coef
    zloss = 1e-4 * jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return out, {"moe_aux": aux, "moe_zloss": zloss}
