"""GQA attention: training (chunked-flash), prefill, and cached decode.

Training/prefill use a flash-style ``lax.scan`` over KV chunks with f32
running max/sum — memory is bounded by one (Tq_local x chunk) score tile
regardless of sequence length, and the layout's sequence sharding keeps
per-chip score work exactly even (no head-divisibility constraints).

Decode shards the KV cache over *sequence* (``cache_seq``): each chip
scores the new query against its cache slice and the softmax over the
sharded axis becomes a distributed log-sum-exp (flash-decode) inserted by
the SPMD partitioner.  Local (sliding-window) layers keep a ring-buffer
cache with explicit slot positions, so window masking is exact across
wrap-around.

GQA is computed with grouped einsums — K/V are never materialized
per-query-head.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import Layout, lshard
from repro.models.layers import init_linear, linear, rope

NEG_INF = jnp.float32(-1e30)


def init_attention(key, cfg: ModelConfig):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["wq"], a["wq"] = init_linear(
        ks[0], d, (h, dh), ("embed",), ("heads", "head_dim"), bias=cfg.qkv_bias
    )
    p["wk"], a["wk"] = init_linear(
        ks[1], d, (kv, dh), ("embed",), ("kv_heads", "head_dim"), bias=cfg.qkv_bias
    )
    p["wv"], a["wv"] = init_linear(
        ks[2], d, (kv, dh), ("embed",), ("kv_heads", "head_dim"), bias=cfg.qkv_bias
    )
    p["wo"], a["wo"] = init_linear(
        ks[3], h * dh, d, ("heads",), ("embed",)
    )
    return p, a


def _qkv(params, x, positions, cfg: ModelConfig):
    """Project + rope. x (B, T, D) -> q (B,T,KV,G,dh), k/v (B,T,KV,dh)."""
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kv
    q = linear(x, params["wq"])  # (B, T, H, dh)
    k = linear(x, params["wk"])  # (B, T, KV, dh)
    v = linear(x, params["wv"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = q * (dh**-0.5)
    b, t = x.shape[0], x.shape[1]
    q = q.reshape(b, t, kv, g, dh)
    return q, k, v


def _out_proj(params, attn_out, cfg: ModelConfig):
    b, t = attn_out.shape[:2]
    flat = attn_out.reshape(b, t, cfg.n_heads * cfg.head_dim)
    return linear(flat, params["wo"])


def attn_train(
    params, x, positions, cfg: ModelConfig, layout: Layout, *,
    window: int | None, kv_chunk: int = 512,
):
    """Causal (optionally windowed) attention, flash-chunked over KV.

    Returns (out (B, T, D), (k, v) full-length caches for prefill reuse).
    """
    b, t, _ = x.shape
    q, k, v = _qkv(params, x, positions, cfg)
    # K/V replicated over the sequence-shard axis (all-gather under 2D-SP)
    k = lshard(k, layout, ("act_batch", "act_kv_seq", "kv_heads", "head_dim"))
    v = lshard(v, layout, ("act_batch", "act_kv_seq", "kv_heads", "head_dim"))

    chunk = min(kv_chunk, t)
    while t % chunk:
        chunk //= 2
    n_chunks = t // chunk
    kc = k.reshape(b, n_chunks, chunk, cfg.n_kv_heads, cfg.head_dim)
    vc = v.reshape(b, n_chunks, chunk, cfg.n_kv_heads, cfg.head_dim)

    qpos = positions  # (B, T) or (T,)
    if qpos.ndim == 1:
        qpos = jnp.broadcast_to(qpos[None], (b, t))

    def flash_step(carry, inputs):
        m, l, o = carry  # (B,KV,G,T) running max/denom; o (B,T,KV,G,dh) f32
        kci, vci, base = inputs  # (B, chunk, KV, dh), (B, chunk, KV, dh), ()
        s = jnp.einsum(
            "btkgd,bskd->bkgts", q, kci, preferred_element_type=jnp.float32
        )  # (B, KV, G, T, chunk) f32
        kpos = base + jnp.arange(chunk)  # absolute key positions
        mask = qpos[:, None, None, :, None] >= kpos[None, None, None, None, :]
        if window is not None:
            mask &= (qpos[:, None, None, :, None] - kpos) < window
        if cfg.logit_softcap:
            s = cfg.logit_softcap * jnp.tanh(s / cfg.logit_softcap)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        o_scaled = o * alpha.transpose(0, 3, 1, 2)[..., None]
        o_new = o_scaled + jnp.einsum(
            "bkgts,bskd->btkgd", p.astype(x.dtype), vci,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, o_new), None

    kv_g = cfg.n_kv_heads
    g = cfg.n_heads // kv_g
    m0 = jnp.full((b, kv_g, g, t), NEG_INF)
    l0 = jnp.zeros((b, kv_g, g, t), jnp.float32)
    o0 = jnp.zeros((b, t, kv_g, g, cfg.head_dim), jnp.float32)
    bases = jnp.arange(n_chunks) * chunk
    (m, l, o), _ = jax.lax.scan(
        flash_step, (m0, l0, o0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), bases),
    )
    o = o / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    o = o.reshape(b, t, cfg.n_heads, cfg.head_dim).astype(x.dtype)
    o = lshard(o, layout, ("act_batch", "act_seq", "heads", "head_dim"))
    return _out_proj(params, o, cfg), (k, v)


def kv_cache_quantized() -> bool:
    import os

    return os.environ.get("REPRO_KV_INT8", "0") == "1"


def make_cache(cfg: ModelConfig, batch: int, length: int, dtype=jnp.bfloat16):
    """KV cache for one attention layer: (k, v, slot_positions).

    With REPRO_KV_INT8=1 the cache stores int8 codes + per-(slot, head)
    f32 scales — KV reads shrink ~2x vs bf16 (the §Perf kv_int8 variant)."""
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    if kv_cache_quantized():
        return {
            "k_q": jnp.zeros((batch, length, kv, dh), jnp.int8),
            "k_s": jnp.zeros((batch, length, kv), jnp.float32),
            "v_q": jnp.zeros((batch, length, kv, dh), jnp.int8),
            "v_s": jnp.zeros((batch, length, kv), jnp.float32),
            "pos": jnp.full((length,), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, length, kv, dh), dtype),
        "v": jnp.zeros((batch, length, kv, dh), dtype),
        "pos": jnp.full((length,), -1, jnp.int32),  # absolute position per slot
    }


def _quant_kv(x):
    """(B, 1, KV, dh) -> (int8 codes, f32 scales (B, 1, KV))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    q = jnp.round(x.astype(jnp.float32) / jnp.maximum(scale[..., None], 1e-9))
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def attn_decode(
    params, x, cache, pos, cfg: ModelConfig, layout: Layout, *,
    window: int | None,
):
    """One-token cached attention. x (B, 1, D); pos () int32 current index.

    Global layers: slot = pos (cache length == max seq).  Local layers:
    slot = pos % window (ring buffer); the stored per-slot absolute
    positions make the window mask exact across wrap-around.
    """
    b = x.shape[0]
    kv_g, g, dh = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.head_dim
    positions = jnp.broadcast_to(pos, (b, 1))
    q, k_new, v_new = _qkv(params, x, positions, cfg)  # q (B,1,KV,G,dh)

    quantized = "k_q" in cache
    length = (cache["k_q"] if quantized else cache["k"]).shape[1]
    slot = pos % length if window is not None else pos
    cpos = jax.lax.dynamic_update_slice(cache["pos"], pos[None].astype(jnp.int32), (slot,))
    kv_spec = ("act_batch", "cache_seq", "kv_heads", "head_dim")
    if quantized:
        kq_new, ks_new = _quant_kv(k_new)
        vq_new, vs_new = _quant_kv(v_new)
        kq = jax.lax.dynamic_update_slice(cache["k_q"], kq_new, (0, slot, 0, 0))
        ks = jax.lax.dynamic_update_slice(cache["k_s"], ks_new, (0, slot, 0))
        vq = jax.lax.dynamic_update_slice(cache["v_q"], vq_new, (0, slot, 0, 0))
        vs = jax.lax.dynamic_update_slice(cache["v_s"], vs_new, (0, slot, 0))
        kq = lshard(kq, layout, kv_spec)
        vq = lshard(vq, layout, kv_spec)
        k = (kq.astype(x.dtype) * ks[..., None].astype(x.dtype))
        v = (vq.astype(x.dtype) * vs[..., None].astype(x.dtype))
        new_cache = {"k_q": kq, "k_s": ks, "v_q": vq, "v_s": vs, "pos": cpos}
    else:
        k = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
        k = lshard(k, layout, kv_spec)
        v = lshard(v, layout, kv_spec)
        new_cache = {"k": k, "v": v, "pos": cpos}

    s = jnp.einsum("btkgd,bskd->bkgts", q, k, preferred_element_type=jnp.float32)
    valid = (cpos >= 0) & (cpos <= pos)
    if window is not None:
        valid &= (pos - cpos) < window
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)  # distributed LSE over the sharded axis
    o = jnp.einsum("bkgts,bskd->btkgd", p.astype(x.dtype), v)
    o = o.reshape(b, 1, cfg.n_heads, cfg.head_dim)
    out = _out_proj(params, o, cfg)
    return out, new_cache
