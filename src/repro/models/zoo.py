"""Model zoo: public entry points per architecture.

``input_specs(cfg, shape)`` builds ShapeDtypeStruct stand-ins for every
model input of a (arch x shape) cell — weak-type-correct, shardable, no
device allocation — exactly what the multi-pod dry-run lowers against.
Stub frontends ([audio]/[vlm] per the brief) surface here: internvl2's
patch embeddings arrive as a precomputed ``prefix_embeds`` input.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tfm


def init_params(key, cfg: ModelConfig):
    return tfm.init_model(key, cfg)


def abstract_params(cfg: ModelConfig, key=None):
    """Param ShapeDtypeStructs via eval_shape (no allocation)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    shapes = jax.eval_shape(lambda k: tfm.init_model(k, cfg)[0], key)
    _, axes = jax.eval_shape(lambda k: tfm.init_model(k, cfg), key), None
    # axes trees contain static tuples; rebuild concretely (cheap)
    return shapes


def param_axes(cfg: ModelConfig):
    """The logical-axis tree (static; built without materializing params)."""
    key = jax.random.PRNGKey(0)
    # init under eval_shape so no arrays are allocated; axes are static.
    axes_box = {}

    def grab(k):
        p, a = tfm.init_model(k, cfg)
        axes_box["axes"] = a
        return p

    jax.eval_shape(grab, key)
    return axes_box["axes"]


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *, dtype=jnp.bfloat16):
    """ShapeDtypeStructs for the cell's inputs (train batch / decode state)."""
    b, t = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, t), i32),
            "targets": jax.ShapeDtypeStruct((b, t), i32),
        }
        if cfg.frontend == "vision_stub":
            # keep total length = t: trim tokens to make room for the prefix
            p = cfg.n_prefix_embeds
            batch = {
                "tokens": jax.ShapeDtypeStruct((b, t - p), i32),
                "targets": jax.ShapeDtypeStruct((b, t - p), i32),
                "prefix_embeds": jax.ShapeDtypeStruct((b, p, cfg.d_model), dtype),
            }
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((b, t), i32)}
        if cfg.frontend == "vision_stub":
            p = cfg.n_prefix_embeds
            batch = {
                "tokens": jax.ShapeDtypeStruct((b, t - p), i32),
                "prefix_embeds": jax.ShapeDtypeStruct((b, p, cfg.d_model), dtype),
            }
        return batch
    # decode: one new token + caches at length t
    caches = jax.eval_shape(
        lambda: tfm.init_caches(cfg, b, t, dtype)
    )
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "caches": caches,
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def make_concrete_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
    """Small concrete batch for smoke tests (CPU)."""
    rng = np.random.default_rng(seed)
    specs = input_specs(cfg, shape)

    def concretize(s):
        if s.dtype == jnp.int32:
            if s.shape == ():
                return jnp.int32(0)
            return jnp.asarray(rng.integers(0, cfg.vocab_size, size=s.shape), jnp.int32)
        return jnp.asarray(rng.normal(size=s.shape) * 0.02, s.dtype)

    return jax.tree.map(concretize, specs)
