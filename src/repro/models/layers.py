"""Shared model layers: norms, rotary embeddings, projections, FFNs.

Sharding is expressed through *logical axis names* attached to every
parameter (a parallel "axes" pytree) and through ``lshard`` constraints on
activations.  ``repro.distributed.sharding`` maps logical names to mesh
axes per execution layout (train / prefill / decode) — models never name
mesh axes directly, so the §Perf loop can re-map layouts without touching
model code.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import lshard  # logical constraint helper

Params = Any  # nested dict of arrays
Axes = Any  # matching nested dict of logical-axis tuples


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _normal(key, shape, scale, dtype=jnp.float32):
    return scale * jax.random.normal(key, shape, dtype=dtype)


def init_linear(key, in_dim, out_shape, in_axes, out_axes, *, bias=False):
    """Weight (in_dim, *out_shape) with fan-in init. Returns (params, axes)."""
    out_shape = (out_shape,) if isinstance(out_shape, int) else tuple(out_shape)
    p = {"w": _normal(key, (in_dim,) + out_shape, 1.0 / np.sqrt(in_dim))}
    a = {"w": tuple(in_axes) + tuple(out_axes)}
    if bias:
        p["b"] = jnp.zeros(out_shape, jnp.float32)
        a["b"] = tuple(out_axes)
    return p, a


def init_norm(dim):
    return {"scale": jnp.ones((dim,), jnp.float32)}, {"scale": ("embed",)}


# ---------------------------------------------------------------------------
# ops
# ---------------------------------------------------------------------------

def rms_norm(x, params, eps: float):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + params["scale"].astype(jnp.float32))
    return out.astype(dt)


def linear(x, params, dtype=None):
    """x (..., in) @ w (in, *out) -> (..., *out).

    Accepts w8a16-quantized weights ({"w_q" int8, "w_s" f32 per-output-
    channel scales}, see ``quantize_tree``): HBM reads shrink ~2x vs bf16;
    dequantization happens in registers.
    """
    dt = dtype or x.dtype
    if "w_q" in params:
        w = params["w_q"].astype(dt) * params["w_s"].astype(dt)[None]
    else:
        w = params["w"].astype(dt)
    y = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(dt)
    if "b" in params:
        y = y + params["b"].astype(dt)
    return y


def _is_linear_leaf(node) -> bool:
    return (
        isinstance(node, dict) and "w" in node
        and hasattr(node["w"], "ndim") and node["w"].ndim >= 2
    )


def quantize_tree(params, axes=None):
    """w8a16 serving quantization: every linear's weight becomes int8 codes
    + per-output-channel f32 scales (symmetric over the *input* dim).
    Stacked (scanned) weights carry a leading 'layers' axis — detected via
    the logical-axes tree — and keep per-layer scales.
    Embeddings/raw MoE expert tensors are left untouched."""

    def one(node, node_axes):
        if not _is_linear_leaf(node):
            return node
        w = node["w"]
        in_axis = 0
        if node_axes is not None and isinstance(node_axes, dict):
            wa = node_axes.get("w")
            if isinstance(wa, tuple) and wa and wa[0] == "layers":
                in_axis = 1
        scale = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=in_axis) / 127.0
        denom = jnp.maximum(jnp.expand_dims(scale, in_axis), 1e-12)
        q = jnp.clip(jnp.round(w.astype(jnp.float32) / denom), -127, 127).astype(jnp.int8)
        out = {"w_q": q, "w_s": scale.astype(jnp.float32)}
        if "b" in node:
            out["b"] = node["b"]
        return out

    def walk(p, a):
        if _is_linear_leaf(p):
            return one(p, a if isinstance(a, dict) else None)
        if isinstance(p, dict):
            return {k: walk(v, a.get(k) if isinstance(a, dict) else None)
                    for k, v in p.items()}
        if isinstance(p, (list, tuple)):
            aa = a if isinstance(a, (list, tuple)) else [None] * len(p)
            return type(p)(walk(v, av) for v, av in zip(p, aa))
        return p

    return walk(params, axes)


def quantize_axes(axes):
    """Logical-axes tree matching ``quantize_tree``'s output structure."""

    def axes_leaf(node) -> bool:
        return isinstance(node, dict) and "w" in node and isinstance(node["w"], tuple)

    def one(node):
        if not axes_leaf(node):
            return node
        out = {"w_q": node["w"], "w_s": node["w"][1:]}
        if "b" in node:
            out["b"] = node["b"]
        return out

    return jax.tree.map(one, axes, is_leaf=axes_leaf)


def rope(x, positions, theta: float):
    """Rotary embedding. x (..., T, H, dh), positions (..., T)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., T, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def _act(name: str):
    return jax.nn.gelu if name == "gelu" else jax.nn.silu


def ffn(x, params, act: str, layout):
    """Gated FFN (SwiGLU / GeGLU)."""
    gate = linear(x, params["gate"])
    up = linear(x, params["up"])
    h = _act(act)(gate) * up
    h = lshard(h, layout, ("act_batch", "act_seq", "ffn"))
    return linear(h, params["down"])


def init_ffn(key, d_model, d_ff):
    k1, k2, k3 = jax.random.split(key, 3)
    p, a = {}, {}
    p["gate"], a["gate"] = init_linear(k1, d_model, d_ff, ("embed",), ("ffn",))
    p["up"], a["up"] = init_linear(k2, d_model, d_ff, ("embed",), ("ffn",))
    p["down"], a["down"] = init_linear(k3, d_ff, d_model, ("ffn",), ("embed",))
    return p, a
