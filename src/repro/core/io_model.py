"""Calibrated I/O cost model.

The container has no NVMe device (and the TPU target has no SSD at all),
so device-time claims from the paper are validated through a calibrated
cost model with the constants the paper itself measures:

  * random 4 KB SSD read        ~100 us   (§3.3: "on the order of 100 us")
  * tunnel hop (PQ + AdjIndex)  ~1 us     (§3.3: "sub-microsecond",
                                           Table 5: 338 us / ~350 tunnels)
  * cached record gather        ~1 us     (hot-node cache hit — fast-tier
                                           rate, no device read)
  * exact-distance + parse      per-node CPU cost from Table 5
  * aggregate IOPS ceiling      ~430 K    (§5.2.2 / §5.4.4)

`estimate` turns per-query operation counts (measured for real by the
search engine) into modeled latency / QPS, including the multi-thread
regime where throughput is bounded by the CPU-side per-I/O budget.
Structural metrics (I/O counts, recall, tunnels) are never modeled —
they are measured.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class IOCostModel:
    ssd_read_us: float = 100.0       # device latency per 4 KB random read
    tunnel_us: float = 1.0           # neighbor-store lookup + PQ per tunneled node
    cache_hit_us: float = 1.0        # cached record gather — fast-tier rate, no
                                     #   device read, no submit/poll, no IOPS cost
    exact_dist_us: float = 4.8       # per fetched node: parse + exact distance
                                     #   (Table 5: 1041 us / ~206 I/Os ≈ 5 us)
    submit_poll_us: float = 0.31     # per I/O submit+poll (64 us / 206 I/Os)
    list_mgmt_us: float = 1.3        # frontier maintenance per expanded node
    iops_ceiling: float = 430_000.0  # aggregate CPU-side I/O processing budget
    pipeline_depth: int = 32         # W — concurrent in-flight reads
    refresh_us_per_record: float = 0.5  # adaptive cache: counter top-k +
                                     #   record upload per hot slot, paid
                                     #   once per refresh (amortized below)

    def refresh_cost_us(self, n_records: float) -> float:
        """One adaptive hot-set refresh: re-materialize ``n_records`` slots."""
        return float(n_records) * self.refresh_us_per_record

    def refresh_amortized_us(self, n_records: float, refresh_every: int,
                             batch_queries: int) -> float:
        """Per-query share of the refresh cost at a given cadence.

        A refresh runs once per ``refresh_every`` batches of
        ``batch_queries`` queries, off the critical path (between
        batches), so its cost is amortized across the interval.
        """
        interval = max(refresh_every, 1) * max(batch_queries, 1)
        return self.refresh_cost_us(n_records) / interval

    def latency_us(self, n_ios: float, n_tunnels: float, n_exact: float | None = None,
                   pipeline_depth: int | None = None,
                   n_cache_hits: float = 0.0,
                   refresh_amortized_us: float = 0.0,
                   overlap_depth: int = 1) -> float:
        """Modeled single-thread per-query latency.

        I/O latency is overlapped across W in-flight reads (PipeANN-style):
        device time contributes ceil(n_ios / W) * ssd_read_us; CPU-side
        per-node work is serial on one thread.  Cache hits are priced at
        the fast-tier rate (``cache_hit_us``, like a tunnel hop): they pay
        no device read and no submit/poll, only the gather + list upkeep.

        ``overlap_depth`` models the *cross-round* software pipeline
        (``SearchConfig.pipeline_depth``): traversal only waits on a
        round's read when the pipe is full, so the serial device time
        amortizes to ceil(rounds / overlap_depth) round-latencies —
        ``overlap_depth=1`` is the synchronous loop, and CPU-side work is
        unchanged (the same records are parsed and scored either way).
        """
        w = pipeline_depth or self.pipeline_depth
        n_exact = n_ios + n_cache_hits if n_exact is None else n_exact
        rounds = np.ceil(n_ios / max(w, 1))
        device = np.ceil(rounds / max(overlap_depth, 1)) * self.ssd_read_us
        fetched = n_ios + n_cache_hits
        cpu = (
            n_ios * self.submit_poll_us
            + n_exact * self.exact_dist_us
            + n_tunnels * self.tunnel_us
            + n_cache_hits * self.cache_hit_us
            + (fetched + n_tunnels) * self.list_mgmt_us
            + refresh_amortized_us
        )
        return float(device + cpu)

    def qps(self, n_ios: float, n_tunnels: float, n_threads: int = 32,
            n_exact: float | None = None, n_cache_hits: float = 0.0,
            refresh_amortized_us: float = 0.0) -> float:
        """Modeled throughput: min(CPU-scaling limit, aggregate IOPS ceiling).

        Only slow-tier reads count against the IOPS ceiling — cache hits
        (like tunnels) are device-side work that scales with threads.
        """
        if n_ios <= 0 and n_tunnels <= 0 and n_cache_hits <= 0:
            return 0.0  # degenerate query that did no work
        lat_s = max(
            self.latency_us(n_ios, n_tunnels, n_exact, n_cache_hits=n_cache_hits,
                            refresh_amortized_us=refresh_amortized_us), 1e-3
        ) / 1e6
        cpu_bound = n_threads / lat_s
        if n_ios > 0:
            io_bound = self.iops_ceiling / n_ios
            return float(min(cpu_bound, io_bound))
        return float(cpu_bound)


DEFAULT_COST_MODEL = IOCostModel()
GEN5_COST_MODEL = IOCostModel(ssd_read_us=50.0)  # §5.4.3: ~2x faster random reads
