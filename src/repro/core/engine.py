"""GateANN engine — the public API.

Build once from a corpus (+ optional metadata), then search with any
predicate and any mode.  The engine owns the four tiers of §3:

  fast tier ("memory"):   PQ codes, neighbor store, filter store
  slow tier ("SSD"):      record store (full vectors + full adjacency)

and exposes the paper's baselines through ``SearchConfig.mode``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import graph as graphm
from repro.core import pq as pqm
from repro.core import search as searchm
from repro.core.filter_store import CheckFn, EqualityFilter, RangeFilter, SubsetFilter, match_all
from repro.core.io_model import DEFAULT_COST_MODEL, IOCostModel
from repro.core.neighbor_store import NeighborStore
from repro.store.vector_store import HostOffloadRecordStore, InMemoryRecordStore


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    degree: int = 32  # graph degree R (paper: 96 at 100M, 128 at 1B)
    build_l: int = 64  # L_build
    alpha: float = 1.2
    pq_chunks: int = 16  # paper default 32 on 128-dim; scaled with D
    r_max: int = 16  # in-memory neighbors per node (runtime knob)
    store_tier: str = "memory"  # memory | host
    seed: int = 0


@dataclasses.dataclass
class GateANNEngine:
    config: EngineConfig
    vectors: jax.Array  # (N, D) — kept for ground-truth/debug only
    record_store: Any
    neighbor_store: NeighborStore
    codec: pqm.PQCodec
    codes: jax.Array
    medoid: jax.Array
    filters: dict

    # -- construction ------------------------------------------------------
    @classmethod
    def build(
        cls,
        vectors: np.ndarray,
        *,
        config: EngineConfig | None = None,
        labels: np.ndarray | None = None,
        attributes: np.ndarray | None = None,
        tag_bits: np.ndarray | None = None,
        graph: graphm.VamanaGraph | None = None,
    ) -> "GateANNEngine":
        config = config or EngineConfig()
        vecs = jnp.asarray(vectors, dtype=jnp.float32)
        n, d = vecs.shape
        if graph is None:
            graph = graphm.build_vamana(
                vecs,
                degree=config.degree,
                build_l=config.build_l,
                alpha=config.alpha,
                seed=config.seed,
            )
        pq_chunks = min(config.pq_chunks, d)
        while d % pq_chunks:
            pq_chunks -= 1
        codec = pqm.train_pq(vecs, n_chunks=pq_chunks, key=jax.random.PRNGKey(config.seed))
        codes = pqm.encode_pq(codec, vecs)
        nbr_store = NeighborStore.from_graph(graph.neighbors, config.r_max)
        if config.store_tier == "host":
            record_store = HostOffloadRecordStore.create(vecs, graph.neighbors)
        else:
            record_store = InMemoryRecordStore(vectors=vecs, neighbors=graph.neighbors)
        filters = {}
        if labels is not None:
            filters["label"] = EqualityFilter(labels=jnp.asarray(labels, dtype=jnp.int32))
        if attributes is not None:
            filters["range"] = RangeFilter(values=jnp.asarray(attributes, dtype=jnp.float32))
        if tag_bits is not None:
            filters["tags"] = SubsetFilter(tag_bits=jnp.asarray(tag_bits))
        return cls(
            config=config,
            vectors=vecs,
            record_store=record_store,
            neighbor_store=nbr_store,
            codec=codec,
            codes=codes,
            medoid=graph.medoid,
            filters=filters,
        )

    # -- search ------------------------------------------------------------
    def make_filter(self, kind: str | None, params) -> CheckFn:
        if kind is None:
            return match_all(int(self.codes.shape[0]))
        return self.filters[kind].bind(*params) if isinstance(params, tuple) else self.filters[
            kind
        ].bind(params)

    def search(
        self,
        queries: np.ndarray | jax.Array,
        *,
        filter_kind: str | None = None,
        filter_params=None,
        search_config: searchm.SearchConfig | None = None,
    ) -> searchm.SearchOutput:
        cfg = search_config or searchm.SearchConfig()
        q = jnp.asarray(queries, dtype=jnp.float32)
        lut = pqm.build_lut(self.codec, q)
        check = self.make_filter(filter_kind, filter_params)
        return searchm.filtered_search(
            fetch=self.record_store.fetch_fn(),
            neighbor_store=self.neighbor_store,
            filter_check=check,
            lut=lut,
            codes=self.codes,
            entry=self.medoid,
            queries=q,
            config=cfg,
        )

    # -- reporting ---------------------------------------------------------
    def memory_report(self) -> dict:
        n, d = self.vectors.shape
        rep = {
            "n": n,
            "dim": d,
            "pq_bytes": int(self.codes.shape[0] * self.codes.shape[1]),
            "neighbor_store_bytes": self.neighbor_store.memory_bytes(),
            "filter_store_bytes": {k: f.memory_bytes() for k, f in self.filters.items()},
        }
        if isinstance(self.record_store, InMemoryRecordStore):
            rep["record_tier_bytes"] = self.record_store.record_bytes()
        return rep

    def modeled_qps(
        self, stats: searchm.SearchStats, *, n_threads: int = 32,
        cost_model: IOCostModel = DEFAULT_COST_MODEL,
    ) -> float:
        return cost_model.qps(
            float(jnp.mean(stats.n_ios)),
            float(jnp.mean(stats.n_tunnels)),
            n_threads=n_threads,
            n_exact=float(jnp.mean(stats.n_exact)),
        )

    def modeled_latency_us(
        self, stats: searchm.SearchStats, *,
        cost_model: IOCostModel = DEFAULT_COST_MODEL, pipeline_depth: int | None = None,
    ) -> float:
        return cost_model.latency_us(
            float(jnp.mean(stats.n_ios)),
            float(jnp.mean(stats.n_tunnels)),
            float(jnp.mean(stats.n_exact)),
            pipeline_depth=pipeline_depth,
        )


def recall_at_k(result_ids: jax.Array, gt_ids: np.ndarray, k: int = 10) -> float:
    """Recall@k against exact filtered ground truth (rows -1-padded)."""
    res = np.asarray(result_ids)[:, :k]
    hits = 0
    denom = 0
    for r, g in zip(res, np.asarray(gt_ids)[:, :k]):
        gset = set(int(x) for x in g if x >= 0)
        if not gset:
            continue
        hits += len(gset & set(int(x) for x in r if x >= 0))
        denom += len(gset)
    return hits / max(denom, 1)
