"""GateANN engine — the public API.

Build once from a corpus (+ optional metadata), then search with any
predicate and any mode.  The engine owns the storage tiers of §3:

  fast tier ("memory"):   PQ codes, neighbor store, filter store
  cache tier:             hot-node record cache (optional — see
                          ``EngineConfig.cache_budget_bytes``; static
                          policies pick the hot set once at build time,
                          ``cache_policy="adaptive"`` re-learns it online
                          from live visit counters, per filter bucket)
  slow tier ("SSD"):      record store (full vectors + full adjacency)

and exposes the paper's baselines through ``SearchConfig.mode``.
Tunneling removes slow-tier reads for filter-failing nodes; the cache
removes them for the hot filter-passing ones near the medoid.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import graph as graphm
from repro.core import pq as pqm
from repro.core import search as searchm
from repro.core.filter_store import CheckFn, EqualityFilter, RangeFilter, SubsetFilter, match_all
from repro.core.io_model import DEFAULT_COST_MODEL, IOCostModel
from repro.core.neighbor_store import NeighborStore
from repro.store import format as idx_format
from repro.store.adaptive import ADAPTIVE_POLICY, AdaptiveRecordCache, filter_bucket
from repro.store.cache import CachedRecordStore, select_hot_set
from repro.store.disk import DiskRecordStore, RetryPolicy
from repro.store.vector_store import HostOffloadRecordStore, InMemoryRecordStore


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    degree: int = 32  # graph degree R (paper: 96 at 100M, 128 at 1B)
    build_l: int = 64  # L_build
    alpha: float = 1.2
    pq_chunks: int = 16  # paper default 32 on 128-dim; scaled with D
    r_max: int = 16  # in-memory neighbors per node (runtime knob)
    store_tier: str = "memory"  # memory | host | disk (disk needs a path)
    # disk tier: bound on preadv gap bridging, in sectors — a merged read
    # never bridges a hole wider than this (it splits into another
    # vectored call instead).  Negative = unbounded (favor syscall count),
    # 0 = never bridge (favor zero read amplification).
    max_gap_sectors: int = -1
    cache_budget_bytes: int = 0  # hot-record cache size (0 disables the tier)
    cache_policy: str = "visit_freq"  # visit_freq | bfs | adaptive
    refresh_every: int = 4  # adaptive: batches between hot-set refreshes
    ema_decay: float = 0.9  # adaptive: per-batch counter decay
    # adaptive: LRU capacity of per-filter hot sets.  Each materialized
    # partition holds its own cache_budget_bytes-sized block, so device
    # residency is up to (1 + cache_partitions) x the budget once several
    # filter buckets see traffic (memory_report's cache_device_bytes
    # shows the true footprint).
    cache_partitions: int = 4
    # engine-wide default for SearchConfig.use_fused_kernel: run stage-A
    # traversal as one fused Pallas pass per round.  Callers passing an
    # explicit search_config keep full control; results are bit-identical
    # either way (unsupported shapes/backends fall back silently).
    use_fused_kernel: bool = False
    # disk-tier resilience (store/disk.py): transient read errors (EIO /
    # EAGAIN / EINTR / ETIMEDOUT) retry up to io_retries times with
    # exponential backoff starting at io_retry_backoff_s; one fetch
    # round's reads may spend at most io_round_deadline_s in I/O
    # (0 = no deadline).  On exhaustion or a tripped deadline,
    # io_on_error="fail" raises (the historical behavior) while
    # "degrade" serves the failed slots as tunneled nodes — graph
    # connectivity intact, the slots dropped from exact-ranked results
    # and counted in SearchStats.n_degraded.
    io_retries: int = 0
    io_retry_backoff_s: float = 1e-3
    io_round_deadline_s: float = 0.0
    io_on_error: str = "fail"
    seed: int = 0


def _open_disk_store(path: str, config: EngineConfig, faults=None) -> DiskRecordStore:
    """Open the slow tier with the config's resilience knobs applied
    (build and load share this so the two paths can't drift)."""
    return DiskRecordStore.open(
        path,
        max_gap_sectors=config.max_gap_sectors,
        retry=RetryPolicy(
            max_retries=config.io_retries,
            backoff_s=config.io_retry_backoff_s,
            seed=config.seed,
        ),
        on_error=config.io_on_error,
        round_deadline_s=config.io_round_deadline_s,
        faults=faults,
    )


def _store_neighbors(store, expected_n: int | None = None) -> jax.Array:
    """Full adjacency of a record store, whatever its tier.

    The in-memory/host/disk tiers expose ``neighbors`` (the disk tier
    parses it from its sidecar section); the sharded tier only has its
    ``local_neighbors`` rows — acceptable only when they cover the whole
    corpus (``expected_n`` guards against wrapping a cache around a
    partial shard, whose rows are locally indexed).  Cache wiring
    threads adjacency through this helper instead of reaching for
    ``backing.neighbors`` directly.
    """
    nbrs = getattr(store, "neighbors", None)
    if nbrs is None:
        nbrs = getattr(store, "local_neighbors", None)
    if nbrs is None:
        raise TypeError(
            f"record store {type(store).__name__} exposes no adjacency "
            "(neighbors / local_neighbors)"
        )
    if expected_n is not None and int(nbrs.shape[0]) != int(expected_n):
        raise ValueError(
            f"record store {type(store).__name__} holds {int(nbrs.shape[0])} "
            f"adjacency rows but the corpus has {int(expected_n)} — a "
            "partial (sharded) backing cannot be wrapped here"
        )
    return nbrs


def _make_cache_tier(backing, *, vectors, neighbors, medoid: int, config: EngineConfig):
    """Wrap ``backing`` in the configured cache tier (or return it as-is)."""
    if config.cache_budget_bytes <= 0:
        return backing
    if config.cache_policy == ADAPTIVE_POLICY:
        cache = AdaptiveRecordCache.create(
            backing,
            vectors=vectors,
            neighbors=neighbors,
            budget_bytes=config.cache_budget_bytes,
            medoid=medoid,
            ema_decay=config.ema_decay,
            refresh_every=config.refresh_every,
            max_partitions=config.cache_partitions,
            seed=config.seed,
        )
        # a budget below one record leaves the tier off
        return cache if cache.n_slots > 0 else backing
    hot = select_hot_set(
        neighbors=neighbors,
        medoid=medoid,
        budget_bytes=config.cache_budget_bytes,
        policy=config.cache_policy,
        vectors=vectors,
        seed=config.seed,
    )
    if hot.size:  # a budget below one record leaves the tier off
        return CachedRecordStore.wrap(
            backing,
            vectors=vectors,
            neighbors=neighbors,
            hot_ids=hot,
            policy=config.cache_policy,
        )
    return backing


def _write_index_file(path, *, config, vectors, neighbors, codec, codes,
                      medoid: int, filters: dict, shards: int = 1) -> None:
    """Serialize every engine component into one page-aligned index file
    (plus one record segment per shard when ``shards > 1``)."""
    filter_arrays = {}
    if "label" in filters:
        filter_arrays["label"] = np.asarray(filters["label"].labels, np.int32)
    if "range" in filters:
        filter_arrays["range"] = np.asarray(filters["range"].values, np.float32)
    if "tags" in filters:
        filter_arrays["tags"] = np.asarray(filters["tags"].tag_bits, np.uint32)
    idx_format.write_index(
        path,
        vectors=np.asarray(vectors, np.float32),
        neighbors=np.asarray(neighbors, np.int32),
        pq_books=np.asarray(codec.books, np.float32),
        pq_codes=np.asarray(codes, np.int32),
        medoid=int(medoid),
        config=dataclasses.asdict(config),
        filters=filter_arrays,
        shards=shards,
    )


@dataclasses.dataclass
class GateANNEngine:
    config: EngineConfig
    # (N, D) full-precision corpus — ground-truth/debug only.  A device
    # array for memory/host tiers; a LAZY host memmap view for disk-tier
    # loads (np.asarray it on the explicit ground-truth path — the search
    # path never reads it, so the corpus stays on disk)
    vectors: Any
    record_store: Any
    neighbor_store: NeighborStore
    codec: pqm.PQCodec
    codes: jax.Array
    medoid: jax.Array
    filters: dict

    # -- construction ------------------------------------------------------
    @classmethod
    def build(
        cls,
        vectors: np.ndarray,
        *,
        config: EngineConfig | None = None,
        labels: np.ndarray | None = None,
        attributes: np.ndarray | None = None,
        tag_bits: np.ndarray | None = None,
        graph: graphm.VamanaGraph | None = None,
        index_path: str | None = None,
    ) -> "GateANNEngine":
        config = config or EngineConfig()
        if config.store_tier == "disk" and index_path is None:
            raise ValueError(
                "store_tier='disk' needs index_path=... (the index file to "
                "write and serve from) — or build in memory and save()/load()"
            )
        vecs = jnp.asarray(vectors, dtype=jnp.float32)
        n, d = vecs.shape
        if graph is None:
            graph = graphm.build_vamana(
                vecs,
                degree=config.degree,
                build_l=config.build_l,
                alpha=config.alpha,
                seed=config.seed,
            )
        pq_chunks = min(config.pq_chunks, d)
        while d % pq_chunks:
            pq_chunks -= 1
        codec = pqm.train_pq(vecs, n_chunks=pq_chunks, key=jax.random.PRNGKey(config.seed))
        codes = pqm.encode_pq(codec, vecs)
        nbr_store = NeighborStore.from_graph(graph.neighbors, config.r_max)
        filters = {}
        if labels is not None:
            filters["label"] = EqualityFilter(labels=jnp.asarray(labels, dtype=jnp.int32))
        if attributes is not None:
            filters["range"] = RangeFilter(values=jnp.asarray(attributes, dtype=jnp.float32))
        if tag_bits is not None:
            filters["tags"] = SubsetFilter(tag_bits=jnp.asarray(tag_bits))
        if config.store_tier == "disk":
            # persist first, then serve the slow tier straight off the file
            _write_index_file(
                index_path, config=config, vectors=vecs,
                neighbors=graph.neighbors, codec=codec, codes=codes,
                medoid=int(graph.medoid), filters=filters,
            )
            record_store = _open_disk_store(index_path, config)
        elif config.store_tier == "host":
            record_store = HostOffloadRecordStore.create(vecs, graph.neighbors)
        else:
            record_store = InMemoryRecordStore(vectors=vecs, neighbors=graph.neighbors)
        record_store = _make_cache_tier(
            record_store,
            vectors=vecs,
            neighbors=graph.neighbors,
            medoid=int(graph.medoid),
            config=config,
        )
        return cls(
            config=config,
            vectors=vecs,
            record_store=record_store,
            neighbor_store=nbr_store,
            codec=codec,
            codes=codes,
            medoid=graph.medoid,
            filters=filters,
        )

    # -- persistence -------------------------------------------------------
    def save(self, path: str, *, shards: int = 1) -> None:
        """Write the whole index (records, graph, PQ, filters, config) to
        one page-aligned file (``repro.store.format``).

        ``load`` restores it without rebuilding the graph or retraining
        PQ; a disk-tier load serves records straight off this file.

        ``shards=k`` splits the record sectors into one page-aligned
        segment file per ``model``-axis shard (``<path>.seg<i>`` + a
        manifest in the header) — a mesh host then opens only its own
        shard's rows (``core.distributed_search.load_shard_records``),
        and a single-host disk load serves all segments through one
        coalesced reader.
        """
        backing = self.record_store
        while isinstance(backing, (CachedRecordStore, AdaptiveRecordCache)):
            backing = backing.backing
        _write_index_file(
            path, config=self.config, vectors=self.vectors,
            neighbors=_store_neighbors(backing, int(self.vectors.shape[0])),
            codec=self.codec, codes=self.codes, medoid=int(self.medoid),
            filters=self.filters, shards=shards,
        )

    @classmethod
    def load(
        cls,
        path: str,
        config_overrides: dict | None = None,
        *,
        warm_disk: bool = False,
        faults=None,
        **overrides,
    ) -> "GateANNEngine":
        """Restore an engine from a saved index file — no graph build, no
        PQ retraining, bit-identical search results.

        The saved ``EngineConfig`` is the default; ``config_overrides``
        (or keyword overrides) change the *runtime* knobs — e.g.
        ``store_tier="disk"`` serves records off the file with measured
        I/O, ``r_max`` re-slices the neighbor store, ``cache_*`` attaches
        a cache tier.

        ``warm_disk=True`` starts a background sequential re-read of the
        record segment files right after the disk store opens, so the OS
        page cache is re-populated while the caller is still compiling
        its first search (no-op on non-disk tiers; see
        ``DiskRecordStore.warm``).

        ``faults=`` attaches a ``store.FaultPlan`` to the disk tier's
        read path (testing / chaos benchmarking only — runtime state,
        never persisted, so it is an explicit keyword rather than a
        config override).  Requires ``store_tier="disk"``.
        """
        idx = idx_format.read_index(path)
        h = idx.header
        known = {f.name for f in dataclasses.fields(EngineConfig)}
        user = {**(config_overrides or {}), **overrides}
        unknown = set(user) - known
        if unknown:
            raise ValueError(
                f"unknown EngineConfig override(s) {sorted(unknown)}; "
                f"valid fields: {sorted(known)}"
            )
        # stored configs may carry fields from other format versions —
        # tolerate those, but never silently drop an explicit override
        cfg = {k: v for k, v in (h.config or {}).items() if k in known}
        cfg.update(user)
        config = EngineConfig(**cfg)
        neighbors = jnp.asarray(idx.neighbors(), jnp.int32)
        books = jnp.asarray(idx.pq_books(), jnp.float32)
        codec = pqm.PQCodec(
            books=books, n_chunks=int(books.shape[0]),
            n_centroids=int(books.shape[1]),
        )
        codes = jnp.asarray(idx.pq_codes(), jnp.int32)
        if config.store_tier == "disk":
            record_store = _open_disk_store(path, config, faults=faults)
            if warm_disk:
                record_store.warm(background=True)
            # the store's LAZY host memmap view — no device transfer, no
            # copy.  The engine's ``vectors`` field is ground-truth/debug
            # state the disk search path never reads; cache selection
            # gathers only hot rows host-side (select_hot_set degrades
            # visit_freq to BFS rather than materialize the corpus)
            vectors = record_store.vectors
        elif faults is not None:
            raise ValueError(
                "faults= wraps the disk tier's read path; this load "
                f"resolves to store_tier={config.store_tier!r}"
            )
        elif config.store_tier == "host":
            vectors = jnp.asarray(idx.vectors(), jnp.float32)
            record_store = HostOffloadRecordStore.create(vectors, neighbors)
        else:
            vectors = jnp.asarray(idx.vectors(), jnp.float32)
            record_store = InMemoryRecordStore(vectors=vectors, neighbors=neighbors)
        record_store = _make_cache_tier(
            record_store, vectors=vectors, neighbors=neighbors,
            medoid=h.medoid, config=config,
        )
        filters = {}
        for kind in idx.filter_kinds():
            arr = idx.filter_array(kind)
            if kind == "label":
                filters[kind] = EqualityFilter(labels=jnp.asarray(arr, jnp.int32))
            elif kind == "range":
                filters[kind] = RangeFilter(values=jnp.asarray(arr, jnp.float32))
            elif kind == "tags":
                filters[kind] = SubsetFilter(tag_bits=jnp.asarray(arr, jnp.uint32))
        return cls(
            config=config,
            vectors=vectors,
            record_store=record_store,
            neighbor_store=NeighborStore.from_graph(neighbors, config.r_max),
            codec=codec,
            codes=codes,
            medoid=jnp.int32(h.medoid),
            filters=filters,
        )

    # -- cache tier --------------------------------------------------------
    def with_cache(
        self,
        budget_bytes: int,
        *,
        policy: str | None = None,
        refresh_every: int | None = None,
        ema_decay: float | None = None,
        cache_partitions: int | None = None,
    ) -> "GateANNEngine":
        """Re-wrap the slow tier at a new cache budget — no index rebuild.

        Like ``r_max``, the cache is a runtime knob: the graph, PQ codes
        and filter stores are shared with ``self``.  ``budget_bytes=0``
        returns an engine with the cache tier removed.  ``policy`` may be
        a static policy (``visit_freq`` / ``bfs``) or ``adaptive``; the
        remaining keywords override the adaptive knobs of ``EngineConfig``.
        """
        backing = self.record_store
        if isinstance(backing, (CachedRecordStore, AdaptiveRecordCache)):
            backing = backing.backing
        cfg = dataclasses.replace(
            self.config,
            cache_budget_bytes=budget_bytes,
            cache_policy=policy or self.config.cache_policy,
            refresh_every=(
                self.config.refresh_every if refresh_every is None else refresh_every
            ),
            ema_decay=self.config.ema_decay if ema_decay is None else ema_decay,
            cache_partitions=(
                self.config.cache_partitions
                if cache_partitions is None
                else cache_partitions
            ),
        )
        store = _make_cache_tier(
            backing,
            vectors=self.vectors,
            neighbors=_store_neighbors(backing, int(self.vectors.shape[0])),
            medoid=int(self.medoid),
            config=cfg,
        )
        return dataclasses.replace(self, config=cfg, record_store=store)

    # -- search ------------------------------------------------------------
    def make_filter(self, kind: str | None, params) -> CheckFn:
        if kind is None:
            return match_all(int(self.codes.shape[0]))
        return self.filters[kind].bind(*params) if isinstance(params, tuple) else self.filters[
            kind
        ].bind(params)

    def search(
        self,
        queries: np.ndarray | jax.Array,
        *,
        filter_kind: str | None = None,
        filter_params=None,
        search_config: searchm.SearchConfig | None = None,
    ) -> searchm.SearchOutput:
        cfg = search_config or searchm.SearchConfig(
            use_fused_kernel=self.config.use_fused_kernel
        )
        q = jnp.asarray(queries, dtype=jnp.float32)
        lut = pqm.build_lut(self.codec, q)
        check = self.make_filter(filter_kind, filter_params)
        store = self.record_store
        cached_mask = None
        visit_counts = None
        bucket = None
        adaptive = isinstance(store, AdaptiveRecordCache)
        if adaptive:
            # between-batch refresh: if the cadence came due and no caller
            # (e.g. RAGServer) already refreshed, catch up before serving
            store.maybe_refresh()
            # route through the partition snapshot for this filter bucket
            # and carry live visit counters through the loop
            bucket = filter_bucket(filter_kind, filter_params)
            store = store.store_for(bucket)
            visit_counts = jnp.zeros((int(self.codes.shape[0]),), jnp.float32)
        if isinstance(store, CachedRecordStore):
            cached_mask = store.cached_mask_fn()
        # pipelined disk search: resolve the async submit/drain pair when
        # the depth asks for overlap AND the (possibly cache-wrapped)
        # store bottoms out at a tier that can serve it (the disk tier).
        # Stores without the pair silently run the synchronous loop —
        # results are bit-identical either way.
        submit = drain = None
        if cfg.pipeline_depth > 1:
            sf = getattr(store, "submit_fn", None)
            df = getattr(store, "drain_fn", None)
            if sf is not None and df is not None:
                submit, drain = sf(), df()
                if submit is None or drain is None:
                    submit = drain = None
        reg = obs.default_registry()
        reg.counter(
            "search.dispatch",
            mode=cfg.mode,
            tier=self.config.store_tier,
            pipelined="1" if submit is not None else "0",
        ).inc()
        try:
            with obs.trace.span("engine.search", mode=cfg.mode):
                out = searchm.filtered_search(
                    fetch=store.fetch_fn(),
                    neighbor_store=self.neighbor_store,
                    filter_check=check,
                    lut=lut,
                    codes=self.codes,
                    entry=self.medoid,
                    queries=q,
                    config=cfg,
                    cached_mask=cached_mask,
                    visit_counts=visit_counts,
                    submit=submit,
                    drain=drain,
                )
                if reg.enabled:
                    # materializes the stats arrays (forcing the ordered
                    # host callbacks to completion) so the span covers
                    # actual I/O, not async dispatch
                    obs.stats.record_search_stats(
                        reg, out.stats,
                        mode=cfg.mode, tier=self.config.store_tier,
                    )
        except BaseException:
            # mid-search failure while a pipelined round is in flight: its
            # submitted-but-undrained token would pin a reader slot and a
            # completion-queue entry until close().  Drain-or-cancel here
            # so a failed search never leaks executor capacity.
            if submit is not None:
                self.abandon_pending_io()
            raise
        if adaptive:
            # fold this batch's counters; the refresh itself runs between
            # batches — either here at the next search's entry, or earlier
            # via a serving layer calling maybe_refresh() off the critical
            # path (RAGServer does, after every batch)
            self.record_store.observe(bucket, out.visit_counts)
        return out

    def warm(
        self,
        queries: np.ndarray | jax.Array,
        *,
        filter_kind: str | None = None,
        filter_params=None,
        search_config: searchm.SearchConfig | None = None,
    ) -> searchm.SearchOutput:
        """Prime the adaptive cache: search, then refresh immediately.

        On a static-cache (or uncached) engine this is just ``search``.
        """
        out = self.search(
            queries,
            filter_kind=filter_kind,
            filter_params=filter_params,
            search_config=search_config,
        )
        if isinstance(self.record_store, AdaptiveRecordCache):
            self.record_store.refresh()
        return out

    def maybe_refresh(self) -> bool:
        """Refresh the adaptive hot sets if the cadence is due."""
        if isinstance(self.record_store, AdaptiveRecordCache):
            return self.record_store.maybe_refresh()
        return False

    # -- measured I/O plumbing ---------------------------------------------
    def measured_store(self) -> DiskRecordStore | None:
        """The slow tier under any cache wrappers, if it measures real
        I/O — serving layers reconcile their modeled accounting against
        its counters.  None when the slow tier only models I/O."""
        store = self.record_store
        while isinstance(store, (CachedRecordStore, AdaptiveRecordCache)):
            store = store.backing
        return store if isinstance(store, DiskRecordStore) else None

    def io_counters(self) -> dict:
        """Measured read counters of the slow tier ({} on modeled tiers)."""
        store = self.measured_store()
        return store.io_counters() if store is not None else {}

    def abandon_pending_io(self) -> int:
        """Drain-or-cancel submitted-but-undrained pipelined disk rounds
        (``DiskRecordStore.abandon_pending``); 0 on non-disk tiers."""
        store = self.measured_store()
        return store.abandon_pending() if store is not None else 0

    # -- reporting ---------------------------------------------------------
    def memory_report(self) -> dict:
        n, d = self.vectors.shape
        rep = {
            "n": n,
            "dim": d,
            "pq_bytes": int(self.codes.shape[0] * self.codes.shape[1]),
            "neighbor_store_bytes": self.neighbor_store.memory_bytes(),
            "filter_store_bytes": {k: f.memory_bytes() for k, f in self.filters.items()},
        }
        store = self.record_store
        if isinstance(store, (CachedRecordStore, AdaptiveRecordCache)):
            rep["cache_nodes"] = store.n_cached
            rep["cache_bytes"] = store.cache_bytes()
            rep["cache_device_bytes"] = store.device_bytes()
            rep["cache_policy"] = store.policy
            if isinstance(store, AdaptiveRecordCache):
                rep["cache_slots"] = store.n_slots
                rep["cache_partitions"] = len(store.partitions)
                rep["cache_refreshes"] = store.n_refreshes
            store = store.backing
        if isinstance(store, InMemoryRecordStore):
            rep["record_tier"] = "memory"
            rep["record_tier_bytes"] = store.record_bytes()
        elif isinstance(store, DiskRecordStore):
            # on-disk footprint + measured (not modeled) read counters
            rep["record_tier"] = "disk"
            rep["record_tier_bytes"] = store.record_bytes()
            rep["disk_path"] = store.path
            rep["disk_index_bytes"] = store.index_bytes()
            rep["disk_sector_bytes"] = store.sector_bytes
            rep["disk_pages_read"] = store.pages_read
            rep["disk_bytes_read"] = store.bytes_read
            rep["disk_io_mode"] = store.io_mode
            rep["disk_shards"] = store.n_shards
            rep["disk_syscalls"] = store.syscalls
            rep["disk_unique_sectors_read"] = store.unique_sectors_read
            rep["disk_inflight_depth_max"] = store.inflight_depth_max
            rep["disk_overlapped_rounds"] = store.overlapped_rounds
            rep["disk_warmed_bytes"] = store.warmed_bytes
            rep["disk_max_gap_sectors"] = store.max_gap_sectors
        elif isinstance(store, HostOffloadRecordStore):
            rep["record_tier"] = "host"
        return rep

    def _refresh_amortized_us(
        self, stats: searchm.SearchStats, cost_model: IOCostModel
    ) -> float:
        """Per-query share of adaptive hot-set refresh cost (0 if static)."""
        store = self.record_store
        if not isinstance(store, AdaptiveRecordCache):
            return 0.0
        return cost_model.refresh_amortized_us(
            store.n_slots * store.last_refresh_sets,
            store.refresh_every,
            int(stats.n_ios.shape[0]),
        )

    def modeled_qps(
        self, stats: searchm.SearchStats, *, n_threads: int = 32,
        cost_model: IOCostModel = DEFAULT_COST_MODEL,
    ) -> float:
        return cost_model.qps(
            float(jnp.mean(stats.n_ios)),
            float(jnp.mean(stats.n_tunnels)),
            n_threads=n_threads,
            n_exact=float(jnp.mean(stats.n_exact)),
            n_cache_hits=float(jnp.mean(stats.n_cache_hits)),
            refresh_amortized_us=self._refresh_amortized_us(stats, cost_model),
        )

    def modeled_latency_us(
        self, stats: searchm.SearchStats, *,
        cost_model: IOCostModel = DEFAULT_COST_MODEL, pipeline_depth: int | None = None,
        overlap_depth: int = 1,
    ) -> float:
        """Modeled per-query latency.  ``pipeline_depth`` is W (in-flight
        reads within a round); ``overlap_depth`` is the software-pipeline
        depth across rounds (``SearchConfig.pipeline_depth``) — device
        read time amortizes across overlapped rounds."""
        return cost_model.latency_us(
            float(jnp.mean(stats.n_ios)),
            float(jnp.mean(stats.n_tunnels)),
            float(jnp.mean(stats.n_exact)),
            pipeline_depth=pipeline_depth,
            n_cache_hits=float(jnp.mean(stats.n_cache_hits)),
            refresh_amortized_us=self._refresh_amortized_us(stats, cost_model),
            overlap_depth=overlap_depth,
        )


def recall_at_k(result_ids: jax.Array, gt_ids: np.ndarray, k: int = 10) -> float:
    """Recall@k against exact filtered ground truth (rows -1-padded).

    Vectorized broadcast membership count — a (B, k, k) equality mask
    instead of per-row Python sets (this is the hot path of the recall
    regression suite and every benchmark sweep).  Ground-truth rows hold
    unique ids, so counting each matched gt id once is exactly the set
    intersection of the old implementation.
    """
    res = np.asarray(result_ids)[:, :k]
    gt = np.asarray(gt_ids)[:, :k]
    gt_valid = gt >= 0
    found = (gt[:, :, None] == res[:, None, :]) & (res[:, None, :] >= 0)
    hits = int((found.any(axis=2) & gt_valid).sum())
    return hits / max(int(gt_valid.sum()), 1)
