"""GateANN engine — the public API.

Build once from a corpus (+ optional metadata), then search with any
predicate and any mode.  The engine owns the storage tiers of §3:

  fast tier ("memory"):   PQ codes, neighbor store, filter store
  cache tier:             hot-node record cache (optional — see
                          ``EngineConfig.cache_budget_bytes``)
  slow tier ("SSD"):      record store (full vectors + full adjacency)

and exposes the paper's baselines through ``SearchConfig.mode``.
Tunneling removes slow-tier reads for filter-failing nodes; the cache
removes them for the hot filter-passing ones near the medoid.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import graph as graphm
from repro.core import pq as pqm
from repro.core import search as searchm
from repro.core.filter_store import CheckFn, EqualityFilter, RangeFilter, SubsetFilter, match_all
from repro.core.io_model import DEFAULT_COST_MODEL, IOCostModel
from repro.core.neighbor_store import NeighborStore
from repro.store.cache import CachedRecordStore, select_hot_set
from repro.store.vector_store import HostOffloadRecordStore, InMemoryRecordStore


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    degree: int = 32  # graph degree R (paper: 96 at 100M, 128 at 1B)
    build_l: int = 64  # L_build
    alpha: float = 1.2
    pq_chunks: int = 16  # paper default 32 on 128-dim; scaled with D
    r_max: int = 16  # in-memory neighbors per node (runtime knob)
    store_tier: str = "memory"  # memory | host
    cache_budget_bytes: int = 0  # hot-record cache size (0 disables the tier)
    cache_policy: str = "visit_freq"  # visit_freq | bfs (see store/cache.py)
    seed: int = 0


@dataclasses.dataclass
class GateANNEngine:
    config: EngineConfig
    vectors: jax.Array  # (N, D) — kept for ground-truth/debug only
    record_store: Any
    neighbor_store: NeighborStore
    codec: pqm.PQCodec
    codes: jax.Array
    medoid: jax.Array
    filters: dict

    # -- construction ------------------------------------------------------
    @classmethod
    def build(
        cls,
        vectors: np.ndarray,
        *,
        config: EngineConfig | None = None,
        labels: np.ndarray | None = None,
        attributes: np.ndarray | None = None,
        tag_bits: np.ndarray | None = None,
        graph: graphm.VamanaGraph | None = None,
    ) -> "GateANNEngine":
        config = config or EngineConfig()
        vecs = jnp.asarray(vectors, dtype=jnp.float32)
        n, d = vecs.shape
        if graph is None:
            graph = graphm.build_vamana(
                vecs,
                degree=config.degree,
                build_l=config.build_l,
                alpha=config.alpha,
                seed=config.seed,
            )
        pq_chunks = min(config.pq_chunks, d)
        while d % pq_chunks:
            pq_chunks -= 1
        codec = pqm.train_pq(vecs, n_chunks=pq_chunks, key=jax.random.PRNGKey(config.seed))
        codes = pqm.encode_pq(codec, vecs)
        nbr_store = NeighborStore.from_graph(graph.neighbors, config.r_max)
        if config.store_tier == "host":
            record_store = HostOffloadRecordStore.create(vecs, graph.neighbors)
        else:
            record_store = InMemoryRecordStore(vectors=vecs, neighbors=graph.neighbors)
        if config.cache_budget_bytes > 0:
            hot = select_hot_set(
                neighbors=graph.neighbors,
                medoid=int(graph.medoid),
                budget_bytes=config.cache_budget_bytes,
                policy=config.cache_policy,
                vectors=vecs,
                seed=config.seed,
            )
            if hot.size:  # a budget below one record leaves the tier off
                record_store = CachedRecordStore.wrap(
                    record_store,
                    vectors=vecs,
                    neighbors=graph.neighbors,
                    hot_ids=hot,
                    policy=config.cache_policy,
                )
        filters = {}
        if labels is not None:
            filters["label"] = EqualityFilter(labels=jnp.asarray(labels, dtype=jnp.int32))
        if attributes is not None:
            filters["range"] = RangeFilter(values=jnp.asarray(attributes, dtype=jnp.float32))
        if tag_bits is not None:
            filters["tags"] = SubsetFilter(tag_bits=jnp.asarray(tag_bits))
        return cls(
            config=config,
            vectors=vecs,
            record_store=record_store,
            neighbor_store=nbr_store,
            codec=codec,
            codes=codes,
            medoid=graph.medoid,
            filters=filters,
        )

    # -- cache tier --------------------------------------------------------
    def with_cache(
        self, budget_bytes: int, *, policy: str | None = None
    ) -> "GateANNEngine":
        """Re-wrap the slow tier at a new cache budget — no index rebuild.

        Like ``r_max``, the cache is a runtime knob: the graph, PQ codes
        and filter stores are shared with ``self``.  ``budget_bytes=0``
        returns an engine with the cache tier removed.
        """
        policy = policy or self.config.cache_policy
        backing = self.record_store
        if isinstance(backing, CachedRecordStore):
            backing = backing.backing
        store = backing
        if budget_bytes > 0:
            hot = select_hot_set(
                neighbors=backing.neighbors,
                medoid=int(self.medoid),
                budget_bytes=budget_bytes,
                policy=policy,
                vectors=self.vectors,
                seed=self.config.seed,
            )
            if hot.size:  # a budget below one record leaves the tier off
                store = CachedRecordStore.wrap(
                    backing,
                    vectors=self.vectors,
                    neighbors=backing.neighbors,
                    hot_ids=hot,
                    policy=policy,
                )
        cfg = dataclasses.replace(
            self.config, cache_budget_bytes=budget_bytes, cache_policy=policy
        )
        return dataclasses.replace(self, config=cfg, record_store=store)

    # -- search ------------------------------------------------------------
    def make_filter(self, kind: str | None, params) -> CheckFn:
        if kind is None:
            return match_all(int(self.codes.shape[0]))
        return self.filters[kind].bind(*params) if isinstance(params, tuple) else self.filters[
            kind
        ].bind(params)

    def search(
        self,
        queries: np.ndarray | jax.Array,
        *,
        filter_kind: str | None = None,
        filter_params=None,
        search_config: searchm.SearchConfig | None = None,
    ) -> searchm.SearchOutput:
        cfg = search_config or searchm.SearchConfig()
        q = jnp.asarray(queries, dtype=jnp.float32)
        lut = pqm.build_lut(self.codec, q)
        check = self.make_filter(filter_kind, filter_params)
        cached_mask = None
        if isinstance(self.record_store, CachedRecordStore):
            cached_mask = self.record_store.cached_mask_fn()
        return searchm.filtered_search(
            fetch=self.record_store.fetch_fn(),
            neighbor_store=self.neighbor_store,
            filter_check=check,
            lut=lut,
            codes=self.codes,
            entry=self.medoid,
            queries=q,
            config=cfg,
            cached_mask=cached_mask,
        )

    # -- reporting ---------------------------------------------------------
    def memory_report(self) -> dict:
        n, d = self.vectors.shape
        rep = {
            "n": n,
            "dim": d,
            "pq_bytes": int(self.codes.shape[0] * self.codes.shape[1]),
            "neighbor_store_bytes": self.neighbor_store.memory_bytes(),
            "filter_store_bytes": {k: f.memory_bytes() for k, f in self.filters.items()},
        }
        store = self.record_store
        if isinstance(store, CachedRecordStore):
            rep["cache_nodes"] = store.n_cached
            rep["cache_bytes"] = store.cache_bytes()
            rep["cache_device_bytes"] = store.device_bytes()
            rep["cache_policy"] = store.policy
            store = store.backing
        if isinstance(store, InMemoryRecordStore):
            rep["record_tier_bytes"] = store.record_bytes()
        return rep

    def modeled_qps(
        self, stats: searchm.SearchStats, *, n_threads: int = 32,
        cost_model: IOCostModel = DEFAULT_COST_MODEL,
    ) -> float:
        return cost_model.qps(
            float(jnp.mean(stats.n_ios)),
            float(jnp.mean(stats.n_tunnels)),
            n_threads=n_threads,
            n_exact=float(jnp.mean(stats.n_exact)),
            n_cache_hits=float(jnp.mean(stats.n_cache_hits)),
        )

    def modeled_latency_us(
        self, stats: searchm.SearchStats, *,
        cost_model: IOCostModel = DEFAULT_COST_MODEL, pipeline_depth: int | None = None,
    ) -> float:
        return cost_model.latency_us(
            float(jnp.mean(stats.n_ios)),
            float(jnp.mean(stats.n_tunnels)),
            float(jnp.mean(stats.n_exact)),
            pipeline_depth=pipeline_depth,
            n_cache_hits=float(jnp.mean(stats.n_cache_hits)),
        )


def recall_at_k(result_ids: jax.Array, gt_ids: np.ndarray, k: int = 10) -> float:
    """Recall@k against exact filtered ground truth (rows -1-padded)."""
    res = np.asarray(result_ids)[:, :k]
    hits = 0
    denom = 0
    for r, g in zip(res, np.asarray(gt_ids)[:, :k]):
        gset = set(int(x) for x in g if x >= 0)
        if not gset:
            continue
        hits += len(gset & set(int(x) for x in r if x >= 0))
        denom += len(gset)
    return hits / max(denom, 1)
