"""Distributed GateANN: filtered search sharded over the production mesh.

Deployment layout (DESIGN.md §2):

  * queries           — sharded over ``data`` (and ``pod``): query DP.
  * record tier       — full-precision vectors + full adjacency sharded
                        row-wise over ``model`` *within each data group*
                        (serving replicas).  A fetch = masked local gather
                        + ``psum`` over ``model`` — remote HBM over ICI,
                        the TPU-native "SSD read".
  * traversal metadata— PQ codes, neighbor store, filter store replicated
                        per device (the paper's "in-memory" tier; ~13 GB
                        at 100M scale, Table 2).

Graph tunneling therefore eliminates *collective* traffic: non-matching
nodes never reach the psum fetch path.  The loop is a fixed-hop
``fori_loop`` inside ``shard_map``; the visited set is a bounded ring
buffer (bitmaps don't scale to 100M x batch).

The multi-pod dry-run lowers this step at BigANN-100M scale on both
production meshes (see ``repro.launch.dryrun --retrieval``).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.frontier import _dedup_mask
from repro.store import format as idx_format

INVALID = jnp.int32(-1)
INF = jnp.float32(3.4e38)


def load_shard_records(
    path: str, shard: int, *, n_shards: int | None = None
) -> tuple[np.ndarray, np.ndarray, int]:
    """Open ONLY this shard's record rows off a persistent index.

    This is the per-host load path for the ``model``-axis record tier:
    on a sharded index (``engine.save(shards=k)``) it memmaps just the
    local segment file — the other shards' bytes are never opened; on a
    monolithic index it memmaps a row-slice of the records section
    (touching only those pages), with ``n_shards`` supplied by the
    caller.  Rows are padded to ``rows_per_shard`` (zero vectors, -1
    adjacency) exactly like ``ShardedRecordStore.shard_arrays``, so the
    result drops into ``make_retrieve_step``'s ``rec_vecs`` /
    ``rec_graph`` slots.

    Returns ``(vectors (rows, D) f32, neighbors (rows, R) i32, rows)``.
    """
    idx = idx_format.read_index(path)
    h = idx.header
    if h.shards:
        k = h.n_shards
        if n_shards is not None and n_shards != k:
            raise ValueError(
                f"{path} is sharded {k}-way but n_shards={n_shards} requested"
            )
        rows = int(h.shards["rows_per_shard"])
        if not 0 <= shard < k:
            raise ValueError(f"shard {shard} out of range [0, {k})")
        recs = idx.segment_records(shard)
    else:
        if n_shards is None:
            raise ValueError(
                f"{path} has monolithic records — pass n_shards to slice it"
            )
        k = int(n_shards)
        rows = -(-h.n // k)
        if not 0 <= shard < k:
            raise ValueError(f"shard {shard} out of range [0, {k})")
        recs = idx.records()[shard * rows : min((shard + 1) * rows, h.n)]
    vecs = np.ascontiguousarray(recs["vec"], np.float32)
    nbrs = np.ascontiguousarray(recs["nbrs"], np.int32)
    pad = rows - vecs.shape[0]
    if pad > 0:  # the last shard may run short of rows_per_shard
        vecs = np.pad(vecs, ((0, pad), (0, 0)))
        nbrs = np.pad(nbrs, ((0, pad), (0, 0)), constant_values=-1)
    return vecs, nbrs, rows


def load_sharded_record_arrays(
    path: str, *, n_shards: int | None = None
) -> tuple[np.ndarray, np.ndarray, int]:
    """Stack every shard's rows for the single-process ``shard_map``
    harness (tests / CPU-mesh emulation): the concatenation of
    ``load_shard_records`` over all shards, shaped exactly like
    ``ShardedRecordStore.shard_arrays`` output."""
    idx = idx_format.read_index(path)
    k = idx.header.n_shards if idx.header.shards else int(n_shards or 1)
    parts = [load_shard_records(path, s, n_shards=None if idx.header.shards else k)
             for s in range(k)]
    vecs = np.concatenate([p[0] for p in parts])
    nbrs = np.concatenate([p[1] for p in parts])
    return vecs, nbrs, parts[0][2]


@dataclasses.dataclass(frozen=True)
class DistSearchConfig:
    search_l: int = 64
    result_k: int = 10
    beam_width: int = 8
    n_hops: int = 48  # fixed rounds (SPMD-friendly)
    visited_cap: int = 2048
    mode: str = "gate"  # gate | post


def _adc(lut, codes_rows):
    """lut (B, C, K) f32; codes_rows (B, M, C) int32 -> (B, M) f32."""
    return jnp.take_along_axis(lut.transpose(0, 2, 1), codes_rows, axis=1).sum(-1)


def make_retrieve_step(
    mesh: Mesh, cfg: DistSearchConfig, *, rows_per_shard: int, multi_pod: bool = False,
):
    """Builds the jitted distributed retrieve step.

    Args (global shapes):
      queries (B, D) f32          sharded (batch_axes, None)
      lut     (B, C, K) f32       per-query ADC tables, sharded like queries
      codes   (N, C) i32          replicated
      nbr_store (N, R_max) i32    replicated
      labels  (N,) i32            replicated
      rec_vecs (N, Dv) f32        sharded ('model', None)
      rec_graph (N, R) i32        sharded ('model', None)
      entry   () i32              replicated
      targets (B,) i32            per-query equality filter target
    """
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    L, W, K_res = cfg.search_l, cfg.beam_width, cfg.result_k

    def step(queries, lut, codes, nbr_store, labels, rec_vecs, rec_graph, entry, targets):
        b = queries.shape[0]
        r = rec_graph.shape[1]
        r_max = nbr_store.shape[1]
        shard = jax.lax.axis_index("model")
        lo = shard * rows_per_shard

        def fetch(ids):  # (B, W) -> vecs (B, W, Dv), nbrs (B, W, R)
            local = ids - lo
            mine = (ids >= 0) & (local >= 0) & (local < rows_per_shard)
            safe = jnp.clip(local, 0, rec_vecs.shape[0] - 1)
            vecs = jnp.where(mine[..., None], rec_vecs[safe], 0.0)
            nbrs = jnp.where(mine[..., None], rec_graph[safe] + 1, 0)
            vecs = jax.lax.psum(vecs, "model")
            nbrs = jax.lax.psum(nbrs, "model") - 1
            return vecs, jnp.where(ids[..., None] >= 0, nbrs, INVALID)

        # frontier + results + ring-buffer visited set
        f_ids = jnp.full((b, L), INVALID)
        f_d = jnp.full((b, L), INF)
        f_exp = jnp.zeros((b, L), bool)
        res_ids = jnp.full((b, K_res), INVALID)
        res_d = jnp.full((b, K_res), INF)
        vis = jnp.full((b, cfg.visited_cap), INVALID)
        vis_n = jnp.zeros((b,), jnp.int32)

        e = jnp.broadcast_to(entry, (b,))
        ed = _adc(lut, codes[e[:, None]])[:, 0]
        f_ids = f_ids.at[:, 0].set(e)
        f_d = f_d.at[:, 0].set(ed)
        vis = vis.at[:, 0].set(e)
        vis_n = vis_n + 1

        n_ios = jnp.zeros((b,), jnp.int32)
        n_tun = jnp.zeros((b,), jnp.int32)

        def is_visited(vis, ids):  # (B, M) membership against the buffer
            return jnp.any(ids[:, :, None] == vis[:, None, :], axis=-1) & (ids >= 0)

        def push_visited(vis, vis_n, ids):  # append (ring overwrite)
            m = ids.shape[1]
            slots = (vis_n[:, None] + jnp.cumsum(jnp.ones_like(ids), axis=1) - 1)
            slots = jnp.where(ids >= 0, slots % cfg.visited_cap, cfg.visited_cap - 1)
            vis = vis.at[jnp.arange(b)[:, None], slots].set(
                jnp.where(ids >= 0, ids, vis[jnp.arange(b)[:, None], slots])
            )
            vis_n = vis_n + jnp.sum(ids >= 0, axis=1).astype(jnp.int32)
            return vis, vis_n

        def body(_, state):
            f_ids, f_d, f_exp, res_ids, res_d, vis, vis_n, n_ios, n_tun = state
            sel_d = jnp.where((~f_exp) & (f_ids >= 0), f_d, INF)
            order = jnp.argsort(sel_d, axis=1)[:, :W]
            sel = jnp.take_along_axis(f_ids, order, axis=1)
            valid = jnp.take_along_axis(sel_d, order, axis=1) < INF
            sel = jnp.where(valid, sel, INVALID)
            upd = jnp.zeros_like(f_exp).at[jnp.arange(b)[:, None], order].set(valid)
            f_exp = f_exp | upd

            passes = (labels[jnp.maximum(sel, 0)] == targets[:, None]) & valid
            if cfg.mode == "gate":
                fetch_mask = passes
                tunnel_mask = valid & (~passes)
            else:  # post-filter baseline
                fetch_mask = valid
                tunnel_mask = jnp.zeros_like(valid)

            vecs, disk_nbrs = fetch(jnp.where(fetch_mask, sel, INVALID))
            exact = jnp.sum((vecs - queries[:, None, :]) ** 2, axis=-1)
            exact = jnp.where(passes & fetch_mask, exact, INF)
            # results insert (dedup by id, exactly like fr.results_insert)
            cat_i = jnp.concatenate([res_ids, jnp.where(passes & fetch_mask, sel, INVALID)], 1)
            cat_d = jnp.concatenate([res_d, exact], 1)
            cat_d = jnp.where(_dedup_mask(cat_i) | (cat_i < 0), INF, cat_d)
            cat_i = jnp.where(cat_d >= INF, INVALID, cat_i)
            ordr = jnp.argsort(cat_d, axis=1)[:, :K_res]
            res_ids = jnp.take_along_axis(cat_i, ordr, axis=1)
            res_d = jnp.take_along_axis(cat_d, ordr, axis=1)

            tun_nbrs = jnp.where(
                tunnel_mask[..., None], nbr_store[jnp.maximum(sel, 0)], INVALID
            ) if cfg.mode == "gate" else jnp.full((b, W, r_max), INVALID)

            new = jnp.concatenate([disk_nbrs.reshape(b, -1), tun_nbrs.reshape(b, -1)], 1)
            # visited-set check + within-round first-occurrence dedup: the
            # single-host loop gets the latter from fr.insert; without it a
            # node reachable from two same-round expansions enters the
            # frontier twice and is fetched twice (double I/O, dup results)
            fresh = (new >= 0) & (~is_visited(vis, new)) & (~_dedup_mask(new))
            new = jnp.where(fresh, new, INVALID)
            vis, vis_n = push_visited(vis, vis_n, new)
            nd = jnp.where(new >= 0, _adc(lut, codes[jnp.maximum(new, 0)]), INF)
            ci = jnp.concatenate([f_ids, new], 1)
            cd = jnp.concatenate([f_d, nd], 1)
            ce = jnp.concatenate([f_exp, jnp.zeros_like(new, bool)], 1)
            cd = jnp.where(_dedup_mask(ci), INF, cd)  # vs frontier residents
            ci = jnp.where(cd >= INF, INVALID, ci)  # dead slots carry no id
            o2 = jnp.argsort(cd, axis=1)[:, :L]
            f_ids = jnp.take_along_axis(ci, o2, axis=1)
            f_d = jnp.take_along_axis(cd, o2, axis=1)
            f_exp = jnp.take_along_axis(ce, o2, axis=1)

            n_ios = n_ios + jnp.sum(fetch_mask, 1).astype(jnp.int32)
            n_tun = n_tun + jnp.sum(tunnel_mask, 1).astype(jnp.int32)
            return f_ids, f_d, f_exp, res_ids, res_d, vis, vis_n, n_ios, n_tun

        state = (f_ids, f_d, f_exp, res_ids, res_d, vis, vis_n, n_ios, n_tun)
        state = jax.lax.fori_loop(0, cfg.n_hops, body, state)
        _, _, _, res_ids, res_d, _, _, n_ios, n_tun = state
        return {"ids": res_ids, "dists": res_d, "n_ios": n_ios, "n_tunnels": n_tun}

    qspec = P(batch_axes, None)
    rep = P(None, None)
    mapped = shard_map(
        step,
        mesh=mesh,
        in_specs=(qspec, P(batch_axes, None, None), rep, rep, P(None),
                  P("model", None), P("model", None), P(), P(batch_axes)),
        out_specs={"ids": qspec, "dists": qspec, "n_ios": P(batch_axes),
                   "n_tunnels": P(batch_axes)},
        check_rep=False,
    )
    return jax.jit(mapped)
