"""Vamana graph construction and in-memory beam search.

This is the index substrate under DiskANN / PipeANN / GateANN: all three
search the *same* standard Vamana graph (paper §5.1).  We implement:

  * ``build_vamana``          — batched two-pass Vamana build
                                (greedy search for candidates + RobustPrune,
                                reverse-edge insertion with overflow pruning).
  * ``build_filtered_vamana`` — the F-DiskANN baseline: label-aware pruning
                                and per-label medoid entry points.
  * ``beam_search_batch``     — jitted batched best-first search over
                                full-precision in-memory vectors (the
                                Vamana baseline, and the build workhorse).

Graphs are dense int32 arrays ``(N, R)`` padded with -1, matching the
paper's fixed-degree on-disk records.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

INVALID = jnp.int32(-1)
INF = jnp.float32(3.4e38)


class VamanaGraph(NamedTuple):
    neighbors: jax.Array  # (N, R) int32, -1 padded
    medoid: jax.Array  # () int32 — global entry point


# ---------------------------------------------------------------------------
# distance helpers
# ---------------------------------------------------------------------------

def l2_sq(x: jax.Array, y: jax.Array) -> jax.Array:
    """Squared L2 between rows of x (..., D) and y (..., D)."""
    diff = x - y
    return jnp.sum(diff * diff, axis=-1)


def l2_sq_pairwise(x: jax.Array, y: jax.Array) -> jax.Array:
    """(Nx, D) x (Ny, D) -> (Nx, Ny)."""
    return (
        jnp.sum(x * x, axis=1, keepdims=True)
        - 2.0 * x @ y.T
        + jnp.sum(y * y, axis=1)[None, :]
    )


def find_medoid(vectors: jax.Array) -> jax.Array:
    """Node closest to the dataset centroid (the DiskANN entry point)."""
    centroid = jnp.mean(vectors, axis=0, keepdims=True)
    return jnp.argmin(l2_sq(vectors, centroid)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# batched best-first beam search (in-memory, full precision)
# ---------------------------------------------------------------------------

class SearchResult(NamedTuple):
    ids: jax.Array  # (B, L) int32 candidate ids, sorted by distance
    dists: jax.Array  # (B, L) float32
    expanded_ids: jax.Array  # (B, max_expand) int32, -1 padded (the visited set V)
    n_expanded: jax.Array  # (B,) int32
    n_hops: jax.Array  # (B,) int32


def _frontier_insert(ids, dists, flags, new_ids, new_dists, new_flags):
    """Merge new candidates into the sorted frontier, dedup by id, keep L."""
    l = ids.shape[-1]
    all_ids = jnp.concatenate([ids, new_ids], axis=-1)
    all_d = jnp.concatenate([dists, new_dists], axis=-1)
    all_f = jnp.concatenate([flags, new_flags], axis=-1)
    # Dedup: mark later duplicates invalid. O(M^2) mask, M small (<= L + W*R).
    m = all_ids.shape[-1]
    eye_lt = jnp.tril(jnp.ones((m, m), dtype=bool), k=-1)
    same = all_ids[..., None, :] == all_ids[..., :, None]  # (..., M, M)
    dup = jnp.any(same & eye_lt[None, ...] & (all_ids[..., None, :] >= 0), axis=-1)
    all_d = jnp.where(dup, INF, all_d)
    all_ids = jnp.where(all_d >= INF, INVALID, all_ids)  # kill dup/dead slots
    order = jnp.argsort(all_d, axis=-1)
    take = order[..., :l]
    return (
        jnp.take_along_axis(all_ids, take, axis=-1),
        jnp.take_along_axis(all_d, take, axis=-1),
        jnp.take_along_axis(all_f, take, axis=-1),
    )


@functools.partial(
    jax.jit,
    static_argnames=("search_l", "beam_width", "max_expand"),
)
def beam_search_batch(
    neighbors: jax.Array,  # (N, R)
    vectors: jax.Array,  # (N, D)
    entry: jax.Array,  # () or (B,) int32
    queries: jax.Array,  # (B, D)
    *,
    search_l: int = 64,
    beam_width: int = 4,
    max_expand: int = 256,
) -> SearchResult:
    """Batched best-first graph search with exact in-memory distances.

    Faithful to DiskANN's GreedySearch: maintain a sorted size-L frontier;
    repeatedly expand the best `beam_width` unexpanded candidates; stop
    when the top-L contains no unexpanded candidate.
    """
    b, d = queries.shape
    n, r = neighbors.shape
    if entry.ndim == 0:
        entry = jnp.broadcast_to(entry, (b,))

    ids0 = jnp.full((b, search_l), INVALID)
    dists0 = jnp.full((b, search_l), INF)
    flags0 = jnp.zeros((b, search_l), dtype=bool)  # True = expanded
    e_dist = l2_sq(vectors[entry], queries)
    ids0 = ids0.at[:, 0].set(entry)
    dists0 = dists0.at[:, 0].set(e_dist)

    exp_ids0 = jnp.full((b, max_expand), INVALID)
    exp_d0 = jnp.full((b, max_expand), INF)
    n_exp0 = jnp.zeros((b,), dtype=jnp.int32)
    hops0 = jnp.zeros((b,), dtype=jnp.int32)

    # visited bitmap (B, ceil(N/32)) packed uint32
    nw = (n + 31) // 32
    visited0 = jnp.zeros((b, nw), dtype=jnp.uint32)

    def set_visited(vis, idx):  # idx (B, K)
        word = jnp.clip(idx // 32, 0, nw - 1)
        bit = (jnp.uint32(1) << (idx % 32).astype(jnp.uint32))
        bit = jnp.where(idx >= 0, bit, 0)
        upd = jnp.zeros_like(vis)

        def body(c, args):
            upd, = args
            upd = upd.at[jnp.arange(b), word[:, c]].set(
                upd[jnp.arange(b), word[:, c]] | bit[:, c]
            )
            return (upd,)

        (upd,) = jax.lax.fori_loop(0, idx.shape[1], body, (upd,))
        return vis | upd

    def is_visited(vis, idx):  # (B, K) -> bool
        word = jnp.clip(idx // 32, 0, nw - 1)
        bit = (jnp.uint32(1) << (idx % 32).astype(jnp.uint32))
        got = jnp.take_along_axis(vis, word, axis=1)
        return (got & bit) != 0

    visited0 = set_visited(visited0, entry[:, None])

    state0 = (ids0, dists0, flags0, visited0, exp_ids0, exp_d0, n_exp0, hops0)

    def cond(state):
        ids, dists, flags, *_ , n_exp, hops = state
        has_work = jnp.any((~flags) & (ids >= 0), axis=1)
        return jnp.any(has_work) & jnp.all(hops < max_expand)

    def body(state):
        ids, dists, flags, visited, exp_ids, exp_d, n_exp, hops = state
        # pick up to beam_width best unexpanded candidates per query
        sel_d = jnp.where((~flags) & (ids >= 0), dists, INF)
        order = jnp.argsort(sel_d, axis=1)[:, :beam_width]  # (B, W)
        sel_ids = jnp.take_along_axis(ids, order, axis=1)  # (B, W)
        sel_valid = jnp.take_along_axis(sel_d, order, axis=1) < INF
        sel_ids = jnp.where(sel_valid, sel_ids, INVALID)

        # mark them expanded in the frontier
        w = order.shape[1]
        flag_upd = jnp.zeros_like(flags)
        flag_upd = flag_upd.at[jnp.arange(b)[:, None], order].set(sel_valid)
        flags = flags | flag_upd

        # record the visited set V (for RobustPrune)
        sel_dists = l2_sq(vectors[jnp.maximum(sel_ids, 0)], queries[:, None, :])
        sel_dists = jnp.where(sel_valid, sel_dists, INF)
        slots = n_exp[:, None] + jnp.arange(w)[None, :]
        slots = jnp.clip(slots, 0, max_expand - 1)
        exp_ids = exp_ids.at[jnp.arange(b)[:, None], slots].set(
            jnp.where(sel_valid, sel_ids, exp_ids[jnp.arange(b)[:, None], slots])
        )
        exp_d = exp_d.at[jnp.arange(b)[:, None], slots].set(
            jnp.where(sel_valid, sel_dists, exp_d[jnp.arange(b)[:, None], slots])
        )
        n_exp = n_exp + jnp.sum(sel_valid, axis=1).astype(jnp.int32)

        # expand: gather neighbor lists
        nbrs = neighbors[jnp.maximum(sel_ids, 0)]  # (B, W, R)
        nbrs = jnp.where(sel_valid[..., None], nbrs, INVALID)
        nbrs = nbrs.reshape(b, w * r)
        fresh = (nbrs >= 0) & (~is_visited(visited, jnp.maximum(nbrs, 0)))
        nbrs = jnp.where(fresh, nbrs, INVALID)
        visited = set_visited(visited, nbrs)

        nd = l2_sq(vectors[jnp.maximum(nbrs, 0)], queries[:, None, :])
        nd = jnp.where(nbrs >= 0, nd, INF)
        nf = jnp.zeros_like(nbrs, dtype=bool)
        ids, dists, flags = _frontier_insert(ids, dists, flags, nbrs, nd, nf)
        return ids, dists, flags, visited, exp_ids, exp_d, n_exp, hops + 1

    ids, dists, flags, visited, exp_ids, exp_d, n_exp, hops = jax.lax.while_loop(
        cond, body, state0
    )
    return SearchResult(ids=ids, dists=dists, expanded_ids=exp_ids, n_expanded=n_exp, n_hops=hops)


# ---------------------------------------------------------------------------
# RobustPrune (vectorized over a batch of points)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("degree",))
def robust_prune_batch(
    point_ids: jax.Array,  # (B,) int32
    cand_ids: jax.Array,  # (B, C) int32, -1 padded (V ∪ current neighbors)
    vectors: jax.Array,  # (N, D)
    *,
    alpha: float,
    degree: int,
) -> jax.Array:
    """DiskANN RobustPrune: greedily keep the closest candidate, drop any
    candidate c' with alpha * d(c, c') <= d(p, c'). Returns (B, degree)."""
    b, c = cand_ids.shape
    p_vec = vectors[point_ids]  # (B, D)
    c_vec = vectors[jnp.maximum(cand_ids, 0)]  # (B, C, D)
    valid = cand_ids >= 0
    # drop self
    valid = valid & (cand_ids != point_ids[:, None])
    d_p = jnp.where(valid, l2_sq(c_vec, p_vec[:, None, :]), INF)  # (B, C)
    # pairwise candidate distances (B, C, C)
    d_cc = jax.vmap(l2_sq_pairwise)(c_vec, c_vec)

    def select_one(state, _):
        alive, d_p_cur, out, k = state
        best = jnp.argmin(jnp.where(alive, d_p_cur, INF), axis=1)  # (B,)
        best_ok = jnp.take_along_axis(jnp.where(alive, d_p_cur, INF), best[:, None], axis=1)[
            :, 0
        ] < INF
        out = out.at[jnp.arange(b), k].set(
            jnp.where(best_ok, jnp.take_along_axis(cand_ids, best[:, None], axis=1)[:, 0], INVALID)
        )
        # occlusion rule
        d_best = jnp.take_along_axis(d_cc, best[:, None, None], axis=1)[:, 0, :]  # (B, C)
        occluded = alpha * d_best <= d_p_cur
        alive = alive & (~occluded) & best_ok[:, None]
        alive = alive.at[jnp.arange(b), best].set(False)
        return (alive, d_p_cur, out, k + 1), None

    out0 = jnp.full((b, degree), INVALID)
    (alive, _, out, _), _ = jax.lax.scan(
        select_one, (valid, d_p, out0, 0), None, length=degree
    )
    return out


# ---------------------------------------------------------------------------
# Vamana build
# ---------------------------------------------------------------------------

def _init_random_graph(n: int, r: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    nbrs = rng.integers(0, n, size=(n, r), dtype=np.int32)
    # avoid self loops
    self_hit = nbrs == np.arange(n, dtype=np.int32)[:, None]
    nbrs[self_hit] = (nbrs[self_hit] + 1) % n
    return nbrs


def build_vamana(
    vectors: np.ndarray | jax.Array,
    *,
    degree: int = 32,
    build_l: int = 64,
    alpha: float = 1.2,
    batch_size: int = 512,
    seed: int = 0,
    two_pass: bool = True,
) -> VamanaGraph:
    """Batched Vamana build (ParlayANN-style batch insertion, two passes).

    Pass 1 uses alpha=1.0, pass 2 the final alpha — as in DiskANN. Each
    batch: greedy-search every point from the medoid, RobustPrune its
    visited set, install edges, then add reverse edges and re-prune nodes
    whose degree overflows.
    """
    vectors = jnp.asarray(vectors, dtype=jnp.float32)
    n, d = vectors.shape
    degree = min(degree, n - 1)
    nbrs = _init_random_graph(n, degree, seed)
    medoid = int(find_medoid(vectors))
    rng = np.random.default_rng(seed + 1)

    alphas = [1.0, alpha] if two_pass else [alpha]
    max_expand = max(2 * build_l, 128)

    for pass_alpha in alphas:
        order = rng.permutation(n)
        for start in range(0, n, batch_size):
            batch = order[start : start + batch_size].astype(np.int32)
            if len(batch) < batch_size:  # pad to a fixed shape (no retrace);
                batch = np.concatenate(  # duplicate writes are idempotent
                    [batch, batch[np.zeros(batch_size - len(batch), dtype=np.int64)]]
                )
            bq = vectors[batch]
            res = beam_search_batch(
                jnp.asarray(nbrs),
                vectors,
                jnp.int32(medoid),
                bq,
                search_l=build_l,
                beam_width=4,
                max_expand=max_expand,
            )
            # candidate pool: visited set ∪ current neighbors
            cur = jnp.asarray(nbrs[batch])  # (B, R)
            cands = jnp.concatenate([res.expanded_ids, res.ids, cur], axis=1)
            pruned = robust_prune_batch(
                jnp.asarray(batch), cands, vectors, alpha=pass_alpha, degree=degree
            )
            pruned_np = np.asarray(pruned)
            nbrs[batch] = pruned_np

            # reverse edges
            src = np.repeat(batch, degree)
            dst = pruned_np.reshape(-1)
            ok = dst >= 0
            src, dst = src[ok], dst[ok]
            overflow_nodes = _add_reverse_edges(nbrs, dst, src, degree)
            if len(overflow_nodes):
                onodes = np.asarray(sorted(overflow_nodes), dtype=np.int32)
                for os in range(0, len(onodes), batch_size):
                    ob = onodes[os : os + batch_size]
                    if len(ob) < batch_size:
                        ob = np.concatenate(
                            [ob, ob[np.zeros(batch_size - len(ob), dtype=np.int64)]]
                        )
                    ocands = jnp.asarray(
                        np.concatenate([nbrs[ob], _overflow_extra(ob)], axis=1)
                    )
                    opr = robust_prune_batch(
                        jnp.asarray(ob), ocands, vectors, alpha=pass_alpha, degree=degree
                    )
                    nbrs[ob] = np.asarray(opr)

    return VamanaGraph(neighbors=jnp.asarray(nbrs), medoid=jnp.int32(medoid))


_OVERFLOW_BUF: dict[int, np.ndarray] = {}


def _overflow_extra(ob: np.ndarray) -> np.ndarray:
    """Extra candidate columns gathered for overflowing nodes this batch."""
    out = np.full((len(ob), _OVERFLOW_W), -1, dtype=np.int32)
    for i, node in enumerate(ob):
        extra = _OVERFLOW_BUF.get(int(node))
        if extra is not None:
            k = min(len(extra), _OVERFLOW_W)
            out[i, :k] = extra[:k]
    return out


_OVERFLOW_W = 32


def _add_reverse_edges(nbrs: np.ndarray, dst: np.ndarray, src: np.ndarray, degree: int):
    """Append src into dst's adjacency; collect nodes that overflow."""
    _OVERFLOW_BUF.clear()
    overflow = set()
    # group by destination
    order = np.argsort(dst, kind="stable")
    dst, src = dst[order], src[order]
    starts = np.searchsorted(dst, np.unique(dst))
    uniq = np.unique(dst)
    bounds = np.append(starts, len(dst))
    for i, node in enumerate(uniq):
        incoming = src[bounds[i] : bounds[i + 1]]
        row = nbrs[node]
        existing = set(row[row >= 0].tolist())
        new = [s for s in incoming.tolist() if s not in existing and s != node]
        if not new:
            continue
        free = np.where(row < 0)[0]
        n_fit = min(len(free), len(new))
        if n_fit:
            nbrs[node, free[:n_fit]] = new[:n_fit]
        rest = new[n_fit:]
        if rest:
            _OVERFLOW_BUF[int(node)] = np.asarray(rest[:_OVERFLOW_W], dtype=np.int32)
            overflow.add(int(node))
    return overflow


# ---------------------------------------------------------------------------
# FilteredVamana (F-DiskANN baseline)
# ---------------------------------------------------------------------------

class FilteredVamanaGraph(NamedTuple):
    neighbors: jax.Array  # (N, R)
    medoid: jax.Array  # global medoid
    label_medoids: jax.Array  # (n_labels,) int32 per-label entry points


def build_filtered_vamana(
    vectors: np.ndarray | jax.Array,
    labels: np.ndarray,  # (N,) int single-label
    *,
    degree: int = 32,
    build_l: int = 64,
    alpha: float = 1.2,
    batch_size: int = 512,
    seed: int = 0,
) -> FilteredVamanaGraph:
    """F-DiskANN's FilteredVamana (single-label form).

    Label-aware construction: candidate generation searches from the
    point's *label medoid* and the candidate pool is biased toward
    same-label nodes; RobustPrune keeps an edge to c' only if it shares
    the point's label or survives the unfiltered rule (the "stitched"
    simplification documented in DESIGN.md §8).
    """
    vectors = jnp.asarray(vectors, dtype=jnp.float32)
    labels = np.asarray(labels)
    n, d = vectors.shape
    n_labels = int(labels.max()) + 1
    base = build_vamana(
        vectors, degree=degree, build_l=build_l, alpha=alpha, batch_size=batch_size, seed=seed
    )
    nbrs = np.asarray(base.neighbors).copy()

    # per-label medoids
    label_medoids = np.zeros(n_labels, dtype=np.int32)
    vec_np = np.asarray(vectors)
    for lab in range(n_labels):
        idx = np.where(labels == lab)[0]
        if len(idx) == 0:
            label_medoids[lab] = int(base.medoid)
            continue
        cen = vec_np[idx].mean(axis=0, keepdims=True)
        label_medoids[lab] = idx[np.argmin(((vec_np[idx] - cen) ** 2).sum(axis=1))]

    # label-aware edge augmentation: reserve a fraction of each node's
    # degree for same-label neighbors found by a filtered search.
    reserve = max(degree // 4, 4)
    rng = np.random.default_rng(seed + 7)
    order = rng.permutation(n)
    labels_j = jnp.asarray(labels.astype(np.int32))
    for start in range(0, n, batch_size):
        batch = order[start : start + batch_size].astype(np.int32)
        bl = labels[batch]
        entries = jnp.asarray(label_medoids[bl])
        res = beam_search_batch(
            jnp.asarray(nbrs), vectors, entries, vectors[batch],
            search_l=build_l, beam_width=4, max_expand=2 * build_l,
        )
        # same-label candidates only
        cand = np.asarray(res.ids)
        cand_lab = np.where(cand >= 0, labels[np.maximum(cand, 0)], -2)
        same = np.where(cand_lab == bl[:, None], cand, -1)
        same_j = jnp.asarray(same.astype(np.int32))
        pruned = robust_prune_batch(
            jnp.asarray(batch), same_j, vectors, alpha=alpha, degree=reserve
        )
        pruned_np = np.asarray(pruned)
        # install into the last `reserve` slots (keeping base connectivity)
        nbrs[batch, degree - reserve :] = pruned_np

    return FilteredVamanaGraph(
        neighbors=jnp.asarray(nbrs),
        medoid=base.medoid,
        label_medoids=jnp.asarray(label_medoids),
    )
