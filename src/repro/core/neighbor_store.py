"""Neighbor store — in-memory adjacency for graph tunneling (§3.2).

Replicates the first ``R_max`` neighbors of each node from the on-disk
graph into a contiguous fixed-stride array.  Built at load time from the
unmodified index (Vamana stores neighbors in proximity order, so a prefix
keeps the closest, most useful routes).  ``R_max`` is a *runtime* knob —
no index rebuild is ever required to change it (§3.4).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@functools.partial(
    jax.tree_util.register_dataclass, data_fields=("neighbors",), meta_fields=()
)
@dataclasses.dataclass(frozen=True)
class NeighborStore:
    neighbors: jax.Array  # (N, R_max) int32, -1 padded

    @classmethod
    def from_graph(cls, full_neighbors: jax.Array, r_max: int) -> "NeighborStore":
        """Extract the first r_max columns (closest neighbors first)."""
        r = full_neighbors.shape[1]
        return cls(neighbors=full_neighbors[:, : min(r_max, r)])

    @property
    def r_max(self) -> int:
        return int(self.neighbors.shape[1])

    def lookup(self, ids: jax.Array) -> jax.Array:
        """(B, K) ids -> (B, K, R_max) neighbor ids; invalid ids -> -1 rows."""
        got = self.neighbors[jnp.maximum(ids, 0)]
        return jnp.where(ids[..., None] >= 0, got, jnp.int32(-1))

    def memory_bytes(self) -> int:
        """Paper Eq. (1): N * (1 + R_max) * 4 B (the +1 models the length
        word of the on-disk record header)."""
        n = int(self.neighbors.shape[0])
        return n * (1 + self.r_max) * 4
