"""Sorted candidate frontier — batched, jittable list operations.

Both GateANN paths (SSD fetch and in-memory tunnel) feed the same sorted
frontier (§3.3 "Putting it together"), so these helpers are shared by the
engine and all baselines.  The frontier is a fixed-size structure-of-arrays
``(ids, dists, expanded)`` sorted by distance, padded with (-1, INF).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

INVALID = jnp.int32(-1)
INF = jnp.float32(3.4e38)


class Frontier(NamedTuple):
    ids: jax.Array  # (B, L) int32
    dists: jax.Array  # (B, L) float32 (PQ distances — priority signal only)
    expanded: jax.Array  # (B, L) bool — dispatched or tunneled already


def make_frontier(batch: int, size: int) -> Frontier:
    return Frontier(
        ids=jnp.full((batch, size), INVALID),
        dists=jnp.full((batch, size), INF),
        expanded=jnp.zeros((batch, size), dtype=bool),
    )


def _dedup_mask(ids: jax.Array) -> jax.Array:
    """True where this slot duplicates an earlier slot with the same id."""
    m = ids.shape[-1]
    lt = jnp.tril(jnp.ones((m, m), dtype=bool), k=-1)
    same = ids[..., None, :] == ids[..., :, None]
    return jnp.any(same & lt & (ids[..., None, :] >= 0), axis=-1)


def insert(frontier: Frontier, new_ids: jax.Array, new_dists: jax.Array) -> Frontier:
    """Merge (B, M) new candidates, dedup by id, keep the best L."""
    l = frontier.ids.shape[-1]
    ids = jnp.concatenate([frontier.ids, new_ids], axis=-1)
    dists = jnp.concatenate([frontier.dists, new_dists], axis=-1)
    expanded = jnp.concatenate(
        [frontier.expanded, jnp.zeros_like(new_ids, dtype=bool)], axis=-1
    )
    dists = jnp.where(_dedup_mask(ids), INF, dists)
    dists = jnp.where(ids < 0, INF, dists)
    ids = jnp.where(dists >= INF, INVALID, ids)  # INF slots are dead slots
    order = jnp.argsort(dists, axis=-1)[..., :l]
    return Frontier(
        ids=jnp.take_along_axis(ids, order, axis=-1),
        dists=jnp.take_along_axis(dists, order, axis=-1),
        expanded=jnp.take_along_axis(expanded, order, axis=-1),
    )


def best_unexpanded(frontier: Frontier, width: int):
    """Select up to `width` best unexpanded candidates.

    Returns (sel_ids (B, W), sel_slots (B, W), valid (B, W)).
    """
    sel_d = jnp.where((~frontier.expanded) & (frontier.ids >= 0), frontier.dists, INF)
    slots = jnp.argsort(sel_d, axis=-1)[..., :width]
    ids = jnp.take_along_axis(frontier.ids, slots, axis=-1)
    valid = jnp.take_along_axis(sel_d, slots, axis=-1) < INF
    return jnp.where(valid, ids, INVALID), slots, valid


def mark_expanded(frontier: Frontier, slots: jax.Array, valid: jax.Array) -> Frontier:
    b = frontier.ids.shape[0]
    upd = jnp.zeros_like(frontier.expanded)
    upd = upd.at[jnp.arange(b)[:, None], slots].set(valid)
    return frontier._replace(expanded=frontier.expanded | upd)


def has_unexpanded(frontier: Frontier, top: int | None = None) -> jax.Array:
    """(B,) — does the (top-`top` of the) frontier hold unexpanded work?"""
    ids, dists, expanded = frontier
    if top is not None and top < ids.shape[-1]:
        ids, dists, expanded = ids[..., :top], dists[..., :top], expanded[..., :top]
    return jnp.any((~expanded) & (ids >= 0), axis=-1)


class ResultList(NamedTuple):
    """Top-K filter-passing candidates scored with *exact* distances."""

    ids: jax.Array  # (B, K)
    dists: jax.Array  # (B, K)


def make_results(batch: int, k: int) -> ResultList:
    return ResultList(
        ids=jnp.full((batch, k), INVALID), dists=jnp.full((batch, k), INF)
    )


def results_insert(res: ResultList, new_ids: jax.Array, new_dists: jax.Array) -> ResultList:
    k = res.ids.shape[-1]
    ids = jnp.concatenate([res.ids, new_ids], axis=-1)
    dists = jnp.concatenate([res.dists, new_dists], axis=-1)
    dists = jnp.where(_dedup_mask(ids) | (ids < 0), INF, dists)
    ids = jnp.where(dists >= INF, INVALID, ids)
    order = jnp.argsort(dists, axis=-1)[..., :k]
    return ResultList(
        ids=jnp.take_along_axis(ids, order, axis=-1),
        dists=jnp.take_along_axis(dists, order, axis=-1),
    )
