"""Product Quantization (PQ) — the in-memory approximate-distance substrate.

DiskANN/PipeANN/GateANN all keep PQ-compressed vectors in memory and use
asymmetric distance computation (ADC) to order graph traversal.  GateANN
additionally uses PQ distances to score tunneled neighbors (§3.3).

This module provides:
  * ``train_pq``   — k-means codebooks per chunk (Lloyd iterations in JAX).
  * ``encode_pq``  — nearest-centroid code assignment.
  * ``build_lut``  — per-query lookup tables for ADC.
  * ``adc_lookup`` — LUT-based approximate distances (delegates to the
                     Pallas kernel wrapper in ``repro.kernels.ops`` when
                     enabled, else the pure-jnp reference).

Shapes / conventions
  vectors : (N, D) float32
  codes   : (N, C) uint8/int32   C = n_chunks, D % C == 0
  books   : (C, K, D/C) float32  K = 256 centroids per chunk
  lut     : (B, C, K) float32    per-query chunk-centroid distances
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class PQCodec(NamedTuple):
    """Trained PQ codebooks."""

    books: jax.Array  # (C, K, Dc)
    n_chunks: int
    n_centroids: int

    @property
    def dim(self) -> int:
        return self.books.shape[0] * self.books.shape[2]


def _kmeans_one_chunk(sub: jax.Array, k: int, iters: int, key: jax.Array) -> jax.Array:
    """Lloyd's k-means for one PQ chunk. sub: (N, Dc) -> (k, Dc)."""
    n = sub.shape[0]
    init_idx = jax.random.choice(key, n, shape=(k,), replace=n < k)
    cents = sub[init_idx]

    def step(cents, _):
        # (N, k) squared distances via ||x||^2 - 2 x.c + ||c||^2
        d = (
            jnp.sum(sub * sub, axis=1, keepdims=True)
            - 2.0 * sub @ cents.T
            + jnp.sum(cents * cents, axis=1)[None, :]
        )
        assign = jnp.argmin(d, axis=1)
        one_hot = jax.nn.one_hot(assign, k, dtype=sub.dtype)  # (N, k)
        counts = one_hot.sum(axis=0)  # (k,)
        sums = one_hot.T @ sub  # (k, Dc)
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), cents)
        return new, None

    cents, _ = jax.lax.scan(step, cents, None, length=iters)
    return cents


@functools.partial(jax.jit, static_argnames=("n_chunks", "n_centroids", "iters"))
def train_pq(
    vectors: jax.Array,
    *,
    n_chunks: int = 32,
    n_centroids: int = 256,
    iters: int = 8,
    key: jax.Array | None = None,
) -> PQCodec:
    """Train per-chunk k-means codebooks on (a sample of) the corpus."""
    if key is None:
        key = jax.random.PRNGKey(0)
    n, d = vectors.shape
    assert d % n_chunks == 0, f"dim {d} not divisible by n_chunks {n_chunks}"
    dc = d // n_chunks
    subs = vectors.reshape(n, n_chunks, dc).transpose(1, 0, 2)  # (C, N, Dc)
    keys = jax.random.split(key, n_chunks)
    books = jax.vmap(lambda s, k: _kmeans_one_chunk(s, n_centroids, iters, k))(subs, keys)
    return PQCodec(books=books, n_chunks=n_chunks, n_centroids=n_centroids)


@jax.jit
def encode_pq(codec: PQCodec, vectors: jax.Array) -> jax.Array:
    """Assign each vector chunk to its nearest centroid. -> (N, C) int32."""
    n, d = vectors.shape
    c, k, dc = codec.books.shape
    subs = vectors.reshape(n, c, dc)

    def per_chunk(sub, book):  # sub (N, Dc), book (K, Dc)
        d2 = (
            jnp.sum(sub * sub, axis=1, keepdims=True)
            - 2.0 * sub @ book.T
            + jnp.sum(book * book, axis=1)[None, :]
        )
        return jnp.argmin(d2, axis=1).astype(jnp.int32)

    codes = jax.vmap(per_chunk, in_axes=(1, 0), out_axes=1)(subs, codec.books)
    return codes  # (N, C)


@jax.jit
def decode_pq(codec: PQCodec, codes: jax.Array) -> jax.Array:
    """Reconstruct approximate vectors from codes. -> (N, D)."""
    c, k, dc = codec.books.shape
    gathered = jax.vmap(lambda book, code: book[code], in_axes=(0, 1), out_axes=1)(
        codec.books, codes
    )  # (N, C, Dc)
    return gathered.reshape(codes.shape[0], c * dc)


@jax.jit
def build_lut(codec: PQCodec, queries: jax.Array) -> jax.Array:
    """Per-query ADC lookup table: lut[b, c, k] = ||q_bc - book_ck||^2.

    queries: (B, D) -> (B, C, K) float32
    """
    b, d = queries.shape
    c, k, dc = codec.books.shape
    q = queries.reshape(b, c, dc)

    def per_chunk(qc, book):  # (B, Dc), (K, Dc)
        return (
            jnp.sum(qc * qc, axis=1, keepdims=True)
            - 2.0 * qc @ book.T
            + jnp.sum(book * book, axis=1)[None, :]
        )

    return jax.vmap(per_chunk, in_axes=(1, 0), out_axes=1)(q, codec.books)  # (B, C, K)


def adc_lookup(lut: jax.Array, codes: jax.Array, *, use_kernel: bool = False) -> jax.Array:
    """Approximate distances dist[b, n] = sum_c lut[b, c, codes[n, c]].

    lut: (B, C, K), codes: (N, C) -> (B, N) float32.
    ``use_kernel=True`` routes through the Pallas ADC kernel.
    """
    if use_kernel:
        from repro.kernels import ops as kops

        return kops.pq_lookup(lut, codes)
    return adc_lookup_ref(lut, codes)


@jax.jit
def adc_lookup_ref(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """Pure-jnp ADC reference: one take_along_axis per chunk, summed."""
    # lut (B, C, K); codes (N, C). Gather along K for each (b, c, n).
    # -> per chunk: lut[:, c, :][:, codes[:, c]] summed over c.
    def per_chunk(acc, c):
        acc = acc + jnp.take(lut[:, c, :], codes[:, c], axis=1)  # (B, N)
        return acc, None

    b = lut.shape[0]
    n = codes.shape[0]
    acc = jnp.zeros((b, n), dtype=lut.dtype)
    acc, _ = jax.lax.scan(per_chunk, acc, jnp.arange(lut.shape[1]))
    return acc


def pq_memory_bytes(n: int, n_chunks: int = 32) -> int:
    """Paper Table 2: PQ vectors = N * 32 B at the default 32 chunks."""
    return n * n_chunks


def train_pq_numpy(vectors: np.ndarray, n_chunks: int = 32, n_centroids: int = 256,
                   iters: int = 8, seed: int = 0) -> PQCodec:
    """Convenience host-side wrapper (samples big corpora before training)."""
    rng = np.random.default_rng(seed)
    sample = vectors
    if vectors.shape[0] > 65536:
        idx = rng.choice(vectors.shape[0], 65536, replace=False)
        sample = vectors[idx]
    return train_pq(
        jnp.asarray(sample, dtype=jnp.float32),
        n_chunks=n_chunks,
        n_centroids=n_centroids,
        iters=iters,
        key=jax.random.PRNGKey(seed),
    )
