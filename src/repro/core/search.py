"""GateANN search loop (Algorithm 1) and the paper's baselines.

One batched, jittable loop implements all five search modes:

  * ``gate``      — GateANN: pre-I/O filter check; filter-passing nodes
                    follow the fetch path (record read + exact distance),
                    filter-failing nodes are *tunneled* in memory
                    (neighbor-store expansion + PQ scoring). §3.3.
  * ``post``      — DiskANN/PipeANN post-filtering: fetch every dispatched
                    node, apply the predicate afterwards. §2.2.
  * ``early``     — the Fig.18 ablation: fetch every node but skip exact
                    distance on non-matching ones (CPU saving, no I/O
                    saving); neighbors expanded normally.
  * ``pre_naive`` — naive pre-filtering: non-matching nodes are dropped
                    outright (no fetch, no expansion) — breaks
                    connectivity, Fig.1(b).
  * ``unfiltered``— plain beam search (selectivity 1.0).

When the record store carries a hot-node cache (``CachedRecordStore``),
``cached_mask`` splits each round's fetches into cache hits (device
gather, counted as ``n_cache_hits``) and slow-tier reads (counted as
``n_ios``) — results are bit-identical either way, only the I/O
accounting and cost change.

The frontier is ordered by PQ distance; results are always drawn from
filter-passing fetched nodes ranked by exact distance (§3.4).  DiskANN's
synchronous beam and PipeANN's asynchronous pipeline both map to the
W-wide dispatch: on TPU a round's W fetches execute as one batched
gather/collective — the hardware-native form of "W in-flight reads".

**Pipelined disk search** (``SearchConfig.pipeline_depth > 1`` with a
store exposing the async ``submit``/``drain`` pair, i.e. the disk tier):
traversal needs only neighbor lists and PQ distances, never the
full-precision record, so the per-round slow-tier read feeds nothing but
the exact-distance result pool.  Stage A expands/tunnels the frontier
from the neighbor lists ``submit`` returns immediately (the adjacency
sidecar) and dispatches round r+1's beam while round r's ``preadv`` is
still in flight; stage B retires completed fetches — up to
``pipeline_depth`` rounds behind — into the result heap, in FIFO round
order.  The result heap is write-only state (beam selection never reads
it), retirement preserves insertion order, and the drained vectors are
byte-identical to the synchronous read, so output is **bit-identical**
to the synchronous loop at every depth; ``pipeline_depth=1`` (the
default) *is* the synchronous loop.  Only wall-clock changes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import frontier as fr
from repro.core import pq as pqm
from repro.core.filter_store import CheckFn
from repro.core.neighbor_store import NeighborStore
from repro.store.cache import CachedMaskFn
from repro.store.vector_store import RecordFetchFn

MODES = ("gate", "post", "early", "pre_naive", "unfiltered")


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    mode: str = "gate"
    search_l: int = 64  # frontier size L
    result_k: int = 10  # top-K
    beam_width: int = 8  # W — dispatch width / pipeline depth
    max_hops: int = 512  # safety bound on rounds
    use_kernel: bool = False  # route PQ scoring through the Pallas kernel
    # software-pipeline depth: max rounds whose slow-tier reads stay in
    # flight before the oldest is retired into the result heap.  1 = the
    # synchronous loop; >1 needs a store with submit/drain (disk tier) and
    # is bit-identical at any depth — only wall-clock changes.
    pipeline_depth: int = 1

    def __post_init__(self):
        assert self.mode in MODES, self.mode
        assert self.pipeline_depth >= 1, self.pipeline_depth


class SearchStats(NamedTuple):
    n_ios: jax.Array  # (B,) records fetched from the slow (expensive) tier
    n_tunnels: jax.Array  # (B,) nodes traversed purely in memory
    n_exact: jax.Array  # (B,) exact distance computations
    n_hops: jax.Array  # (B,) dispatch rounds
    n_cache_hits: jax.Array  # (B,) record fetches served by the cache tier


class SearchOutput(NamedTuple):
    ids: jax.Array  # (B, K) result ids (filter-passing, exact-ranked)
    dists: jax.Array  # (B, K)
    stats: SearchStats
    # (N,) per-node fetch-path visit counts accumulated on top of the
    # caller-supplied ``visit_counts`` array; None when counting is off.
    visit_counts: jax.Array | None = None


def _adc_ids(lut: jax.Array, codes: jax.Array, ids: jax.Array, use_kernel: bool) -> jax.Array:
    """PQ distances for gathered ids. lut (B,C,K), codes (N,C), ids (B,M)."""
    got = codes[jnp.maximum(ids, 0)]  # (B, M, C)
    if use_kernel:
        from repro.kernels import ops as kops

        d = kops.pq_lookup_gathered(lut, got)
    else:
        # sum_c lut[b, c, got[b, m, c]]
        b, m, c = got.shape
        d = jnp.take_along_axis(
            lut.transpose(0, 2, 1),  # (B, K, C)
            got,  # (B, M, C) indexes K axis
            axis=1,
        ).sum(axis=-1)
    return jnp.where(ids >= 0, d, fr.INF)


def _exact_dist(queries: jax.Array, vecs: jax.Array, use_kernel: bool) -> jax.Array:
    """(B, D) queries vs (B, W, D) fetched rows -> (B, W) squared L2."""
    if use_kernel:
        from repro.kernels import ops as kops

        return kops.l2_dist(queries, vecs)
    diff = vecs - queries[:, None, :]
    return jnp.sum(diff * diff, axis=-1)


@functools.partial(jax.jit, static_argnames=("config",))
def filtered_search(
    *,
    fetch: RecordFetchFn,
    neighbor_store: NeighborStore,
    filter_check: CheckFn,
    lut: jax.Array,  # (B, C, K) per-query ADC tables
    codes: jax.Array,  # (N, C) PQ codes (the in-memory compressed tier)
    entry: jax.Array,  # () int32 medoid (or (B,) per-query entries)
    queries: jax.Array,  # (B, D) full-precision queries
    config: SearchConfig,
    cached_mask: CachedMaskFn | None = None,  # (B, W) ids -> cache-hit mask
    visit_counts: jax.Array | None = None,  # (N,) f32 running fetch counters
    submit=None,  # async pair: (B, W) ids -> (token, nbrs (B, W, R))
    drain=None,  # (token, ids, flag) -> vecs (B, W, D)
) -> SearchOutput:
    b, d = queries.shape
    n = codes.shape[0]
    L, W, K = config.search_l, config.beam_width, config.result_k
    mode = config.mode
    r_max = neighbor_store.r_max

    if entry.ndim == 0:
        entry = jnp.broadcast_to(entry, (b,))

    frontier = fr.make_frontier(b, L)
    entry_d = _adc_ids(lut, codes, entry[:, None], config.use_kernel)[:, 0]
    frontier = frontier._replace(
        ids=frontier.ids.at[:, 0].set(entry),
        dists=frontier.dists.at[:, 0].set(entry_d),
    )
    results = fr.make_results(b, K)

    nw = (n + 31) // 32
    visited = jnp.zeros((b, nw), dtype=jnp.uint32)

    def set_visited(vis, idx):
        word = jnp.clip(idx // 32, 0, nw - 1)
        bit = jnp.where(idx >= 0, jnp.uint32(1) << (idx % 32).astype(jnp.uint32), 0)
        upd = jnp.zeros_like(vis)

        def body(c, upd):
            return upd.at[jnp.arange(b), word[:, c]].set(
                upd[jnp.arange(b), word[:, c]] | bit[:, c]
            )

        upd = jax.lax.fori_loop(0, idx.shape[1], body, upd)
        return vis | upd

    def is_visited(vis, idx):
        word = jnp.clip(idx // 32, 0, nw - 1)
        bit = jnp.uint32(1) << (idx % 32).astype(jnp.uint32)
        return (jnp.take_along_axis(vis, word, axis=1) & bit) != 0

    visited = set_visited(visited, entry[:, None])

    stats0 = SearchStats(
        n_ios=jnp.zeros((b,), jnp.int32),
        n_tunnels=jnp.zeros((b,), jnp.int32),
        n_exact=jnp.zeros((b,), jnp.int32),
        n_hops=jnp.zeros((b,), jnp.int32),
        n_cache_hits=jnp.zeros((b,), jnp.int32),
    )
    # Optional online frequency counting for the adaptive cache: the (N,)
    # counter array is loop-carried device state — each round scatter-adds
    # the fetch-path dispatches (the population a record cache can serve).
    # ``None`` keeps the extra state out of the trace entirely.
    track_visits = visit_counts is not None
    vc0 = visit_counts if track_visits else jnp.zeros((0,), jnp.float32)

    def stage_a(frontier, visited, stats, vc):
        """One round of beam selection + masking + bookkeeping — everything
        except touching the record itself.  Shared verbatim by the
        synchronous and pipelined loops, so their traversal (and stats)
        cannot diverge."""
        sel_ids, slots, valid = fr.best_unexpanded(frontier, W)
        frontier = fr.mark_expanded(frontier, slots, valid)

        passes = filter_check(sel_ids) & valid  # in-memory predicate (filter store)

        if mode == "unfiltered":
            fetch_mask = valid
            tunnel_mask = jnp.zeros_like(valid)
            result_mask = valid
            exact_mask = valid
        elif mode == "post":
            fetch_mask = valid  # predicate applied only after the read
            tunnel_mask = jnp.zeros_like(valid)
            result_mask = passes
            exact_mask = valid  # exact distance computed for every fetch
        elif mode == "early":
            fetch_mask = valid  # still pays the full read ...
            tunnel_mask = jnp.zeros_like(valid)
            result_mask = passes
            exact_mask = passes  # ... but skips exact distance on misses
        elif mode == "pre_naive":
            # non-matching nodes dropped outright — except the entry point,
            # which any implementation must expand to start the search
            is_entry = sel_ids == entry[:, None]
            fetch_mask = passes | (is_entry & valid)
            tunnel_mask = jnp.zeros_like(valid)
            result_mask = passes
            exact_mask = fetch_mask
        else:  # gate
            fetch_mask = passes
            tunnel_mask = valid & (~passes)  # tunneled in memory
            result_mask = passes
            exact_mask = passes

        # ---- split fetches into cache hits and slow-tier reads
        if cached_mask is None:
            hit_mask = jnp.zeros_like(fetch_mask)
        else:
            hit_mask = cached_mask(sel_ids) & fetch_mask
        slow_mask = fetch_mask & (~hit_mask)

        if track_visits:
            vc = vc.at[jnp.maximum(sel_ids, 0).ravel()].add(
                jnp.where(fetch_mask, 1.0, 0.0).ravel()
            )

        fetch_ids = jnp.where(fetch_mask, sel_ids, fr.INVALID)
        stats = SearchStats(
            n_ios=stats.n_ios + jnp.sum(slow_mask, axis=1).astype(jnp.int32),
            n_tunnels=stats.n_tunnels + jnp.sum(tunnel_mask, axis=1).astype(jnp.int32),
            n_exact=stats.n_exact + jnp.sum(exact_mask, axis=1).astype(jnp.int32),
            n_hops=stats.n_hops + 1,
            n_cache_hits=stats.n_cache_hits + jnp.sum(hit_mask, axis=1).astype(jnp.int32),
        )
        return frontier, stats, vc, sel_ids, fetch_ids, tunnel_mask, result_mask

    def expand(frontier, visited, sel_ids, tunnel_mask, disk_nbrs):
        """Frontier growth from this round's neighbor lists (fetch path:
        full-R disk adjacency; tunnel path: the in-memory r_max slice)."""
        if mode == "gate":
            tun_ids = jnp.where(tunnel_mask, sel_ids, fr.INVALID)
            tun_nbrs = neighbor_store.lookup(tun_ids)  # (B, W, R_max)
        else:
            tun_nbrs = jnp.full((b, W, r_max), fr.INVALID)

        new = jnp.concatenate(
            [disk_nbrs.reshape(b, -1), tun_nbrs.reshape(b, -1)], axis=-1
        )
        fresh = (new >= 0) & (~is_visited(visited, jnp.maximum(new, 0)))
        new = jnp.where(fresh, new, fr.INVALID)
        visited = set_visited(visited, new)
        new_d = _adc_ids(lut, codes, new, config.use_kernel)  # PQ priority signal
        return fr.insert(frontier, new, new_d), visited

    def retire(results, sel_ids, result_mask, vecs, live):
        """Stage B: score one round's fetched records and push them into
        the result heap.  ``live=False`` turns it into a heap no-op (all
        ids INVALID / dists INF) for pipeline warmup/flush padding."""
        exact_d = _exact_dist(queries, vecs, config.use_kernel)
        ok = result_mask & live
        exact_d = jnp.where(ok, exact_d, fr.INF)
        return fr.results_insert(
            results, jnp.where(ok, sel_ids, fr.INVALID), exact_d
        )

    def cond(state):
        frontier, _, _, stats = state[0], state[1], state[2], state[3]
        return jnp.any(fr.has_unexpanded(frontier)) & jnp.all(stats.n_hops < config.max_hops)

    pipelined = config.pipeline_depth > 1 and submit is not None and drain is not None

    if not pipelined:
        # ---- synchronous loop: fetch blocks, this round retires itself
        state0 = (frontier, results, visited, stats0, vc0)

        def body(state):
            frontier, results, visited, stats, vc = state
            frontier, stats, vc, sel_ids, fetch_ids, tunnel_mask, result_mask = (
                stage_a(frontier, visited, stats, vc)
            )
            vecs, disk_nbrs = fetch(fetch_ids)  # (B, W, D), (B, W, R)
            results = retire(results, sel_ids, result_mask, vecs,
                             jnp.bool_(True))
            frontier, visited = expand(
                frontier, visited, sel_ids, tunnel_mask, disk_nbrs
            )
            return frontier, results, visited, stats, vc

        frontier, results, visited, stats, vc = jax.lax.while_loop(
            cond, body, state0
        )
        return SearchOutput(
            ids=results.ids,
            dists=results.dists,
            stats=stats,
            visit_counts=vc if track_visits else None,
        )

    # ---- two-stage software pipeline: up to `depth` rounds of slow-tier
    # reads stay in flight; stage A keeps traversing off the submit-time
    # neighbor lists, stage B retires the oldest round into the result
    # heap.  FIFO retirement == the synchronous insertion order, and the
    # heap is write-only state, so output is bit-identical at any depth.
    depth = config.pipeline_depth
    pend_ids0 = jnp.full((depth, b, W), fr.INVALID)  # sel_ids per round
    pend_fids0 = jnp.full((depth, b, W), fr.INVALID)  # fetch_ids per round
    pend_rm0 = jnp.zeros((depth, b, W), dtype=bool)  # result_mask per round
    pend_tok0 = jnp.full((depth,), -1, jnp.int32)
    state0 = (frontier, results, visited, stats0, vc0,
              pend_ids0, pend_fids0, pend_rm0, pend_tok0)

    def pbody(state):
        (frontier, results, visited, stats, vc,
         p_ids, p_fids, p_rm, p_tok) = state
        r = stats.n_hops[0]  # this round's index (all rows hop together)
        frontier, stats, vc, sel_ids, fetch_ids, tunnel_mask, result_mask = (
            stage_a(frontier, visited, stats, vc)
        )
        # stage A: dispatch this round's read; neighbors come back now
        token, disk_nbrs = submit(fetch_ids)
        frontier, visited = expand(
            frontier, visited, sel_ids, tunnel_mask, disk_nbrs
        )
        wp = jnp.mod(r, depth)
        p_ids = p_ids.at[wp].set(sel_ids)
        p_fids = p_fids.at[wp].set(fetch_ids)
        p_rm = p_rm.at[wp].set(result_mask)
        p_tok = p_tok.at[wp].set(token)
        # stage B: once the pipe is full, retire the oldest round (the
        # drain is issued every round; `live` gates the warmup no-ops so
        # the host interleaving stays fixed and deterministic)
        live = r >= depth - 1
        dp = jnp.mod(r - (depth - 1), depth)
        vecs = drain(p_tok[dp], p_fids[dp], live)
        results = retire(results, p_ids[dp], p_rm[dp], vecs, live)
        return (frontier, results, visited, stats, vc,
                p_ids, p_fids, p_rm, p_tok)

    (frontier, results, visited, stats, vc,
     p_ids, p_fids, p_rm, p_tok) = jax.lax.while_loop(cond, pbody, state0)

    # flush: retire the (up to depth-1) rounds still in flight, oldest
    # first — same FIFO order, same heap insertions as the sync loop
    n_hops = stats.n_hops[0]
    for j in range(depth - 1):
        rr = n_hops - (depth - 1) + j  # round to retire
        live = rr >= 0
        dp = jnp.mod(rr, depth)
        vecs = drain(p_tok[dp], p_fids[dp], live)
        results = retire(results, p_ids[dp], p_rm[dp], vecs, live)

    return SearchOutput(
        ids=results.ids,
        dists=results.dists,
        stats=stats,
        visit_counts=vc if track_visits else None,
    )
