"""GateANN search loop (Algorithm 1) and the paper's baselines.

One batched, jittable loop implements all five search modes:

  * ``gate``      — GateANN: pre-I/O filter check; filter-passing nodes
                    follow the fetch path (record read + exact distance),
                    filter-failing nodes are *tunneled* in memory
                    (neighbor-store expansion + PQ scoring). §3.3.
  * ``post``      — DiskANN/PipeANN post-filtering: fetch every dispatched
                    node, apply the predicate afterwards. §2.2.
  * ``early``     — the Fig.18 ablation: fetch every node but skip exact
                    distance on non-matching ones (CPU saving, no I/O
                    saving); neighbors expanded normally.
  * ``pre_naive`` — naive pre-filtering: non-matching nodes are dropped
                    outright (no fetch, no expansion) — breaks
                    connectivity, Fig.1(b).
  * ``unfiltered``— plain beam search (selectivity 1.0).

When the record store carries a hot-node cache (``CachedRecordStore``),
``cached_mask`` splits each round's fetches into cache hits (device
gather, counted as ``n_cache_hits``) and slow-tier reads (counted as
``n_ios``) — results are bit-identical either way, only the I/O
accounting and cost change.

The frontier is ordered by PQ distance; results are always drawn from
filter-passing fetched nodes ranked by exact distance (§3.4).  DiskANN's
synchronous beam and PipeANN's asynchronous pipeline both map to the
W-wide dispatch: on TPU a round's W fetches execute as one batched
gather/collective — the hardware-native form of "W in-flight reads".

**Pipelined disk search** (``SearchConfig.pipeline_depth > 1`` with a
store exposing the async ``submit``/``drain`` pair, i.e. the disk tier):
traversal needs only neighbor lists and PQ distances, never the
full-precision record, so the per-round slow-tier read feeds nothing but
the exact-distance result pool.  Stage A expands/tunnels the frontier
from the neighbor lists ``submit`` returns immediately (the adjacency
sidecar) and dispatches round r+1's beam while round r's ``preadv`` is
still in flight; stage B retires completed fetches — up to
``pipeline_depth`` rounds behind — into the result heap, in FIFO round
order.  The result heap is write-only state (beam selection never reads
it), retirement preserves insertion order, and the drained vectors are
byte-identical to the synchronous read, so output is **bit-identical**
to the synchronous loop at every depth; ``pipeline_depth=1`` (the
default) *is* the synchronous loop.  Only wall-clock changes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import frontier as fr
from repro.core import pq as pqm
from repro.core.filter_store import CheckFn
from repro.core.neighbor_store import NeighborStore
from repro.kernels import fused_traversal as ftk
from repro.store.cache import CachedMaskFn
from repro.store.vector_store import RecordFetchFn

MODES = ("gate", "post", "early", "pre_naive", "unfiltered")


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    mode: str = "gate"
    search_l: int = 64  # frontier size L
    result_k: int = 10  # top-K
    beam_width: int = 8  # W — dispatch width / pipeline depth
    max_hops: int = 512  # safety bound on rounds
    use_kernel: bool = False  # route PQ scoring through the Pallas kernel
    # software-pipeline depth: max rounds whose slow-tier reads stay in
    # flight before the oldest is retired into the result heap.  1 = the
    # synchronous loop; >1 needs a store with submit/drain (disk tier) and
    # is bit-identical at any depth — only wall-clock changes.
    pipeline_depth: int = 1
    # run stage A (ADC + masks + beam select + frontier merge) as ONE
    # fused Pallas pass per round (kernels.fused_traversal) instead of
    # separate ops with HBM round-trips between them.  Bit-identical to
    # the unfused loop at any mode/tier/depth; silently falls back when
    # the shapes or backend don't support the kernel.
    use_fused_kernel: bool = False

    def __post_init__(self):
        assert self.mode in MODES, self.mode
        assert self.pipeline_depth >= 1, self.pipeline_depth


class SearchStats(NamedTuple):
    n_ios: jax.Array  # (B,) records fetched from the slow (expensive) tier
    n_tunnels: jax.Array  # (B,) nodes traversed purely in memory
    n_exact: jax.Array  # (B,) exact distance computations
    n_hops: jax.Array  # (B,) dispatch rounds
    n_cache_hits: jax.Array  # (B,) record fetches served by the cache tier
    # (B,) result-candidate slots whose slow-tier read failed and was
    # served degraded (tunnel sentinel — see DiskRecordStore resilience):
    # traversal kept the node, the exact-ranked results dropped it.
    # Always zero unless the store runs with on_error="degrade" AND a
    # read actually failed.
    n_degraded: jax.Array


class SearchOutput(NamedTuple):
    ids: jax.Array  # (B, K) result ids (filter-passing, exact-ranked)
    dists: jax.Array  # (B, K)
    stats: SearchStats
    # (N,) per-node fetch-path visit counts accumulated on top of the
    # caller-supplied ``visit_counts`` array; None when counting is off.
    visit_counts: jax.Array | None = None


def _adc_ids(lut: jax.Array, codes: jax.Array, ids: jax.Array, use_kernel: bool) -> jax.Array:
    """PQ distances for gathered ids. lut (B,C,K), codes (N,C), ids (B,M)."""
    got = codes[jnp.maximum(ids, 0)]  # (B, M, C)
    if use_kernel:
        from repro.kernels import ops as kops

        d = kops.pq_lookup_gathered(lut, got)
    else:
        # sum_c lut[b, c, got[b, m, c]]
        b, m, c = got.shape
        d = jnp.take_along_axis(
            lut.transpose(0, 2, 1),  # (B, K, C)
            got,  # (B, M, C) indexes K axis
            axis=1,
        ).sum(axis=-1)
    # fence the reduction (same reason as _exact_dist): these distances
    # order the frontier, so an ULP of context-dependent fusion drift
    # would change traversal between the unfused and fused-kernel loops
    d = jax.lax.optimization_barrier(d)
    return jnp.where(ids >= 0, d, fr.INF)


def _exact_dist(queries: jax.Array, vecs: jax.Array, use_kernel: bool) -> jax.Array:
    """(B, D) queries vs (B, W, D) fetched rows -> (B, W) squared L2.

    Fenced with optimization barriers: the sum reduction must produce the
    same bits regardless of what XLA fuses around the call site, or the
    sync / pipelined / fused-kernel loops (different graphs, same math)
    could drift by an ULP in their exact result distances.
    """
    queries, vecs = jax.lax.optimization_barrier((queries, vecs))
    if use_kernel:
        from repro.kernels import ops as kops

        return jax.lax.optimization_barrier(kops.l2_dist(queries, vecs))
    diff = vecs - queries[:, None, :]
    sq = diff * diff
    # Fixed-association pairwise tree instead of jnp.sum: XLA's reduce
    # accumulation order is implementation-defined and can differ between
    # otherwise-identical modules (the barrier fences fusion, not reduce
    # codegen), which showed up as 1-ULP drift between the unfused and
    # fused-kernel search loops.  Explicit adds are IEEE-strict.
    while sq.shape[-1] > 1:
        half = sq.shape[-1] // 2 * 2
        head = sq[..., 0:half:2] + sq[..., 1:half:2]
        if half != sq.shape[-1]:
            head = jnp.concatenate([head, sq[..., half:]], axis=-1)
        sq = head
    return jax.lax.optimization_barrier(sq[..., 0])


@functools.partial(jax.jit, static_argnames=("config",))
def filtered_search(
    *,
    fetch: RecordFetchFn,
    neighbor_store: NeighborStore,
    filter_check: CheckFn,
    lut: jax.Array,  # (B, C, K) per-query ADC tables
    codes: jax.Array,  # (N, C) PQ codes (the in-memory compressed tier)
    entry: jax.Array,  # () int32 medoid (or (B,) per-query entries)
    queries: jax.Array,  # (B, D) full-precision queries
    config: SearchConfig,
    cached_mask: CachedMaskFn | None = None,  # (B, W) ids -> cache-hit mask
    visit_counts: jax.Array | None = None,  # (N,) f32 running fetch counters
    submit=None,  # async pair: (B, W) ids -> (token, nbrs (B, W, R))
    drain=None,  # (token, ids, flag) -> vecs (B, W, D)
) -> SearchOutput:
    b, d = queries.shape
    n = codes.shape[0]
    L, W, K = config.search_l, config.beam_width, config.result_k
    mode = config.mode
    r_max = neighbor_store.r_max

    if entry.ndim == 0:
        entry = jnp.broadcast_to(entry, (b,))

    frontier = fr.make_frontier(b, L)
    entry_d = _adc_ids(lut, codes, entry[:, None], config.use_kernel)[:, 0]
    frontier = frontier._replace(
        ids=frontier.ids.at[:, 0].set(entry),
        dists=frontier.dists.at[:, 0].set(entry_d),
    )
    results = fr.make_results(b, K)

    nw = (n + 31) // 32
    visited = jnp.zeros((b, nw), dtype=jnp.uint32)

    def set_visited(vis, idx):
        word = jnp.clip(idx // 32, 0, nw - 1)
        bit = jnp.where(idx >= 0, jnp.uint32(1) << (idx % 32).astype(jnp.uint32), 0)
        upd = jnp.zeros_like(vis)

        def body(c, upd):
            return upd.at[jnp.arange(b), word[:, c]].set(
                upd[jnp.arange(b), word[:, c]] | bit[:, c]
            )

        upd = jax.lax.fori_loop(0, idx.shape[1], body, upd)
        return vis | upd

    def is_visited(vis, idx):
        word = jnp.clip(idx // 32, 0, nw - 1)
        bit = jnp.uint32(1) << (idx % 32).astype(jnp.uint32)
        return (jnp.take_along_axis(vis, word, axis=1) & bit) != 0

    visited = set_visited(visited, entry[:, None])

    stats0 = SearchStats(
        n_ios=jnp.zeros((b,), jnp.int32),
        n_tunnels=jnp.zeros((b,), jnp.int32),
        n_exact=jnp.zeros((b,), jnp.int32),
        n_hops=jnp.zeros((b,), jnp.int32),
        n_cache_hits=jnp.zeros((b,), jnp.int32),
        n_degraded=jnp.zeros((b,), jnp.int32),
    )
    # Optional online frequency counting for the adaptive cache: the (N,)
    # counter array is loop-carried device state — each round scatter-adds
    # the fetch-path dispatches (the population a record cache can serve).
    # ``None`` keeps the extra state out of the trace entirely.
    track_visits = visit_counts is not None
    vc0 = visit_counts if track_visits else jnp.zeros((0,), jnp.float32)

    def stage_a(frontier, visited, stats, vc):
        """One round of beam selection + masking + bookkeeping — everything
        except touching the record itself.  Shared verbatim by the
        synchronous and pipelined loops, so their traversal (and stats)
        cannot diverge."""
        sel_ids, slots, valid = fr.best_unexpanded(frontier, W)
        frontier = fr.mark_expanded(frontier, slots, valid)

        passes = filter_check(sel_ids) & valid  # in-memory predicate (filter store)

        # per-mode dispatch masks — shared with the fused kernel body and
        # its reference twin, so the three paths cannot drift
        fetch_mask, tunnel_mask, result_mask, exact_mask = ftk.mode_masks(
            mode, sel_ids, valid, passes, entry[:, None]
        )

        # ---- split fetches into cache hits and slow-tier reads
        if cached_mask is None:
            hit_mask = jnp.zeros_like(fetch_mask)
        else:
            hit_mask = cached_mask(sel_ids) & fetch_mask
        slow_mask = fetch_mask & (~hit_mask)

        if track_visits:
            vc = vc.at[jnp.maximum(sel_ids, 0).ravel()].add(
                jnp.where(fetch_mask, 1.0, 0.0).ravel()
            )

        fetch_ids = jnp.where(fetch_mask, sel_ids, fr.INVALID)
        stats = SearchStats(
            n_ios=stats.n_ios + jnp.sum(slow_mask, axis=1).astype(jnp.int32),
            n_tunnels=stats.n_tunnels + jnp.sum(tunnel_mask, axis=1).astype(jnp.int32),
            n_exact=stats.n_exact + jnp.sum(exact_mask, axis=1).astype(jnp.int32),
            n_hops=stats.n_hops + 1,
            n_cache_hits=stats.n_cache_hits + jnp.sum(hit_mask, axis=1).astype(jnp.int32),
            n_degraded=stats.n_degraded,  # advanced by retire, not stage A
        )
        return frontier, stats, vc, sel_ids, fetch_ids, tunnel_mask, result_mask

    def expand(frontier, visited, sel_ids, tunnel_mask, disk_nbrs):
        """Frontier growth from this round's neighbor lists (fetch path:
        full-R disk adjacency; tunnel path: the in-memory r_max slice)."""
        if mode == "gate":
            tun_ids = jnp.where(tunnel_mask, sel_ids, fr.INVALID)
            tun_nbrs = neighbor_store.lookup(tun_ids)  # (B, W, R_max)
        else:
            tun_nbrs = jnp.full((b, W, r_max), fr.INVALID)

        new = jnp.concatenate(
            [disk_nbrs.reshape(b, -1), tun_nbrs.reshape(b, -1)], axis=-1
        )
        fresh = (new >= 0) & (~is_visited(visited, jnp.maximum(new, 0)))
        new = jnp.where(fresh, new, fr.INVALID)
        visited = set_visited(visited, new)
        new_d = _adc_ids(lut, codes, new, config.use_kernel)  # PQ priority signal
        return fr.insert(frontier, new, new_d), visited

    def retire(results, stats, sel_ids, result_mask, vecs, live):
        """Stage B: score one round's fetched records and push them into
        the result heap.  ``live=False`` turns it into a heap no-op (all
        ids INVALID / dists INF) for pipeline warmup/flush padding.

        A slot whose slow-tier read failed under ``on_error="degrade"``
        arrives with the +inf sentinel vector: it keeps its traversal
        role (neighbors were already served from the adjacency sidecar)
        but its exact-distance contribution is dropped — the INF
        distance maps the slot to INVALID in ``results_insert`` — and
        the loss is counted in ``stats.n_degraded``.  Real corpus
        vectors are finite, so with zero injected faults the sentinel
        never appears and this is bit-identical to the pre-resilience
        loop."""
        exact_d = _exact_dist(queries, vecs, config.use_kernel)
        deg = jnp.any(jnp.isinf(vecs), axis=-1) & result_mask & live
        ok = result_mask & live & ~deg
        exact_d = jnp.where(ok, exact_d, fr.INF)
        results = fr.results_insert(
            results, jnp.where(ok, sel_ids, fr.INVALID), exact_d
        )
        stats = stats._replace(
            n_degraded=stats.n_degraded + jnp.sum(deg, axis=1).astype(jnp.int32)
        )
        return results, stats

    def cond(state):
        frontier, _, _, stats = state[0], state[1], state[2], state[3]
        return jnp.any(fr.has_unexpanded(frontier)) & jnp.all(stats.n_hops < config.max_hops)

    pipelined = config.pipeline_depth > 1 and submit is not None and drain is not None

    # ---- fused stage-A routing: one Pallas pass per round replaces the
    # best_unexpanded / filter / mode-mask / insert op chain.  The round
    # is rotated — each kernel call merges the previous round's candidates
    # AND selects the next beam — so the loop carries the kernel's output
    # (a FusedRound) instead of a bare frontier.  Results are bit-identical
    # (the kernel replicates the stable-sort semantics of frontier.insert /
    # best_unexpanded exactly); fall back silently when the adjacency
    # width can't be probed or the shapes/backend are unsupported.
    use_fused = config.use_fused_kernel
    if use_fused:
        try:
            probe = (lambda i: submit(i)[1]) if pipelined else (lambda i: fetch(i)[1])
            nbrs_s = jax.eval_shape(probe, jax.ShapeDtypeStruct((b, W), jnp.int32))
            m_new = W * (int(nbrs_s.shape[-1]) + r_max)
            use_fused = ftk.fused_supported(
                l=L, width=W, m=m_new, c=codes.shape[1], k=lut.shape[2]
            )
        except Exception:
            use_fused = False

    # Trace-time dispatch accounting: this Python body runs once per jit
    # trace (shape/config change), not per call, so this counts *traces*
    # — which loop variant actually compiled — not query batches.
    # Per-call volume lives in the engine layer (``search.dispatch``).
    obs.default_registry().counter(
        "search.traces",
        mode=mode,
        fused="1" if use_fused else "0",
        pipelined="1" if pipelined else "0",
    ).inc()

    if use_fused:  # gatelint: disable=trace-host-branch — trace-static: r_max is pytree aux (a Python int) and fused_supported returns a host bool
        # Pallas kernel on TPU/GPU, its bit-identical jnp twin on CPU —
        # see fused_round_for_backend for why interpret mode stays out of
        # the serving loop
        round_fn = ftk.fused_round_for_backend()

        def fused_call(fids, fds, fexp, fpass, new_ids, new_codes, new_passes):
            return round_fn(
                fids, fds, fexp, fpass, new_ids, new_codes, new_passes,
                lut, entry, mode=mode, width=W,
            )

        def fused_account(rnd, stats, vc):
            """The non-kernel half of stage A: cache-tier split, visit
            counters, stats — same arithmetic as the unfused stage_a."""
            if cached_mask is None:
                hit_mask = jnp.zeros_like(rnd.fetch_mask)
            else:
                hit_mask = cached_mask(rnd.sel_ids) & rnd.fetch_mask
            slow_mask = rnd.fetch_mask & (~hit_mask)
            if track_visits:
                vc = vc.at[jnp.maximum(rnd.sel_ids, 0).ravel()].add(
                    jnp.where(rnd.fetch_mask, 1.0, 0.0).ravel()
                )
            stats = SearchStats(
                n_ios=stats.n_ios + jnp.sum(slow_mask, axis=1).astype(jnp.int32),
                n_tunnels=stats.n_tunnels
                + jnp.sum(rnd.tunnel_mask, axis=1).astype(jnp.int32),
                n_exact=stats.n_exact
                + jnp.sum(rnd.exact_mask, axis=1).astype(jnp.int32),
                n_hops=stats.n_hops + 1,
                n_cache_hits=stats.n_cache_hits
                + jnp.sum(hit_mask, axis=1).astype(jnp.int32),
                n_degraded=stats.n_degraded,  # advanced by retire
            )
            return stats, vc

        def fused_new(sel_ids, tunnel_mask, visited, disk_nbrs):
            """This round's candidate batch for the next kernel call —
            identical to the head of the unfused ``expand``, plus the code
            gather and filter verdicts the kernel consumes as payload."""
            if mode == "gate":
                tun_ids = jnp.where(tunnel_mask, sel_ids, fr.INVALID)
                tun_nbrs = neighbor_store.lookup(tun_ids)  # (B, W, R_max)
            else:
                tun_nbrs = jnp.full((b, W, r_max), fr.INVALID)
            new = jnp.concatenate(
                [disk_nbrs.reshape(b, -1), tun_nbrs.reshape(b, -1)], axis=-1
            )
            fresh = (new >= 0) & (~is_visited(visited, jnp.maximum(new, 0)))
            new = jnp.where(fresh, new, fr.INVALID)
            visited = set_visited(visited, new)
            new_codes = codes[jnp.maximum(new, 0)]
            new_passes = filter_check(new)
            return new, new_codes, new_passes, visited

        def fused_cond(state):
            rnd, stats = state[0], state[3]
            return jnp.any(rnd.valid) & jnp.all(stats.n_hops < config.max_hops)

        # pre-loop call (M=0): select round 0's beam from the entry-seeded
        # frontier.  any(valid) ≡ has_unexpanded, so the loop condition is
        # unchanged in substance.
        rnd0 = fused_call(
            frontier.ids, frontier.dists, frontier.expanded,
            filter_check(frontier.ids),
            jnp.zeros((b, 0), jnp.int32),
            jnp.zeros((b, 0, codes.shape[1]), jnp.int32),
            jnp.zeros((b, 0), bool),
        )

        if not pipelined:
            def fused_body(state):
                rnd, results, visited, stats, vc = state
                stats, vc = fused_account(rnd, stats, vc)
                vecs, disk_nbrs = fetch(rnd.fetch_ids)
                results, stats = retire(
                    results, stats, rnd.sel_ids, rnd.result_mask, vecs,
                    jnp.bool_(True),
                )
                new, new_codes, new_passes, visited = fused_new(
                    rnd.sel_ids, rnd.tunnel_mask, visited, disk_nbrs
                )
                rnd = fused_call(
                    rnd.frontier_ids, rnd.frontier_dists, rnd.frontier_expanded,
                    rnd.frontier_passes, new, new_codes, new_passes,
                )
                return rnd, results, visited, stats, vc

            rnd, results, visited, stats, vc = jax.lax.while_loop(
                fused_cond, fused_body, (rnd0, results, visited, stats0, vc0)
            )
            return SearchOutput(
                ids=results.ids,
                dists=results.dists,
                stats=stats,
                visit_counts=vc if track_visits else None,
            )

        # fused pipelined loop: same submit/drain rings and FIFO retirement
        # as the unfused pipeline below — the kernel call sits between this
        # round's submit and the oldest round's drain, preserving the host
        # callback order exactly.
        depth = config.pipeline_depth
        p_ids0 = jnp.full((depth, b, W), fr.INVALID)
        p_fids0 = jnp.full((depth, b, W), fr.INVALID)
        p_rm0 = jnp.zeros((depth, b, W), dtype=bool)
        p_tok0 = jnp.full((depth,), -1, jnp.int32)

        def fused_pbody(state):
            (rnd, results, visited, stats, vc,
             p_ids, p_fids, p_rm, p_tok) = state
            r = stats.n_hops[0]
            stats, vc = fused_account(rnd, stats, vc)
            token, disk_nbrs = submit(rnd.fetch_ids)
            new, new_codes, new_passes, visited = fused_new(
                rnd.sel_ids, rnd.tunnel_mask, visited, disk_nbrs
            )
            nrnd = fused_call(
                rnd.frontier_ids, rnd.frontier_dists, rnd.frontier_expanded,
                rnd.frontier_passes, new, new_codes, new_passes,
            )
            wp = jnp.mod(r, depth)
            p_ids = p_ids.at[wp].set(rnd.sel_ids)
            p_fids = p_fids.at[wp].set(rnd.fetch_ids)
            p_rm = p_rm.at[wp].set(rnd.result_mask)
            p_tok = p_tok.at[wp].set(token)
            live = r >= depth - 1
            dp = jnp.mod(r - (depth - 1), depth)
            vecs = drain(p_tok[dp], p_fids[dp], live)
            results, stats = retire(results, stats, p_ids[dp], p_rm[dp],
                                    vecs, live)
            return (nrnd, results, visited, stats, vc,
                    p_ids, p_fids, p_rm, p_tok)

        (rnd, results, visited, stats, vc,
         p_ids, p_fids, p_rm, p_tok) = jax.lax.while_loop(
            fused_cond, fused_pbody,
            (rnd0, results, visited, stats0, vc0,
             p_ids0, p_fids0, p_rm0, p_tok0),
        )
        n_hops = stats.n_hops[0]
        for j in range(depth - 1):
            rr = n_hops - (depth - 1) + j
            live = rr >= 0
            dp = jnp.mod(rr, depth)
            vecs = drain(p_tok[dp], p_fids[dp], live)
            results, stats = retire(results, stats, p_ids[dp], p_rm[dp],
                                    vecs, live)
        return SearchOutput(
            ids=results.ids,
            dists=results.dists,
            stats=stats,
            visit_counts=vc if track_visits else None,
        )

    if not pipelined:
        # ---- synchronous loop: fetch blocks, this round retires itself
        state0 = (frontier, results, visited, stats0, vc0)

        def body(state):
            frontier, results, visited, stats, vc = state
            frontier, stats, vc, sel_ids, fetch_ids, tunnel_mask, result_mask = (
                stage_a(frontier, visited, stats, vc)
            )
            vecs, disk_nbrs = fetch(fetch_ids)  # (B, W, D), (B, W, R)
            results, stats = retire(results, stats, sel_ids, result_mask,
                                    vecs, jnp.bool_(True))
            frontier, visited = expand(
                frontier, visited, sel_ids, tunnel_mask, disk_nbrs
            )
            return frontier, results, visited, stats, vc

        frontier, results, visited, stats, vc = jax.lax.while_loop(
            cond, body, state0
        )
        return SearchOutput(
            ids=results.ids,
            dists=results.dists,
            stats=stats,
            visit_counts=vc if track_visits else None,
        )

    # ---- two-stage software pipeline: up to `depth` rounds of slow-tier
    # reads stay in flight; stage A keeps traversing off the submit-time
    # neighbor lists, stage B retires the oldest round into the result
    # heap.  FIFO retirement == the synchronous insertion order, and the
    # heap is write-only state, so output is bit-identical at any depth.
    depth = config.pipeline_depth
    pend_ids0 = jnp.full((depth, b, W), fr.INVALID)  # sel_ids per round
    pend_fids0 = jnp.full((depth, b, W), fr.INVALID)  # fetch_ids per round
    pend_rm0 = jnp.zeros((depth, b, W), dtype=bool)  # result_mask per round
    pend_tok0 = jnp.full((depth,), -1, jnp.int32)
    state0 = (frontier, results, visited, stats0, vc0,
              pend_ids0, pend_fids0, pend_rm0, pend_tok0)

    def pbody(state):
        (frontier, results, visited, stats, vc,
         p_ids, p_fids, p_rm, p_tok) = state
        r = stats.n_hops[0]  # this round's index (all rows hop together)
        frontier, stats, vc, sel_ids, fetch_ids, tunnel_mask, result_mask = (
            stage_a(frontier, visited, stats, vc)
        )
        # stage A: dispatch this round's read; neighbors come back now
        token, disk_nbrs = submit(fetch_ids)
        frontier, visited = expand(
            frontier, visited, sel_ids, tunnel_mask, disk_nbrs
        )
        wp = jnp.mod(r, depth)
        p_ids = p_ids.at[wp].set(sel_ids)
        p_fids = p_fids.at[wp].set(fetch_ids)
        p_rm = p_rm.at[wp].set(result_mask)
        p_tok = p_tok.at[wp].set(token)
        # stage B: once the pipe is full, retire the oldest round (the
        # drain is issued every round; `live` gates the warmup no-ops so
        # the host interleaving stays fixed and deterministic)
        live = r >= depth - 1
        dp = jnp.mod(r - (depth - 1), depth)
        vecs = drain(p_tok[dp], p_fids[dp], live)
        results, stats = retire(results, stats, p_ids[dp], p_rm[dp],
                                vecs, live)
        return (frontier, results, visited, stats, vc,
                p_ids, p_fids, p_rm, p_tok)

    (frontier, results, visited, stats, vc,
     p_ids, p_fids, p_rm, p_tok) = jax.lax.while_loop(cond, pbody, state0)

    # flush: retire the (up to depth-1) rounds still in flight, oldest
    # first — same FIFO order, same heap insertions as the sync loop
    n_hops = stats.n_hops[0]
    for j in range(depth - 1):
        rr = n_hops - (depth - 1) + j  # round to retire
        live = rr >= 0
        dp = jnp.mod(rr, depth)
        vecs = drain(p_tok[dp], p_fids[dp], live)
        results, stats = retire(results, stats, p_ids[dp], p_rm[dp],
                                vecs, live)

    return SearchOutput(
        ids=results.ids,
        dists=results.dists,
        stats=stats,
        visit_counts=vc if track_visits else None,
    )
