"""Filter store — O(1) in-memory predicate evaluation by node id (§3.2).

The store is deliberately decoupled from the graph index: it is built from
a separate metadata array and can be swapped without touching the graph.
Supported predicate families (paper §3.2 "equality, range, multi-label
subset, or conjunctions thereof"):

  * ``EqualityFilter``   — single categorical label per node.
  * ``RangeFilter``      — continuous attribute per node, per-query [lo, hi].
  * ``SubsetFilter``     — multi-label bitset per node; query passes when
                           its tag set is a subset of the node's tags
                           (the YFCC-10M semantics, §5.2.5).
  * ``AndFilter``        — conjunction of the above.

``bind`` returns a ``jax.tree_util.Partial`` — a pytree whose function
identity is a stable module-level callable and whose bound metadata /
per-query parameters are traced leaves.  The search loop therefore
evaluates predicates on whole dispatch beams with zero host round-trips
and *without retracing* across query batches.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.tree_util import Partial

# A CheckFn maps (B, K) int32 node ids -> (B, K) bool matches.
CheckFn = Partial


def _eq_check(labels, targets, ids):
    lab = labels[jnp.maximum(ids, 0)]
    return (lab == targets[:, None]) & (ids >= 0)


def _range_check(values, lo, hi, ids):
    v = values[jnp.maximum(ids, 0)]
    return (v >= lo[:, None]) & (v <= hi[:, None]) & (ids >= 0)


def _subset_check(tag_bits, query_bits, ids):
    node = tag_bits[jnp.maximum(ids, 0)]  # (B, K, W)
    q = query_bits[:, None, :]
    return jnp.all((node & q) == q, axis=-1) & (ids >= 0)


def _and_check(fns, ids):
    out = fns[0](ids)
    for f in fns[1:]:
        out = out & f(ids)
    return out


def _all_check(ids):
    return ids >= 0


@dataclasses.dataclass(frozen=True)
class EqualityFilter:
    """Single fixed-width label per node (1 B/node in the paper's Table 2)."""

    labels: jax.Array  # (N,) int32

    def bind(self, target_labels) -> CheckFn:
        t = jnp.asarray(target_labels, dtype=jnp.int32)
        return Partial(_eq_check, self.labels, t)

    def memory_bytes(self) -> int:
        return int(self.labels.shape[0])  # 1 B/node logical

    def selectivity(self, target_label: int) -> float:
        return float(jnp.mean(self.labels == target_label))


@dataclasses.dataclass(frozen=True)
class RangeFilter:
    """Continuous attribute; per-query closed interval [lo, hi]."""

    values: jax.Array  # (N,) float32

    def bind(self, lo, hi=None) -> CheckFn:
        if hi is None:
            lo, hi = lo  # allow bind((lo, hi))
        return Partial(
            _range_check,
            self.values,
            jnp.asarray(lo, dtype=jnp.float32),
            jnp.asarray(hi, dtype=jnp.float32),
        )

    def memory_bytes(self) -> int:
        return int(self.values.shape[0] * 4)


@dataclasses.dataclass(frozen=True)
class SubsetFilter:
    """Multi-label bitsets packed into uint32 words: (N, n_words).

    Query tags (B, n_words) pass node n iff q_tags ⊆ node_tags, i.e.
    (q & node) == q word-wise.
    """

    tag_bits: jax.Array  # (N, W) uint32

    def bind(self, query_bits) -> CheckFn:
        return Partial(_subset_check, self.tag_bits, jnp.asarray(query_bits, dtype=jnp.uint32))

    def memory_bytes(self) -> int:
        return int(self.tag_bits.shape[0] * self.tag_bits.shape[1] * 4)


@dataclasses.dataclass(frozen=True)
class AndFilter:
    parts: tuple

    def bind(self, *args) -> CheckFn:
        fns = tuple(
            p.bind(*a) if isinstance(a, tuple) else p.bind(a)
            for p, a in zip(self.parts, args)
        )
        return Partial(_and_check, fns)

    def memory_bytes(self) -> int:
        return sum(p.memory_bytes() for p in self.parts)


def pack_tags(tag_lists: Sequence[Sequence[int]], vocab_size: int) -> np.ndarray:
    """Pack per-node tag lists into uint32 bitset rows (N, ceil(V/32))."""
    n_words = (vocab_size + 31) // 32
    out = np.zeros((len(tag_lists), n_words), dtype=np.uint32)
    for i, tags in enumerate(tag_lists):
        for t in tags:
            out[i, t // 32] |= np.uint32(1) << np.uint32(t % 32)
    return out


pack_query_tags = pack_tags


def match_all(n: int | None = None) -> CheckFn:
    """Unfiltered search (selectivity 1.0)."""
    return Partial(_all_check)
