from repro.core.engine import EngineConfig, GateANNEngine, recall_at_k
from repro.core.search import SearchConfig, SearchOutput, SearchStats, filtered_search
from repro.core.graph import VamanaGraph, build_vamana, build_filtered_vamana, beam_search_batch
from repro.core.io_model import IOCostModel, DEFAULT_COST_MODEL, GEN5_COST_MODEL

__all__ = [
    "EngineConfig",
    "GateANNEngine",
    "recall_at_k",
    "SearchConfig",
    "SearchOutput",
    "SearchStats",
    "filtered_search",
    "VamanaGraph",
    "build_vamana",
    "build_filtered_vamana",
    "beam_search_batch",
    "IOCostModel",
    "DEFAULT_COST_MODEL",
    "GEN5_COST_MODEL",
]
