"""timing-wallclock — wall-clock reads where a duration is computed.

The PR 8 policy: every duration (span math, latency accounting, elapsed
prints) is on ``time.perf_counter()``.  ``time.time()`` and
``time.monotonic()`` remain legal for *absolute* timestamps, so the rule
only fires when the wall-clock value participates in duration math:

  * a subtraction with a wall-clock call (or a value assigned from one)
    on either side: ``time.time() - t0``, ``dt = now - start``;
  * an augmented ``-=`` involving one;
  * a tainted value passed to an obs-style recording call
    (``observe``/``record``/``span``/``push``/``add_sample``).

Taint is simple forward flow per function scope: names and ``self.x``
attrs assigned from a banned clock call (or from another tainted value)
are tainted.  Import aliases are honored (``from time import time as
now`` still counts; ``from time import perf_counter as time`` does
not).
"""
from __future__ import annotations

import ast

from repro.analysis.core import Finding, dotted

_BANNED = {"time", "monotonic"}
_OBS_SINKS = {"observe", "record", "span", "push", "add_sample"}


def _banned_aliases(tree: ast.AST) -> tuple[set, set]:
    """(dotted call names that are banned clocks, module aliases of `time`)."""
    banned_calls = set()
    time_modules = {"time"}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "time":
                    time_modules.add(alias.asname or "time")
        elif isinstance(node, ast.ImportFrom) and node.module == "time":
            for alias in node.names:
                if alias.name in _BANNED:
                    banned_calls.add(alias.asname or alias.name)
    return banned_calls, time_modules


class _Clock:
    def __init__(self, banned_calls: set, time_modules: set):
        self.banned_calls = banned_calls
        self.time_modules = time_modules

    def is_banned_call(self, node: ast.AST) -> str | None:
        if not isinstance(node, ast.Call):
            return None
        name = dotted(node.func)
        parts = name.split(".")
        if len(parts) == 2 and parts[0] in self.time_modules and parts[1] in _BANNED:
            return name
        if len(parts) == 1 and parts[0] in self.banned_calls:
            return name
        return None


def _target_key(node: ast.AST) -> str | None:
    """A stable key for taintable targets: bare names and self attrs."""
    if isinstance(node, ast.Name):
        return node.id
    if (isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return f"self.{node.attr}"
    return None


class _FuncScan:
    def __init__(self, clock: _Clock, path: str, scope_name: str):
        self.clock = clock
        self.path = path
        self.scope = scope_name
        self.tainted: set = set()
        self.findings: list[Finding] = []

    def _expr_taint(self, expr: ast.AST) -> str | None:
        """The banned clock name if expr carries wall-clock taint."""
        for node in ast.walk(expr):
            name = self.clock.is_banned_call(node)
            if name:
                return name
            key = _target_key(node)
            if key is not None and key in self.tainted:
                if isinstance(node, ast.Name) and not isinstance(node.ctx, ast.Load):
                    continue
                return key
        return None

    def scan(self, stmts: list) -> list[Finding]:
        for stmt in stmts:
            self._stmt(stmt)
        return self.findings

    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # separate scope; nested functions get their own scan
            _FuncScan(self.clock, self.path,
                      f"{self.scope}.{stmt.name}").scan(stmt.body)
            return
        if isinstance(stmt, (ast.Assign, ast.AnnAssign)):
            value = stmt.value
            if value is not None:
                self._check_expr(value)
                src = self._expr_taint(value)
                targets = (stmt.targets if isinstance(stmt, ast.Assign)
                           else [stmt.target])
                if src is not None:
                    for t in targets:
                        key = _target_key(t)
                        if key:
                            self.tainted.add(key)
            return
        if isinstance(stmt, ast.AugAssign):
            self._check_expr(stmt.value)
            if isinstance(stmt.op, ast.Sub):
                src = self._expr_taint(stmt.value) or (
                    _target_key(stmt.target)
                    if _target_key(stmt.target) in self.tainted else None)
                if src:
                    self.findings.append(Finding(
                        self.path, stmt.lineno, "timing-wallclock",
                        f"duration computed from wall clock (`{src}`) — "
                        "use time.perf_counter()",
                    ))
            if self._expr_taint(stmt.value):
                key = _target_key(stmt.target)
                if key:
                    self.tainted.add(key)
            return
        # recurse into compound statements, checking embedded expressions
        for child_block in self._blocks(stmt):
            for s in child_block:
                self._stmt(s)
        for expr in self._exprs(stmt):
            self._check_expr(expr)

    @staticmethod
    def _blocks(stmt: ast.stmt) -> list:
        blocks = []
        for field in ("body", "orelse", "finalbody"):
            val = getattr(stmt, field, None)
            if isinstance(val, list):
                blocks.append(val)
        for h in getattr(stmt, "handlers", []) or []:
            blocks.append(h.body)
        return blocks

    @staticmethod
    def _exprs(stmt: ast.stmt) -> list:
        out = []
        for field in ("test", "iter", "value"):
            val = getattr(stmt, field, None)
            if isinstance(val, ast.expr):
                out.append(val)
        for item in getattr(stmt, "items", []) or []:
            out.append(item.context_expr)
        return out

    def _check_expr(self, expr: ast.AST) -> None:
        for node in ast.walk(expr):
            if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Sub):
                src = self._expr_taint(node.left) or self._expr_taint(node.right)
                if src:
                    self.findings.append(Finding(
                        self.path, node.lineno, "timing-wallclock",
                        f"duration computed from wall clock (`{src}`) — "
                        "use time.perf_counter()",
                    ))
            if isinstance(node, ast.Call):
                callee = dotted(node.func).split(".")[-1]
                if callee in _OBS_SINKS:
                    for a in list(node.args) + [kw.value for kw in node.keywords]:
                        src = self._expr_taint(a)
                        if src:
                            self.findings.append(Finding(
                                self.path, node.lineno, "timing-wallclock",
                                f"wall-clock value (`{src}`) fed to "
                                f"`{callee}()` — obs spans are on "
                                "time.perf_counter()",
                            ))
                            break


def check(tree: ast.AST, source: str, path: str) -> list[Finding]:
    banned_calls, time_modules = _banned_aliases(tree)
    clock = _Clock(banned_calls, time_modules)
    findings: list[Finding] = []
    # module level plus each top-level function/method get their own scope
    _ModuleWalker(clock, path, findings).visit(tree)
    # defs nested inside module-level compound statements can be reached
    # twice (once via block recursion, once via the walker) — dedupe
    seen: set = set()
    unique = []
    for f in findings:
        key = (f.line, f.rule, f.message)
        if key not in seen:
            seen.add(key)
            unique.append(f)
    return unique


class _ModuleWalker(ast.NodeVisitor):
    def __init__(self, clock: _Clock, path: str, findings: list):
        self.clock = clock
        self.path = path
        self.findings = findings

    def visit_Module(self, node: ast.Module) -> None:
        scan = _FuncScan(self.clock, self.path, "<module>")
        top = [s for s in node.body
               if not isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.ClassDef))]
        self.findings.extend(scan.scan(top))
        self.generic_visit(node)

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.generic_visit(node)

    def _visit_func(self, node) -> None:
        self.findings.extend(
            _FuncScan(self.clock, self.path, node.name).scan(node.body))
        # do NOT generic_visit: _FuncScan recurses into nested defs itself

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func


__all__ = ["check"]
