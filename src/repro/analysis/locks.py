"""lock-guarded-write — counter/state mutations outside their lock.

Per class, the guarded-attribute set is inferred from two sources:

  1. any method whose name ends in ``_locked`` (the repo convention for
     "caller holds the lock"): every ``self.x = ...`` target it assigns
     is guarded by ``_lock`` (e.g. ``DiskRecordStore._reset_counters_locked``
     declares the measured I/O counters);
  2. an explicit trailing ``# guarded by <lockname>`` comment on an
     attribute assignment — either ``self.x = ...`` in a method or a
     class-body field line (dataclass style).

The rule then flags, in any method that is not ``__init__`` /
``__post_init__`` / ``*_locked``, a read-modify-write of a guarded
attribute while the guarding ``with self.<lockname>:`` is not held:

  * ``self.x += 1`` / ``self.x[k] += v``   (augmented assign)
  * ``self.x = f(self.x)``                  (assign reading itself)
  * ``self.x[k] = v`` / ``del self.x[k]``   (container store/delete)
  * ``self.x.append(...)`` and friends      (mutator method calls)

Plain overwrites (``self.x = 0`` with no self-read) are deliberately
not flagged — they are atomic under the GIL and common in teardown.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.core import Finding

# the phrase may sit anywhere in a trailing comment:
#   self._pending = {}  # guarded by _lock
#   self._inflight = 0  # live counter, not reset; guarded by _lock
_GUARD_RE = re.compile(r"#.*\bguarded by\s+(\w+)")

_MUTATORS = {
    "append", "appendleft", "extend", "extendleft", "insert", "pop",
    "popleft", "popitem", "remove", "clear", "update", "add", "discard",
    "setdefault", "move_to_end", "sort", "reverse",
}

_EXEMPT_METHODS = {"__init__", "__post_init__", "__new__"}


def _self_attr(node: ast.AST) -> str | None:
    """'x' for a ``self.x`` attribute node, else None."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _guard_comment(source_lines: list[str], lineno: int) -> str | None:
    if 1 <= lineno <= len(source_lines):
        m = _GUARD_RE.search(source_lines[lineno - 1])
        if m:
            return m.group(1)
    return None


def _infer_guarded(cls: ast.ClassDef, source_lines: list[str]) -> dict[str, str]:
    """attr name -> guarding lock attr name."""
    guarded: dict[str, str] = {}
    # class-body field annotations (dataclass style)
    for stmt in cls.body:
        target = None
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
            target = stmt.target.id
        elif (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)):
            target = stmt.targets[0].id
        if target:
            lock = _guard_comment(source_lines, stmt.lineno)
            if lock:
                guarded[target] = lock
    for stmt in cls.body:
        if not isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        locked_init = stmt.name.endswith("_locked")
        for node in ast.walk(stmt):
            targets: list[tuple[ast.AST, int]] = []
            if isinstance(node, ast.Assign):
                targets = [(t, node.lineno) for t in node.targets]
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
                targets = [(node.target, node.lineno)]
            for t, lineno in targets:
                attr = _self_attr(t)
                if attr is None:
                    continue
                lock = _guard_comment(source_lines, lineno)
                if lock:
                    guarded[attr] = lock
                elif locked_init:
                    guarded.setdefault(attr, "_lock")
    return guarded


def _reads_self_attr(expr: ast.AST, attr: str) -> bool:
    for node in ast.walk(expr):
        if _self_attr(node) == attr:
            return True
    return False


class _MethodScan:
    """Walk one method body tracking which ``self.<lock>`` names are held."""

    def __init__(self, guarded: dict[str, str], cls_name: str,
                 method_name: str, path: str):
        self.guarded = guarded
        self.cls_name = cls_name
        self.method_name = method_name
        self.path = path
        self.findings: list[Finding] = []

    def run(self, body: list[ast.stmt]) -> list[Finding]:
        self._stmts(body, held=frozenset())
        return self.findings

    def _stmts(self, stmts: list[ast.stmt], held: frozenset) -> None:
        for stmt in stmts:
            self._stmt(stmt, held)

    def _stmt(self, stmt: ast.stmt, held: frozenset) -> None:
        if isinstance(stmt, ast.With):
            acquired = set()
            for item in stmt.items:
                attr = _self_attr(item.context_expr)
                if attr:
                    acquired.add(attr)
            self._stmts(stmt.body, held | acquired)
            return
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested def: conservatively treat as running without the lock
            self._stmts(stmt.body, frozenset())
            return
        if isinstance(stmt, (ast.If, ast.While)):
            self._check_expr(stmt.test, held)
            self._stmts(stmt.body, held)
            self._stmts(stmt.orelse, held)
            return
        if isinstance(stmt, ast.For):
            self._check_expr(stmt.iter, held)
            self._stmts(stmt.body, held)
            self._stmts(stmt.orelse, held)
            return
        if isinstance(stmt, ast.Try):
            self._stmts(stmt.body, held)
            for h in stmt.handlers:
                self._stmts(h.body, held)
            self._stmts(stmt.orelse, held)
            self._stmts(stmt.finalbody, held)
            return
        # leaf statements
        if isinstance(stmt, ast.AugAssign):
            attr = _self_attr(stmt.target)
            if attr is None and isinstance(stmt.target, ast.Subscript):
                attr = _self_attr(stmt.target.value)
            self._flag_if_unheld(attr, held, stmt.lineno, "augmented assignment")
            self._check_expr(stmt.value, held)
            return
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                attr = _self_attr(t)
                if attr is not None and _reads_self_attr(stmt.value, attr):
                    self._flag_if_unheld(attr, held, stmt.lineno,
                                         "read-modify-write assignment")
                if isinstance(t, ast.Subscript):
                    sub_attr = _self_attr(t.value)
                    self._flag_if_unheld(sub_attr, held, stmt.lineno,
                                         "subscript store")
            self._check_expr(stmt.value, held)
            return
        if isinstance(stmt, ast.Delete):
            for t in stmt.targets:
                if isinstance(t, ast.Subscript):
                    self._flag_if_unheld(_self_attr(t.value), held,
                                         stmt.lineno, "subscript delete")
            return
        self._check_expr(stmt, held)

    def _check_expr(self, node: ast.AST, held: frozenset) -> None:
        """Find mutator calls on guarded attrs inside any expression."""
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Attribute):
                if sub.func.attr in _MUTATORS:
                    attr = _self_attr(sub.func.value)
                    self._flag_if_unheld(attr, held, sub.lineno,
                                         f".{sub.func.attr}() call")

    def _flag_if_unheld(self, attr: str | None, held: frozenset,
                        lineno: int, what: str) -> None:
        if attr is None:
            return
        lock = self.guarded.get(attr)
        if lock is None or lock in held:
            return
        self.findings.append(Finding(
            self.path, lineno, "lock-guarded-write",
            f"{self.cls_name}.{self.method_name}: {what} on "
            f"`self.{attr}` (guarded by `{lock}`) outside "
            f"`with self.{lock}:`",
        ))


def check(tree: ast.AST, source: str, path: str) -> list[Finding]:
    source_lines = source.splitlines()
    findings: list[Finding] = []
    for cls in [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]:
        guarded = _infer_guarded(cls, source_lines)
        if not guarded:
            continue
        for stmt in cls.body:
            if not isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if stmt.name in _EXEMPT_METHODS or stmt.name.endswith("_locked"):
                continue
            scan = _MethodScan(guarded, cls.name, stmt.name, path)
            findings.extend(scan.run(stmt.body))
    return findings


__all__ = ["check"]
