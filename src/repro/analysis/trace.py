"""trace hygiene — host control flow / dynamic shapes / RNG in jitted code.

Traced contexts are discovered structurally, with no jax import:

  * functions (defs or lambdas) passed in the body position of
    ``lax.while_loop(cond, body, init)``, ``lax.scan(f, ...)``,
    ``lax.fori_loop(lo, hi, body, init)`` — matched by callee name, so
    ``jax.lax.while_loop`` and a bare ``while_loop`` both count;
  * functions decorated with ``jax.jit`` / ``jit`` /
    ``partial(jax.jit, ...)``.  Parameters named in a literal
    ``static_argnames=`` are trace-time constants and excluded from
    taint.

Inside a traced context, three rules fire:

  * ``trace-host-branch`` — a Python ``if``/``while`` whose test reaches
    a value derived from the traced parameters.  Static tests are
    exempt: ``x is None``, ``x.shape/.ndim/.dtype/.size`` accesses,
    ``isinstance``/``len`` on statics, and anything built only from
    untainted names.
  * ``trace-dynamic-shape`` — ``nonzero``/``flatnonzero``/``argwhere``/
    ``unique`` without ``size=``, or one-argument ``where(cond)``.
  * ``trace-unseeded-rng`` — any ``np.random.*`` / ``numpy.random.*`` /
    ``random.<fn>()`` call (host RNG is baked in at trace time).

Taint propagation is simple forward flow over assignments: a name is
tainted if its value expression uses a tainted name *dynamically*
(i.e. not exclusively under a static attribute or an ``is`` compare).
"""
from __future__ import annotations

import ast

from repro.analysis.core import Finding, dotted, func_name

_LOOP_BODY_ARGS = {
    # callee name -> indices of positional args that are traced callables
    "while_loop": (0, 1),
    "fori_loop": (2,),
    "scan": (0,),
}
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "aval"}
_DYN_SHAPE_FNS = {"nonzero", "flatnonzero", "argwhere", "unique"}


def _is_jit_decorator(dec: ast.expr) -> bool:
    name = dotted(dec)
    if name.endswith("jit") or ".jit" in name:
        return True
    if isinstance(dec, ast.Call):
        if dotted(dec.func).endswith("partial"):
            return any(dotted(a).endswith("jit") for a in dec.args)
    return False


def _jit_static_argnames(dec: ast.expr) -> set:
    names: set = set()
    calls = [dec] if isinstance(dec, ast.Call) else []
    for call in calls:
        for kw in call.keywords:
            if kw.arg in ("static_argnames", "static_argnums"):
                for node in ast.walk(kw.value):
                    if isinstance(node, ast.Constant) and isinstance(node.value, str):
                        names.add(node.value)
    return names


def _collect_traced(tree: ast.AST):
    """Yield (fn_node, static_param_names, why) for every traced context."""
    defs: dict[str, list] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, []).append(node)

    seen: set = set()

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _is_jit_decorator(dec):
                    statics = _jit_static_argnames(dec)
                    if id(node) not in seen:
                        seen.add(id(node))
                        yield node, statics, "jit-decorated"
                    break
        if isinstance(node, ast.Call):
            callee = func_name(node)
            positions = _LOOP_BODY_ARGS.get(callee)
            if positions is None:
                continue
            for idx in positions:
                if idx >= len(node.args):
                    continue
                arg = node.args[idx]
                fns = []
                if isinstance(arg, ast.Lambda):
                    fns = [arg]
                elif isinstance(arg, ast.Name):
                    fns = defs.get(arg.id, [])
                for fn in fns:
                    if id(fn) not in seen:
                        seen.add(id(fn))
                        yield fn, set(), f"body of {callee}"


def _param_names(fn) -> list[str]:
    if isinstance(fn, ast.Lambda):
        a = fn.args
    else:
        a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def _dynamic_names(expr: ast.AST, tainted: set) -> list[ast.Name]:
    """Tainted Name loads used *dynamically* in expr.

    A use is static (and skipped) when it appears under a static
    attribute (``x.shape``), as an operand of an ``is``/``is not``
    compare, or inside ``isinstance(...)``.
    """
    static_ids: set = set()

    def mark_static(node: ast.AST) -> None:
        for sub in ast.walk(node):
            static_ids.add(id(sub))

    for node in ast.walk(expr):
        if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
            mark_static(node.value)
        if isinstance(node, ast.Compare):
            if any(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                mark_static(node.left)
                for cmp in node.comparators:
                    mark_static(cmp)
        if isinstance(node, ast.Call) and func_name(node) in ("isinstance", "len", "getattr", "hasattr"):
            for a in node.args:
                mark_static(a)

    out = []
    for node in ast.walk(expr):
        if (isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load)
                and node.id in tainted and id(node) not in static_ids):
            out.append(node)
    return out


def _returns_array(expr: ast.AST) -> bool:
    """Heuristic: calls into jnp/jax/lax produce traced values."""
    for node in ast.walk(expr):
        if isinstance(node, ast.Call):
            name = dotted(node.func)
            root = name.split(".")[0]
            if root in ("jnp", "jax", "lax") and not any(
                    part in _STATIC_ATTRS for part in name.split(".")):
                if "eval_shape" in name:
                    continue
                return True
    return False


class _TracedScan:
    def __init__(self, fn, statics: set, why: str, path: str):
        self.fn = fn
        self.why = why
        self.path = path
        self.tainted = {p for p in _param_names(fn) if p not in statics}
        self.findings: list[Finding] = []

    def run(self) -> list[Finding]:
        body = self.fn.body
        if isinstance(self.fn, ast.Lambda):
            self._expr_rules(body)
            return self.findings
        self._stmts(body)
        return self.findings

    def _stmts(self, stmts: list) -> None:
        for stmt in stmts:
            self._stmt(stmt)

    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested defs inherit the enclosing taint (closures over the
            # carry are traced too)
            self._stmts(stmt.body)
            return
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = stmt.value
            if value is not None:
                self._expr_rules(value)
                dyn = _dynamic_names(value, self.tainted)
                if dyn or _returns_array(value):
                    targets = (stmt.targets if isinstance(stmt, ast.Assign)
                               else [stmt.target])
                    for t in targets:
                        for node in ast.walk(t):
                            if isinstance(node, ast.Name):
                                self.tainted.add(node.id)
            return
        if isinstance(stmt, (ast.If, ast.While)):
            dyn = _dynamic_names(stmt.test, self.tainted)
            if dyn:
                kw = "while" if isinstance(stmt, ast.While) else "if"
                names = ", ".join(sorted({n.id for n in dyn}))
                self.findings.append(Finding(
                    self.path, stmt.lineno, "trace-host-branch",
                    f"Python `{kw}` on traced value(s) `{names}` inside "
                    f"{self.why} — use jnp.where/lax.cond",
                ))
            self._expr_rules(stmt.test)
            self._stmts(stmt.body)
            self._stmts(stmt.orelse)
            return
        if isinstance(stmt, ast.For):
            self._expr_rules(stmt.iter)
            # loop targets over a traced iterable are themselves traced
            if _dynamic_names(stmt.iter, self.tainted):
                for node in ast.walk(stmt.target):
                    if isinstance(node, ast.Name):
                        self.tainted.add(node.id)
            self._stmts(stmt.body)
            self._stmts(stmt.orelse)
            return
        if isinstance(stmt, ast.Try):
            self._stmts(stmt.body)
            for h in stmt.handlers:
                self._stmts(h.body)
            self._stmts(stmt.orelse)
            self._stmts(stmt.finalbody)
            return
        if isinstance(stmt, ast.With):
            self._stmts(stmt.body)
            return
        if isinstance(stmt, ast.Return) and stmt.value is not None:
            self._expr_rules(stmt.value)
            return
        if isinstance(stmt, ast.Expr):
            self._expr_rules(stmt.value)
            return

    def _expr_rules(self, expr: ast.AST) -> None:
        """dynamic-shape and RNG rules over every call in the expression."""
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call):
                continue
            callee = func_name(node)
            receiver = dotted(node.func)
            if callee in _DYN_SHAPE_FNS:
                has_size = any(kw.arg == "size" for kw in node.keywords)
                if not has_size:
                    self.findings.append(Finding(
                        self.path, node.lineno, "trace-dynamic-shape",
                        f"`{callee}` without `size=` inside {self.why} "
                        "has a data-dependent output shape",
                    ))
            elif callee == "where" and len(node.args) == 1 and not node.keywords:
                self.findings.append(Finding(
                    self.path, node.lineno, "trace-dynamic-shape",
                    f"one-argument `where(cond)` inside {self.why} has a "
                    "data-dependent output shape — pass x/y or use "
                    "`size=` via nonzero",
                ))
            if ".random." in f".{receiver}." and "jax" not in receiver.split("."):
                self.findings.append(Finding(
                    self.path, node.lineno, "trace-unseeded-rng",
                    f"host RNG `{receiver}` inside {self.why} is baked in "
                    "at trace time — thread a jax.random key through the "
                    "carry",
                ))


def check(tree: ast.AST, source: str, path: str) -> list[Finding]:
    findings: list[Finding] = []
    for fn, statics, why in _collect_traced(tree):
        findings.extend(_TracedScan(fn, statics, why, path).run())
    return findings


__all__ = ["check"]
