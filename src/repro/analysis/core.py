"""gatelint core — findings, suppressions, baseline, and the rule registry.

The analysis package is **pure stdlib AST**: importing it (or running
``scripts/gatelint.py``) must never pull in jax/numpy, so the CI gate
runs in seconds on a bare interpreter.  Each rule module exposes
``check(tree, source, path) -> list[Finding]``; this module owns the
shared plumbing:

  * :class:`Finding` — one diagnostic, with file:line, rule id, message.
  * inline suppressions — ``# gatelint: disable=<rule>[,<rule>] — reason``
    on the flagged line.  The reason is mandatory: a reasonless pragma
    still suppresses (so CI stays green while someone writes the
    justification) but raises its own ``suppression-missing-reason``
    finding, as does a pragma naming a rule that doesn't exist.
  * the findings baseline — ``analysis_baseline.json`` entries of
    ``{"path", "rule", "count", "reason"}`` absorb up to ``count``
    findings of that rule in that file (line-insensitive, so unrelated
    edits never invalidate the baseline).  Findings beyond the allowance
    surface normally.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    family: str
    summary: str
    rationale: str


RULES: dict[str, Rule] = {
    r.id: r
    for r in [
        Rule(
            id="lock-guarded-write",
            family="lock-discipline",
            summary="read-modify-write of a lock-guarded attribute outside "
                    "its `with self.<lock>:` block",
            rationale=(
                "Counter attributes declared in a `*_locked` initializer "
                "(e.g. `_reset_counters_locked`) or annotated "
                "`# guarded by _lock` are shared across threads — the disk "
                "store's measured counters advance from reader-pool "
                "threads, the serve front end's inflight map from client "
                "threads.  A `self.x += 1`, `self.d[k] = v`, or "
                "`self.q.append(...)` outside the guarding `with` block is "
                "a lost-update race: it reproduces only under the 6-thread "
                "hammer, and then only sometimes.  Methods whose name ends "
                "in `_locked` are exempt (the caller holds the lock by "
                "convention), as is `__init__` (the object is not shared "
                "yet)."
            ),
        ),
        Rule(
            id="trace-host-branch",
            family="trace-hygiene",
            summary="Python `if`/`while` on a traced value inside a jitted "
                    "loop body",
            rationale=(
                "Bodies passed to `lax.while_loop`/`scan`/`fori_loop` (and "
                "`@jax.jit` functions) trace once: a Python branch on a "
                "traced array raises ConcretizationTypeError at best, or "
                "silently bakes one branch into the compiled loop at "
                "worst.  Branching on trace-time statics is fine — config "
                "attributes, `.shape`/`.ndim`/`.dtype`, `is None` checks — "
                "and the rule exempts those; it fires only when the test "
                "expression reaches a value derived from the body's own "
                "(traced) parameters.  Use `jnp.where`/`lax.cond` instead."
            ),
        ),
        Rule(
            id="trace-dynamic-shape",
            family="trace-hygiene",
            summary="data-dependent output shape inside a jitted loop body",
            rationale=(
                "`nonzero`/`flatnonzero`/`argwhere`/`unique` without "
                "`size=`, and one-argument `where(cond)`, produce shapes "
                "that depend on runtime values — inside a traced loop "
                "carry that is a retrace per shape (or an outright error). "
                "The repo's fixed-shape discipline (bucketed batch sizes, "
                "padded frontier slots, `n_slots`-row cache blocks) exists "
                "so jit never retraces mid-serve; pass `size=`/`fill_value=` "
                "or restructure with masks."
            ),
        ),
        Rule(
            id="trace-unseeded-rng",
            family="trace-hygiene",
            summary="host RNG (`np.random.*` / `random.*`) inside a jitted "
                    "path",
            rationale=(
                "A host RNG call inside a traced body executes once at "
                "trace time and its value is baked into the compiled "
                "executable — every subsequent call replays the same "
                "'random' constant, and results stop being reproducible "
                "from a seed.  Thread `jax.random` keys through the loop "
                "carry instead; host-side np.random is fine outside traced "
                "code when seeded explicitly."
            ),
        ),
        Rule(
            id="timing-wallclock",
            family="timing-policy",
            summary="`time.time()`/`time.monotonic()` used to compute a "
                    "duration (or fed to an obs span)",
            rationale=(
                "Span math is on `time.perf_counter()` (PR 8 policy): "
                "wall clock steps under NTP — a step backwards mid-request "
                "produces negative spans, and the serve-latency histograms "
                "quietly corrupt.  `time.monotonic()` is step-immune but "
                "coarser than perf_counter on some platforms and its use "
                "for durations splits the codebase across two clocks; the "
                "policy is one clock for every duration.  Absolute "
                "timestamps (logging when something happened) may still "
                "use time.time()."
            ),
        ),
        Rule(
            id="token-leak",
            family="io-token-lifecycle",
            summary="a `submit()` I/O token that does not reach `drain()` / "
                    "`abandon_pending()` on every path",
            rationale=(
                "`DiskRecordStore.submit()` pins a reader-pool slot and a "
                "completion-queue entry until the token is drained or "
                "abandoned.  A token that is dropped (result discarded, "
                "used on only one branch, or bypassed by an exception "
                "between submit and drain) leaks that slot until close() — "
                "under serving load the pool starves and every later "
                "search stalls.  Drain on all paths, or wrap in "
                "try/finally with `drain`/`abandon_pending` in the "
                "`finally`.  Executor pools (`pool.submit`) are exempt: "
                "their futures have no store-side lifecycle."
            ),
        ),
        Rule(
            id="silent-except",
            family="error-hygiene",
            summary="a broad `except` (bare / Exception / OSError family) "
                    "whose body only passes",
            rationale=(
                "A bare `except:` — or one catching Exception/OSError and "
                "then doing nothing — erases the only evidence that an I/O "
                "path failed.  This repo's resilience contract is that "
                "every swallowed error is *counted* (`warm_errors`, "
                "`retry_exhausted`, `degraded_records`) or re-raised after "
                "transient/fatal classification; a silent swallow is where "
                "reconciliation drift and phantom recall loss hide, and it "
                "only reproduces under the fault-injection harness.  Count "
                "the error into an obs counter, re-raise the fatal subset, "
                "or — for genuinely best-effort paths (teardown "
                "destructors, stale-file sweeps) — suppress with a pragma "
                "that records why swallowing is safe."
            ),
        ),
        Rule(
            id="suppression-missing-reason",
            family="meta",
            summary="a `# gatelint: disable=` pragma without a justification "
                    "(or naming an unknown rule)",
            rationale=(
                "Suppressions are part of the correctness record: the next "
                "builder must be able to tell a justified exception from a "
                "silenced bug.  Write "
                "`# gatelint: disable=<rule> — <why this is safe>`."
            ),
        ),
        Rule(
            id="parse-error",
            family="meta",
            summary="file could not be parsed as Python",
            rationale=(
                "gatelint runs on the AST; a file that does not parse "
                "cannot be checked and is reported instead of skipped "
                "(a syntax error reaching CI is itself a finding)."
            ),
        ),
    ]
}


@dataclasses.dataclass
class Finding:
    path: str
    line: int
    rule: str
    message: str
    suppressed: bool = False
    suppress_reason: str | None = None
    baselined: bool = False

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule}: {self.message}"

    def to_json(self) -> dict:
        out = {
            "file": self.path,
            "line": self.line,
            "rule": self.rule,
            "message": self.message,
        }
        if self.suppressed:
            out["suppressed"] = True
            out["suppress_reason"] = self.suppress_reason
        if self.baselined:
            out["baselined"] = True
        return out


# ``—`` (em dash) is the documented separator; ``--`` is accepted so the
# pragma can be typed on a keyboard without compose keys.
_SUPPRESS_RE = re.compile(
    r"#\s*gatelint:\s*disable=([A-Za-z0-9_\-, ]+?)\s*(?:(?:—|--)\s*(\S.*))?$"
)


def parse_suppressions(source: str) -> dict[int, tuple[set, str | None]]:
    """line number -> (rule ids suppressed on that line, reason or None)."""
    out: dict[int, tuple[set, str | None]] = {}
    for i, line in enumerate(source.splitlines(), 1):
        m = _SUPPRESS_RE.search(line)
        if m:
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            out[i] = (rules, m.group(2))
    return out


def _checkers():
    # imported lazily so a single rule module failing to import doesn't
    # take the registry down with it at module-import time
    from repro.analysis import excepts, locks, timing, tokens, trace

    return (locks.check, trace.check, timing.check, tokens.check,
            excepts.check)


def lint_source(source: str, path: str) -> list[Finding]:
    """All findings for one file's source, suppressions applied."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 1, "parse-error", str(e.msg))]
    findings: list[Finding] = []
    for check in _checkers():
        findings.extend(check(tree, source, path))

    sup = parse_suppressions(source)
    for f in findings:
        hit = sup.get(f.line)
        if hit and f.rule in hit[0]:
            f.suppressed = True
            f.suppress_reason = hit[1]
    for line, (rules, reason) in sorted(sup.items()):
        unknown = sorted(r for r in rules if r not in RULES)
        if unknown:
            findings.append(Finding(
                path, line, "suppression-missing-reason",
                f"suppression names unknown rule(s): {', '.join(unknown)}",
            ))
        if not reason:
            findings.append(Finding(
                path, line, "suppression-missing-reason",
                "suppression has no justification — write "
                "`# gatelint: disable=<rule> — reason`",
            ))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def iter_py_files(paths) -> list[str]:
    out = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(
                d for d in dirs
                if not d.startswith(".") and d != "__pycache__"
            )
            out.extend(
                os.path.join(root, f) for f in sorted(files)
                if f.endswith(".py")
            )
    return out


def _norm(path: str) -> str:
    rel = os.path.relpath(path)
    return rel.replace(os.sep, "/")


def lint_paths(paths) -> list[Finding]:
    findings: list[Finding] = []
    for fp in iter_py_files(paths):
        with open(fp, "r", encoding="utf-8") as f:
            source = f.read()
        findings.extend(lint_source(source, _norm(fp)))
    return findings


# -- baseline ---------------------------------------------------------------
def load_baseline(path: str) -> list[dict]:
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    if doc.get("version") != 1:
        raise ValueError(f"unsupported baseline version: {doc.get('version')}")
    entries = doc["entries"]
    for e in entries:
        for key in ("path", "rule", "count", "reason"):
            if key not in e:
                raise ValueError(f"baseline entry missing {key!r}: {e}")
    return entries


def apply_baseline(findings: list[Finding], entries: list[dict]) -> None:
    """Mark findings covered by the baseline allowance (in place).

    Matching is (path, rule) with a per-entry count — deliberately
    line-insensitive so unrelated edits to a baselined file don't
    invalidate the entry.  Findings beyond ``count`` stay live.
    """
    budget = {(e["path"], e["rule"]): int(e["count"]) for e in entries}
    for f in findings:
        if f.suppressed:
            continue
        key = (f.path, f.rule)
        if budget.get(key, 0) > 0:
            budget[key] -= 1
            f.baselined = True


def summarize(findings: list[Finding]) -> dict:
    live = [f for f in findings if not f.suppressed and not f.baselined]
    by_rule: dict[str, int] = {}
    for f in live:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    return {
        "total": len(findings),
        "live": len(live),
        "suppressed": sum(1 for f in findings if f.suppressed),
        "baselined": sum(1 for f in findings if f.baselined),
        "by_rule": dict(sorted(by_rule.items())),
    }


# -- small shared AST helpers ----------------------------------------------
def dotted(node: ast.AST) -> str:
    """Best-effort dotted-name rendering: ``self._pool`` -> 'self._pool'."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    if isinstance(node, ast.Call):
        return dotted(node.func)
    if isinstance(node, ast.Subscript):
        return dotted(node.value)
    return ""


def func_name(call: ast.Call) -> str:
    """The called name without its receiver: ``a.b.submit(...)`` -> 'submit'."""
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""
