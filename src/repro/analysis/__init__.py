"""gatelint — project-specific static analysis + lockdep runtime recorder.

Pure stdlib (ast/json/re/threading): importing this package must never
pull in jax or numpy, so the CI lint job runs on a bare interpreter.

Static rules (see ``core.RULES`` / ``scripts/gatelint.py --explain``):

  * ``lock-guarded-write``   — lock discipline on guarded attributes
  * ``trace-host-branch``    — Python control flow on traced values
  * ``trace-dynamic-shape``  — data-dependent shapes in jitted loops
  * ``trace-unseeded-rng``   — host RNG baked in at trace time
  * ``timing-wallclock``     — durations off time.time/monotonic
  * ``token-leak``           — submit() tokens that never drain
  * ``silent-except``        — broad except handlers that swallow errors

Runtime companion: :mod:`repro.analysis.lockdep`.
"""
from repro.analysis.core import (  # noqa: F401
    RULES,
    Finding,
    apply_baseline,
    lint_paths,
    lint_source,
    load_baseline,
    summarize,
)
from repro.analysis.lockdep import (  # noqa: F401
    LockOrderRecorder,
    instrument_disk_store,
)
