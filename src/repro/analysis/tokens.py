"""token-leak — every store submit() token reaches drain/abandon.

Intraprocedural dataflow over each function body:

  1. A *submission* is a call whose callee is ``submit``/``_host_submit``
     on a receiver that is not an executor pool (receiver names
     containing ``pool``/``executor``/``threads`` are exempt —
     ``self._pool.submit(...)`` returns a Future with no store-side
     lifecycle).
  2. ``Expr``-statement submissions (result discarded on the floor) are
     flagged immediately.
  3. For ``token = submit(...)`` / ``token, nbrs = submit(...)``
     assignments, the token must be *used* on every path from the
     submission to function exit.  Any later use counts as resolution —
     a ``drain(token)``/``abandon``, but also storing it in a pending
     map, returning it, or passing it to another call (ownership
     transfer; the new owner is checked at its own site).  The
     all-paths check walks the statement list after the submission
     (and outward through enclosing blocks): an ``if`` resolves only
     when both arms (or a later statement) do; loop bodies are treated
     as may-execute-again, so a use anywhere in an enclosing loop body
     counts.
  4. Exception edges: when the resolving use is itself a
     ``drain``/``abandon`` call in the same block, any intervening
     statement that makes a call may raise and skip the drain — unless
     the drain sits in a ``finally`` or an except handler.  That is
     flagged as a may-leak-on-exception finding.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Finding, dotted, func_name

_SUBMIT_NAMES = {"submit", "_host_submit"}
_POOL_HINTS = ("pool", "executor", "threads")
_RESOLVE_HINTS = ("drain", "abandon")


def _is_store_submit(call: ast.Call) -> bool:
    if func_name(call) not in _SUBMIT_NAMES:
        return False
    if isinstance(call.func, ast.Attribute):
        receiver = dotted(call.func.value).lower()
        if any(h in receiver for h in _POOL_HINTS):
            return False
    return True


def _token_targets(assign: ast.Assign) -> list[str]:
    """Token names bound by ``tok = submit(...)`` / ``tok, x = submit(...)``.

    For tuple unpacking the token is by convention the first element
    (``submit`` returns ``(token, neighbors)``).
    """
    if len(assign.targets) != 1:
        return []
    t = assign.targets[0]
    if isinstance(t, ast.Name):
        return [t.id]
    if isinstance(t, (ast.Tuple, ast.List)) and t.elts:
        first = t.elts[0]
        if isinstance(first, ast.Name):
            return [first.id]
    return []


def _uses_name(node: ast.AST, name: str) -> bool:
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Name) and sub.id == name
                and isinstance(sub.ctx, ast.Load)):
            return True
    return False


def _contains_call(node: ast.AST) -> bool:
    return any(isinstance(sub, ast.Call) for sub in ast.walk(node))


class _Parents(ast.NodeVisitor):
    """stmt -> (containing block list, index, owner stmt or function)."""

    def __init__(self, fn):
        self.blockinfo: dict[int, tuple[list, int, object]] = {}
        self.loop_stack_of: dict[int, tuple] = {}
        self._loops: list = []
        self._walk_block(fn.body, fn)

    def _walk_block(self, block: list, owner) -> None:
        for i, stmt in enumerate(block):
            self.blockinfo[id(stmt)] = (block, i, owner)
            self.loop_stack_of[id(stmt)] = tuple(self._loops)
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # nested defs are separate dataflow scopes — analyzed on
                # their own by check(); don't merge their blocks into ours
                continue
            is_loop = isinstance(stmt, (ast.For, ast.While, ast.AsyncFor))
            if is_loop:
                self._loops.append(stmt)
            for field in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, field, None)
                if isinstance(sub, list):
                    self._walk_block(sub, stmt)
            for h in getattr(stmt, "handlers", []) or []:
                self._walk_block(h.body, stmt)
            if is_loop:
                self._loops.pop()


def _covers(stmts: list, token: str) -> bool:
    """True if every path through stmts uses `token` (or exits early)."""
    for stmt in stmts:
        if isinstance(stmt, (ast.Return, ast.Raise, ast.Continue, ast.Break)):
            # early exit: a Return/Raise that uses the token resolves it;
            # one that doesn't is an escape from this block — the caller
            # (outer-continuation walk) accounts for what runs after.
            return _uses_name(stmt, token)
        if isinstance(stmt, ast.If):
            if stmt.orelse:
                if _covers(stmt.body, token) and _covers(stmt.orelse, token):
                    return True
            if _uses_name(stmt.test, token):
                return True
            continue
        if isinstance(stmt, (ast.For, ast.While)):
            # may execute zero times — only the header counts for sure
            header = stmt.iter if isinstance(stmt, ast.For) else stmt.test
            if _uses_name(header, token):
                return True
            continue
        if isinstance(stmt, ast.Try):
            if stmt.finalbody and _covers(stmt.finalbody, token):
                return True
            if _covers(stmt.body, token):
                # resolved on the normal path; handlers own the error path
                return True
            continue
        if _uses_name(stmt, token):
            return True
    return False


def _in_raises_block(stmt, parents: "_Parents") -> bool:
    node = stmt
    while True:
        info = parents.blockinfo.get(id(node))
        if info is None:
            return False
        _, _, owner = info
        if isinstance(owner, ast.With):
            for item in owner.items:
                if "raises" in dotted(item.context_expr):
                    return True
        if isinstance(owner, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return False
        node = owner


def _enclosing_finally_or_handler(stmt, parents: _Parents) -> bool:
    node = stmt
    while True:
        info = parents.blockinfo.get(id(node))
        if info is None:
            return False
        block, _, owner = info
        if isinstance(owner, ast.Try):
            if block is owner.finalbody:
                return True
            if any(block is h.body for h in owner.handlers):
                return True
        if isinstance(owner, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            return False
        node = owner


def _analyze_function(fn, path: str) -> list[Finding]:
    findings: list[Finding] = []
    parents = _Parents(fn)

    for stmt in ast.walk(fn):
        # discarded result: `store.submit(ids)` as a bare statement.
        # Exempt submits under `with pytest.raises(...)`: the call is
        # expected to raise, so no token is ever created.
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
            if (_is_store_submit(stmt.value)
                    and id(stmt) in parents.blockinfo
                    and not _in_raises_block(stmt, parents)):
                findings.append(Finding(
                    path, stmt.lineno, "token-leak",
                    "submit() result discarded — the token must reach "
                    "drain() or abandon_pending()",
                ))
            continue
        if not (isinstance(stmt, ast.Assign)
                and isinstance(stmt.value, ast.Call)
                and _is_store_submit(stmt.value)):
            continue
        tokens = _token_targets(stmt)
        if not tokens:
            continue
        token = tokens[0]
        info = parents.blockinfo.get(id(stmt))
        if info is None:
            continue
        block, idx, _ = info

        # continuation: trailing statements of this block, then outward
        # through enclosing blocks; enclosing loop bodies re-run in full
        continuation: list = list(block[idx + 1:])
        node = stmt
        while True:
            pinfo = parents.blockinfo.get(id(node))
            if pinfo is None:
                break
            pblock, pidx, owner = pinfo
            if node is not stmt:
                continuation.extend(pblock[pidx + 1:])
            if isinstance(owner, (ast.FunctionDef, ast.AsyncFunctionDef)):
                break
            node = owner
        for loop in parents.loop_stack_of.get(id(stmt), ()):
            continuation.extend(loop.body)

        used_anywhere = any(_uses_name(s, token) for s in continuation)
        if not used_anywhere:
            findings.append(Finding(
                path, stmt.lineno, "token-leak",
                f"token `{token}` from submit() is never drained or "
                "abandoned",
            ))
            continue
        if not _covers(continuation, token):
            findings.append(Finding(
                path, stmt.lineno, "token-leak",
                f"token `{token}` from submit() is not drained on every "
                "path — cover the else/early-return branches or use "
                "try/finally",
            ))
            continue

        # exception edge: submit ... <calls that may raise> ... drain,
        # with the drain in the same block and not exception-protected
        tail = block[idx + 1:]
        resolver = None
        for s in tail:
            if _uses_name(s, token):
                resolver = s
                break
        if resolver is None or isinstance(resolver, ast.Try):
            continue
        is_drain = any(
            any(h in dotted(c.func).lower() for h in _RESOLVE_HINTS)
            for c in ast.walk(resolver) if isinstance(c, ast.Call)
            if _uses_name(c, token)
        )
        if not is_drain:
            continue
        between = tail[:tail.index(resolver)]
        risky = [s for s in between if _contains_call(s)]
        if risky and not _enclosing_finally_or_handler(resolver, parents):
            findings.append(Finding(
                path, risky[0].lineno, "token-leak",
                f"call between submit() and drain of `{token}` may raise "
                "and leak the token — drain in a finally or abandon in "
                "the handler",
            ))
    return findings


def check(tree: ast.AST, source: str, path: str) -> list[Finding]:
    findings: list[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            findings.extend(_analyze_function(node, path))
    return findings


__all__ = ["check"]
