"""silent-except — broad exception handlers whose body discards the error.

An ``except`` clause that catches everything (bare, ``Exception``,
``BaseException``) or the whole I/O family (``OSError`` and its aliases
``IOError``/``EnvironmentError``) and then does nothing — ``pass``,
``continue``, or a bare string/ellipsis expression — erases the only
evidence that an I/O path failed.  This repo's resilience contract is
that every swallowed error is *counted* (``warm_errors``,
``retry_exhausted``, ``degraded_records``) or re-raised after
classification (:func:`repro.store.disk.is_transient`); a silent
swallow is where reconciliation drift and phantom recall loss hide.

The rule is narrow on purpose:

  * Handlers that catch a *specific* non-I/O exception
    (``KeyError``, ``queue.Empty``, ``StopIteration``...) are exempt —
    narrow catches are a deliberate statement about expected control
    flow, silent or not.
  * A handler body with any real statement (a counter increment, a log
    call, a ``raise``, an assignment) is exempt — the error was
    handled, however minimally.
  * Docstring-only / ``...``-only bodies count as silent: they are
    ``pass`` with extra steps.

Fix by counting the error into an obs counter, re-raising the fatal
subset, or — when swallowing really is correct (interpreter-teardown
destructors, best-effort cache cleanup) — suppressing with a pragma
that records *why*.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Finding

# Names that make a handler "broad": everything, or the whole OS-error
# family (IOError/EnvironmentError are aliases of OSError since py3.3).
_BROAD = {"Exception", "BaseException", "OSError", "IOError",
          "EnvironmentError"}


def _type_names(node: ast.expr | None) -> list[str] | None:
    """Caught exception names, or None for a bare ``except:``."""
    if node is None:
        return None
    if isinstance(node, ast.Tuple):
        out = []
        for e in node.elts:
            out.extend(_type_names(e) or [])
        return out
    if isinstance(node, ast.Name):
        return [node.id]
    if isinstance(node, ast.Attribute):
        return [node.attr]  # e.g. builtins.OSError, socket.error
    return []


def _is_broad(handler: ast.ExceptHandler) -> bool:
    names = _type_names(handler.type)
    if names is None:  # bare except:
        return True
    return any(n in _BROAD for n in names)


def _is_silent(body: list[ast.stmt]) -> bool:
    """True when no statement in the body does anything observable."""
    for stmt in body:
        if isinstance(stmt, (ast.Pass, ast.Continue)):
            continue
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Constant):
            continue  # docstring / ellipsis / bare literal
        return False
    return True


def check(tree: ast.AST, source: str, path: str) -> list[Finding]:
    findings: list[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if not (_is_broad(node) and _is_silent(node.body)):
            continue
        names = _type_names(node.type)
        caught = "bare except" if names is None else (
            "except " + "/".join(names))
        findings.append(Finding(
            path, node.lineno, "silent-except",
            f"{caught} swallows the error without counting, logging, or "
            "re-raising — count it into an obs counter or justify with a "
            "pragma",
        ))
    return findings
