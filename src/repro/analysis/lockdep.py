"""lockdep-lite — runtime lock-acquisition-order recording.

Static analysis can prove a mutation happened under *a* lock; it cannot
prove two locks are always taken in a consistent order.  This module
wraps locks in recording proxies and builds a name-keyed edge graph of
observed nesting (``A -> B`` means "acquired B while holding A").  A
pair of edges ``A -> B`` and ``B -> A`` — or a self-edge ``A -> A``
across two *instances* of the same lock class — is a lock-order
inversion: two threads interleaving those acquisitions can deadlock.

The serve hammer runs under this recorder (nightly) to pin the store's
invariant: ``DiskRecordStore._lock`` and the per-segment ``_open_lock``
are never nested in either direction (fd opening happens before counter
accounting, and the adjacency path takes ``_lock`` only).

Pure stdlib; safe to import from tests without jax.
"""
from __future__ import annotations

import threading


class _WrappedLock:
    """Proxy for a Lock/RLock that reports acquire/release to a recorder."""

    def __init__(self, recorder: "LockOrderRecorder", lock, name: str):
        self._rec = recorder
        self._lock = lock
        self._name = name

    def acquire(self, *args, **kwargs):
        got = self._lock.acquire(*args, **kwargs)
        if got:
            self._rec._note_acquire(self._name)
        return got

    def release(self):
        self._rec._note_release(self._name)
        self._lock.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def locked(self):
        return self._lock.locked()

    def __repr__(self):
        return f"<WrappedLock {self._name} wrapping {self._lock!r}>"


class LockOrderRecorder:
    """Records per-thread lock nesting; reports order inversions.

    Usage::

        rec = LockOrderRecorder()
        obj._lock = rec.wrap(obj._lock, "Thing._lock")
        ... exercise under threads ...
        rec.assert_no_inversions()
    """

    def __init__(self):
        self._tls = threading.local()
        self._meta = threading.Lock()
        # (held_name, acquired_name) -> observation count
        self._edges: dict[tuple, int] = {}

    def wrap(self, lock, name: str) -> _WrappedLock:
        return _WrappedLock(self, lock, name)

    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = []
            self._tls.stack = stack
        return stack

    def _note_acquire(self, name: str) -> None:
        stack = self._stack()
        if stack:
            with self._meta:
                for held in set(stack):
                    key = (held, name)
                    self._edges[key] = self._edges.get(key, 0) + 1
        stack.append(name)

    def _note_release(self, name: str) -> None:
        stack = self._stack()
        # releases are LIFO in `with`-discipline code, but tolerate
        # out-of-order release by removing the most recent occurrence
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] == name:
                del stack[i]
                return

    def edges(self) -> dict:
        with self._meta:
            return dict(self._edges)

    def inversions(self) -> list:
        """Order-inverted name pairs: both A->B and B->A observed.

        A self-edge (A while holding A) is reported too — with
        non-reentrant locks that is nested acquisition of two instances
        sharing a class, which deadlocks the moment two threads take
        them in opposite instance order.
        """
        edges = self.edges()
        out = []
        for (a, b) in sorted(edges):
            if a == b:
                out.append((a, b))
            elif a < b and (b, a) in edges:
                out.append((a, b))
        return out

    def assert_no_inversions(self) -> None:
        inv = self.inversions()
        if inv:
            edges = self.edges()
            detail = "; ".join(
                f"{a} <-> {b} (counts {edges.get((a, b), 0)}/"
                f"{edges.get((b, a), 0)})"
                for a, b in inv
            )
            raise AssertionError(f"lock-order inversions observed: {detail}")


def instrument_disk_store(recorder: LockOrderRecorder, store) -> None:
    """Wrap a DiskRecordStore's counter lock and per-segment open locks.

    Duck-typed on purpose (no import of repro.store here): anything with
    a ``_lock`` and a ``_segments`` list whose items carry ``_open_lock``
    gets the same treatment.
    """
    store._lock = recorder.wrap(store._lock, type(store).__name__ + "._lock")
    for seg in getattr(store, "_segments", []):
        if hasattr(seg, "_open_lock"):
            seg._open_lock = recorder.wrap(
                seg._open_lock, type(seg).__name__ + "._open_lock")


__all__ = ["LockOrderRecorder", "instrument_disk_store"]
