from repro.store.vector_store import (
    InMemoryRecordStore,
    ShardedRecordStore,
    HostOffloadRecordStore,
    RecordFetchFn,
)
from repro.store.cache import (
    CachedRecordStore,
    CachedMaskFn,
    CACHE_POLICIES,
    bfs_hot_set,
    select_hot_set,
    visit_freq_hot_set,
)

__all__ = [
    "InMemoryRecordStore",
    "ShardedRecordStore",
    "HostOffloadRecordStore",
    "RecordFetchFn",
    "CachedRecordStore",
    "CachedMaskFn",
    "CACHE_POLICIES",
    "bfs_hot_set",
    "select_hot_set",
    "visit_freq_hot_set",
]
