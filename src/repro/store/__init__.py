from repro.store.vector_store import (
    InMemoryRecordStore,
    ShardedRecordStore,
    HostOffloadRecordStore,
    RecordFetchFn,
)
from repro.store.cache import (
    CachedRecordStore,
    CachedMaskFn,
    CACHE_POLICIES,
    bfs_hot_set,
    select_hot_set,
    visit_freq_hot_set,
)
from repro.store.adaptive import (
    ADAPTIVE_POLICY,
    AdaptiveRecordCache,
    filter_bucket,
)

__all__ = [
    "ADAPTIVE_POLICY",
    "AdaptiveRecordCache",
    "filter_bucket",
    "InMemoryRecordStore",
    "ShardedRecordStore",
    "HostOffloadRecordStore",
    "RecordFetchFn",
    "CachedRecordStore",
    "CachedMaskFn",
    "CACHE_POLICIES",
    "bfs_hot_set",
    "select_hot_set",
    "visit_freq_hot_set",
]
