from repro.store.vector_store import (
    InMemoryRecordStore,
    ShardedRecordStore,
    HostOffloadRecordStore,
    RecordFetchFn,
)

__all__ = [
    "InMemoryRecordStore",
    "ShardedRecordStore",
    "HostOffloadRecordStore",
    "RecordFetchFn",
]
