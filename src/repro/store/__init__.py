from repro.store.vector_store import (
    InMemoryRecordStore,
    ShardedRecordStore,
    HostOffloadRecordStore,
    RecordFetchFn,
)
from repro.store.cache import (
    CachedRecordStore,
    CachedMaskFn,
    CACHE_POLICIES,
    bfs_hot_set,
    select_hot_set,
    visit_freq_hot_set,
)
from repro.store.adaptive import (
    ADAPTIVE_POLICY,
    AdaptiveRecordCache,
    filter_bucket,
)
from repro.store.format import (
    FORMAT_VERSION,
    PAGE_BYTES,
    IndexFile,
    IndexFormatError,
    IndexHeader,
    read_header,
    read_index,
    record_sector_bytes,
    write_index,
)
from repro.store.disk import DiskRecordStore

__all__ = [
    "ADAPTIVE_POLICY",
    "AdaptiveRecordCache",
    "filter_bucket",
    "FORMAT_VERSION",
    "PAGE_BYTES",
    "IndexFile",
    "IndexFormatError",
    "IndexHeader",
    "read_header",
    "read_index",
    "record_sector_bytes",
    "write_index",
    "DiskRecordStore",
    "InMemoryRecordStore",
    "ShardedRecordStore",
    "HostOffloadRecordStore",
    "RecordFetchFn",
    "CachedRecordStore",
    "CachedMaskFn",
    "CACHE_POLICIES",
    "bfs_hot_set",
    "select_hot_set",
    "visit_freq_hot_set",
]
