"""Adaptive, filter-aware record cache — the static hot set made a control loop.

The static ``CachedRecordStore`` (store/cache.py) picks its hot set once,
from *unfiltered* sample traversals.  That is the wrong population under a
selective predicate: gate-mode fetches are drawn from the filter-passing
nodes only, so a cache populated for the unfiltered visit distribution
thrashes exactly where filtered search pays the most I/O.  This module
closes the loop:

  * **online frequency counting** — the search loop carries an (N,)
    counter array as device state and scatter-adds each round's
    fetch-path dispatches (``filtered_search(visit_counts=...)``); no
    Python in the hot path.  Batch counts are folded into an EMA
    (``counts = decay * counts + batch``) so the hot set tracks the
    *recent* workload and old regimes age out.
  * **periodic refresh** — ``refresh()`` re-materializes the
    device-resident hot set from the live counters under the same
    ``cache_budget_bytes``.  Every materialization packs exactly
    ``n_slots`` rows (zero-padded), so refreshes never change jit shapes
    and therefore never retrace the search loop.
  * **per-filter hot sets** — a small LRU of (filter-kind, param-bucket)
    -> partition, each with its own counters and its own materialized hot
    set.  A selective label predicate gets a cache partition populated by
    *its* fetch distribution instead of polluting (and being polluted by)
    the global one.  Note each materialized partition is a full
    ``budget_bytes`` block: device residency is up to
    ``(1 + max_partitions) x budget`` (``device_bytes()`` reports the
    true footprint).

Results stay bit-identical to the uncached engine by construction: the
cache only reroutes record fetches between the slow tier (``n_ios``) and
the cache tier (``n_cache_hits``) — the I/O-conservation property tests
enforce this for every budget / policy / refresh cadence.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.store.cache import CachedRecordStore, record_nbytes, select_hot_set
from repro.store.vector_store import is_lazy_host

ADAPTIVE_POLICY = "adaptive"


def filter_bucket(kind: str | None, params) -> tuple | None:
    """Hashable (filter-kind, param-bucket) partition key; None = global.

    Buckets are deliberately coarse — a partition should capture a query
    *population* (e.g. "label == 3", "norm in bin 7"), not one batch:

      * equality — the batch's most common target label.
      * range    — (lo, hi) rounded to 3 significant decimals (batch mean).
      * subset   — the bit-pattern of the batch's first query tags.
    """
    if kind is None or params is None:
        return None
    p = np.asarray(params)
    if kind == "label":
        vals, counts = np.unique(p.astype(np.int64), return_counts=True)
        return (kind, int(vals[np.argmax(counts)]))
    if kind == "range":
        # params is a (lo, hi) pair exactly as RangeFilter.bind unpacks it:
        # a 2-tuple of scalars/arrays, or an array whose axis 0 is (lo, hi)
        lo, hi = p[0], p[1]
        return (kind, round(float(np.mean(lo)), 3), round(float(np.mean(hi)), 3))
    if kind == "tags":
        row = p[0] if p.ndim > 1 else p
        return (kind, row.astype(np.uint32).tobytes())
    return (kind, p.tobytes())


@dataclasses.dataclass
class _Partition:
    counts: jax.Array  # (N,) f32 EMA of this filter bucket's fetches
    store: CachedRecordStore | None = None  # materialized at refresh
    dirty: bool = True  # saw traffic since its store was last materialized


@dataclasses.dataclass
class AdaptiveRecordCache:
    """Mutable cache controller; the engine routes fetches through it.

    Searches read from an immutable ``CachedRecordStore`` snapshot (the
    partition's if one is materialized for the query's filter bucket, the
    global one otherwise); ``observe`` folds the returned visit counters
    into the EMAs; ``refresh`` republishes the snapshots from the live
    counters.  Mutation happens only between searches, never inside jit.
    """

    backing: Any  # slow-tier record store
    # (N, D) full records for re-materialization — a device array for the
    # in-memory tiers, or the disk tier's LAZY host memmap view (refreshes
    # then gather only the hot rows host-side; the corpus stays on disk)
    vectors: Any
    neighbors: jax.Array  # (N, R)
    budget_bytes: int
    ema_decay: float = 0.9
    refresh_every: int = 4  # batches between refreshes (0 = manual only)
    max_partitions: int = 4  # LRU capacity for per-filter hot sets
    seed_hot_ids: np.ndarray | None = None  # cold-start hot set

    counts: jax.Array = None  # (N,) f32 global EMA
    partitions: "OrderedDict[tuple, _Partition]" = None
    global_store: CachedRecordStore = None
    n_refreshes: int = 0
    batches_since_refresh: int = 0
    last_refresh_sets: int = 1  # hot sets rebuilt by the latest refresh

    # -- construction ------------------------------------------------------
    @classmethod
    def create(
        cls,
        backing: Any,
        *,
        vectors,
        neighbors,
        budget_bytes: int,
        medoid: int,
        ema_decay: float = 0.9,
        refresh_every: int = 4,
        max_partitions: int = 4,
        seed: int = 0,
    ) -> "AdaptiveRecordCache":
        vecs = vectors if is_lazy_host(vectors) else jnp.asarray(vectors, jnp.float32)
        nbrs = jnp.asarray(neighbors, jnp.int32)
        # cold start: the static visit_freq hot set — the best filter-blind
        # guess until real traffic populates the counters (select_hot_set
        # degrades to BFS when the vectors are a lazy disk view)
        seed_hot = select_hot_set(
            neighbors=nbrs, medoid=medoid, budget_bytes=budget_bytes,
            policy="visit_freq", vectors=vecs, seed=seed,
        )
        self = cls(
            backing=backing,
            vectors=vecs,
            neighbors=nbrs,
            budget_bytes=int(budget_bytes),
            ema_decay=float(ema_decay),
            refresh_every=int(refresh_every),
            max_partitions=int(max_partitions),
            seed_hot_ids=np.asarray(seed_hot, np.int32),
        )
        self.counts = jnp.zeros((nbrs.shape[0],), jnp.float32)
        self.partitions = OrderedDict()
        self.global_store = self._materialize(self.seed_hot_ids)
        return self

    @property
    def n_slots(self) -> int:
        d = int(self.vectors.shape[1])
        r = int(self.neighbors.shape[1])
        n = int(self.neighbors.shape[0])
        return min(self.budget_bytes // record_nbytes(d, r), n)

    @property
    def policy(self) -> str:
        return ADAPTIVE_POLICY

    # -- the read path (immutable snapshots, safe inside jit) --------------
    def store_for(self, bucket: tuple | None) -> CachedRecordStore:
        """The snapshot serving this filter bucket (LRU-touches it)."""
        part = self.partitions.get(bucket) if bucket is not None else None
        if part is not None:
            self.partitions.move_to_end(bucket)
            if part.store is not None:
                return part.store
        return self.global_store

    # -- the control loop --------------------------------------------------
    def observe(self, bucket: tuple | None, batch_counts: jax.Array) -> None:
        """Fold one batch's visit counters into the EMAs (device math)."""
        bc = jnp.asarray(batch_counts, jnp.float32)
        self.counts = self.ema_decay * self.counts + bc
        if bucket is not None:
            part = self.partitions.get(bucket)
            if part is None:
                part = _Partition(counts=jnp.zeros_like(self.counts))
                self.partitions[bucket] = part
                while len(self.partitions) > self.max_partitions:
                    self.partitions.popitem(last=False)  # evict LRU
                    obs.default_registry().counter(
                        "cache.partition_evictions"
                    ).inc()
            part.counts = self.ema_decay * part.counts + bc
            part.dirty = True
            self.partitions.move_to_end(bucket)
        self.batches_since_refresh += 1

    def _materialize(self, hot_ids: np.ndarray) -> CachedRecordStore:
        """A snapshot with a fixed ``n_slots``-row block (device gather —
        O(n_slots) per refresh, never a corpus round-trip, never a
        retrace)."""
        return CachedRecordStore.wrap(
            self.backing,
            vectors=self.vectors,
            neighbors=self.neighbors,
            hot_ids=hot_ids,
            policy=ADAPTIVE_POLICY,
            n_slots=self.n_slots,
        )

    def _hot_from_counts(self, counts: jax.Array) -> np.ndarray:
        """Top-``n_slots`` ids by live counter, seed-padded for cold slots.

        O(N + k log k): argpartition isolates the k winners, then only
        those are sorted (count desc, id asc for determinism) — a full
        corpus argsort per refreshed set would dominate the between-batch
        window at large N.
        """
        c = np.asarray(counts)
        k = min(self.n_slots, c.size)
        cand = np.argpartition(-c, k - 1)[:k] if 0 < k < c.size else np.arange(c.size)[:k]
        order = cand[np.lexsort((cand, -c[cand]))]
        hot = order[c[order] > 0].astype(np.int32)
        if hot.size < self.n_slots and self.seed_hot_ids is not None:
            extra = self.seed_hot_ids[~np.isin(self.seed_hot_ids, hot)]
            hot = np.concatenate([hot, extra[: self.n_slots - hot.size]])
        return hot

    def refresh(self) -> None:
        """Re-materialize the stale hot sets from the live counters.

        Only the global set and *dirty* partitions (traffic since their
        last materialization) are rebuilt — an idle partition keeps its
        snapshot for free.  ``last_refresh_sets`` records how many sets
        the refresh actually rebuilt, for honest cost modeling.
        """
        sets = 1
        self.global_store = self._materialize(self._hot_from_counts(self.counts))
        for part in self.partitions.values():
            if part.dirty or part.store is None:
                part.store = self._materialize(self._hot_from_counts(part.counts))
                part.dirty = False
                sets += 1
        self.last_refresh_sets = sets
        self.n_refreshes += 1
        self.batches_since_refresh = 0
        reg = obs.default_registry()
        if reg.enabled:
            reg.counter("cache.refreshes").inc()
            reg.counter("cache.refresh_sets").inc(sets)
            reg.gauge("cache.partitions").set(len(self.partitions))

    def maybe_refresh(self) -> bool:
        """Refresh if the cadence is due; returns whether it ran."""
        if self.refresh_every > 0 and self.batches_since_refresh >= self.refresh_every:
            self.refresh()
            return True
        return False

    # -- state across save()/load() ----------------------------------------
    # The EMA counters are device state keyed to THIS store's node ids: a
    # freshly loaded engine must never inherit them implicitly (an index
    # written by a different build, or a future format that reorders rows,
    # would make stale counters silently mis-rank the hot set).  load()
    # therefore always starts from reset_counters() semantics — the
    # cold-start seed hot set, zero counts, no partitions — and a caller
    # who wants to carry a learned workload across a restart does it
    # explicitly: export_state() before save, restore_state() after load
    # (validated against the new store's geometry, then refreshed so the
    # published hot sets immediately reflect the carried counters).

    def reset_counters(self) -> None:
        """Forget the learned workload: zero the EMAs, drop partitions,
        republish the cold-start seed hot set."""
        self.counts = jnp.zeros_like(self.counts)
        self.partitions = OrderedDict()
        self.global_store = self._materialize(self.seed_hot_ids)
        self.batches_since_refresh = 0

    def export_state(self) -> dict:
        """Portable counter state: global + per-partition EMAs (host
        arrays), tagged with the corpus geometry for restore validation."""
        return {
            "n": int(self.counts.shape[0]),
            "counts": np.asarray(self.counts, np.float32),
            "partitions": [
                (key, np.asarray(part.counts, np.float32))
                for key, part in self.partitions.items()
            ],
        }

    def restore_state(self, state: dict) -> None:
        """Adopt exported counters onto this (possibly reloaded) store.

        Node ids must mean the same rows they meant at export — the only
        thing checkable from here is the corpus length, so a mismatch is
        rejected loudly instead of mis-ranking silently.  The hot sets
        are refreshed immediately, so the first post-restore search
        already serves the carried workload's hot set.
        """
        n = int(self.counts.shape[0])
        counts = np.asarray(state["counts"], np.float32)
        if int(state.get("n", -1)) != n or counts.shape != (n,):
            raise ValueError(
                f"adaptive state holds counters for n={state.get('n')} "
                f"records but this store has n={n} — counters are keyed "
                "to node ids and cannot be remapped across stores"
            )
        self.counts = jnp.asarray(counts)
        self.partitions = OrderedDict()
        for key, counts in list(state.get("partitions", []))[-self.max_partitions:]:
            part = _Partition(counts=jnp.asarray(counts, jnp.float32))
            self.partitions[tuple(key) if isinstance(key, list) else key] = part
        self.refresh()

    # -- reporting ---------------------------------------------------------
    def n_materialized(self) -> int:
        return 1 + sum(1 for p in self.partitions.values() if p.store is not None)

    def device_bytes(self) -> int:
        """Snapshot blocks + counters + slot maps actually held on device."""
        per_store = self.global_store.device_bytes()
        n = int(self.neighbors.shape[0])
        counters = (1 + len(self.partitions)) * n * 4
        return self.n_materialized() * per_store + counters

    def cache_bytes(self) -> int:
        return self.global_store.cache_bytes()

    @property
    def n_cached(self) -> int:
        return self.global_store.n_cached

    def hot_ids(self) -> np.ndarray:
        return self.global_store.hot_ids()

    # -- passthroughs (engine/test code reaches the backing arrays) --------
    def fetch_fn(self):
        return self.global_store.fetch_fn()

    def cached_mask_fn(self):
        return self.global_store.cached_mask_fn()

    def submit_fn(self):
        """Async pair of the global snapshot (per-bucket searches go
        through ``store_for(bucket).submit_fn()`` instead)."""
        return self.global_store.submit_fn()

    def drain_fn(self):
        return self.global_store.drain_fn()

    def io_counters(self) -> dict:
        """Measured counters of the slow tier ({} for modeled backings)."""
        f = getattr(self.backing, "io_counters", None)
        return f() if f is not None else {}

    def abandon_pending(self) -> int:
        f = getattr(self.backing, "abandon_pending", None)
        return f() if f is not None else 0

    def record_bytes(self) -> int:
        return self.backing.record_bytes()
