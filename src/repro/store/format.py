"""Persistent index format — versioned, page-aligned, memmap-readable.

GateANN is an SSD system: the quantity the paper optimizes is 4 KB-sector
reads.  This module gives the reproduction real at-rest state with the
same geometry, following the page-aligned layouts of PAGER and DiskANN:

  page 0 .. HEADER_PAGES-1   header: magic | version | json_len | JSON
                             (section table, shapes/dtypes/offsets, the
                             medoid, and the EngineConfig used at build)
  records section            N record *sectors*, one per node, each
                             ``record_sector_bytes(D, R)`` long (a 4 KB
                             multiple): full vector f32[D] | degree i32 |
                             adjacency i32[R] (-1 padded) | zero pad —
                             exactly the sector ``InMemoryRecordStore
                             .record_bytes()`` already prices
  sidecar sections           full adjacency (the neighbor-store source),
                             PQ codebooks, PQ codes, and one section per
                             filter store — each starting on a page
                             boundary

Every section offset is 4 KB-aligned, so the record section can be
served straight off the file by ``DiskRecordStore`` (store/disk.py) one
aligned sector per node, and every sidecar loads as a zero-copy
``np.memmap`` view.  Readers validate magic, version, and that every
section lies inside the file (truncation), and raise ``IndexFormatError``
otherwise.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any

import numpy as np

from repro.store.cache import record_nbytes

FORMAT_MAGIC = b"GANN"
FORMAT_VERSION = 1
PAGE_BYTES = 4096
HEADER_PAGES = 4  # 16 KB of header keeps the JSON table comfortable
_PRELUDE = np.dtype([("magic", "S4"), ("version", "<u4"), ("json_len", "<u8")])

# section names with a fixed meaning (filters are "filter_<kind>")
SEC_RECORDS = "records"
SEC_NEIGHBORS = "neighbors"
SEC_PQ_BOOKS = "pq_books"
SEC_PQ_CODES = "pq_codes"
FILTER_PREFIX = "filter_"


class IndexFormatError(ValueError):
    """Bad magic, unsupported version, or a corrupt/truncated index file."""


def record_sector_bytes(dim: int, degree: int) -> int:
    """Bytes of one on-disk record sector (a 4 KB multiple)."""
    return record_nbytes(dim, degree)


def record_dtype(dim: int, degree: int) -> np.dtype:
    """Structured view of one record sector (pad folded into itemsize)."""
    return np.dtype(
        {
            "names": ["vec", "deg", "nbrs"],
            "formats": [("<f4", (dim,)), "<i4", ("<i4", (degree,))],
            "offsets": [0, 4 * dim, 4 * dim + 4],
            "itemsize": record_sector_bytes(dim, degree),
        }
    )


def pack_records(vectors: np.ndarray, neighbors: np.ndarray) -> np.ndarray:
    """(N, D) f32 + (N, R) i32 -> (N,) structured record sectors."""
    n, d = vectors.shape
    r = neighbors.shape[1]
    rec = np.zeros((n,), dtype=record_dtype(d, r))
    rec["vec"] = np.asarray(vectors, "<f4")
    rec["deg"] = (np.asarray(neighbors) >= 0).sum(axis=1).astype("<i4")
    rec["nbrs"] = np.asarray(neighbors, "<i4")
    return rec


@dataclasses.dataclass(frozen=True)
class IndexHeader:
    path: str
    version: int
    n: int
    dim: int
    degree: int
    sector_bytes: int
    medoid: int
    config: dict
    sections: dict  # name -> {offset, nbytes, dtype, shape}
    file_bytes: int

    def describe(self) -> str:
        """Human-readable layout summary (``convert_index.py inspect``)."""
        lines = [
            f"GateANN index v{self.version}: {self.path}",
            f"  n={self.n} dim={self.dim} degree={self.degree} "
            f"medoid={self.medoid} sector={self.sector_bytes} B "
            f"file={self.file_bytes} B",
            f"  config: {json.dumps(self.config, sort_keys=True)}",
            f"  {'section':<16s} {'offset':>12s} {'bytes':>12s} "
            f"{'dtype':>6s} shape",
        ]
        for name, s in self.sections.items():
            lines.append(
                f"  {name:<16s} {s['offset']:>12d} {s['nbytes']:>12d} "
                f"{s['dtype']:>6s} {tuple(s['shape'])}"
            )
        return "\n".join(lines)


def _page_up(nbytes: int) -> int:
    return ((nbytes + PAGE_BYTES - 1) // PAGE_BYTES) * PAGE_BYTES


def write_index(
    path: str,
    *,
    vectors: np.ndarray,
    neighbors: np.ndarray,
    pq_books: np.ndarray,
    pq_codes: np.ndarray,
    medoid: int,
    config: dict | None = None,
    filters: dict[str, np.ndarray] | None = None,
) -> IndexHeader:
    """Write a complete index file; returns the header it wrote.

    ``filters`` maps filter kind (``label`` / ``range`` / ``tags``) to the
    per-node metadata array; dtypes are preserved in the section table.
    """
    vectors = np.ascontiguousarray(vectors, "<f4")
    neighbors = np.ascontiguousarray(neighbors, "<i4")
    n, d = vectors.shape
    r = neighbors.shape[1]
    if neighbors.shape[0] != n:
        raise ValueError(f"vectors n={n} but neighbors n={neighbors.shape[0]}")
    arrays: dict[str, np.ndarray] = {
        SEC_RECORDS: pack_records(vectors, neighbors),
        SEC_NEIGHBORS: neighbors,
        SEC_PQ_BOOKS: np.ascontiguousarray(pq_books, "<f4"),
        SEC_PQ_CODES: np.ascontiguousarray(pq_codes, "<i4"),
    }
    for kind, arr in (filters or {}).items():
        arrays[FILTER_PREFIX + kind] = np.ascontiguousarray(arr)

    sections: dict[str, dict] = {}
    offset = HEADER_PAGES * PAGE_BYTES
    for name, arr in arrays.items():
        sections[name] = {
            "offset": offset,
            "nbytes": int(arr.nbytes),
            "dtype": arr.dtype.str if arr.dtype.names is None else "record",
            "shape": list(arr.shape),
        }
        offset += _page_up(int(arr.nbytes))

    meta = {
        "n": int(n),
        "dim": int(d),
        "degree": int(r),
        "sector_bytes": record_sector_bytes(d, r),
        "medoid": int(medoid),
        "config": dict(config or {}),
        "sections": sections,
    }
    blob = json.dumps(meta, sort_keys=True).encode("utf-8")
    capacity = HEADER_PAGES * PAGE_BYTES - _PRELUDE.itemsize
    if len(blob) > capacity:
        raise IndexFormatError(
            f"header table {len(blob)} B exceeds {capacity} B; "
            f"raise HEADER_PAGES"
        )
    prelude = np.zeros((), dtype=_PRELUDE)
    prelude["magic"] = FORMAT_MAGIC
    prelude["version"] = FORMAT_VERSION
    prelude["json_len"] = len(blob)

    # write-then-rename: a crash mid-write never leaves a corrupt index
    # at the final path, and saving over a file that backs a live
    # DiskRecordStore is safe — the old memmap keeps the old inode
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            f.write(prelude.tobytes())
            f.write(blob)
            f.write(b"\0" * (HEADER_PAGES * PAGE_BYTES - _PRELUDE.itemsize - len(blob)))
            for name, arr in arrays.items():
                if f.tell() != sections[name]["offset"]:
                    raise IndexFormatError(
                        f"internal: section {name} landing at {f.tell()} "
                        f"but table says {sections[name]['offset']}"
                    )
                arr.tofile(f)  # streams — no section-sized bytes copy
                f.write(b"\0" * (_page_up(arr.nbytes) - arr.nbytes))
            f.flush()
            os.fsync(f.fileno())  # data durable before the rename commits
        os.replace(tmp, path)
        dir_fd = os.open(os.path.dirname(os.path.abspath(path)), os.O_RDONLY)
        try:
            os.fsync(dir_fd)  # ... and the rename itself durable
        finally:
            os.close(dir_fd)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return read_header(path)


def read_header(path: str) -> IndexHeader:
    """Parse and validate the header pages (magic, version, truncation)."""
    try:
        file_bytes = os.path.getsize(path)
    except OSError as e:
        raise IndexFormatError(f"cannot stat index file {path}: {e}") from e
    if file_bytes < HEADER_PAGES * PAGE_BYTES:
        raise IndexFormatError(
            f"{path}: {file_bytes} B is smaller than the "
            f"{HEADER_PAGES * PAGE_BYTES} B header"
        )
    with open(path, "rb") as f:
        raw = f.read(HEADER_PAGES * PAGE_BYTES)
    prelude = np.frombuffer(raw, dtype=_PRELUDE, count=1)[0]
    if bytes(prelude["magic"]) != FORMAT_MAGIC:
        raise IndexFormatError(f"{path}: bad magic {bytes(prelude['magic'])!r} — "
                               "not a GateANN index file")
    version = int(prelude["version"])
    if not 1 <= version <= FORMAT_VERSION:
        raise IndexFormatError(
            f"{path}: format version {version} not supported "
            f"(this build reads <= {FORMAT_VERSION})"
        )
    json_len = int(prelude["json_len"])
    if json_len > len(raw) - _PRELUDE.itemsize:
        raise IndexFormatError(f"{path}: header table length {json_len} overruns "
                               "the header pages — corrupt header")
    try:
        meta = json.loads(raw[_PRELUDE.itemsize : _PRELUDE.itemsize + json_len])
    except ValueError as e:
        raise IndexFormatError(f"{path}: unparseable header table: {e}") from e
    # a bit-flipped header can parse as JSON and still be garbage: any
    # missing/ill-typed field must surface as IndexFormatError, not as a
    # KeyError/TypeError leaking out of the reader
    try:
        n = int(meta["n"])
        sector_bytes = int(meta["sector_bytes"])
        sections = dict(meta.get("sections", {}))
        spans = []
        for name, s in sections.items():
            offset, nbytes = int(s["offset"]), int(s["nbytes"])
            if offset % PAGE_BYTES:
                raise IndexFormatError(f"{path}: section {name} offset "
                                       f"{offset} is not page-aligned")
            if offset < HEADER_PAGES * PAGE_BYTES:
                raise IndexFormatError(f"{path}: section {name} offset "
                                       f"{offset} overlaps the header pages")
            if nbytes < 0:
                raise IndexFormatError(f"{path}: section {name} has "
                                       f"negative size {nbytes}")
            spans.append((offset, offset + _page_up(nbytes), name))
            if offset + nbytes > file_bytes:
                raise IndexFormatError(
                    f"{path}: section {name} ends at {offset + nbytes} but "
                    f"the file is {file_bytes} B — truncated index"
                )
            # dtype x shape must account for exactly nbytes, else a lying
            # table would mmap past the section (or fail as a raw ValueError)
            shape = tuple(int(x) for x in s["shape"])
            if s["dtype"] == "record":
                want = (n,)
                itemsize = sector_bytes if sector_bytes > 0 else -1
            else:
                want = shape
                itemsize = np.dtype(s["dtype"]).itemsize
            expect = int(np.prod(want, dtype=np.int64)) * itemsize
            if shape != want or expect != nbytes:
                raise IndexFormatError(
                    f"{path}: section {name} declares shape {shape} x "
                    f"{s['dtype']} but nbytes={nbytes} (expected {expect} "
                    f"for shape {want}) — corrupt section table"
                )
        header = IndexHeader(
            path=path,
            version=version,
            n=int(meta["n"]),
            dim=int(meta["dim"]),
            degree=int(meta["degree"]),
            sector_bytes=int(meta["sector_bytes"]),
            medoid=int(meta["medoid"]),
            config=dict(meta.get("config", {})),
            sections=sections,
            file_bytes=file_bytes,
        )
    except (KeyError, TypeError, ValueError) as e:
        if isinstance(e, IndexFormatError):
            raise
        raise IndexFormatError(f"{path}: corrupt header table: {e!r}") from e
    spans.sort()
    for (_, end_a, name_a), (start_b, _, name_b) in zip(spans, spans[1:]):
        if start_b < end_a:
            raise IndexFormatError(f"{path}: sections {name_a} and {name_b} "
                                   "overlap — corrupt section table")
    if header.n < 0 or header.dim <= 0 or header.degree <= 0:
        raise IndexFormatError(f"{path}: nonsensical geometry "
                               f"n={header.n} dim={header.dim} degree={header.degree}")
    if not 0 <= header.medoid < max(header.n, 1):
        raise IndexFormatError(f"{path}: medoid {header.medoid} out of "
                               f"range [0, {header.n})")
    if header.sector_bytes != record_sector_bytes(header.dim, header.degree):
        raise IndexFormatError(
            f"{path}: sector_bytes={header.sector_bytes} inconsistent with "
            f"dim={header.dim} degree={header.degree} (expected "
            f"{record_sector_bytes(header.dim, header.degree)})"
        )
    return header


@dataclasses.dataclass(frozen=True)
class IndexFile:
    """Read-side handle: header + zero-copy memmap views per section."""

    header: IndexHeader

    def section(self, name: str) -> np.memmap:
        s = self.header.sections.get(name)
        if s is None:
            raise IndexFormatError(f"{self.header.path}: no section {name!r}")
        h = self.header
        dtype = (
            record_dtype(h.dim, h.degree) if s["dtype"] == "record"
            else np.dtype(s["dtype"])
        )
        shape = tuple(s["shape"]) if s["dtype"] != "record" else (h.n,)
        try:
            return np.memmap(h.path, dtype=dtype, mode="r", offset=s["offset"],
                             shape=shape)
        except (ValueError, OSError) as e:
            raise IndexFormatError(
                f"{h.path}: cannot map section {name}: {e}"
            ) from e

    def has_section(self, name: str) -> bool:
        return name in self.header.sections

    def records(self) -> np.memmap:
        return self.section(SEC_RECORDS)

    def vectors(self) -> np.ndarray:
        """Full-precision vectors parsed out of the record sectors."""
        return np.ascontiguousarray(self.records()["vec"])

    def neighbors(self) -> np.ndarray:
        return np.ascontiguousarray(self.section(SEC_NEIGHBORS))

    def pq_books(self) -> np.ndarray:
        return np.ascontiguousarray(self.section(SEC_PQ_BOOKS))

    def pq_codes(self) -> np.ndarray:
        return np.ascontiguousarray(self.section(SEC_PQ_CODES))

    def filter_kinds(self) -> list[str]:
        return [
            name[len(FILTER_PREFIX):]
            for name in self.header.sections
            if name.startswith(FILTER_PREFIX)
        ]

    def filter_array(self, kind: str) -> np.ndarray:
        return np.ascontiguousarray(self.section(FILTER_PREFIX + kind))


def read_index(path: str) -> IndexFile:
    return IndexFile(header=read_header(path))
