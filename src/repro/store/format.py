"""Persistent index format — versioned, page-aligned, memmap-readable.

GateANN is an SSD system: the quantity the paper optimizes is 4 KB-sector
reads.  This module gives the reproduction real at-rest state with the
same geometry, following the page-aligned layouts of PAGER and DiskANN:

  page 0 .. HEADER_PAGES-1   header: magic | version | json_len | JSON
                             (section table, shapes/dtypes/offsets, the
                             medoid, and the EngineConfig used at build)
  records section            N record *sectors*, one per node, each
                             ``record_sector_bytes(D, R)`` long (a 4 KB
                             multiple): full vector f32[D] | degree i32 |
                             adjacency i32[R] (-1 padded) | zero pad —
                             exactly the sector ``InMemoryRecordStore
                             .record_bytes()`` already prices
  sidecar sections           full adjacency (the neighbor-store source),
                             PQ codebooks, PQ codes, and one section per
                             filter store — each starting on a page
                             boundary

Every section offset is 4 KB-aligned, so the record section can be
served straight off the file by ``DiskRecordStore`` (store/disk.py) one
aligned sector per node, and every sidecar loads as a zero-copy
``np.memmap`` view.  Readers validate magic, version, and that every
section lies inside the file (truncation), and raise ``IndexFormatError``
otherwise.

Format v2 adds **sharded record segments**: ``write_index(shards=k)``
splits the record sectors row-wise into ``k`` page-aligned segment files
(``<index>.seg<i>``, one per ``model``-axis shard, each with its own
one-page "GSEG" header) and records a ``shards`` manifest in the main
header instead of a monolithic ``records`` section.  A distributed
serving host opens only its own shard's segment; the sidecar sections
(adjacency, PQ, filters — the replicated fast tier) stay in the main
file.  v1 files (monolithic records, no manifest) read unchanged.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Any

import numpy as np

from repro.store.cache import record_nbytes

FORMAT_MAGIC = b"GANN"
FORMAT_VERSION = 2  # v2: optional sharded record segments (v1 reads fine)
SEGMENT_MAGIC = b"GSEG"
PAGE_BYTES = 4096
HEADER_PAGES = 4  # 16 KB of header keeps the JSON table comfortable
SEGMENT_HEADER_PAGES = 1  # segment headers carry a small JSON blob only
_PRELUDE = np.dtype([("magic", "S4"), ("version", "<u4"), ("json_len", "<u8")])

# section names with a fixed meaning (filters are "filter_<kind>")
SEC_RECORDS = "records"
SEC_NEIGHBORS = "neighbors"
SEC_PQ_BOOKS = "pq_books"
SEC_PQ_CODES = "pq_codes"
FILTER_PREFIX = "filter_"


class IndexFormatError(ValueError):
    """Bad magic, unsupported version, or a corrupt/truncated index file."""


def record_sector_bytes(dim: int, degree: int) -> int:
    """Bytes of one on-disk record sector (a 4 KB multiple)."""
    return record_nbytes(dim, degree)


def record_dtype(dim: int, degree: int) -> np.dtype:
    """Structured view of one record sector (pad folded into itemsize)."""
    return np.dtype(
        {
            "names": ["vec", "deg", "nbrs"],
            "formats": [("<f4", (dim,)), "<i4", ("<i4", (degree,))],
            "offsets": [0, 4 * dim, 4 * dim + 4],
            "itemsize": record_sector_bytes(dim, degree),
        }
    )


def pack_records(vectors: np.ndarray, neighbors: np.ndarray) -> np.ndarray:
    """(N, D) f32 + (N, R) i32 -> (N,) structured record sectors."""
    n, d = vectors.shape
    r = neighbors.shape[1]
    rec = np.zeros((n,), dtype=record_dtype(d, r))
    rec["vec"] = np.asarray(vectors, "<f4")
    rec["deg"] = (np.asarray(neighbors) >= 0).sum(axis=1).astype("<i4")
    rec["nbrs"] = np.asarray(neighbors, "<i4")
    return rec


@dataclasses.dataclass(frozen=True)
class IndexHeader:
    path: str
    version: int
    n: int
    dim: int
    degree: int
    sector_bytes: int
    medoid: int
    config: dict
    sections: dict  # name -> {offset, nbytes, dtype, shape}
    file_bytes: int
    # sharded-record manifest (v2): {n_shards, rows_per_shard, segments:
    # [{name, row_start, n_rows, nbytes}]} — None for monolithic records
    shards: dict | None = None

    @property
    def n_shards(self) -> int:
        """Record-segment count (1 when the records are monolithic)."""
        return int(self.shards["n_shards"]) if self.shards else 1

    def segment_path(self, shard: int) -> str:
        """Absolute path of one shard's record segment file."""
        if not self.shards:
            raise IndexFormatError(f"{self.path}: not a sharded index")
        seg = self.shards["segments"][shard]
        return os.path.join(os.path.dirname(os.path.abspath(self.path)),
                            seg["name"])

    def describe(self) -> str:
        """Human-readable layout summary (``convert_index.py inspect``)."""
        lines = [
            f"GateANN index v{self.version}: {self.path}",
            f"  n={self.n} dim={self.dim} degree={self.degree} "
            f"medoid={self.medoid} sector={self.sector_bytes} B "
            f"file={self.file_bytes} B",
            f"  config: {json.dumps(self.config, sort_keys=True)}",
            f"  {'section':<16s} {'offset':>12s} {'bytes':>12s} "
            f"{'dtype':>6s} shape",
        ]
        for name, s in self.sections.items():
            lines.append(
                f"  {name:<16s} {s['offset']:>12d} {s['nbytes']:>12d} "
                f"{s['dtype']:>6s} {tuple(s['shape'])}"
            )
        if self.shards:
            lines.append(
                f"  record segments: {self.shards['n_shards']} shards x "
                f"{self.shards['rows_per_shard']} rows"
            )
            for i, seg in enumerate(self.shards["segments"]):
                lines.append(
                    f"  seg{i:<12d} rows [{seg['row_start']}, "
                    f"{seg['row_start'] + seg['n_rows']}) "
                    f"{seg['nbytes']:>12d} B  {seg['name']}"
                )
        return "\n".join(lines)


def _page_up(nbytes: int) -> int:
    return ((nbytes + PAGE_BYTES - 1) // PAGE_BYTES) * PAGE_BYTES


def _prelude_bytes(magic: bytes, blob: bytes, header_pages: int) -> bytes:
    """Prelude + JSON blob padded out to ``header_pages`` whole pages."""
    capacity = header_pages * PAGE_BYTES - _PRELUDE.itemsize
    if len(blob) > capacity:
        raise IndexFormatError(
            f"header table {len(blob)} B exceeds {capacity} B; "
            f"raise HEADER_PAGES"
        )
    prelude = np.zeros((), dtype=_PRELUDE)
    prelude["magic"] = magic
    prelude["version"] = FORMAT_VERSION
    prelude["json_len"] = len(blob)
    return prelude.tobytes() + blob + b"\0" * (capacity - len(blob))


def _atomic_write(path: str, writer) -> None:
    """write-then-rename: a crash mid-write never leaves a corrupt file
    at the final path, and saving over a file that backs a live
    DiskRecordStore is safe — the old memmap keeps the old inode."""
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            writer(f)
            f.flush()
            os.fsync(f.fileno())  # data durable before the rename commits
        os.replace(tmp, path)
        dir_fd = os.open(os.path.dirname(os.path.abspath(path)), os.O_RDONLY)
        try:
            os.fsync(dir_fd)  # ... and the rename itself durable
        finally:
            os.close(dir_fd)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def _write_segment(path: str, recs: np.ndarray, meta: dict) -> None:
    """One shard's record segment: a one-page GSEG header + raw sectors."""
    blob = json.dumps(meta, sort_keys=True).encode("utf-8")

    def writer(f):
        f.write(_prelude_bytes(SEGMENT_MAGIC, blob, SEGMENT_HEADER_PAGES))
        recs.tofile(f)

    _atomic_write(path, writer)


def write_index(
    path: str,
    *,
    vectors: np.ndarray,
    neighbors: np.ndarray,
    pq_books: np.ndarray,
    pq_codes: np.ndarray,
    medoid: int,
    config: dict | None = None,
    filters: dict[str, np.ndarray] | None = None,
    shards: int = 1,
) -> IndexHeader:
    """Write a complete index file; returns the header it wrote.

    ``filters`` maps filter kind (``label`` / ``range`` / ``tags``) to the
    per-node metadata array; dtypes are preserved in the section table.

    ``shards > 1`` splits the record sectors row-wise into one page-aligned
    segment file per ``model``-axis shard (``<path>.seg<i>-<gen>`` next to
    the index) and records a ``shards`` manifest in the header instead of
    a monolithic ``records`` section — a serving host then opens only its
    own shard's rows.  Segment names carry a per-save generation token and
    are written (atomically) BEFORE the main file commits: a re-save over
    a live index never touches the segments the old manifest references,
    so a crash or concurrent reader anywhere in the sequence sees either
    the complete old index or the complete new one.  Stale generations are
    swept after the commit (safe for live readers — their open fds/memmaps
    pin the old inodes).
    """
    vectors = np.ascontiguousarray(vectors, "<f4")
    neighbors = np.ascontiguousarray(neighbors, "<i4")
    n, d = vectors.shape
    r = neighbors.shape[1]
    if neighbors.shape[0] != n:
        raise ValueError(f"vectors n={n} but neighbors n={neighbors.shape[0]}")
    shards = int(shards)
    if not 1 <= shards <= max(n, 1):
        raise ValueError(f"shards={shards} must be in [1, n={n}]")
    sector = record_sector_bytes(d, r)
    arrays: dict[str, np.ndarray] = {}
    shard_manifest = None
    if shards == 1:
        arrays[SEC_RECORDS] = pack_records(vectors, neighbors)
    else:
        rows = -(-n // shards)  # ceil — the last shard may run short
        base = os.path.basename(path)
        gen = os.urandom(4).hex()  # per-save generation token (see above)
        segments = []
        for i in range(shards):
            s, e = i * rows, min((i + 1) * rows, n)
            recs = pack_records(vectors[s:e], neighbors[s:e])
            seg_name = f"{base}.seg{i}-{gen}"
            seg_meta = {
                "shard": i, "n_shards": shards, "row_start": int(s),
                "n_rows": int(e - s), "n": int(n), "dim": int(d),
                "degree": int(r), "sector_bytes": sector,
            }
            _write_segment(
                os.path.join(os.path.dirname(os.path.abspath(path)), seg_name),
                recs, seg_meta,
            )
            segments.append({
                "name": seg_name, "row_start": int(s),
                "n_rows": int(e - s), "nbytes": int(recs.nbytes),
            })
        shard_manifest = {
            "n_shards": shards, "rows_per_shard": int(rows),
            "segments": segments,
        }
    arrays[SEC_NEIGHBORS] = neighbors
    arrays[SEC_PQ_BOOKS] = np.ascontiguousarray(pq_books, "<f4")
    arrays[SEC_PQ_CODES] = np.ascontiguousarray(pq_codes, "<i4")
    for kind, arr in (filters or {}).items():
        arrays[FILTER_PREFIX + kind] = np.ascontiguousarray(arr)

    sections: dict[str, dict] = {}
    offset = HEADER_PAGES * PAGE_BYTES
    for name, arr in arrays.items():
        sections[name] = {
            "offset": offset,
            "nbytes": int(arr.nbytes),
            "dtype": arr.dtype.str if arr.dtype.names is None else "record",
            "shape": list(arr.shape),
        }
        offset += _page_up(int(arr.nbytes))

    meta = {
        "n": int(n),
        "dim": int(d),
        "degree": int(r),
        "sector_bytes": sector,
        "medoid": int(medoid),
        "config": dict(config or {}),
        "sections": sections,
    }
    if shard_manifest is not None:
        meta["shards"] = shard_manifest
    blob = json.dumps(meta, sort_keys=True).encode("utf-8")
    header_bytes = _prelude_bytes(FORMAT_MAGIC, blob, HEADER_PAGES)

    def writer(f):
        f.write(header_bytes)
        for name, arr in arrays.items():
            if f.tell() != sections[name]["offset"]:
                raise IndexFormatError(
                    f"internal: section {name} landing at {f.tell()} "
                    f"but table says {sections[name]['offset']}"
                )
            arr.tofile(f)  # streams — no section-sized bytes copy
            f.write(b"\0" * (_page_up(arr.nbytes) - arr.nbytes))

    _atomic_write(path, writer)
    _sweep_stale_segments(path, shard_manifest)
    return read_header(path)


def _sweep_stale_segments(path: str, manifest: dict | None) -> None:
    """Best-effort removal of segment files from superseded generations.

    Runs only after the main file committed; live readers of the old
    index keep serving — their open fds/memmaps pin the old inodes."""
    live = {s["name"] for s in (manifest or {}).get("segments", ())}
    for seg in glob.glob(f"{path}.seg*"):
        if os.path.basename(seg) not in live:
            try:
                os.remove(seg)
            except OSError:  # gatelint: disable=silent-except — best-effort sweep after the atomic commit already succeeded; a still-open fd or permission quirk pins the stale inode and the next save retries the removal
                pass


def _validated_shards(path: str, shards: dict, n: int, sector_bytes: int) -> dict:
    """Normalize + validate a shard manifest: segments must partition
    [0, n) contiguously, sizes must match the sector geometry, and names
    must be plain file names (resolved next to the index, never outside)."""
    n_shards = int(shards["n_shards"])
    rows = int(shards["rows_per_shard"])
    segs = list(shards["segments"])
    if n_shards < 1 or len(segs) != n_shards:
        raise IndexFormatError(
            f"{path}: manifest declares n_shards={n_shards} but lists "
            f"{len(segs)} segments"
        )
    if rows != -(-n // n_shards):
        raise IndexFormatError(
            f"{path}: manifest rows_per_shard={rows} inconsistent with "
            f"n={n} over {n_shards} shards"
        )
    out = []
    expect_start = 0
    for i, s in enumerate(segs):
        name = str(s["name"])
        row_start, n_rows, nbytes = int(s["row_start"]), int(s["n_rows"]), int(s["nbytes"])
        if os.path.basename(name) != name or name in (".", ".."):
            raise IndexFormatError(
                f"{path}: segment {i} name {name!r} is not a plain file name"
            )
        if row_start != expect_start or n_rows <= 0:
            raise IndexFormatError(
                f"{path}: segment {i} covers rows [{row_start}, "
                f"{row_start + n_rows}) — segments must partition [0, {n}) "
                "contiguously"
            )
        if n_rows != min(rows, n - row_start):
            raise IndexFormatError(
                f"{path}: segment {i} holds {n_rows} rows, expected "
                f"{min(rows, n - row_start)}"
            )
        if nbytes != n_rows * sector_bytes:
            raise IndexFormatError(
                f"{path}: segment {i} declares {nbytes} B for {n_rows} "
                f"rows x {sector_bytes} B sectors"
            )
        expect_start += n_rows
        out.append({"name": name, "row_start": row_start,
                    "n_rows": n_rows, "nbytes": nbytes})
    if expect_start != n:
        raise IndexFormatError(
            f"{path}: segments cover {expect_start} rows but the corpus "
            f"has {n}"
        )
    return {"n_shards": n_shards, "rows_per_shard": rows, "segments": out}


def read_segment_header(seg_path: str, *, expect: dict | None = None) -> dict:
    """Parse + validate one segment's GSEG header page.

    ``expect`` (from the parent manifest) pins shard identity and geometry
    — a stale or swapped segment file must fail loudly, not serve the
    wrong rows."""
    try:
        file_bytes = os.path.getsize(seg_path)
    except OSError as e:
        raise IndexFormatError(f"cannot stat segment file {seg_path}: {e}") from e
    head = SEGMENT_HEADER_PAGES * PAGE_BYTES
    if file_bytes < head:
        raise IndexFormatError(
            f"{seg_path}: {file_bytes} B is smaller than the {head} B "
            "segment header"
        )
    with open(seg_path, "rb") as f:
        raw = f.read(head)
    prelude = np.frombuffer(raw, dtype=_PRELUDE, count=1)[0]
    if bytes(prelude["magic"]) != SEGMENT_MAGIC:
        raise IndexFormatError(
            f"{seg_path}: bad magic {bytes(prelude['magic'])!r} — not a "
            "GateANN record segment"
        )
    if not 1 <= int(prelude["version"]) <= FORMAT_VERSION:
        raise IndexFormatError(
            f"{seg_path}: segment version {int(prelude['version'])} not "
            f"supported (this build reads <= {FORMAT_VERSION})"
        )
    json_len = int(prelude["json_len"])
    if json_len > len(raw) - _PRELUDE.itemsize:
        raise IndexFormatError(f"{seg_path}: segment header overrun")
    try:
        meta = json.loads(raw[_PRELUDE.itemsize : _PRELUDE.itemsize + json_len])
        fields = {k: int(meta[k]) for k in
                  ("shard", "n_shards", "row_start", "n_rows", "n", "dim",
                   "degree", "sector_bytes")}
    except (KeyError, TypeError, ValueError) as e:
        raise IndexFormatError(
            f"{seg_path}: corrupt segment header: {e!r}"
        ) from e
    if file_bytes < head + fields["n_rows"] * fields["sector_bytes"]:
        raise IndexFormatError(
            f"{seg_path}: {file_bytes} B cannot hold {fields['n_rows']} "
            f"rows x {fields['sector_bytes']} B — truncated segment"
        )
    for key, want in (expect or {}).items():
        if fields.get(key) != want:
            raise IndexFormatError(
                f"{seg_path}: segment header {key}={fields.get(key)} but "
                f"the index manifest expects {want} — wrong/stale segment"
            )
    return fields


def read_header(path: str) -> IndexHeader:
    """Parse and validate the header pages (magic, version, truncation)."""
    try:
        file_bytes = os.path.getsize(path)
    except OSError as e:
        raise IndexFormatError(f"cannot stat index file {path}: {e}") from e
    if file_bytes < HEADER_PAGES * PAGE_BYTES:
        raise IndexFormatError(
            f"{path}: {file_bytes} B is smaller than the "
            f"{HEADER_PAGES * PAGE_BYTES} B header"
        )
    with open(path, "rb") as f:
        raw = f.read(HEADER_PAGES * PAGE_BYTES)
    prelude = np.frombuffer(raw, dtype=_PRELUDE, count=1)[0]
    if bytes(prelude["magic"]) != FORMAT_MAGIC:
        raise IndexFormatError(f"{path}: bad magic {bytes(prelude['magic'])!r} — "
                               "not a GateANN index file")
    version = int(prelude["version"])
    if not 1 <= version <= FORMAT_VERSION:
        raise IndexFormatError(
            f"{path}: format version {version} not supported "
            f"(this build reads <= {FORMAT_VERSION})"
        )
    json_len = int(prelude["json_len"])
    if json_len > len(raw) - _PRELUDE.itemsize:
        raise IndexFormatError(f"{path}: header table length {json_len} overruns "
                               "the header pages — corrupt header")
    try:
        meta = json.loads(raw[_PRELUDE.itemsize : _PRELUDE.itemsize + json_len])
    except ValueError as e:
        raise IndexFormatError(f"{path}: unparseable header table: {e}") from e
    # a bit-flipped header can parse as JSON and still be garbage: any
    # missing/ill-typed field must surface as IndexFormatError, not as a
    # KeyError/TypeError leaking out of the reader
    try:
        n = int(meta["n"])
        sector_bytes = int(meta["sector_bytes"])
        sections = dict(meta.get("sections", {}))
        spans = []
        for name, s in sections.items():
            offset, nbytes = int(s["offset"]), int(s["nbytes"])
            if offset % PAGE_BYTES:
                raise IndexFormatError(f"{path}: section {name} offset "
                                       f"{offset} is not page-aligned")
            if offset < HEADER_PAGES * PAGE_BYTES:
                raise IndexFormatError(f"{path}: section {name} offset "
                                       f"{offset} overlaps the header pages")
            if nbytes < 0:
                raise IndexFormatError(f"{path}: section {name} has "
                                       f"negative size {nbytes}")
            spans.append((offset, offset + _page_up(nbytes), name))
            if offset + nbytes > file_bytes:
                raise IndexFormatError(
                    f"{path}: section {name} ends at {offset + nbytes} but "
                    f"the file is {file_bytes} B — truncated index"
                )
            # dtype x shape must account for exactly nbytes, else a lying
            # table would mmap past the section (or fail as a raw ValueError)
            shape = tuple(int(x) for x in s["shape"])
            if s["dtype"] == "record":
                want = (n,)
                itemsize = sector_bytes if sector_bytes > 0 else -1
            else:
                want = shape
                itemsize = np.dtype(s["dtype"]).itemsize
            expect = int(np.prod(want, dtype=np.int64)) * itemsize
            if shape != want or expect != nbytes:
                raise IndexFormatError(
                    f"{path}: section {name} declares shape {shape} x "
                    f"{s['dtype']} but nbytes={nbytes} (expected {expect} "
                    f"for shape {want}) — corrupt section table"
                )
        shards_meta = meta.get("shards")
        if shards_meta is not None:
            shards_meta = _validated_shards(path, shards_meta, n, sector_bytes)
            if SEC_RECORDS in sections:
                raise IndexFormatError(
                    f"{path}: both a monolithic records section and a shard "
                    "manifest — corrupt header table"
                )
        header = IndexHeader(
            path=path,
            version=version,
            n=int(meta["n"]),
            dim=int(meta["dim"]),
            degree=int(meta["degree"]),
            sector_bytes=int(meta["sector_bytes"]),
            medoid=int(meta["medoid"]),
            config=dict(meta.get("config", {})),
            sections=sections,
            file_bytes=file_bytes,
            shards=shards_meta,
        )
    except (KeyError, TypeError, ValueError) as e:
        if isinstance(e, IndexFormatError):
            raise
        raise IndexFormatError(f"{path}: corrupt header table: {e!r}") from e
    spans.sort()
    for (_, end_a, name_a), (start_b, _, name_b) in zip(spans, spans[1:]):
        if start_b < end_a:
            raise IndexFormatError(f"{path}: sections {name_a} and {name_b} "
                                   "overlap — corrupt section table")
    if header.n < 0 or header.dim <= 0 or header.degree <= 0:
        raise IndexFormatError(f"{path}: nonsensical geometry "
                               f"n={header.n} dim={header.dim} degree={header.degree}")
    if not 0 <= header.medoid < max(header.n, 1):
        raise IndexFormatError(f"{path}: medoid {header.medoid} out of "
                               f"range [0, {header.n})")
    if header.sector_bytes != record_sector_bytes(header.dim, header.degree):
        raise IndexFormatError(
            f"{path}: sector_bytes={header.sector_bytes} inconsistent with "
            f"dim={header.dim} degree={header.degree} (expected "
            f"{record_sector_bytes(header.dim, header.degree)})"
        )
    return header


@dataclasses.dataclass(frozen=True)
class IndexFile:
    """Read-side handle: header + zero-copy memmap views per section."""

    header: IndexHeader

    def section(self, name: str) -> np.memmap:
        s = self.header.sections.get(name)
        if s is None:
            raise IndexFormatError(f"{self.header.path}: no section {name!r}")
        h = self.header
        dtype = (
            record_dtype(h.dim, h.degree) if s["dtype"] == "record"
            else np.dtype(s["dtype"])
        )
        shape = tuple(s["shape"]) if s["dtype"] != "record" else (h.n,)
        try:
            return np.memmap(h.path, dtype=dtype, mode="r", offset=s["offset"],
                             shape=shape)
        except (ValueError, OSError) as e:
            raise IndexFormatError(
                f"{h.path}: cannot map section {name}: {e}"
            ) from e

    def has_section(self, name: str) -> bool:
        return name in self.header.sections

    def records(self) -> np.memmap:
        if self.header.shards:
            raise IndexFormatError(
                f"{self.header.path}: sharded index has no monolithic "
                "records section — use segment_records(shard)"
            )
        return self.section(SEC_RECORDS)

    def segment_records(self, shard: int) -> np.memmap:
        """One shard's record sectors, memmapped off its segment file."""
        h = self.header
        if not h.shards:
            if shard != 0:
                raise IndexFormatError(
                    f"{h.path}: monolithic index has only shard 0"
                )
            return self.records()
        seg = h.shards["segments"][shard]
        seg_path = h.segment_path(shard)
        read_segment_header(seg_path, expect={
            "shard": shard, "n_shards": h.shards["n_shards"],
            "row_start": seg["row_start"], "n_rows": seg["n_rows"],
            "n": h.n, "dim": h.dim, "degree": h.degree,
            "sector_bytes": h.sector_bytes,
        })
        try:
            return np.memmap(
                seg_path, dtype=record_dtype(h.dim, h.degree), mode="r",
                offset=SEGMENT_HEADER_PAGES * PAGE_BYTES,
                shape=(seg["n_rows"],),
            )
        except (ValueError, OSError) as e:
            raise IndexFormatError(
                f"{seg_path}: cannot map record segment: {e}"
            ) from e

    def vectors(self) -> np.ndarray:
        """Full-precision vectors parsed out of the record sectors."""
        if self.header.shards:
            return np.concatenate([
                np.ascontiguousarray(self.segment_records(i)["vec"])
                for i in range(self.header.n_shards)
            ])
        return np.ascontiguousarray(self.records()["vec"])

    def neighbors(self) -> np.ndarray:
        return np.ascontiguousarray(self.section(SEC_NEIGHBORS))

    def pq_books(self) -> np.ndarray:
        return np.ascontiguousarray(self.section(SEC_PQ_BOOKS))

    def pq_codes(self) -> np.ndarray:
        return np.ascontiguousarray(self.section(SEC_PQ_CODES))

    def filter_kinds(self) -> list[str]:
        return [
            name[len(FILTER_PREFIX):]
            for name in self.header.sections
            if name.startswith(FILTER_PREFIX)
        ]

    def filter_array(self, kind: str) -> np.ndarray:
        return np.ascontiguousarray(self.section(FILTER_PREFIX + kind))


def read_index(path: str) -> IndexFile:
    return IndexFile(header=read_header(path))
