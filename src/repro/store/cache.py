"""Hot-node record cache — the middle storage tier between fast and slow.

Tunneling removes record reads for filter-*failing* nodes; every
filter-passing node still pays a full slow-tier fetch — including the hot
nodes near the medoid that nearly every query traverses.  A static cache
of frequently-visited records is the standard complementary I/O reduction
in SSD-graph systems (DiskANN's ``num_nodes_to_cache``, PipeANN's BFS
cache): keep the full records of the hottest nodes device-resident so a
hit costs a plain gather instead of a slow-tier read.

``CachedRecordStore`` wraps any backing record store exposing
``fetch_fn()`` (``InMemoryRecordStore`` / ``ShardedRecordStore`` /
``HostOffloadRecordStore``); the ``vectors`` / ``neighbors`` /
``record_bytes`` passthroughs additionally require an in-memory-style
backing (the sharded tier keeps only ``local_*`` arrays — pass the full
host arrays to ``wrap`` and skip the passthroughs there).
The hot set is chosen once at build time —
by visit frequency over sample traversals (``visit_freq``) or by BFS
depth from the medoid (``bfs``) — and served as a device-resident gather
inside jit.  The search loop asks ``cached_mask_fn`` which dispatched ids
are hits, counts them as ``n_cache_hits`` instead of ``n_ios``, and the
backing store only ever sees the misses (hit ids are masked to -1 before
the slow-tier fetch, so a hit costs zero slow-tier I/O — no psum payload
on the sharded tier, no host DMA on the offload tier).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.tree_util import Partial

from repro import obs
from repro.store.vector_store import RecordFetchFn, is_lazy_host

CACHE_POLICIES = ("visit_freq", "bfs")

# Maps (B, W) ids -> (B, W) bool: True where the record is cache-resident.
CachedMaskFn = Callable[[jax.Array], jax.Array]


def record_nbytes(dim: int, degree: int) -> int:
    """Slow-tier bytes of one record, 4 KB-aligned like DiskANN sectors."""
    raw = dim * 4 + (degree + 1) * 4
    return ((raw + 4095) // 4096) * 4096


def bfs_hot_set(neighbors: np.ndarray, medoid: int, n_slots: int) -> np.ndarray:
    """First ``n_slots`` nodes in BFS order from the medoid.

    This is the PipeANN/DiskANN warm-up policy: the nodes every query
    crosses first are the ones closest (in hops) to the entry point.
    """
    nbrs = np.asarray(neighbors)
    n = nbrs.shape[0]
    n_slots = min(n_slots, n)
    if n_slots <= 0:
        return np.zeros((0,), np.int32)
    seen = np.zeros(n, bool)
    order: list[int] = []
    frontier = np.asarray([int(medoid)])
    seen[frontier] = True
    while len(order) < n_slots and frontier.size:
        take = min(n_slots - len(order), frontier.size)
        order.extend(frontier[:take].tolist())
        nxt = nbrs[frontier].ravel()
        nxt = nxt[nxt >= 0]
        nxt = np.unique(nxt)
        nxt = nxt[~seen[nxt]]
        seen[nxt] = True
        frontier = nxt
    return np.asarray(order[:n_slots], np.int32)


def visit_freq_hot_set(
    vectors: np.ndarray | jax.Array,
    neighbors: np.ndarray | jax.Array,
    medoid: int,
    n_slots: int,
    *,
    n_samples: int = 64,
    search_l: int = 64,
    seed: int = 0,
) -> np.ndarray:
    """Top ``n_slots`` nodes by visit frequency over sample traversals.

    Runs unfiltered beam searches for ``n_samples`` perturbed corpus
    vectors and counts how often each node is expanded; ties and unfilled
    slots fall back to BFS order from the medoid, so small caches always
    contain the medoid neighborhood even if sampling is sparse.
    """
    from repro.core.graph import beam_search_batch

    nbrs = np.asarray(neighbors)
    n = nbrs.shape[0]
    n_slots = min(n_slots, n)
    if n_slots <= 0:
        return np.zeros((0,), np.int32)
    vecs = jnp.asarray(vectors, jnp.float32)
    rng = np.random.default_rng(seed)
    picks = rng.integers(0, n, size=min(n_samples, n))
    noise = rng.normal(0.0, 0.05, size=(picks.size, vecs.shape[1]))
    queries = jnp.asarray(np.asarray(vecs)[picks] + noise, jnp.float32)
    res = beam_search_batch(
        jnp.asarray(nbrs), vecs, jnp.int32(medoid), queries,
        search_l=search_l, beam_width=4, max_expand=4 * search_l,
    )
    expanded = np.asarray(res.expanded_ids).ravel()
    counts = np.bincount(expanded[expanded >= 0], minlength=n)
    hot = np.argsort(-counts, kind="stable")[:n_slots]
    hot = hot[counts[hot] > 0].astype(np.int32)
    if hot.size < n_slots:  # pad from BFS order, skipping already-chosen ids
        bfs = bfs_hot_set(nbrs, medoid, n)
        extra = bfs[~np.isin(bfs, hot)][: n_slots - hot.size]
        hot = np.concatenate([hot, extra.astype(np.int32)])
    return hot


def select_hot_set(
    *,
    neighbors: np.ndarray | jax.Array,
    medoid: int,
    budget_bytes: int,
    policy: str = "visit_freq",
    vectors: np.ndarray | jax.Array | None = None,
    n_samples: int = 64,
    seed: int = 0,
) -> np.ndarray:
    """Pick the hot-set ids that fit in ``budget_bytes`` of record storage."""
    assert policy in CACHE_POLICIES, policy
    nbrs = np.asarray(neighbors)
    n, r = nbrs.shape
    dim = int(vectors.shape[1]) if vectors is not None else 0
    per_record = record_nbytes(dim, r)
    n_slots = min(int(budget_bytes) // per_record, n)
    # visit_freq samples whole-corpus traversals on device — with a lazy
    # disk-backed vectors view that would materialize the corpus, so fall
    # back to the BFS warm-up policy (vectors still size the budget above)
    if policy == "visit_freq" and vectors is not None and not is_lazy_host(vectors):
        return visit_freq_hot_set(
            vectors, nbrs, int(medoid), n_slots, n_samples=n_samples, seed=seed
        )
    return bfs_hot_set(nbrs, int(medoid), n_slots)


def _cached_fetch(backing_fetch, slot_of, cache_vecs, cache_nbrs, ids):
    slot = jnp.where(ids >= 0, slot_of[jnp.maximum(ids, 0)], jnp.int32(-1))
    hit = slot >= 0
    # the slow tier only ever sees the misses — a hit is a pure device gather
    vecs, nbrs = backing_fetch(jnp.where(hit, jnp.int32(-1), ids))
    safe = jnp.maximum(slot, 0)
    vecs = jnp.where(hit[..., None], cache_vecs[safe], vecs)
    nbrs = jnp.where(hit[..., None], cache_nbrs[safe], nbrs)
    return vecs, nbrs


def _cached_submit(backing_submit, slot_of, cache_nbrs, ids):
    """Async stage A through the cache: only the miss set is submitted to
    the slow tier; hit rows' neighbor lists are a device gather, so the
    returned adjacency matches the synchronous ``_cached_fetch`` exactly."""
    slot = jnp.where(ids >= 0, slot_of[jnp.maximum(ids, 0)], jnp.int32(-1))
    hit = slot >= 0
    token, nbrs = backing_submit(jnp.where(hit, jnp.int32(-1), ids))
    safe = jnp.maximum(slot, 0)
    return token, jnp.where(hit[..., None], cache_nbrs[safe], nbrs)


def _cached_drain(backing_drain, slot_of, cache_vecs, token, ids, live):
    """Async stage B through the cache: the slow tier drains the miss
    rows; hit rows' vectors come off the device-resident block (recomputed
    from ``ids`` — the hit split is a pure function of the slot map, so it
    agrees with what ``_cached_submit`` masked out rounds earlier)."""
    slot = jnp.where(ids >= 0, slot_of[jnp.maximum(ids, 0)], jnp.int32(-1))
    hit = slot >= 0
    vecs = backing_drain(token, jnp.where(hit, jnp.int32(-1), ids), live)
    safe = jnp.maximum(slot, 0)
    return jnp.where(hit[..., None], cache_vecs[safe], vecs)


def _cached_mask(slot_of, ids):
    return (ids >= 0) & (slot_of[jnp.maximum(ids, 0)] >= 0)


@dataclasses.dataclass(frozen=True)
class CachedRecordStore:
    """A static hot-record cache in front of any backing record store."""

    backing: Any  # any store exposing fetch_fn()
    slot_of: jax.Array  # (N,) int32 — node id -> cache slot, -1 if uncached
    cache_vectors: jax.Array  # (C, D) device-resident hot records
    cache_neighbors: jax.Array  # (C, R) full adjacency of the hot records
    policy: str = "visit_freq"

    @classmethod
    def wrap(
        cls,
        backing: Any,
        *,
        vectors: np.ndarray | jax.Array,
        neighbors: np.ndarray | jax.Array,
        hot_ids: np.ndarray,
        policy: str = "visit_freq",
        n_slots: int | None = None,
    ) -> "CachedRecordStore":
        """Cache ``hot_ids`` rows of the full (vectors, neighbors) arrays.

        With ``n_slots``, the cache block is truncated/zero-padded to
        exactly that many rows (surplus rows stay unmapped — ``slot_of``
        never points at them), so repeated wraps at one budget produce
        identically-shaped arrays and never retrace the jitted search
        loop — the adaptive cache refreshes through this path.  The hot
        rows are gathered on device, so a refresh costs O(n_slots), not
        a corpus round-trip.
        """
        nbrs = jnp.asarray(neighbors, jnp.int32)
        hot = np.asarray(hot_ids, np.int32)
        if n_slots is not None:
            hot = hot[:n_slots]
        n = nbrs.shape[0]
        slot_of = np.full((n,), -1, np.int32)
        slot_of[hot] = np.arange(hot.size, dtype=np.int32)
        # an empty hot set keeps one dummy row (never hit: slot_of is all
        # -1) so the jit-side gather always has a non-empty operand
        rows = jnp.asarray(hot) if hot.size else jnp.zeros((1,), jnp.int32)
        dim = int(vectors.shape[1])
        if is_lazy_host(vectors):
            # disk-backed lazy view: gather ONLY the hot rows host-side —
            # shipping the whole corpus to device would defeat the tier
            rows_np = hot if hot.size else np.zeros((1,), np.int32)
            cache_vecs = jnp.asarray(
                np.ascontiguousarray(vectors[rows_np]), jnp.float32
            )
        else:
            cache_vecs = jnp.asarray(vectors, jnp.float32)[rows]
        cache_nbrs = nbrs[rows]
        target = max(n_slots, 1) if n_slots is not None else int(cache_vecs.shape[0])
        pad = target - int(cache_vecs.shape[0])
        if pad > 0:
            cache_vecs = jnp.concatenate(
                [cache_vecs, jnp.zeros((pad, dim), jnp.float32)]
            )
            cache_nbrs = jnp.concatenate(
                [cache_nbrs, jnp.full((pad, nbrs.shape[1]), -1, jnp.int32)]
            )
        # telemetry: one materialization per wrap — the adaptive refresh
        # loop runs through here, so this counts hot-set rebuilds too
        obs.default_registry().counter(
            "cache.materializations", policy=policy
        ).inc()
        return cls(
            backing=backing,
            slot_of=jnp.asarray(slot_of),
            cache_vectors=cache_vecs,
            cache_neighbors=cache_nbrs,
            policy=policy,
        )

    # -- the two jit-side entry points -------------------------------------
    def fetch_fn(self) -> RecordFetchFn:
        return Partial(
            _cached_fetch,
            self.backing.fetch_fn(),
            self.slot_of,
            self.cache_vectors,
            self.cache_neighbors,
        )

    def cached_mask_fn(self) -> CachedMaskFn:
        return Partial(_cached_mask, self.slot_of)

    def submit_fn(self):
        """Async submission through the cache, or None if the backing
        store has no async pair (in-memory/host/sharded tiers)."""
        bs = getattr(self.backing, "submit_fn", None)
        if bs is None:
            return None
        return Partial(_cached_submit, bs(), self.slot_of, self.cache_neighbors)

    def drain_fn(self):
        bd = getattr(self.backing, "drain_fn", None)
        if bd is None:
            return None
        return Partial(_cached_drain, bd(), self.slot_of, self.cache_vectors)

    # -- reporting ---------------------------------------------------------
    @property
    def n_cached(self) -> int:
        return int((np.asarray(self.slot_of) >= 0).sum())

    def cache_bytes(self) -> int:
        """Slow-tier bytes the cache displaces (4 KB-aligned records)."""
        d = int(self.cache_vectors.shape[1])
        return self.n_cached * record_nbytes(d, int(self.cache_neighbors.shape[1]))

    def device_bytes(self) -> int:
        """Actual device bytes held: packed records + the slot map."""
        c, d = self.cache_vectors.shape
        r = int(self.cache_neighbors.shape[1])
        return c * (d + r) * 4 + int(self.slot_of.shape[0]) * 4

    def hot_ids(self) -> np.ndarray:
        """Cached node ids in slot order."""
        slot_of = np.asarray(self.slot_of)
        ids = np.flatnonzero(slot_of >= 0)
        return ids[np.argsort(slot_of[ids])].astype(np.int32)

    def io_counters(self) -> dict:
        """Measured counters of the backing tier ({} when it only models
        its I/O) — serving layers attribute per-tenant reads through this
        without caring how many cache tiers sit above the slow store."""
        f = getattr(self.backing, "io_counters", None)
        return f() if f is not None else {}

    def abandon_pending(self) -> int:
        """Retire the backing tier's submitted-but-undrained rounds (0
        when the backing has no async pair)."""
        f = getattr(self.backing, "abandon_pending", None)
        return f() if f is not None else 0

    # -- passthroughs so engine/test code can reach the backing arrays -----
    @property
    def vectors(self):
        return self.backing.vectors

    @property
    def neighbors(self):
        return self.backing.neighbors

    def record_bytes(self) -> int:
        return self.backing.record_bytes()
