"""Record stores — the expensive storage tier holding full-precision records.

A *record* is the TPU analogue of DiskANN's 4 KB SSD sector: the node's
full-precision vector together with its full adjacency list.  Fetching a
record is the expensive operation GateANN's tunneling avoids; three tiers
are provided:

  * ``InMemoryRecordStore``   — plain device gathers (CPU tests, and the
                                Vamana in-memory baseline tier).
  * ``ShardedRecordStore``    — records sharded over the mesh ``model``
                                axis; a fetch is a masked local gather +
                                ``psum`` over ``model`` (remote HBM over
                                ICI — the production "SSD read").
  * ``HostOffloadRecordStore``— records pinned in host memory via
                                ``memory_kind='pinned_host'``; a fetch is
                                a host-DMA gather (closest analogue to an
                                NVMe read on a real TPU host).

All expose ``fetch_fn() -> (ids (B, W)) -> (vecs (B, W, D), nbrs (B, W, R))``
usable inside jit / shard_map.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.tree_util import Partial

# A RecordFetchFn maps (B, W) ids -> (vecs (B, W, D), nbrs (B, W, R)).
# Concrete stores return jax.tree_util.Partial so fetches are pytrees
# (stable function identity, traced storage leaves — no retrace per call).
RecordFetchFn = Callable[[jax.Array], Tuple[jax.Array, jax.Array]]


def is_lazy_host(a) -> bool:
    """True for lazy host-resident corpus views (the disk tier's
    ``vectors``) that must never be shipped to the device wholesale —
    cache wiring gathers the hot rows host-side instead.  Covers
    memmap-backed arrays and any object flagging ``__lazy_host__``
    (e.g. the multi-segment ``LazySegmentVectors``)."""
    if getattr(a, "__lazy_host__", False):
        return True
    while isinstance(a, np.ndarray):
        if isinstance(a, np.memmap):
            return True
        if a.base is None:
            return False
        a = a.base
    return False


def _inmem_fetch(vectors, neighbors, ids):
    safe = jnp.maximum(ids, 0)
    vecs = jnp.where(ids[..., None] >= 0, vectors[safe], 0.0)
    nbrs = jnp.where(ids[..., None] >= 0, neighbors[safe], jnp.int32(-1))
    return vecs, nbrs


@dataclasses.dataclass(frozen=True)
class InMemoryRecordStore:
    vectors: jax.Array  # (N, D) float32
    neighbors: jax.Array  # (N, R) int32

    def fetch_fn(self) -> RecordFetchFn:
        return Partial(_inmem_fetch, self.vectors, self.neighbors)

    def record_bytes(self) -> int:
        n, d = self.vectors.shape
        r = self.neighbors.shape[1]
        # 4 KB-aligned like DiskANN sectors
        raw = d * 4 + (r + 1) * 4
        return n * ((raw + 4095) // 4096) * 4096


_SHARDED_FETCH_CACHE: dict = {}


def _sharded_fetch_factory(axis_name):
    """Per-axis-name fetch fn with stable identity (cached)."""
    if axis_name not in _SHARDED_FETCH_CACHE:

        def fetch(lv, ln, rows, ids, _axis=axis_name):
            shard = jax.lax.axis_index(_axis)
            local = ids - shard * rows
            mine = (ids >= 0) & (local >= 0) & (local < rows)
            safe = jnp.clip(local, 0, lv.shape[0] - 1)
            vecs = jnp.where(mine[..., None], lv[safe], 0.0)
            nbrs = jnp.where(mine[..., None], ln[safe] + 1, 0)  # shift: -1 pad sums right
            vecs = jax.lax.psum(vecs, _axis)
            nbrs = jax.lax.psum(nbrs, _axis) - 1  # unshift: unowned/-1 rows -> -1
            nbrs = jnp.where(ids[..., None] >= 0, nbrs, jnp.int32(-1))
            return vecs, nbrs

        _SHARDED_FETCH_CACHE[axis_name] = fetch
    return _SHARDED_FETCH_CACHE[axis_name]


@dataclasses.dataclass(frozen=True)
class ShardedRecordStore:
    """Records sharded row-wise over the ``model`` mesh axis.

    Inside a ``shard_map`` over ``model``, each device holds rows
    [shard_id * rows_per_shard, ...). A fetch broadcasts the id beam
    (replicated over ``model``), every device gathers the rows it owns
    (zeros elsewhere), and one ``psum`` over ``model`` materializes the
    records on all devices.  Collective bytes per fetch =
    B * W * record_size — this is the quantity graph tunneling removes.
    """

    local_vectors: jax.Array  # (N/shards, D) — per-device rows inside shard_map
    local_neighbors: jax.Array  # (N/shards, R)
    rows_per_shard: int
    axis_name: str = "model"

    def fetch_fn(self) -> RecordFetchFn:
        return Partial(
            _sharded_fetch_factory(self.axis_name),
            self.local_vectors,
            self.local_neighbors,
            jnp.int32(self.rows_per_shard),
        )

    @staticmethod
    def shard_arrays(vectors: np.ndarray, neighbors: np.ndarray, n_shards: int):
        """Pad + split host arrays into per-shard rows (for shard_map use)."""
        n = vectors.shape[0]
        rows = -(-n // n_shards)
        pad = rows * n_shards - n
        v = np.pad(vectors, ((0, pad), (0, 0)))
        g = np.pad(neighbors, ((0, pad), (0, 0)), constant_values=-1)
        return v, g, rows


@dataclasses.dataclass(frozen=True)
class HostOffloadRecordStore:
    """Records resident in host memory (``pinned_host``); fetch = host DMA.

    Falls back to an in-memory store if the backend lacks host memory
    spaces (e.g. some CPU builds).
    """

    vectors: jax.Array
    neighbors: jax.Array

    @classmethod
    def create(cls, vectors, neighbors) -> "HostOffloadRecordStore":
        try:
            dev = jax.devices()[0]
            host_sharding = jax.sharding.SingleDeviceSharding(dev, memory_kind="pinned_host")
            vectors = jax.device_put(jnp.asarray(vectors), host_sharding)
            neighbors = jax.device_put(jnp.asarray(neighbors), host_sharding)
        except (ValueError, RuntimeError):  # backend without pinned_host
            vectors = jnp.asarray(vectors)
            neighbors = jnp.asarray(neighbors)
        return cls(vectors=vectors, neighbors=neighbors)

    def fetch_fn(self) -> RecordFetchFn:
        return Partial(_inmem_fetch, self.vectors, self.neighbors)
