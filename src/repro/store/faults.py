"""Deterministic, seedable fault injection for the disk read path.

A :class:`FaultPlan` describes *what the SSD does wrong* — transient
``EIO`` / ``EAGAIN`` errors, short reads, injected latency — either as
per-call probabilities or as a scripted schedule of (call_index, kind)
pairs.  :class:`FaultInjector` (one per opened store) turns the plan
into the three fd-read entry points ``DiskRecordStore`` actually
issues:

  * ``preadv(fd, views, offset)``  — the coalesced vectored read
  * ``pread(fd, n, offset)``       — the per-range fallback
  * ``gather(fn)``                 — the memmap oracle's fancy-gather

so every io_mode AND the async ``submit``/``drain`` reader pool (whose
workers call the same ``_host_fetch``) flow through one choke point.
Nothing else in the store changes: with an all-zero plan the wrapper
calls straight through to ``os.preadv``/``os.pread`` and search results
are bit-identical to an uninjected store.

Determinism: fault decisions are a pure function of ``(plan.seed,
call_index)`` — each read call draws its own ``np.random.default_rng``
stream, so the decision for call #17 is the same no matter how calls
interleave across reader threads.  The *set* of faulted calls is stable
under concurrency; which logical round a given call index lands on can
shift with thread scheduling, which is why tier-1 tests use scripted
``schedule`` entries against single-threaded (depth-1) reads and leave
the probabilistic sweeps to the nightly chaos matrix.

Short reads are injected *honestly*: the injector issues a real
``os.preadv``/``os.pread`` truncated to ``short_frac`` of the wanted
bytes, so the resume loops in ``_preadv_full``/``_pread_full`` are
exercised against genuine partial data, not a simulated return code.
"""
from __future__ import annotations

import dataclasses
import errno
import os
import threading
import time

import numpy as np

FAULT_KINDS = ("eio", "eagain", "short", "delay")

_ERRNO = {"eio": errno.EIO, "eagain": errno.EAGAIN}


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """What to inject, how often, and in what order.

    ``p_<kind>`` are per-read-call probabilities (stacked: one uniform
    per call is drawn against cumulative thresholds, so at most one
    fault fires per call and the sum must stay <= 1).  ``schedule``
    overrides the dice for specific call indices — ``((3, "eio"),
    (7, "short"))`` faults exactly calls 3 and 7 — and works with all
    probabilities at zero, which is what deterministic tier-1 tests
    use.  ``max_faults`` bounds the total injected (None = unbounded).
    """

    seed: int = 0
    p_eio: float = 0.0
    p_eagain: float = 0.0
    p_short: float = 0.0
    p_delay: float = 0.0
    delay_s: float = 0.001
    short_frac: float = 0.5  # fraction of wanted bytes a short read returns
    schedule: tuple = ()  # ((call_index, kind), ...) scripted overrides
    max_faults: int | None = None

    def __post_init__(self):
        total = self.p_eio + self.p_eagain + self.p_short + self.p_delay
        if not 0.0 <= total <= 1.0:
            raise ValueError(f"fault probabilities sum to {total}, not in [0, 1]")
        if not 0.0 < self.short_frac < 1.0:
            raise ValueError(f"short_frac={self.short_frac} must be in (0, 1)")
        for idx, kind in self.schedule:
            if kind not in FAULT_KINDS:
                raise ValueError(f"schedule kind {kind!r} not in {FAULT_KINDS}")
            if int(idx) < 0:
                raise ValueError(f"schedule call index {idx} is negative")

    @property
    def active(self) -> bool:
        """True if this plan can ever inject anything."""
        return bool(self.schedule) or (
            self.p_eio + self.p_eagain + self.p_short + self.p_delay
        ) > 0.0

    def injector(self) -> "FaultInjector":
        return FaultInjector(self)


class FaultInjector:
    """One store's live injection state: a call counter plus the three
    wrapped read entry points.  Thread-safe — the reader pool's workers
    share one injector."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._schedule = {int(i): k for i, k in plan.schedule}
        self._p_total = plan.p_eio + plan.p_eagain + plan.p_short + plan.p_delay
        self._lock = threading.Lock()
        self.calls = 0  # guarded by _lock
        self.faults_injected = 0  # guarded by _lock
        self.injected = {k: 0 for k in FAULT_KINDS}  # guarded by _lock

    def counters(self) -> dict:
        with self._lock:
            out = {"read_calls": self.calls, "faults_injected": self.faults_injected}
            out.update({f"injected_{k}": v for k, v in self.injected.items()})
            return out

    def _decide(self) -> str | None:
        """Pick this call's fault (or None), advancing the call counter."""
        with self._lock:
            idx = self.calls
            self.calls += 1
            kind = self._schedule.get(idx)
            if kind is None and self._p_total > 0.0:
                u = float(np.random.default_rng((self.plan.seed, idx)).random())
                acc = 0.0
                for k in FAULT_KINDS:
                    acc += getattr(self.plan, "p_" + k)
                    if u < acc:
                        kind = k
                        break
            if kind is not None:
                if (
                    self.plan.max_faults is not None
                    and self.faults_injected >= self.plan.max_faults
                ):
                    return None
                self.faults_injected += 1
                self.injected[kind] += 1
            return kind

    def _raise(self, kind: str, op: str, offset: int) -> None:
        raise OSError(_ERRNO[kind], f"injected {kind} ({op} at offset {offset})")

    # -- the wrapped read entry points (os.* signatures) -------------------
    def preadv(self, fd: int, views, offset: int) -> int:
        kind = self._decide()
        if kind in ("eio", "eagain"):
            self._raise(kind, "preadv", offset)
        if kind == "delay":
            time.sleep(self.plan.delay_s)
        elif kind == "short":
            batch = list(views)
            want = sum(len(v) for v in batch)
            target = min(max(1, int(want * self.plan.short_frac)), max(want - 1, 1))
            if target < want:
                # issue a REAL read of the truncated prefix: the caller's
                # resume loop re-reads the rest from the actual file
                cut, n = [], 0
                for v in batch:
                    take = min(len(v), target - n)
                    cut.append(v[:take])
                    n += take
                    if n >= target:
                        break
                return os.preadv(fd, cut, offset)
        return os.preadv(fd, views, offset)

    def pread(self, fd: int, n: int, offset: int) -> bytes:
        kind = self._decide()
        if kind in ("eio", "eagain"):
            self._raise(kind, "pread", offset)
        if kind == "delay":
            time.sleep(self.plan.delay_s)
        elif kind == "short":
            k = min(max(1, int(n * self.plan.short_frac)), max(n - 1, 1))
            if k < n:
                return os.pread(fd, k, offset)
        return os.pread(fd, n, offset)

    def gather(self, fn):
        """Wrap one memmap fancy-gather; ``short`` has no meaning for a
        page-faulted read, so only error/delay kinds fire here."""
        kind = self._decide()
        if kind in ("eio", "eagain"):
            self._raise(kind, "gather", 0)
        if kind == "delay":
            time.sleep(self.plan.delay_s)
        return fn()


__all__ = ["FAULT_KINDS", "FaultPlan", "FaultInjector"]
