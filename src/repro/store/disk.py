"""File-backed slow tier — the record store that actually does I/O.

``DiskRecordStore`` serves ``(B, W)`` id beams straight off the
page-aligned record section of an index file (store/format.py) through
``jax.experimental.io_callback``: the jitted search loop dispatches a
beam, the host callback gathers the corresponding 4 KB-aligned sectors
from an ``np.memmap``, and the result re-enters the trace.  Same
``RecordFetchFn`` contract as the in-memory/host/sharded stores, so the
cache tiers (``CachedRecordStore`` / ``AdaptiveRecordCache``) wrap it
unchanged — a cache hit masks the id to -1 before the callback, so a hit
costs zero file reads.

Unlike every other tier, this one *measures* its I/O instead of modeling
it: monotonic ``pages_read`` / ``bytes_read`` / ``records_read`` counters
advance inside the host callback by exactly the sectors gathered.  Tests
and ``benchmarks/disk_sweep.py`` reconcile counter deltas against the
search loop's ``SearchStats.n_ios`` — the paper's central quantity
(sector reads removed by tunneling) measured, not modeled.

Counter discipline: jax dispatch is asynchronous, so read the counters
only after materializing the search outputs (``np.asarray(out.ids)`` or
``jax.block_until_ready``) — every fetch feeds the loop-carried state, so
output materialization implies all callbacks ran.
"""
from __future__ import annotations

import os
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback
from jax.tree_util import Partial

from repro.store.format import PAGE_BYTES, IndexFile, read_header


class DiskRecordStore:
    """Slow-tier record store backed by an on-disk index file."""

    def __init__(self, path: str):
        header = read_header(path)
        self.path = path
        self.header = header
        self.n = header.n
        self.dim = header.dim
        self.degree = header.degree
        self.sector_bytes = header.sector_bytes
        self.pages_per_record = header.sector_bytes // PAGE_BYTES
        # measured, monotonic I/O counters (advanced by the host callback)
        self.pages_read = 0
        self.bytes_read = 0
        self.records_read = 0
        self._records = IndexFile(header).records()  # (N,) sector memmap
        self._neighbors = None  # lazy full-adjacency parse (host convenience)
        self._vectors = None
        # one Partial per store: stable pytree identity, so repeated
        # searches against the same store never retrace the jitted loop
        self._fetch = Partial(self._traced_fetch)

    @classmethod
    def open(cls, path: str) -> "DiskRecordStore":
        return cls(path)

    # -- the measured host read --------------------------------------------
    def _host_fetch(self, ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Gather record sectors for ``ids`` (>= 0); count what was read."""
        ids = np.asarray(ids)
        valid = ids >= 0
        flat = np.clip(ids, 0, self.n - 1).reshape(-1)
        vmask = valid.reshape(-1)
        vecs = np.zeros(ids.shape + (self.dim,), np.float32)
        nbrs = np.full(ids.shape + (self.degree,), -1, np.int32)
        m = int(vmask.sum())
        if m:
            got = self._records[flat[vmask]]  # the only file reads
            vecs.reshape(-1, self.dim)[vmask] = got["vec"]
            nbrs.reshape(-1, self.degree)[vmask] = got["nbrs"]
        self.records_read += m
        self.pages_read += m * self.pages_per_record
        self.bytes_read += m * self.sector_bytes
        return vecs, nbrs

    def _traced_fetch(self, ids: jax.Array) -> Tuple[jax.Array, jax.Array]:
        out_shapes = (
            jax.ShapeDtypeStruct(ids.shape + (self.dim,), jnp.float32),
            jax.ShapeDtypeStruct(ids.shape + (self.degree,), jnp.int32),
        )
        # ordered: fetches must all execute (and in program order) so the
        # measured counters reconcile exactly with SearchStats.n_ios
        return io_callback(self._host_fetch, out_shapes, ids, ordered=True)

    def fetch_fn(self):
        return self._fetch

    # -- measured-I/O reporting --------------------------------------------
    def io_counters(self) -> dict:
        return {
            "records_read": self.records_read,
            "pages_read": self.pages_read,
            "bytes_read": self.bytes_read,
        }

    def reset_io_counters(self) -> None:
        self.pages_read = self.bytes_read = self.records_read = 0

    def index_bytes(self) -> int:
        """Total on-disk footprint of the index file."""
        return int(os.path.getsize(self.path))

    def record_bytes(self) -> int:
        """Slow-tier record-section bytes (same pricing as the other tiers)."""
        return self.n * self.sector_bytes

    # -- host-side passthroughs (cache wiring, tests, ground truth) --------
    @property
    def neighbors(self) -> jax.Array:
        if self._neighbors is None:
            self._neighbors = jnp.asarray(
                IndexFile(self.header).neighbors(), jnp.int32
            )
        return self._neighbors

    @property
    def vectors(self) -> jax.Array:
        if self._vectors is None:
            self._vectors = jnp.asarray(
                np.ascontiguousarray(self._records["vec"]), jnp.float32
            )
        return self._vectors
