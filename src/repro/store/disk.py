"""File-backed slow tier — the record store that actually does I/O.

``DiskRecordStore`` serves ``(B, W)`` id beams straight off the
page-aligned record section of an index file (store/format.py) through
``jax.experimental.io_callback``: the jitted search loop dispatches a
beam, the host callback reads the corresponding 4 KB-aligned sectors,
and the result re-enters the trace.  Same ``RecordFetchFn`` contract as
the in-memory/host/sharded stores, so the cache tiers
(``CachedRecordStore`` / ``AdaptiveRecordCache``) wrap it unchanged — a
cache hit masks the id to -1 before the callback, so a hit costs zero
file reads.

The read path is **coalesced**, the way PipeANN keeps W reads in flight
instead of issuing them one by one: each round's beam is sorted,
deduplicated, and merged into contiguous sector ranges, then fetched as

  * ``io_mode="preadv"`` (default where available) — ONE vectored
    ``os.preadv`` per round and segment: wanted ranges scatter directly
    into the output buffer, the gaps between them land in a reusable
    discard buffer (counted in ``gap_sectors_read`` — the page-cache
    over-read this trade buys its single syscall with; production
    deployments bound it by sharding, see below).  Rounds wider than
    ``IOV_MAX`` split into multiple counted calls.
  * ``io_mode="pread"`` — one ``os.pread`` per merged range (no
    over-read; ``syscalls == ranges_read``).
  * ``io_mode="gather"`` — the legacy per-record memmap fancy-gather
    (page faults, no explicit syscalls; kept as the parity oracle).

Results are scattered back to beam order, so search output is
bit-identical across all three modes.

Unlike every other tier, this one *measures* its I/O instead of modeling
it.  Two counter families advance inside the host callback, guarded by a
``threading.Lock`` (engines sharing one store — every ``with_cache``
re-wrap does — must not lose updates):

  * logical  — ``records_read`` / ``pages_read`` / ``bytes_read``: the
    sectors the search loop *requested* (duplicates included).  These
    reconcile EXACTLY with summed ``SearchStats.n_ios`` — the mask
    discipline check (cache hits and filter-gated nodes never reach the
    file).
  * physical — ``unique_sectors_read`` / ``ranges_read`` / ``syscalls``
    / ``gap_sectors_read`` / ``read_rounds``: what the coalesced reader
    actually did.  Contract: ``unique_sectors_read <= records_read``
    with equality when a round has no intra-round duplicates, and on the
    preadv path ``syscalls == read_rounds`` (one vectored read per round
    per touched segment).

Bridged gaps are bounded by ``max_gap_sectors``: when the hole between
two wanted ranges exceeds the bound, the round splits into another
vectored call instead of reading through it — the syscall-count vs
read-amplification trade as an explicit knob (``None``/negative =
unbounded, today's single-call behavior; ``0`` = never bridge, one call
per merged range).

**Asynchronous pipeline interface** (the PipeANN overlap, done host-side):
``submit(ids) -> (token, nbrs)`` enqueues the round's coalesced sector
read on a background reader pool and returns immediately with the
neighbor lists served from the index file's full-adjacency *sidecar* —
traversal needs only neighbor lists and PQ distances, never the
full-precision record, so the search loop can dispatch round r+1's beam
while round r's ``preadv`` is still in flight.  ``drain(token) ->
records`` blocks until that round's read completes and returns the
record vectors for the exact-distance result pool.  Reads stay
bit-identical to the synchronous ``fetch_fn`` path (same coalesced
reader, same counters); two extra counters measure the overlap actually
achieved: ``inflight_depth_max`` (peak submitted-but-undrained tokens)
and ``overlapped_rounds`` (submissions issued while an earlier read was
still undrained).

A sharded index (``engine.save(shards=k)``) opens one reader per record
segment; only the segments a round's beam touches are read (and on a
mesh, ``core.distributed_search.load_shard_records`` opens just the
local shard's file).

``warm(background=True)`` sequentially re-reads the segment files on a
daemon thread to re-populate the OS page cache after a load (counted in
``warmed_bytes``); ``close()`` only signals it to stop — it never blocks
on the warmer.

**Resilience** (``RetryPolicy`` / ``on_error`` / ``round_deadline_s``):
transient read errors (``TRANSIENT_ERRNOS``) retry with bounded
exponential backoff + seeded jitter (``retried_ios``/``retry_exhausted``
counters, ``disk.retry`` obs spans); a per-round deadline bounds how
long one fetch round may spend in I/O (``deadline_trips``).  When
retries exhaust or the deadline trips, ``on_error="degrade"`` marks the
failed records instead of raising: their vectors come back as the +inf
tunnel sentinel and their neighbor lists from the adjacency sidecar, so
the search loop keeps full graph connectivity and simply drops the slots
from the exact-ranked results — GateANN's own tunneling, repurposed as
the degraded mode (``degraded_records``; ``SearchStats.n_degraded``
carries the per-query view).  Logical counters keep counting every
*requested* record under faults, so n_ios reconciliation is fault-proof.
``store/faults.py`` injects deterministic faults underneath all of this
for tests and the chaos-matrix nightly.

Counter discipline: jax dispatch is asynchronous, so read the counters
only after materializing the search outputs (``np.asarray(out.ids)`` or
``jax.block_until_ready``) — every fetch feeds the loop-carried state, so
output materialization implies all callbacks ran (a drain blocks on its
round's read, so retired rounds have fully-counted I/O).
"""
from __future__ import annotations

import dataclasses
import errno
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback
from jax.tree_util import Partial

from repro import obs
from repro.store.format import (
    PAGE_BYTES,
    SEC_NEIGHBORS,
    SEGMENT_HEADER_PAGES,
    IndexFile,
    record_dtype,
    read_header,
)
from repro.store.vector_store import is_lazy_host  # re-export (home base)

_HAVE_PREADV = hasattr(os, "preadv")
_HAVE_PREAD = hasattr(os, "pread")
_IOV_MAX = 1000  # stay under the kernel's 1024-iovec ceiling
_GAP_CHUNK = 1 << 20  # discard-buffer granularity for bridged gaps

IO_MODES = ("preadv", "pread", "gather")

# error taxonomy: these errnos are worth retrying — the device/page-cache
# path can transiently fail (EIO on a flaky link, EAGAIN under pressure,
# EINTR on a signal, ETIMEDOUT from network-backed block devices) and
# succeed on the reattempt.  Everything else (EBADF, ENOENT, EFAULT, a
# short-read EOF, ...) means the request itself is wrong or the file is
# gone: retrying cannot help, so those raise immediately whatever the
# policy says.
TRANSIENT_ERRNOS = frozenset(
    {errno.EIO, errno.EAGAIN, errno.EINTR, errno.ETIMEDOUT}
)

ON_ERROR_POLICIES = ("fail", "degrade")


def is_transient(exc: BaseException) -> bool:
    """True for OSErrors a bounded retry may fix (see TRANSIENT_ERRNOS)."""
    return isinstance(exc, OSError) and exc.errno in TRANSIENT_ERRNOS


class ReadDeadlineError(OSError):
    """The per-round read deadline tripped before this read completed.

    Carries ``errno.ETIMEDOUT`` so the degrade path treats it like any
    other exhausted transient error (the round's remaining slots degrade
    instead of failing the query)."""

    def __init__(self, msg: str):
        super().__init__(errno.ETIMEDOUT, msg)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff + deterministic jitter for
    transient read errors.  ``max_retries=0`` (the default) preserves the
    historical fail-fast behavior exactly."""

    max_retries: int = 0
    backoff_s: float = 1e-3  # first backoff; doubles (backoff_mult) after
    backoff_mult: float = 2.0
    jitter: float = 0.5  # +/- fraction of each backoff, seeded, not wall-clock
    seed: int = 0

    def backoff(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based), jitter applied.

        Deterministic: the jitter draw is a pure function of
        ``(seed, attempt)``, so a scripted fault test sleeps the same
        amount every run."""
        delay = self.backoff_s * self.backoff_mult ** (attempt - 1)
        if self.jitter > 0.0:
            u = float(np.random.default_rng((self.seed, attempt)).random())
            delay *= 1.0 + self.jitter * (2.0 * u - 1.0)
        return max(delay, 0.0)


def default_io_mode() -> str:
    if _HAVE_PREADV:
        return "preadv"
    if _HAVE_PREAD:
        return "pread"
    return "gather"


def merge_ranges(sectors: np.ndarray) -> np.ndarray:
    """Sorted unique sector ids -> (R, 2) [start, count) contiguous runs."""
    sectors = np.asarray(sectors, np.int64)
    if sectors.size == 0:
        return np.zeros((0, 2), np.int64)
    breaks = np.flatnonzero(np.diff(sectors) != 1)
    starts = np.concatenate([[0], breaks + 1])
    ends = np.concatenate([breaks, [sectors.size - 1]])
    return np.stack([sectors[starts], ends - starts + 1], axis=1)


def _preadv_full(readv, views, offset) -> int:
    """Vectored read of ``views`` at ``offset``, resuming short reads and
    chunking at IOV_MAX; returns the number of preadv calls issued.

    ``readv(batch, off) -> int`` is an ``os.preadv``-compatible callable
    with the fd bound — the raw syscall, the fault injector's wrapper,
    or the store's retrying wrapper."""
    calls = 0
    pending = list(views)
    off = int(offset)
    while pending:
        batch = pending[:_IOV_MAX]
        want = sum(len(v) for v in batch)
        got = readv(batch, off)
        calls += 1
        if got <= 0:
            raise IOError(f"preadv: unexpected EOF at offset {off}")
        off += got
        if got == want:
            pending = pending[_IOV_MAX:]
            continue
        # short read (EOF excluded by validation; signals can still truncate)
        k = 0
        while got >= len(batch[k]):
            got -= len(batch[k])
            k += 1
        rest = list(batch[k:])
        if got:
            rest[0] = rest[0][got:]
        pending = rest + pending[_IOV_MAX:]
    return calls


def _pread_full(read, view, offset) -> int:
    """Plain positional read into ``view``; returns syscalls issued.

    ``read(n, off) -> bytes`` is an ``os.pread``-compatible callable
    with the fd bound."""
    calls = 0
    off = int(offset)
    mv = memoryview(view)
    while len(mv):
        data = read(len(mv), off)
        calls += 1
        if not data:
            raise IOError(f"pread: unexpected EOF at offset {off}")
        mv[: len(data)] = data
        mv = mv[len(data):]
        off += len(data)
    return calls


def _passthrough_gather(fn):
    """The uninjected gather entry point: just run the memmap gather."""
    return fn()


@dataclasses.dataclass
class _Segment:
    """One open record file: fd for coalesced reads, lazy memmap for the
    gather oracle and the lazy ``vectors`` view."""

    path: str
    row_start: int
    n_rows: int
    data_offset: int  # file offset of sector 0 (row ``row_start``)
    rec_dtype: np.dtype
    fd: int = -1  # guarded by _open_lock
    _mmap: np.memmap | None = None  # guarded by _open_lock
    # first-open is lazy and stores are shared across threads — an
    # unsynchronized double-open would leak the losing thread's fd
    _open_lock: threading.Lock = dataclasses.field(default_factory=threading.Lock)

    def open_fd(self) -> int:
        if self.fd < 0:
            with self._open_lock:
                if self.fd < 0:
                    self.fd = os.open(self.path, os.O_RDONLY)
        return self.fd

    def records(self) -> np.memmap:
        if self._mmap is None:
            with self._open_lock:
                if self._mmap is None:
                    self._mmap = np.memmap(
                        self.path, dtype=self.rec_dtype, mode="r",
                        offset=self.data_offset, shape=(self.n_rows,),
                    )
        return self._mmap

    def close(self) -> None:
        with self._open_lock:
            if self.fd >= 0:
                os.close(self.fd)
                self.fd = -1
            self._mmap = None


class LazySegmentVectors:
    """Read-only lazy ``(N, D)`` corpus view over per-segment record
    memmaps — the sharded counterpart of the single-segment memmap view.

    Row indexing (int / slice / integer- or boolean-array) gathers ONLY
    the touched rows off the touched segments; ``np.asarray`` is the
    explicit materialization (ground-truth/debug) path.  Flagged
    ``__lazy_host__`` so ``is_lazy_host`` keeps cache wiring host-side
    regardless of segment count.
    """

    __lazy_host__ = True

    def __init__(self, segments: list[_Segment], dim: int):
        self._segments = segments
        self._row_starts = np.asarray([s.row_start for s in segments], np.int64)
        self._n = segments[-1].row_start + segments[-1].n_rows
        self._dim = int(dim)

    @property
    def shape(self) -> tuple:
        return (self._n, self._dim)

    @property
    def dtype(self):
        return np.dtype(np.float32)

    ndim = 2

    def __len__(self) -> int:
        return self._n

    def __getitem__(self, idx):
        if isinstance(idx, (int, np.integer)):
            if not -self._n <= idx < self._n:
                raise IndexError(f"row {idx} out of range [0, {self._n})")
            return self[np.asarray([idx], np.int64)][0]
        if isinstance(idx, slice):
            idx = np.arange(*idx.indices(self._n), dtype=np.int64)
        idx = np.asarray(idx)
        if idx.dtype == bool:
            idx = np.flatnonzero(idx)
        if idx.ndim != 1:
            raise TypeError(
                "LazySegmentVectors supports 1-D row indexing only; "
                "np.asarray(...) it for anything fancier"
            )
        rows = np.where(idx < 0, idx + self._n, idx).astype(np.int64)
        out = np.empty((rows.size, self._dim), np.float32)
        seg_of = np.searchsorted(self._row_starts, rows, side="right") - 1
        for si in np.unique(seg_of):
            seg = self._segments[si]
            mask = seg_of == si
            out[mask] = seg.records()["vec"][rows[mask] - seg.row_start]
        return out

    def __array__(self, dtype=None, copy=None):  # noqa: D105 — np protocol
        out = np.concatenate([s.records()["vec"] for s in self._segments])
        return out.astype(dtype) if dtype is not None else out


class DiskRecordStore:
    """Slow-tier record store backed by an on-disk index file."""

    def __init__(
        self,
        path: str,
        *,
        io_mode: str = "auto",
        max_gap_sectors: int | None = None,
        reader_threads: int = 4,
        faults=None,  # FaultPlan (store/faults.py) — testing/chaos only
        retry: RetryPolicy | None = None,
        on_error: str = "fail",
        round_deadline_s: float = 0.0,
    ):
        header = read_header(path)
        self.path = path
        self.header = header
        self.n = header.n
        self.dim = header.dim
        self.degree = header.degree
        self.sector_bytes = header.sector_bytes
        self.pages_per_record = header.sector_bytes // PAGE_BYTES
        if io_mode == "auto":
            io_mode = default_io_mode()
        if io_mode not in IO_MODES:
            raise ValueError(f"io_mode={io_mode!r} not in {IO_MODES}")
        if io_mode == "preadv" and not _HAVE_PREADV:
            io_mode = "pread" if _HAVE_PREAD else "gather"
        if io_mode == "pread" and not _HAVE_PREAD:
            io_mode = "gather"
        self.io_mode = io_mode
        # preadv gap-bridging bound, in sectors (None/negative = unbounded)
        if max_gap_sectors is not None and max_gap_sectors < 0:
            max_gap_sectors = None
        self.max_gap_sectors = max_gap_sectors
        self.reader_threads = max(int(reader_threads), 1)
        # resilience policy: how transient read errors are retried and what
        # happens when retries exhaust / the round deadline trips.  All
        # three knobs may be retuned at runtime (configure_resilience).
        if on_error not in ON_ERROR_POLICIES:
            raise ValueError(f"on_error={on_error!r} not in {ON_ERROR_POLICIES}")
        self.retry_policy = retry if retry is not None else RetryPolicy()
        self.on_error = on_error
        self.round_deadline_s = float(round_deadline_s)  # 0 = no deadline
        # fault injection (store/faults.py): the injector wraps the three
        # os-level read entry points; every io_mode and the async reader
        # pool flow through them, nothing else changes.  With faults=None
        # the raw os calls are bound directly — zero overhead.
        self._injector = faults.injector() if faults is not None else None
        if self._injector is not None:
            self._io_preadv = self._injector.preadv
            self._io_pread = self._injector.pread
            self._io_gather = self._injector.gather
        else:
            self._io_preadv = os.preadv if _HAVE_PREADV else None
            self._io_pread = os.pread if _HAVE_PREAD else None
            self._io_gather = _passthrough_gather
        # measured, monotonic I/O counters (advanced by the host callback,
        # guarded by _lock — stores are shared across with_cache re-wraps
        # and may serve several engines/threads at once)
        self._lock = threading.Lock()
        self._reset_counters_locked()
        # telemetry: mirror the measured counters into registry families
        # (captured at construction — tests swap in private registries via
        # obs.use_registry).  Registry counters are MONOTONIC for the
        # registry's lifetime: reset_io_counters() resets only the store
        # attributes above, so cross-reset contracts compare registry
        # totals against registry totals (search.ios vs disk.records_read).
        self._obs = obs.default_registry()
        self._obs_label = os.path.basename(path)
        mk = lambda name: self._obs.counter(name, store=self._obs_label)  # noqa: E731
        self._obs_counters = {
            "records_read": mk("disk.records_read"),
            "pages_read": mk("disk.pages_read"),
            "bytes_read": mk("disk.bytes_read"),
            "unique_sectors_read": mk("disk.unique_sectors_read"),
            "ranges_read": mk("disk.ranges_read"),
            "syscalls": mk("disk.syscalls"),
            "gap_sectors_read": mk("disk.gap_sectors_read"),
            "fetch_rounds": mk("disk.fetch_rounds"),
            "read_rounds": mk("disk.read_rounds"),
            "overlapped_rounds": mk("disk.overlapped_rounds"),
            "submits": mk("disk.submits"),
            "drains": mk("disk.drains"),
            "abandoned_tokens": mk("disk.abandoned_tokens"),
            "abandon_events": mk("disk.abandon_events"),
            "warmed_bytes": mk("disk.warmed_bytes"),
            "retried_ios": mk("disk.retried_ios"),
            "retry_exhausted": mk("disk.retry_exhausted"),
            "deadline_trips": mk("disk.deadline_trips"),
            "degraded_records": mk("disk.degraded_records"),
            "warm_errors": mk("disk.warm_errors"),
        }
        self._obs_inflight = self._obs.gauge(
            "disk.inflight_depth", store=self._obs_label
        )
        rd = record_dtype(header.dim, header.degree)
        idx = IndexFile(header)
        if header.shards:
            self._segments = []
            for i, seg in enumerate(header.shards["segments"]):
                idx.segment_records(i)  # validates the GSEG header now
                self._segments.append(_Segment(
                    path=header.segment_path(i),
                    row_start=seg["row_start"], n_rows=seg["n_rows"],
                    data_offset=SEGMENT_HEADER_PAGES * PAGE_BYTES,
                    rec_dtype=rd,
                ))
        else:
            self._segments = [_Segment(
                path=path, row_start=0, n_rows=header.n,
                data_offset=header.sections["records"]["offset"],
                rec_dtype=rd,
            )]
        self._row_starts = np.asarray(
            [s.row_start for s in self._segments], np.int64
        )
        self._scratch = bytearray(0)  # discard buffer for bridged gaps
        self._neighbors = None  # lazy full-adjacency parse (host convenience)
        self._nbrs_host = None  # lazy host memmap of the adjacency sidecar
        self._vectors_view = None  # lazy host view — never a device array
        # async submission/completion state: a background reader pool plus
        # the completion queue (token -> in-flight Future), all under _lock
        self._pool: ThreadPoolExecutor | None = None
        self._pending: dict[int, object] = {}  # guarded by _lock
        self._next_token = 0  # guarded by _lock
        self._inflight = 0  # submitted-but-undrained tokens, live not reset; guarded by _lock
        # background page-cache warmer (non-blocking close: stop is an event)
        self._warm_stop = threading.Event()
        self._warm_thread: threading.Thread | None = None
        # one Partial per store: stable pytree identity, so repeated
        # searches against the same store never retrace the jitted loop
        self._fetch = Partial(self._traced_fetch)
        self._submit = Partial(self._traced_submit)
        self._drain = Partial(self._traced_drain)

    @classmethod
    def open(cls, path: str, **kwargs) -> "DiskRecordStore":
        return cls(path, **kwargs)

    def close(self) -> None:
        self._warm_stop.set()  # signal only — never blocks on the warmer
        # tokens nobody will ever drain are leaks — retire them first so
        # close() is also the backstop that makes them visible
        self.abandon_pending()
        pool = self._pool
        if pool is not None:
            # let queued reads finish against still-open fds, then drop
            # whatever results nobody will drain
            pool.shutdown(wait=True)
            self._pool = None
        with self._lock:
            self._pending.clear()
            self._inflight = 0
        for seg in self._segments:
            seg.close()

    def abandon_pending(self) -> int:
        """Drain-or-cancel every submitted-but-undrained round.

        The pipelined search loop issues one drain per submit, so on the
        happy path the completion queue runs dry by itself.  If the caller
        dies between stage A and stage B (a search error surfacing at
        materialization, a serving batch failing mid-flight), the rounds
        still in flight would otherwise pin executor slots and queue
        entries until ``close()``.  This is the ``finally`` path: cancel
        what hasn't started, block out what has (the reads run against
        still-open fds and their I/O is already counted), and count every
        retired token in ``abandoned_tokens`` — asserted zero by the
        happy-path tests, so a leak is a test failure, not a slow death.
        """
        with self._lock:
            orphans = list(self._pending.values())
            self._pending.clear()
            self._inflight = 0
        for fut in orphans:
            if not fut.cancel():
                try:
                    fut.result()  # already running: let the read finish
                except Exception:  # gatelint: disable=silent-except — the abandoning caller is already unwinding with its own exception; this read's I/O is counted and its result unwanted
                    pass
        if orphans:
            with self._lock:
                self.abandoned_tokens += len(orphans)
            if self._obs.enabled:
                self._obs_counters["abandoned_tokens"].inc(len(orphans))
                self._obs_counters["abandon_events"].inc()
                self._obs_inflight.set(0)
        return len(orphans)

    def __del__(self):  # best-effort fd cleanup
        try:
            self.close()
        except Exception:  # gatelint: disable=silent-except — interpreter-teardown destructor; attributes may already be collected and there is no caller to report to
            pass

    # -- the coalesced physical read ---------------------------------------
    def _gap_views(self, gap_bytes: int) -> list:
        """Discard iovecs bridging ``gap_bytes`` (reused buffer — preadv
        overwrites it per gap, and the contents are never looked at)."""
        chunk = min(gap_bytes, _GAP_CHUNK)
        if len(self._scratch) < chunk:
            self._scratch = bytearray(chunk)
        views = []
        mv = memoryview(self._scratch)
        while gap_bytes:
            take = min(gap_bytes, _GAP_CHUNK)
            views.append(mv[:take])
            gap_bytes -= take
        return views

    def _with_retries(self, fn, *, deadline, tally):
        """Run one raw read call with the resilience policy applied.

        Transient OSErrors (see ``TRANSIENT_ERRNOS``) retry up to
        ``retry_policy.max_retries`` times with exponential backoff +
        seeded jitter; each reattempt is counted in the round tally's
        ``retried_ios`` and timed under a ``disk.retry`` span.  Fatal
        errors raise immediately.  A tripped ``deadline`` (absolute
        ``perf_counter`` seconds, None = no deadline) raises
        :class:`ReadDeadlineError` before issuing further I/O; backoffs
        are clipped so a retry never sleeps past it."""
        rp = self.retry_policy
        attempt = 0
        while True:
            if deadline is not None and time.perf_counter() >= deadline:
                raise ReadDeadlineError(
                    f"round deadline ({self.round_deadline_s:.4f}s) tripped"
                )
            try:
                return fn()
            except OSError as e:
                if not is_transient(e):
                    raise
                if attempt >= rp.max_retries:
                    tally["retry_exhausted"] += 1
                    raise
                attempt += 1
                tally["retried_ios"] += 1
                delay = rp.backoff(attempt)
                if deadline is not None:
                    delay = min(delay, max(deadline - time.perf_counter(), 0.0))
                with obs.trace.span("disk.retry", store=self._obs_label,
                                    errno=str(e.errno)):
                    time.sleep(delay)

    def _fail_span(self, ok, tally, lo, hi, exc) -> None:
        """One read group (a vectored call / merged range / segment
        gather) failed after retries.  Under ``on_error="degrade"`` and a
        transient cause, mark the group's wanted-record span failed — the
        whole group, conservatively, since a mid-group error leaves the
        buffer's valid prefix unknown — and keep reading the rest of the
        round.  Fatal errors and the ``"fail"`` policy re-raise."""
        if isinstance(exc, ReadDeadlineError):
            tally["deadline_trips"] = 1  # once per round, not per group
        if self.on_error != "degrade" or not is_transient(exc):
            raise exc
        ok[lo:hi] = False

    def _read_unique(self, uniq: np.ndarray, io: dict) -> Tuple[np.ndarray, np.ndarray]:
        """Read the (sorted, unique) record sectors ``uniq`` coalesced.

        ``io`` is the caller's physical-I/O tally for this round
        (syscalls / ranges / gap sectors / retry counters) — advanced
        in place so the evidence of completed calls and exhausted
        retries survives even when a fatal/``"fail"``-policy error
        unwinds this read.  Returns the (U,) structured records and a
        (U,) bool mask of which records were actually read — all-True
        unless ``on_error="degrade"`` absorbed a failed group (those
        records' buffer contents are garbage and must not be served).
        """
        sector = self.sector_bytes
        u = int(uniq.size)
        buf = np.empty(u * sector, np.uint8)
        out_mv = memoryview(buf)
        ok = np.ones(u, bool)
        deadline = None
        if self.round_deadline_s > 0.0:
            deadline = time.perf_counter() + self.round_deadline_s
        seg_of = np.searchsorted(self._row_starts, uniq, side="right") - 1
        bounds = np.searchsorted(seg_of, np.arange(len(self._segments) + 1))
        pos = 0  # output cursor: sorted ids -> contiguous output slices
        for si in range(len(self._segments)):
            lo, hi = int(bounds[si]), int(bounds[si + 1])
            if lo == hi:
                continue
            seg = self._segments[si]
            local = uniq[lo:hi] - seg.row_start
            ranges = merge_ranges(local)
            io["ranges"] += int(ranges.shape[0])
            if self.io_mode == "gather":
                try:
                    got = self._with_retries(
                        lambda: self._io_gather(lambda: seg.records()[local]),
                        deadline=deadline, tally=io,
                    )
                    buf.view(self._segments[0].rec_dtype)[pos : pos + local.size] = got
                except OSError as e:
                    self._fail_span(ok, io, pos, pos + local.size, e)
                pos += local.size
                continue
            fd = seg.open_fd()
            readv = lambda batch, off: self._with_retries(  # noqa: E731
                lambda: self._io_preadv(fd, batch, off),
                deadline=deadline, tally=io,
            )
            read1 = lambda n, off: self._with_retries(  # noqa: E731
                lambda: self._io_pread(fd, n, off),
                deadline=deadline, tally=io,
            )
            if self.io_mode == "pread":
                for start, count in ranges:
                    nb = int(count) * sector
                    try:
                        io["syscalls"] += _pread_full(
                            read1, out_mv[pos * sector : pos * sector + nb],
                            seg.data_offset + int(start) * sector,
                        )
                    except OSError as e:
                        self._fail_span(ok, io, pos, pos + int(count), e)
                    pos += int(count)
                continue
            # preadv: one vectored call per round and segment — wanted
            # ranges scatter straight into the output, bridged gaps land
            # in the discard buffer.  A gap wider than max_gap_sectors is
            # never bridged: the round splits into another vectored call
            # there instead, trading a syscall for the over-read.  Groups
            # are collected first, then issued, so a failed group maps
            # cleanly to its wanted-record span.
            max_gap = self.max_gap_sectors
            groups = []  # (views, group_start_sector, pos_lo, pos_hi)
            views = []
            prev_end = None
            group_start = 0
            gpos_lo = pos
            for start, count in ranges:
                gap = 0 if prev_end is None else int(start - prev_end)
                if views and max_gap is not None and gap > max_gap:
                    groups.append((views, group_start, gpos_lo, pos))
                    views = []
                    prev_end = None
                    gap = 0
                if prev_end is None:
                    group_start = int(start)
                    gpos_lo = pos
                elif gap:
                    io["gap_sectors"] += gap
                    views.extend(self._gap_views(gap * sector))
                nb = int(count) * sector
                views.append(out_mv[pos * sector : pos * sector + nb])
                pos += int(count)
                prev_end = int(start + count)
            groups.append((views, group_start, gpos_lo, pos))
            for g_views, g_start, g_lo, g_hi in groups:
                try:
                    io["syscalls"] += _preadv_full(
                        readv, g_views, seg.data_offset + g_start * sector
                    )
                except OSError as e:
                    self._fail_span(ok, io, g_lo, g_hi, e)
        return buf.view(self._segments[0].rec_dtype), ok

    # -- the measured host read --------------------------------------------
    def _host_fetch(self, ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Serve record sectors for ``ids`` (>= 0); count what was read."""
        ids = np.asarray(ids)
        valid = ids >= 0
        flat = np.clip(ids, 0, self.n - 1).reshape(-1)
        vmask = valid.reshape(-1)
        vecs = np.zeros(ids.shape + (self.dim,), np.float32)
        nbrs = np.full(ids.shape + (self.degree,), -1, np.int32)
        m = int(vmask.sum())
        io = {"syscalls": 0, "ranges": 0, "gap_sectors": 0,
              "retried_ios": 0, "retry_exhausted": 0, "deadline_trips": 0}
        u = 0
        n_degraded = 0
        if m:
            uniq, inv = np.unique(flat[vmask], return_inverse=True)
            u = int(uniq.size)
            try:
                with obs.trace.span("disk.preadv", store=self._obs_label,
                                    io_mode=self.io_mode):
                    recs, ok_u = self._read_unique(uniq, io)
            except OSError:
                # the raise unwinds this fetch, but completed syscalls and
                # exhausted retries already happened — fold the physical
                # evidence before propagating so a "fail"-policy error
                # never hides its retry history from the counters (no
                # records served, so the logical counters stay untouched)
                with self._lock:
                    self.ranges_read += io["ranges"]
                    self.syscalls += io["syscalls"]
                    self.gap_sectors_read += io["gap_sectors"]
                    self.retried_ios += io["retried_ios"]
                    self.retry_exhausted += io["retry_exhausted"]
                    self.deadline_trips += io["deadline_trips"]
                    self.fetch_rounds += 1
                    self.read_rounds += 1
                if self._obs.enabled:
                    c = self._obs_counters
                    c["ranges_read"].inc(io["ranges"])
                    c["syscalls"].inc(io["syscalls"])
                    c["gap_sectors_read"].inc(io["gap_sectors"])
                    c["retried_ios"].inc(io["retried_ios"])
                    c["retry_exhausted"].inc(io["retry_exhausted"])
                    c["deadline_trips"].inc(io["deadline_trips"])
                    c["fetch_rounds"].inc()
                    c["read_rounds"].inc()
                raise
            got = recs[inv]  # scatter back to beam order (dups included)
            gvec = got["vec"]
            gnbr = got["nbrs"]
            if not ok_u.all():
                # degraded slots: the buffer bytes for a failed group are
                # garbage.  Replace the vector with the +inf sentinel (the
                # search loop drops the exact-distance contribution — the
                # GateANN tunnel semantics) and serve the neighbor list
                # from the adjacency sidecar, so traversal/connectivity is
                # IDENTICAL to a successful fetch.  fancy-indexing ``recs``
                # already copied, so in-place writes are safe.
                bad = ~ok_u[inv]
                n_degraded = int(bad.sum())
                gvec[bad] = np.inf
                gnbr[bad] = self._adjacency_host()[flat[vmask][bad]]
            vecs.reshape(-1, self.dim)[vmask] = gvec
            nbrs.reshape(-1, self.degree)[vmask] = gnbr
        with self._lock:
            # logical counters keep counting every REQUESTED record —
            # degraded reads included — so n_ios reconciliation holds
            # under faults; degraded_records carries the failure tally
            self.records_read += m
            self.pages_read += m * self.pages_per_record
            self.bytes_read += m * self.sector_bytes
            self.unique_sectors_read += u
            self.ranges_read += io["ranges"]
            self.syscalls += io["syscalls"]
            self.gap_sectors_read += io["gap_sectors"]
            self.fetch_rounds += 1
            self.read_rounds += int(u > 0)
            self.retried_ios += io["retried_ios"]
            self.retry_exhausted += io["retry_exhausted"]
            self.deadline_trips += io["deadline_trips"]
            self.degraded_records += n_degraded
        if self._obs.enabled:
            c = self._obs_counters
            # records BEFORE unique: a registry snapshot taken between the
            # two increments under-counts unique, so the mid-flight
            # invariant unique_sectors_read <= records_read always holds
            c["records_read"].inc(m)
            c["pages_read"].inc(m * self.pages_per_record)
            c["bytes_read"].inc(m * self.sector_bytes)
            c["unique_sectors_read"].inc(u)
            c["ranges_read"].inc(io["ranges"])
            c["syscalls"].inc(io["syscalls"])
            c["gap_sectors_read"].inc(io["gap_sectors"])
            c["fetch_rounds"].inc()
            c["read_rounds"].inc(int(u > 0))
            if io["retried_ios"]:
                c["retried_ios"].inc(io["retried_ios"])
            if io["retry_exhausted"]:
                c["retry_exhausted"].inc(io["retry_exhausted"])
            if io["deadline_trips"]:
                c["deadline_trips"].inc(io["deadline_trips"])
            if n_degraded:
                c["degraded_records"].inc(n_degraded)
        return vecs, nbrs

    def _traced_fetch(self, ids: jax.Array) -> Tuple[jax.Array, jax.Array]:
        out_shapes = (
            jax.ShapeDtypeStruct(ids.shape + (self.dim,), jnp.float32),
            jax.ShapeDtypeStruct(ids.shape + (self.degree,), jnp.int32),
        )
        # ordered: fetches must all execute (and in program order) so the
        # measured counters reconcile exactly with SearchStats.n_ios
        return io_callback(self._host_fetch, out_shapes, ids, ordered=True)

    def fetch_fn(self):
        return self._fetch

    # -- the asynchronous submission/completion pair -----------------------
    def _adjacency_host(self) -> np.ndarray:
        """Host view of the full-adjacency sidecar section (N, R) int32.

        This is what makes the pipeline bit-identical: the sidecar holds
        the exact array the record sectors' ``nbrs`` fields were packed
        from, so serving neighbor lists here instead of from the in-flight
        record read changes nothing but the wait."""
        if self._nbrs_host is None:
            with self._lock:
                if self._nbrs_host is None:
                    self._nbrs_host = IndexFile(self.header).section(SEC_NEIGHBORS)
        return self._nbrs_host

    def _host_submit(self, ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Enqueue the round's coalesced sector read; return (token, nbrs).

        The neighbor lists come from the adjacency sidecar immediately —
        the caller can expand the frontier and dispatch the next beam
        while this round's record read is still in flight on the pool."""
        ids = np.asarray(ids)
        valid = ids >= 0
        flat = np.clip(ids, 0, self.n - 1).reshape(-1)
        nbrs = np.full(ids.shape + (self.degree,), -1, np.int32)
        vmask = valid.reshape(-1)
        with obs.trace.span("disk.submit", store=self._obs_label):
            if vmask.any():
                adj = self._adjacency_host()
                nbrs.reshape(-1, self.degree)[vmask] = adj[flat[vmask]]
        job_ids = np.array(ids, copy=True)  # the callback buffer is reused
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.reader_threads,
                    thread_name_prefix="gateann-reader",
                )
            token = self._next_token
            self._next_token = (self._next_token + 1) % (1 << 30)
            self._pending[token] = self._pool.submit(self._host_fetch, job_ids)
            self._inflight += 1
            inflight = self._inflight
            self.inflight_depth_max = max(self.inflight_depth_max, self._inflight)
            overlapped = self._inflight >= 2
            if overlapped:
                self.overlapped_rounds += 1
        if self._obs.enabled:
            self._obs_counters["submits"].inc()
            if overlapped:
                self._obs_counters["overlapped_rounds"].inc()
            self._obs_inflight.set(inflight)
        return np.int32(token), nbrs

    def _host_drain(self, token: np.ndarray, ids: np.ndarray, flag: np.ndarray):
        """Retire one submitted round: block until its read completed and
        return the record vectors.  ``flag=False`` is the pipeline-warmup
        no-op (the loop issues a fixed drain per round; early rounds have
        nothing to retire) — it returns zeros without touching the queue."""
        vecs = np.zeros(np.asarray(ids).shape + (self.dim,), np.float32)
        if not bool(flag):
            return vecs
        with self._lock:
            fut = self._pending.pop(int(token), None)
            if fut is not None:
                self._inflight -= 1
                inflight = self._inflight
        if fut is None:
            raise KeyError(
                f"drain of unknown token {int(token)} — not submitted, "
                "already drained, or the store was closed"
            )
        with obs.trace.span("disk.drain_wait", store=self._obs_label):
            got_vecs, _got_nbrs = fut.result()
        if self._obs.enabled:
            self._obs_counters["drains"].inc()
            self._obs_inflight.set(inflight)
        return got_vecs

    def _traced_submit(self, ids: jax.Array) -> Tuple[jax.Array, jax.Array]:
        out_shapes = (
            jax.ShapeDtypeStruct((), jnp.int32),
            jax.ShapeDtypeStruct(ids.shape + (self.degree,), jnp.int32),
        )
        # ordered like the synchronous fetch: submissions and drains must
        # interleave in program order so FIFO retirement (and counter
        # reconciliation) is deterministic
        return io_callback(self._host_submit, out_shapes, ids, ordered=True)

    def _traced_drain(
        self, token: jax.Array, ids: jax.Array, flag: jax.Array
    ) -> jax.Array:
        out_shape = jax.ShapeDtypeStruct(ids.shape + (self.dim,), jnp.float32)
        return io_callback(self._host_drain, out_shape, token, ids, flag,
                           ordered=True)

    def submit_fn(self):
        return self._submit

    def drain_fn(self):
        return self._drain

    # -- background page-cache re-warm -------------------------------------
    def warm(self, *, background: bool = True, chunk_bytes: int = 4 << 20):
        """Sequentially re-read the segment files to re-populate the OS
        page cache (the post-``load`` warm-up of a freshly booted server).

        ``background=True`` runs on a daemon thread and returns it;
        ``close()`` signals the thread to stop but never joins it (the
        warmer reads through its own fds, so the store's fds close
        immediately).  Bytes actually read land in ``warmed_bytes``.

        Re-entrant calls serialize: a still-running warmer is stopped
        and joined first, so two overlapping warms never double-count
        ``warmed_bytes`` (and ``warm_wait`` always tracks the live one)."""
        prev = self._warm_thread
        if prev is not None and prev.is_alive():
            self._warm_stop.set()
            prev.join()
        self._warm_stop.clear()
        if not background:
            self._warm_run(chunk_bytes)
            return None
        t = threading.Thread(
            target=self._warm_run, args=(chunk_bytes,),
            name="gateann-warm", daemon=True,
        )
        self._warm_thread = t
        t.start()
        return t

    def _warm_run(self, chunk_bytes: int) -> None:
        for seg in self._segments:
            if self._warm_stop.is_set():
                return
            try:
                fd = os.open(seg.path, os.O_RDONLY)
            except OSError:
                # re-saved/swept segment — nothing to warm, but a vanished
                # file is still evidence (a sweep race, a bad mount):
                # count it instead of discarding it
                with self._lock:
                    self.warm_errors += 1
                if self._obs.enabled:
                    self._obs_counters["warm_errors"].inc()
                continue
            try:
                size = os.fstat(fd).st_size
                off = 0
                while off < size and not self._warm_stop.is_set():
                    data = os.pread(fd, min(chunk_bytes, size - off), off)
                    if not data:
                        break
                    off += len(data)
                    with self._lock:
                        self.warmed_bytes += len(data)
                    if self._obs.enabled:
                        self._obs_counters["warmed_bytes"].inc(len(data))
            finally:
                os.close(fd)

    def warm_wait(self, timeout: float | None = None) -> bool:
        """Join the background warmer (tests/benchmarks); True if done."""
        t = self._warm_thread
        if t is None:
            return True
        t.join(timeout)
        return not t.is_alive()

    def drop_page_cache(self) -> None:
        """Advise the kernel to evict this index's pages (cold-cache
        benchmarking — ``posix_fadvise(DONTNEED)``; no-op if unsupported)."""
        if not hasattr(os, "posix_fadvise"):
            return
        paths = {self.path} | {seg.path for seg in self._segments}
        for p in paths:
            try:
                fd = os.open(p, os.O_RDONLY)
            except OSError:
                # a cold-cache benchmark that silently fails to drop the
                # cache reports warm numbers as cold — count the miss
                with self._lock:
                    self.warm_errors += 1
                if self._obs.enabled:
                    self._obs_counters["warm_errors"].inc()
                continue
            try:
                os.posix_fadvise(fd, 0, 0, os.POSIX_FADV_DONTNEED)
            finally:
                os.close(fd)

    # -- measured-I/O reporting --------------------------------------------
    def _reset_counters_locked(self) -> None:
        # logical: what the search loop requested (reconciles with n_ios)
        self.records_read = 0
        self.pages_read = 0
        self.bytes_read = 0
        # physical: what the coalesced reader actually did
        self.unique_sectors_read = 0
        self.ranges_read = 0
        self.syscalls = 0
        self.gap_sectors_read = 0
        self.fetch_rounds = 0
        self.read_rounds = 0
        # pipeline overlap (advanced by submit; _inflight itself is live
        # state, not a counter, and survives resets)
        self.inflight_depth_max = 0
        self.overlapped_rounds = 0
        # submitted rounds retired by abandon_pending instead of a drain —
        # zero on every happy path (the pipeline drains what it submits)
        self.abandoned_tokens = 0
        # background warmer
        self.warmed_bytes = 0
        # resilience: transient-error retries, exhaustions after bounded
        # retry, per-round deadline trips, and record slots served
        # degraded (tunnel sentinel) instead of failing the query
        self.retried_ios = 0
        self.retry_exhausted = 0
        self.deadline_trips = 0
        self.degraded_records = 0
        # warm/drop-page-cache paths that hit an OSError (previously a
        # silent swallow — see the silent-except gatelint rule)
        self.warm_errors = 0

    def io_counters(self) -> dict:
        with self._lock:
            return {
                "records_read": self.records_read,
                "pages_read": self.pages_read,
                "bytes_read": self.bytes_read,
                "unique_sectors_read": self.unique_sectors_read,
                "ranges_read": self.ranges_read,
                "syscalls": self.syscalls,
                "gap_sectors_read": self.gap_sectors_read,
                "fetch_rounds": self.fetch_rounds,
                "read_rounds": self.read_rounds,
                "inflight_depth_max": self.inflight_depth_max,
                "overlapped_rounds": self.overlapped_rounds,
                "abandoned_tokens": self.abandoned_tokens,
                "warmed_bytes": self.warmed_bytes,
                "retried_ios": self.retried_ios,
                "retry_exhausted": self.retry_exhausted,
                "deadline_trips": self.deadline_trips,
                "degraded_records": self.degraded_records,
                "warm_errors": self.warm_errors,
            }

    def configure_resilience(
        self,
        *,
        retry: RetryPolicy | None = None,
        on_error: str | None = None,
        round_deadline_s: float | None = None,
    ) -> None:
        """Retune the resilience policy at runtime (the serve layer's
        ``FaultPolicy`` knob and per-batch deadline budgets map here).
        Takes effect on the next read round; safe to call between
        batches while reads are quiescent."""
        if on_error is not None and on_error not in ON_ERROR_POLICIES:
            raise ValueError(f"on_error={on_error!r} not in {ON_ERROR_POLICIES}")
        with self._lock:
            if retry is not None:
                self.retry_policy = retry
            if on_error is not None:
                self.on_error = on_error
            if round_deadline_s is not None:
                self.round_deadline_s = float(round_deadline_s)

    def fault_counters(self) -> dict:
        """The fault injector's tally ({} when no FaultPlan is attached)."""
        return self._injector.counters() if self._injector is not None else {}

    def reset_io_counters(self) -> None:
        """Zero the store-local counters.  The mirrored ``disk.*``
        registry families are NOT reset — registry counters stay
        monotonic so telemetry contracts hold across benchmark resets."""
        with self._lock:
            self._reset_counters_locked()

    def index_bytes(self) -> int:
        """Total on-disk footprint: main file plus any record segments."""
        total = int(os.path.getsize(self.path))
        if self.header.shards:
            total += sum(int(os.path.getsize(s.path)) for s in self._segments)
        return total

    def record_bytes(self) -> int:
        """Slow-tier record-section bytes (same pricing as the other tiers)."""
        return self.n * self.sector_bytes

    @property
    def n_shards(self) -> int:
        return len(self._segments) if self.header.shards else 1

    # -- host-side passthroughs (cache wiring, tests, ground truth) --------
    @property
    def neighbors(self) -> jax.Array:
        if self._neighbors is None:
            self._neighbors = jnp.asarray(
                IndexFile(self.header).neighbors(), jnp.int32
            )
        return self._neighbors

    @property
    def vectors(self) -> np.ndarray:
        """Full-precision vectors as a LAZY host view of the record file.

        No device transfer and (for the single-segment case) no copy —
        the paper-scale corpus must stay on disk until an explicit
        ground-truth/debug path asks (``device_vectors``); at 1B x
        128-dim the old eager materialization was the disk tier's undoing.
        """
        if self._vectors_view is None:
            if len(self._segments) == 1:
                self._vectors_view = self._segments[0].records()["vec"]
            else:  # lazy across segments too — gathers only touched rows
                self._vectors_view = LazySegmentVectors(self._segments, self.dim)
        return self._vectors_view

    def device_vectors(self) -> jax.Array:
        """EXPLICIT full-corpus device materialization (ground truth/debug)."""
        return jnp.asarray(np.ascontiguousarray(self.vectors), jnp.float32)
