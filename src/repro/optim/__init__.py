from repro.optim.adamw import (
    OptConfig,
    opt_init,
    opt_update,
    clip_by_global_norm,
    global_norm,
)
from repro.optim.schedules import warmup_cosine

__all__ = [
    "OptConfig",
    "opt_init",
    "opt_update",
    "clip_by_global_norm",
    "global_norm",
    "warmup_cosine",
]
