"""Optimizers: AdamW (fp32 state), Adafactor (factored second moment, for
400B-scale state on 16 GB chips), and blockwise-8-bit AdamW (Dettmers-style
quantized moments — a distributed-training memory trick kept as an option).

All are pure-pytree functional optimizers: ``init(params) -> state``,
``update(grads, state, params, lr) -> (new_params, new_state)``.
Optimizer state inherits the ZeRO storage sharding of its parameter.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"  # adamw | adafactor | adamw8bit
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def adamw_update(grads, state: AdamWState, params, lr, cfg: OptConfig):
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        new_p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
        return new_p, m, v

    flat = jax.tree.map(upd, grads, state.m, state.v, params)
    new_p = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern) — factored second moments, no first moment
# ---------------------------------------------------------------------------

class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any  # row stats (or full v for <2D leaves)
    vc: Any  # col stats (or None sentinel zeros)


def _factored(p):
    return p.ndim >= 2


def adafactor_init(params) -> AdafactorState:
    def vr_of(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros_like(p, dtype=jnp.float32)

    def vc_of(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)

    return AdafactorState(
        step=jnp.zeros((), jnp.int32),
        vr=jax.tree.map(vr_of, params),
        vc=jax.tree.map(vc_of, params),
    )


def adafactor_update(grads, state: AdafactorState, params, lr, cfg: OptConfig):
    step = state.step + 1
    beta2 = 1.0 - step.astype(jnp.float32) ** -0.8  # schedule from the paper

    def upd(g, vr, vc, p):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if _factored(p):
            vr = beta2 * vr + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * vc + (1 - beta2) * g2.mean(axis=-2)
            denom = (
                vr[..., None] / jnp.maximum(vr.mean(axis=-1, keepdims=True), 1e-30)[..., None]
            ) * vc[..., None, :]
            u = g / jnp.sqrt(denom + cfg.eps)
        else:
            vr = beta2 * vr + (1 - beta2) * g2
            u = g / jnp.sqrt(vr + cfg.eps)
            vc = vc
        # update clipping (RMS <= 1)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        new_p = p - lr * (u + cfg.weight_decay * p)
        return new_p, vr, vc

    flat = jax.tree.map(upd, grads, state.vr, state.vc, params)
    pick = lambda i: jax.tree.map(lambda t: t[i], flat, is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), AdafactorState(step=step, vr=pick(1), vc=pick(2))


# ---------------------------------------------------------------------------
# Blockwise 8-bit AdamW (quantized moments + fp32 per-block scales)
# ---------------------------------------------------------------------------

_BLOCK = 256


class Adam8State(NamedTuple):
    step: jax.Array
    m_q: Any  # int8 blocks
    m_s: Any  # fp32 scales
    v_q: Any
    v_s: Any


def _quant(x):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale[:, None], 1e-12)).astype(jnp.int8)
    return q, scale


def _dequant(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def adamw8bit_init(params) -> Adam8State:
    qz = jax.tree.map(lambda p: _quant(jnp.zeros_like(p, jnp.float32))[0], params)
    sz = jax.tree.map(lambda p: _quant(jnp.zeros_like(p, jnp.float32))[1], params)
    return Adam8State(
        step=jnp.zeros((), jnp.int32),
        m_q=qz, m_s=sz,
        v_q=jax.tree.map(jnp.copy, qz), v_s=jax.tree.map(jnp.copy, sz),
    )


def adamw8bit_update(grads, state: Adam8State, params, lr, cfg: OptConfig):
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, mq, ms, vq, vs, p):
        g = g.astype(jnp.float32)
        m = cfg.b1 * _dequant(mq, ms, p.shape) + (1 - cfg.b1) * g
        v = cfg.b2 * _dequant(vq, vs, p.shape) + (1 - cfg.b2) * g * g
        new_p = p - lr * ((m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps) + cfg.weight_decay * p)
        mq, ms = _quant(m)
        vq, vs = _quant(v)
        return new_p, mq, ms, vq, vs

    flat = jax.tree.map(upd, grads, state.m_q, state.m_s, state.v_q, state.v_s, params)
    pick = lambda i: jax.tree.map(lambda t: t[i], flat, is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), Adam8State(step=step, m_q=pick(1), m_s=pick(2), v_q=pick(3), v_s=pick(4))


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def opt_init(params, cfg: OptConfig):
    return {
        "adamw": adamw_init,
        "adafactor": adafactor_init,
        "adamw8bit": adamw8bit_init,
    }[cfg.name](params)


def opt_update(grads, state, params, lr, cfg: OptConfig):
    return {
        "adamw": adamw_update,
        "adafactor": adafactor_update,
        "adamw8bit": adamw8bit_update,
    }[cfg.name](grads, state, params, lr, cfg)
