"""Backend capability probe shared by every Pallas kernel wrapper.

Compiled Pallas lowering exists for TPU (Mosaic) and GPU (Triton); on
every other backend (CPU foremost) the kernels run in interpret mode —
bit-accurate kernel-body semantics, evaluated as plain XLA ops.

``interpret=None`` on a kernel entry point means "resolve from the
backend": compiled whenever the backend supports it, interpret
otherwise.  Passing an explicit bool is an opt-out in either direction
(``interpret=True`` forces interpretation on TPU for debugging;
``interpret=False`` on CPU will fail loudly rather than silently
interpret).
"""
from __future__ import annotations

import functools

import jax

_COMPILED_BACKENDS = ("tpu", "gpu")


@functools.cache
def supports_compiled_pallas(backend: str | None = None) -> bool:
    """Does this backend have a compiled (non-interpret) Pallas lowering?"""
    return (backend or jax.default_backend()) in _COMPILED_BACKENDS


def resolve_interpret(interpret: bool | None) -> bool:
    """Map the tri-state ``interpret`` kwarg to a concrete mode."""
    if interpret is None:
        return not supports_compiled_pallas()
    return interpret
