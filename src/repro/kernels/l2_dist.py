"""Pallas TPU kernel: exact squared-L2 re-ranking distances.

The fetch path computes exact distances between each query and its W
fetched full-precision records (paper: "Processing (exact dist.)" —
69.5% of PipeANN's per-query time, Table 5).  The contraction
``‖q − x‖² = ‖q‖² − 2·q·x + ‖x‖²`` puts the q·x term on the MXU.

Tiles: one query per program; the (W, D) record tile and (D,) query tile
live in VMEM (W·D·4 B = 32·512·4 = 64 KB at the default maxima).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret


def _l2_kernel(q_ref, x_ref, out_ref):
    """q_ref: (1, D) f32; x_ref: (1, W, D) f32; out_ref: (1, W) f32."""
    q = q_ref[0]  # (D,)
    x = x_ref[0]  # (W, D)
    qx = jax.lax.dot_general(
        x, q, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (W,)
    out_ref[0] = jnp.sum(x * x, axis=1) - 2.0 * qx + jnp.sum(q * q)


@functools.partial(jax.jit, static_argnames=("interpret",))
def l2_dist(
    queries: jax.Array,  # (B, D) float32
    rows: jax.Array,  # (B, W, D) float32
    *,
    interpret: bool | None = None,
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    b, d = queries.shape
    bb, w, dd = rows.shape
    assert bb == b and dd == d
    return pl.pallas_call(
        _l2_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, w, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, w), jnp.float32),
        interpret=interpret,
    )(queries.astype(jnp.float32), rows.astype(jnp.float32))
