"""Pallas TPU kernel: PQ asymmetric-distance computation (ADC).

This is GateANN's hottest in-memory loop — tunneling spends ~49% of
per-query time in "PQ + AdjIndex" (paper Table 5).  The CPU reference
implementation is a per-chunk table gather; on TPU the gather is
re-expressed as a **one-hot × LUT contraction** so the inner loop runs on
the MXU/VPU over VMEM-resident tiles instead of doing scalar gathers:

    dist[m] = Σ_c lut[c, codes[m, c]]
            = Σ_c Σ_k onehot(codes[m, c])[k] · lut[c, k]

Two entry points share the kernel body:

  * ``pq_lookup_gathered`` — per-query code rows (B, M, C), used by the
    search loop on gathered neighbor ids.
  * ``pq_scan``            — shared code matrix (N, C) scanned by every
    query (brute-force ADC / re-ranking sweeps).

Rows padded up to the block size are forced to **+INF inside the
kernel** (they used to reuse whatever codes the padding held and emit
finite distances — harmless for these sliced entry points, but a trap
for any fused consumer selecting over the raw block).  ``keep_padding``
returns the full padded array so tests can pin the sentinel lanes.

Block shapes: M is tiled (default 128 rows per program) so the one-hot
workspace (C·Mt·K f32 = 32·128·256·4 B = 4 MB) fits comfortably in VMEM
alongside the LUT tile (C·K·4 B = 32 KB); all tile trailing dims are
multiples of 128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret

# numpy scalar, not jnp: the kernel bodies reference it, and a traced jnp
# scalar would be captured as a pallas_call constant (a trace error)
_INF = np.float32(3.4e38)


def _adc_body(lut, codes):
    """(C, K) lut × (Mt, C) codes -> (Mt,) summed ADC distances."""
    c, k = lut.shape
    # one-hot contraction: (C, Mt, K) ⊗ (C, K) -> (C, Mt) -> sum over C
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (c, codes.shape[0], k), 2)
    onehot = (codes.T[:, :, None] == iota_k).astype(lut.dtype)  # (C, Mt, K)
    per_chunk = jax.lax.dot_general(
        onehot,
        lut,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),  # batch C, contract K
        preferred_element_type=jnp.float32,
    )  # (C, Mt)
    return jnp.sum(per_chunk, axis=0)


def _real_rows(block: int, rows: int):
    """Mask of genuine (non-padding) rows within this program's tile."""
    row0 = pl.program_id(1) * block
    return row0 + jax.lax.iota(jnp.int32, block) < rows


def _adc_kernel(lut_ref, codes_ref, out_ref, *, block_m: int, m: int):
    """One (query b, row-tile m) program.

    lut_ref:   (1, C, K) f32 VMEM
    codes_ref: (1, Mt, C) int32 VMEM
    out_ref:   (1, Mt) f32 VMEM — padded rows (>= m) emit +INF
    """
    d = _adc_body(lut_ref[0], codes_ref[0])
    out_ref[0] = jnp.where(_real_rows(block_m, m), d, _INF)


@functools.partial(
    jax.jit, static_argnames=("block_m", "interpret", "keep_padding")
)
def pq_lookup_gathered(
    lut: jax.Array,  # (B, C, K) float32
    codes: jax.Array,  # (B, M, C) int32
    *,
    block_m: int = 128,
    interpret: bool | None = None,
    keep_padding: bool = False,
) -> jax.Array:
    """Per-query gathered ADC: out[b, m] = sum_c lut[b, c, codes[b, m, c]]."""
    interpret = resolve_interpret(interpret)
    b, c, k = lut.shape
    bb, m, cc = codes.shape
    assert bb == b and cc == c, (lut.shape, codes.shape)
    block_m = min(block_m, m)
    pad_m = (-m) % block_m
    if pad_m:
        codes = jnp.pad(codes, ((0, 0), (0, pad_m), (0, 0)))
    mp = m + pad_m
    out = pl.pallas_call(
        functools.partial(_adc_kernel, block_m=block_m, m=m),
        grid=(b, mp // block_m),
        in_specs=[
            pl.BlockSpec((1, c, k), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, block_m, c), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, mp), jnp.float32),
        interpret=interpret,
    )(lut.astype(jnp.float32), codes.astype(jnp.int32))
    return out if keep_padding else out[:, :m]


def _adc_scan_kernel(lut_ref, codes_ref, out_ref, *, block_n: int, n: int):
    """One (query b, node-tile n) program over a shared code matrix.

    lut_ref:   (1, C, K) f32; codes_ref: (Nt, C) int32; out_ref: (1, Nt) f32
    """
    d = _adc_body(lut_ref[0], codes_ref[...])
    out_ref[0] = jnp.where(_real_rows(block_n, n), d, _INF)


@functools.partial(
    jax.jit, static_argnames=("block_n", "interpret", "keep_padding")
)
def pq_scan(
    lut: jax.Array,  # (B, C, K) float32
    codes: jax.Array,  # (N, C) int32 — shared across queries
    *,
    block_n: int = 512,
    interpret: bool | None = None,
    keep_padding: bool = False,
) -> jax.Array:
    """Brute-force ADC sweep: out[b, n] = sum_c lut[b, c, codes[n, c]]."""
    interpret = resolve_interpret(interpret)
    b, c, k = lut.shape
    n, cc = codes.shape
    assert cc == c
    block_n = min(block_n, n)
    pad_n = (-n) % block_n
    if pad_n:
        codes = jnp.pad(codes, ((0, pad_n), (0, 0)))
    np_ = n + pad_n
    out = pl.pallas_call(
        functools.partial(_adc_scan_kernel, block_n=block_n, n=n),
        grid=(b, np_ // block_n),
        in_specs=[
            pl.BlockSpec((1, c, k), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_n, c), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, np_), jnp.float32),
        interpret=interpret,
    )(lut.astype(jnp.float32), codes.astype(jnp.int32))
    return out if keep_padding else out[:, :n]
