"""Jitted public wrappers over the Pallas kernels.

``interpret`` mode is selected automatically: compiled on TPU, Python
interpretation (bit-accurate kernel-body semantics) everywhere else.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import l2_dist as _l2
from repro.kernels import pq_lookup as _pq
from repro.kernels import topk_merge as _tk


@functools.cache
def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def pq_lookup_gathered(lut, codes, *, block_m: int = 128):
    return _pq.pq_lookup_gathered(lut, codes, block_m=block_m, interpret=_interpret())


# Alias used by core.search
pq_lookup = pq_lookup_gathered


def pq_scan(lut, codes, *, block_n: int = 512):
    return _pq.pq_scan(lut, codes, block_n=block_n, interpret=_interpret())


def l2_dist(queries, rows):
    return _l2.l2_dist(queries, rows, interpret=_interpret())


def topk_merge(dists, ids, k: int):
    return _tk.topk_merge(dists, ids, k, interpret=_interpret())
