"""Jitted public wrappers over the Pallas kernels.

``interpret`` mode is selected automatically (``kernels.backend``):
compiled Pallas wherever a compiled lowering exists (TPU via Mosaic, GPU
via Triton), Python interpretation — bit-accurate kernel-body semantics —
only where it doesn't (CPU).  Interpret mode is an explicit opt-out via
the ``interpret=`` kwarg on the underlying modules, never a silent
default on an accelerator.
"""
from __future__ import annotations

from repro.kernels import fused_traversal as _ft
from repro.kernels import l2_dist as _l2
from repro.kernels import pq_lookup as _pq
from repro.kernels import topk_merge as _tk
from repro.kernels.backend import supports_compiled_pallas


def _interpret() -> bool:
    """Resolved interpret mode for this process's default backend."""
    return not supports_compiled_pallas()


def pq_lookup_gathered(lut, codes, *, block_m: int = 128):
    return _pq.pq_lookup_gathered(lut, codes, block_m=block_m, interpret=_interpret())


# Alias used by core.search
pq_lookup = pq_lookup_gathered


def pq_scan(lut, codes, *, block_n: int = 512):
    return _pq.pq_scan(lut, codes, block_n=block_n, interpret=_interpret())


def l2_dist(queries, rows):
    return _l2.l2_dist(queries, rows, interpret=_interpret())


def topk_merge(dists, ids, k: int):
    return _tk.topk_merge(dists, ids, k, interpret=_interpret())


def fused_traversal_round(*args, mode: str, width: int):
    """One fused stage-A round (see ``kernels.fused_traversal``)."""
    return _ft.fused_traversal_round(
        *args, mode=mode, width=width, interpret=_interpret()
    )
