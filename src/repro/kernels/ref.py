"""Pure-jnp oracles for every Pallas kernel (the correctness contracts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pq_lookup_gathered_ref(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """lut (B, C, K) f32, codes (B, M, C) i32 -> (B, M) f32."""
    # out[b, m] = sum_c lut[b, c, codes[b, m, c]]
    return jnp.take_along_axis(
        lut.transpose(0, 2, 1),  # (B, K, C)
        codes,  # (B, M, C) indexes the K axis
        axis=1,
    ).sum(axis=-1).astype(jnp.float32)


def pq_scan_ref(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """lut (B, C, K) f32, codes (N, C) i32 -> (B, N) f32."""
    b = lut.shape[0]
    return pq_lookup_gathered_ref(lut, jnp.broadcast_to(codes[None], (b,) + codes.shape))


def l2_dist_ref(queries: jax.Array, rows: jax.Array) -> jax.Array:
    """queries (B, D), rows (B, W, D) -> (B, W) squared L2."""
    diff = rows.astype(jnp.float32) - queries.astype(jnp.float32)[:, None, :]
    return jnp.sum(diff * diff, axis=-1)


def topk_merge_ref(dists: jax.Array, ids: jax.Array, k: int):
    """Sorted ascending top-k of (dists, ids)."""
    order = jnp.argsort(dists, axis=-1)[:, :k]
    return (
        jnp.take_along_axis(dists, order, axis=-1).astype(jnp.float32),
        jnp.take_along_axis(ids, order, axis=-1).astype(jnp.int32),
    )
