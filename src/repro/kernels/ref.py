"""Pure-jnp oracles for every Pallas kernel (the correctness contracts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_INF = jnp.float32(3.4e38)
_INVALID = jnp.int32(-1)


def pq_lookup_gathered_ref(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """lut (B, C, K) f32, codes (B, M, C) i32 -> (B, M) f32."""
    # out[b, m] = sum_c lut[b, c, codes[b, m, c]]
    return jnp.take_along_axis(
        lut.transpose(0, 2, 1),  # (B, K, C)
        codes,  # (B, M, C) indexes the K axis
        axis=1,
    ).sum(axis=-1).astype(jnp.float32)


def pq_scan_ref(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """lut (B, C, K) f32, codes (N, C) i32 -> (B, N) f32."""
    b = lut.shape[0]
    return pq_lookup_gathered_ref(lut, jnp.broadcast_to(codes[None], (b,) + codes.shape))


def l2_dist_ref(queries: jax.Array, rows: jax.Array) -> jax.Array:
    """queries (B, D), rows (B, W, D) -> (B, W) squared L2."""
    diff = rows.astype(jnp.float32) - queries.astype(jnp.float32)[:, None, :]
    return jnp.sum(diff * diff, axis=-1)


def topk_merge_ref(dists: jax.Array, ids: jax.Array, k: int):
    """Sorted ascending top-k on the lexicographic (dist, id) key.

    Distance ties break by ascending id — the same total order the
    bitonic kernel realizes, so kernel and oracle agree on which id
    survives at rank k even among duplicate distances.
    """
    order = jnp.lexsort((ids, dists), axis=-1)[:, :k]
    return (
        jnp.take_along_axis(dists, order, axis=-1).astype(jnp.float32),
        jnp.take_along_axis(ids, order, axis=-1).astype(jnp.int32),
    )


def _dedup_mask_ref(ids: jax.Array) -> jax.Array:
    """True where a slot duplicates an earlier slot with the same id
    (``core.frontier._dedup_mask`` semantics, restated here so the
    kernels package stays dependency-free of ``core``)."""
    m = ids.shape[-1]
    lt = jnp.tril(jnp.ones((m, m), dtype=bool), k=-1)
    same = ids[..., None, :] == ids[..., :, None]
    return jnp.any(same & lt & (ids[..., None, :] >= 0), axis=-1)


def fused_traversal_round_ref(
    frontier_ids: jax.Array,  # (B, L) int32
    frontier_dists: jax.Array,  # (B, L) float32
    frontier_expanded: jax.Array,  # (B, L) bool
    frontier_passes: jax.Array,  # (B, L) bool
    new_ids: jax.Array,  # (B, M) int32
    new_codes: jax.Array,  # (B, M, C) int32
    new_passes: jax.Array,  # (B, M) bool
    lut: jax.Array,  # (B, C, K) float32
    entry: jax.Array,  # (B,) int32
    *,
    mode: str,
    width: int,
):
    """jnp twin of ``fused_traversal.fused_traversal_round``.

    Composes the unfused building blocks — ADC reference, stable-argsort
    frontier merge (``frontier.insert`` semantics), stable-argsort beam
    selection (``frontier.best_unexpanded``), and the shared
    ``mode_masks`` — in the same rotated round shape as the kernel.
    Returns a ``fused_traversal.FusedRound``.
    """
    from repro.kernels.fused_traversal import FusedRound, mode_masks

    b, l = frontier_ids.shape
    m = new_ids.shape[1]

    if m:
        nd = pq_lookup_gathered_ref(lut, new_codes)
        nd = jnp.where(new_ids >= 0, nd, _INF)
        ids = jnp.concatenate([frontier_ids, new_ids], axis=-1)
        dists = jnp.concatenate([frontier_dists, nd], axis=-1)
        exp = jnp.concatenate(
            [frontier_expanded, jnp.zeros((b, m), bool)], axis=-1
        )
        pas = jnp.concatenate([frontier_passes, new_passes], axis=-1)
    else:
        ids, dists = frontier_ids, frontier_dists
        exp, pas = frontier_expanded, frontier_passes

    # frontier.insert: dedup + invalid -> dead (+INF, -1), stable top-L
    dists = jnp.where(_dedup_mask_ref(ids) | (ids < 0), _INF, dists)
    ids = jnp.where(dists >= _INF, _INVALID, ids)
    order = jnp.argsort(dists, axis=-1)[:, :l]
    mf_ids = jnp.take_along_axis(ids, order, axis=-1)
    mf_d = jnp.take_along_axis(dists, order, axis=-1)
    mf_exp = jnp.take_along_axis(exp, order, axis=-1)
    mf_pas = jnp.take_along_axis(pas, order, axis=-1)

    # frontier.best_unexpanded + mark_expanded
    selkey = jnp.where((~mf_exp) & (mf_ids >= 0), mf_d, _INF)
    slots = jnp.argsort(selkey, axis=-1)[:, :width]
    valid = jnp.take_along_axis(selkey, slots, axis=-1) < _INF
    sel_ids = jnp.where(
        valid, jnp.take_along_axis(mf_ids, slots, axis=-1), _INVALID
    )
    passes = jnp.take_along_axis(mf_pas, slots, axis=-1) & valid
    upd = jnp.zeros_like(mf_exp)
    upd = upd.at[jnp.arange(b)[:, None], slots].set(valid)
    mf_exp = mf_exp | upd

    fetch, tun, res, exact = mode_masks(mode, sel_ids, valid, passes,
                                        entry[:, None])
    return FusedRound(
        frontier_ids=mf_ids,
        frontier_dists=mf_d,
        frontier_expanded=mf_exp,
        frontier_passes=mf_pas,
        sel_ids=sel_ids,
        valid=valid,
        fetch_ids=jnp.where(fetch, sel_ids, _INVALID),
        fetch_mask=fetch,
        tunnel_mask=tun,
        result_mask=res,
        exact_mask=exact,
    )
