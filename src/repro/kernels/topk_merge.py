"""Pallas TPU kernel: bitonic top-k selection for frontier maintenance.

Frontier upkeep ("Other: list mgmt", 26–34% of per-query time in paper
Table 5) is a sort-and-truncate over the merged candidate list.  A full
``argsort`` is wasteful when only the best L survive; this kernel runs a
static **bitonic sorting network** over a VMEM tile of (dist, id) pairs
and emits the first L — ids ride along through every compare-exchange.

Ordering is **deterministic on the lexicographic (dist, id) key**: ties
in distance break by ascending id, in both this kernel and the
``ref.topk_merge_ref`` oracle.  A bitonic network is not a stable sort,
so breaking ties by network position (the old behavior) let kernel and
reference disagree about which id survives at rank k whenever two
candidates shared a distance; the id tiebreak makes the key total and
the result unique.  Padding rows (to the power-of-two network width)
carry an id *above* every real id, so they sort after genuine
+INF-distance entries and come back as (-1, +INF).

The network is O(M log² M) compare-exchanges of full vectors, entirely on
the VPU with no data-dependent control flow — exactly the shape TPUs
like.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret

_INF = jnp.float32(3.4e38)
# pad id: sorts after every real id at equal (+INF) distance; mapped back
# to -1 on output.  Real ids are node indices, far below int32 max.
_PAD_ID = jnp.int32(2**31 - 1)


def _bitonic_kernel(d_ref, i_ref, od_ref, oi_ref, *, m: int, l: int):
    d = d_ref[0]  # (M,) f32
    ids = i_ref[0]  # (M,) i32
    logm = m.bit_length() - 1
    idx = jnp.arange(m)
    for stage in range(logm):
        block = 1 << (stage + 1)
        for sub in reversed(range(stage + 1)):
            j = 1 << sub
            partner = idx ^ j
            pd = d[partner]
            pi = ids[partner]
            # strict lexicographic (dist, id) "self < partner"; ids are
            # unique per batch row in the intended use, but even with
            # duplicates the <= on equal keys keeps the exchange stable
            lt = (d < pd) | ((d == pd) & (ids <= pi))
            is_lower = (idx & j) == 0
            ascending = (idx & block) == 0
            keep_self = jnp.where(
                ascending, jnp.where(is_lower, lt, ~lt),
                jnp.where(is_lower, ~lt, lt),
            )
            d = jnp.where(keep_self, d, pd)
            ids = jnp.where(keep_self, ids, pi)
    od_ref[0] = d[:l]
    oi_ref[0] = ids[:l]


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_merge(
    dists: jax.Array,  # (B, M) float32 — merged candidate keys
    ids: jax.Array,  # (B, M) int32
    k: int,
    *,
    interpret: bool | None = None,
):
    """Sorted top-k by ascending (distance, id). Returns (dists (B,k), ids (B,k))."""
    interpret = resolve_interpret(interpret)
    b, m = dists.shape
    mp = 1 << (m - 1).bit_length()  # next power of two
    if mp != m:
        dists = jnp.pad(dists, ((0, 0), (0, mp - m)), constant_values=_INF)
        ids = jnp.pad(ids, ((0, 0), (0, mp - m)), constant_values=_PAD_ID)
    k = min(k, mp)
    od, oi = pl.pallas_call(
        functools.partial(_bitonic_kernel, m=mp, l=k),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, mp), lambda i: (i, 0)),
            pl.BlockSpec((1, mp), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        interpret=interpret,
    )(dists.astype(jnp.float32), ids.astype(jnp.int32))
    return od, jnp.where(oi == _PAD_ID, jnp.int32(-1), oi)
