"""Pallas TPU kernel: bitonic top-k selection for frontier maintenance.

Frontier upkeep ("Other: list mgmt", 26–34% of per-query time in paper
Table 5) is a sort-and-truncate over the merged candidate list.  A full
``argsort`` is wasteful when only the best L survive; this kernel runs a
static **bitonic sorting network** over a VMEM tile of (dist, id) pairs
and emits the first L — ids ride along through every compare-exchange, so
the result is a consistent (dist, id) ordering.

The network is O(M log² M) compare-exchanges of full vectors, entirely on
the VPU with no data-dependent control flow — exactly the shape TPUs
like.  M is padded to a power of two with +INF keys.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INF = jnp.float32(3.4e38)


def _bitonic_kernel(d_ref, i_ref, od_ref, oi_ref, *, m: int, l: int):
    d = d_ref[0]  # (M,) f32
    ids = i_ref[0]  # (M,) i32
    logm = m.bit_length() - 1
    idx = jnp.arange(m)
    for stage in range(logm):
        block = 1 << (stage + 1)
        for sub in reversed(range(stage + 1)):
            j = 1 << sub
            partner = idx ^ j
            pd = d[partner]
            pi = ids[partner]
            is_lower = (idx & j) == 0
            ascending = (idx & block) == 0
            keep_self = jnp.where(
                ascending, jnp.where(is_lower, d <= pd, d >= pd),
                jnp.where(is_lower, d >= pd, d <= pd),
            )
            d = jnp.where(keep_self, d, pd)
            ids = jnp.where(keep_self, ids, pi)
    od_ref[0] = d[:l]
    oi_ref[0] = ids[:l]


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_merge(
    dists: jax.Array,  # (B, M) float32 — merged candidate keys
    ids: jax.Array,  # (B, M) int32
    k: int,
    *,
    interpret: bool = True,
):
    """Sorted top-k by ascending distance. Returns (dists (B,k), ids (B,k))."""
    b, m = dists.shape
    mp = 1 << (m - 1).bit_length()  # next power of two
    if mp != m:
        dists = jnp.pad(dists, ((0, 0), (0, mp - m)), constant_values=_INF)
        ids = jnp.pad(ids, ((0, 0), (0, mp - m)), constant_values=-1)
    k = min(k, mp)
    od, oi = pl.pallas_call(
        functools.partial(_bitonic_kernel, m=mp, l=k),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, mp), lambda i: (i, 0)),
            pl.BlockSpec((1, mp), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        interpret=interpret,
    )(dists.astype(jnp.float32), ids.astype(jnp.int32))
    return od, oi
