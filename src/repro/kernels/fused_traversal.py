"""Pallas TPU kernel: one fused stage-A traversal round.

With SSD reads overlapped (pipelined disk search) the in-memory traversal
is the throughput wall: each round runs PQ-lookup, filter masking,
candidate selection, and frontier top-k merge as *separate* ops with HBM
round-trips between them (NDSEARCH's argument — traversal compute, not
just I/O, bounds graph-ANNS throughput).  This kernel fuses one whole
round into a single VMEM-resident pass per query:

  1. **ADC PQ-lookup** over the round's gathered candidate codes — the
     same one-hot × LUT contraction as ``pq_lookup`` (MXU-friendly, and
     bitwise-identical to the unfused ``take_along_axis(...).sum(-1)``
     reference on every backend we pin).
  2. **Kill masking** — invalid ids and within-concat duplicates go to
     (+INF, -1), replicating ``frontier.insert``'s ``_dedup_mask``
     (earlier slot wins) exactly.
  3. **Frontier merge** — a bitonic sorting network over the padded
     [old frontier ‖ new candidates] keyed on ``(dist, seq)``; the
     position tiebreak makes the (unstable) network reproduce a *stable*
     ascending sort bit-for-bit, so the merged frontier equals
     ``jnp.argsort``'s.  ``expanded`` / filter-pass flags ride along as
     payload lanes through every compare-exchange.
  4. **Beam selection** — rank-by-pairwise-comparison over the merged
     frontier picks the ``width`` best unexpanded entries (ties by slot,
     matching ``frontier.best_unexpanded``'s stable argsort) and marks
     them expanded.
  5. **Filter / tunnel masks** — the per-mode fetch/tunnel/result/exact
     mask logic (``mode_masks`` below — the *same function* the unfused
     loop calls) runs on the selected beam inside the kernel.

Filter-store lookups stay outside (they are per-query closures over
engine state); their boolean verdicts enter once per candidate and ride
the sort as payload, so the kernel never re-evaluates a predicate.

The round is *rotated* relative to the unfused loop: one call merges the
previous round's candidates and selects the next beam, which is exactly
``expand`` ∘ ``stage_a`` of ``core/search.py``.  ``filtered_search``
carries the selection in loop state; results are bit-identical (pinned
by the fused-vs-unfused parity lattice in ``tests/test_fused_traversal``).

Everything is padded to powers of two with (+INF, -1, seq>=real) pad
entries, which sort strictly after every real slot — M (candidate count)
and L (frontier length) need not be powers of two.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret

# numpy scalars, not jnp: the kernel body references them, and a traced
# jnp scalar would be captured as a pallas_call constant (a trace error)
INF = np.float32(3.4e38)
INVALID = np.int32(-1)

# ADC one-hot workspace tile: bounds VMEM at C * _ADC_TILE * K * 4 bytes
# (4 MB at C=32, K=256) regardless of the candidate count M.
_ADC_TILE = 128

# conservative ceilings for the silent fallback: the padded sort width
# (VPU lanes per compare-exchange) and the one-hot workspace bytes
_MAX_SORT = 4096
_MAX_ADC_BYTES = 8 * 1024 * 1024


def mode_masks(mode: str, sel_ids, valid, passes, entry_ids):
    """Per-mode dispatch masks for a selected beam — the single source of
    truth shared by the unfused ``stage_a``, this kernel's body, and the
    jnp reference twin (``ref.fused_traversal_round_ref``).

    All arguments broadcast elementwise against ``sel_ids`` (boolean
    ``valid``/``passes``; ``entry_ids`` is the per-query entry id).
    Returns ``(fetch_mask, tunnel_mask, result_mask, exact_mask)``.
    """
    no = jnp.zeros_like(valid)
    if mode == "unfiltered":
        return valid, no, valid, valid
    if mode == "post":
        return valid, no, passes, valid
    if mode == "early":
        return valid, no, passes, passes
    if mode == "pre_naive":
        is_entry = sel_ids == entry_ids
        fetch = passes | (is_entry & valid)
        return fetch, no, passes, fetch
    # gate
    return passes, valid & (~passes), passes, passes


class FusedRound(NamedTuple):
    """One kernel call's outputs: the merged+marked frontier and the next
    beam with its per-mode masks (shapes ``(B, L)`` / ``(B, W)``)."""

    frontier_ids: jax.Array
    frontier_dists: jax.Array
    frontier_expanded: jax.Array  # bool
    frontier_passes: jax.Array  # bool — filter verdict payload per slot
    sel_ids: jax.Array
    valid: jax.Array  # bool
    fetch_ids: jax.Array  # sel_ids where fetch_mask, else -1
    fetch_mask: jax.Array  # bool
    tunnel_mask: jax.Array  # bool
    result_mask: jax.Array  # bool
    exact_mask: jax.Array  # bool


def fused_supported(*, l: int, width: int, m: int, c: int, k: int,
                    backend: str | None = None) -> bool:
    """Can the fused kernel serve these shapes on this backend?

    Callers fall back to the unfused loop (bit-identical results, just
    more HBM round-trips) when this returns False — the flag is a perf
    knob, never a correctness one.
    """
    backend = backend or jax.default_backend()
    if backend not in ("cpu", "gpu", "tpu"):
        return False
    if width < 1 or l < 1 or m < 0:
        return False
    total = l + m
    pad = 1 << (total - 1).bit_length()
    if pad > _MAX_SORT:
        return False
    if c * _ADC_TILE * k * 4 > _MAX_ADC_BYTES:
        return False
    return True


def _adc(lut, codes, ids):
    """In-kernel ADC: dist[m] = Σ_c lut[c, codes[m, c]]; invalid -> +INF.

    Tiled over M so the one-hot workspace stays bounded; each tile is the
    same batched-over-C contraction as ``pq_lookup._adc_kernel`` (whose
    ``jnp.sum`` over chunks is bitwise-equal to the unfused
    ``take_along_axis(...).sum(-1)`` — pinned in tests).
    """
    c, k = lut.shape
    m = codes.shape[0]
    parts = []
    for t0 in range(0, m, _ADC_TILE):
        tile = codes[t0 : min(t0 + _ADC_TILE, m)]  # (Mt, C)
        iota_k = jax.lax.broadcasted_iota(jnp.int32, (c, tile.shape[0], k), 2)
        onehot = (tile.T[:, :, None] == iota_k).astype(lut.dtype)  # (C, Mt, K)
        per_chunk = jax.lax.dot_general(
            onehot, lut,
            dimension_numbers=(((2,), (1,)), ((0,), (0,))),  # batch C, contract K
            preferred_element_type=jnp.float32,
        )  # (C, Mt)
        parts.append(jnp.sum(per_chunk, axis=0))
    d = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
    return jnp.where(ids >= 0, d, INF)


def _bitonic_merge(dists, ids, exp, pas, total: int):
    """Stable ascending sort of (dists, payload) via a bitonic network.

    ``total`` real entries are padded to a power of two with
    (+INF, -1, expanded, fail) lanes whose seq numbers sit *after* every
    real slot, so pads sort strictly last among INF ties.  The seq lane
    makes the network's total order equal a stable sort by distance.
    """
    p = 1 << (total - 1).bit_length()
    if p != total:
        pad = p - total
        dists = jnp.concatenate([dists, jnp.full((pad,), INF)])
        ids = jnp.concatenate([ids, jnp.full((pad,), INVALID)])
        exp = jnp.concatenate([exp, jnp.ones((pad,), exp.dtype)])
        pas = jnp.concatenate([pas, jnp.zeros((pad,), pas.dtype)])
    seq = jax.lax.iota(jnp.int32, p)
    idx = jax.lax.iota(jnp.int32, p)
    d, i, e, f, s = dists, ids, exp, pas, seq
    logp = p.bit_length() - 1
    for stage in range(logp):
        block = 1 << (stage + 1)
        for sub in reversed(range(stage + 1)):
            j = 1 << sub
            partner = idx ^ j
            pd, pi, pe, pf, ps = d[partner], i[partner], e[partner], f[partner], s[partner]
            # strict lexicographic (dist, seq) — seqs are unique, so this
            # is a total order and == / >= cases never arise
            lt = (d < pd) | ((d == pd) & (s < ps))
            is_lower = (idx & j) == 0
            ascending = (idx & block) == 0
            keep = jnp.where(ascending,
                             jnp.where(is_lower, lt, ~lt),
                             jnp.where(is_lower, ~lt, lt))
            d = jnp.where(keep, d, pd)
            i = jnp.where(keep, i, pi)
            e = jnp.where(keep, e, pe)
            f = jnp.where(keep, f, pf)
            s = jnp.where(keep, s, ps)
    return d, i, e, f


def _fused_kernel(
    fid_ref, fd_ref, fexp_ref, fpass_ref,
    nid_ref, ncodes_ref, npass_ref, lut_ref, entry_ref,
    ofid_ref, ofd_ref, ofexp_ref, ofpass_ref,
    osel_ref, ovalid_ref, ofids_ref, ofetch_ref, otun_ref, ores_ref, oexact_ref,
    *, mode: str, l: int, m: int, width: int,
):
    """One query's round: merge M candidates into the L-frontier, select
    the next W-beam, emit its per-mode masks.  Bool lanes travel as i32."""
    fid = fid_ref[0]
    fd = fd_ref[0]
    fexp = fexp_ref[0]
    fpass = fpass_ref[0]

    if m:
        nid = nid_ref[0]
        nd = _adc(lut_ref[0], ncodes_ref[0], nid)
        ids = jnp.concatenate([fid, nid])
        dists = jnp.concatenate([fd, nd])
        exp = jnp.concatenate([fexp, jnp.zeros((m,), fexp.dtype)])
        pas = jnp.concatenate([fpass, npass_ref[0]])
    else:  # round-0 call: nothing to merge, just select from the frontier
        ids, dists, exp, pas = fid, fd, fexp, fpass

    total = l + m
    # kill mask, exactly as frontier.insert: a slot dies if it duplicates
    # an EARLIER slot holding the same (non-negative) id, or its own id is
    # invalid; dead slots become (+INF, -1)
    pos = jax.lax.iota(jnp.int32, total)
    earlier = pos[None, :] < pos[:, None]  # [a, b] — slot b precedes a
    same = ids[None, :] == ids[:, None]
    dup = jnp.any(same & earlier & (ids[None, :] >= 0), axis=-1)
    dists = jnp.where(dup | (ids < 0), INF, dists)
    ids = jnp.where(dists >= INF, INVALID, ids)

    sd, sids, sexp, spas = _bitonic_merge(dists, ids, exp, pas, total)
    mf_d, mf_ids, mf_exp, mf_pas = sd[:l], sids[:l], sexp[:l], spas[:l]

    # beam selection == frontier.best_unexpanded: stable argsort of the
    # masked key, realized as rank-by-pairwise-comparison (ties by slot)
    selkey = jnp.where((mf_exp == 0) & (mf_ids >= 0), mf_d, INF)
    lpos = jax.lax.iota(jnp.int32, l)
    prec = (selkey[None, :] < selkey[:, None]) | (
        (selkey[None, :] == selkey[:, None]) & (lpos[None, :] < lpos[:, None])
    )
    rank = jnp.sum(prec.astype(jnp.int32), axis=-1)  # (L,)
    selected = (rank < width) & (selkey < INF)
    mf_exp = mf_exp | selected.astype(mf_exp.dtype)

    # scatter the selected slots into beam order (rank w -> lane w)
    wpos = jax.lax.iota(jnp.int32, width)
    oh = (rank[None, :] == wpos[:, None]) & selected[None, :]  # (W, L)
    valid = jnp.any(oh, axis=-1)
    sel_ids = jnp.sum(jnp.where(oh, mf_ids[None, :], 0), axis=-1)
    sel_ids = jnp.where(valid, sel_ids, INVALID)
    passes = jnp.any(oh & (mf_pas[None, :] != 0), axis=-1) & valid

    fetch, tun, res, exact = mode_masks(mode, sel_ids, valid, passes,
                                        entry_ref[0, 0])

    ofid_ref[0] = mf_ids
    ofd_ref[0] = mf_d
    ofexp_ref[0] = mf_exp
    ofpass_ref[0] = mf_pas
    osel_ref[0] = sel_ids
    ovalid_ref[0] = valid.astype(jnp.int32)
    ofids_ref[0] = jnp.where(fetch, sel_ids, INVALID)
    ofetch_ref[0] = fetch.astype(jnp.int32)
    otun_ref[0] = tun.astype(jnp.int32)
    ores_ref[0] = res.astype(jnp.int32)
    oexact_ref[0] = exact.astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("mode", "width", "interpret")
)
def fused_traversal_round(
    frontier_ids: jax.Array,  # (B, L) int32
    frontier_dists: jax.Array,  # (B, L) float32
    frontier_expanded: jax.Array,  # (B, L) bool
    frontier_passes: jax.Array,  # (B, L) bool — filter verdicts per slot
    new_ids: jax.Array,  # (B, M) int32 — already visited-masked (-1 = dead)
    new_codes: jax.Array,  # (B, M, C) int32 — gathered PQ codes
    new_passes: jax.Array,  # (B, M) bool — filter verdicts for new ids
    lut: jax.Array,  # (B, C, K) float32 per-query ADC tables
    entry: jax.Array,  # (B,) int32 per-query entry point (pre_naive mode)
    *,
    mode: str,
    width: int,
    interpret: bool | None = None,
) -> FusedRound:
    """Batched fused round; see module docstring.  Grid is one program
    per query; everything for a query lives in VMEM for the whole pass."""
    interpret = resolve_interpret(interpret)
    b, l = frontier_ids.shape
    m = new_ids.shape[1]
    c, k = lut.shape[1], lut.shape[2]
    w = width

    kern = functools.partial(_fused_kernel, mode=mode, l=l, m=m, width=w)
    row = lambda i: (i, 0)
    row3 = lambda i: (i, 0, 0)
    out = pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, l), row),  # frontier ids
            pl.BlockSpec((1, l), row),  # frontier dists
            pl.BlockSpec((1, l), row),  # frontier expanded
            pl.BlockSpec((1, l), row),  # frontier passes
            pl.BlockSpec((1, max(m, 1)), row),  # new ids
            pl.BlockSpec((1, max(m, 1), c), row3),  # new codes
            pl.BlockSpec((1, max(m, 1)), row),  # new passes
            pl.BlockSpec((1, c, k), row3),  # lut
            pl.BlockSpec((1, 1), row),  # entry
        ],
        out_specs=[
            pl.BlockSpec((1, l), row),
            pl.BlockSpec((1, l), row),
            pl.BlockSpec((1, l), row),
            pl.BlockSpec((1, l), row),
            pl.BlockSpec((1, w), row),
            pl.BlockSpec((1, w), row),
            pl.BlockSpec((1, w), row),
            pl.BlockSpec((1, w), row),
            pl.BlockSpec((1, w), row),
            pl.BlockSpec((1, w), row),
            pl.BlockSpec((1, w), row),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l), jnp.int32),  # frontier ids
            jax.ShapeDtypeStruct((b, l), jnp.float32),  # frontier dists
            jax.ShapeDtypeStruct((b, l), jnp.int32),  # frontier expanded
            jax.ShapeDtypeStruct((b, l), jnp.int32),  # frontier passes
            jax.ShapeDtypeStruct((b, w), jnp.int32),  # sel_ids
            jax.ShapeDtypeStruct((b, w), jnp.int32),  # valid
            jax.ShapeDtypeStruct((b, w), jnp.int32),  # fetch_ids
            jax.ShapeDtypeStruct((b, w), jnp.int32),  # fetch_mask
            jax.ShapeDtypeStruct((b, w), jnp.int32),  # tunnel_mask
            jax.ShapeDtypeStruct((b, w), jnp.int32),  # result_mask
            jax.ShapeDtypeStruct((b, w), jnp.int32),  # exact_mask
        ],
        interpret=interpret,
    )(
        frontier_ids.astype(jnp.int32),
        frontier_dists.astype(jnp.float32),
        frontier_expanded.astype(jnp.int32),
        frontier_passes.astype(jnp.int32),
        _at_least_one(new_ids.astype(jnp.int32), INVALID),
        _at_least_one_3d(new_codes.astype(jnp.int32)),
        _at_least_one(new_passes.astype(jnp.int32), jnp.int32(0)),
        lut.astype(jnp.float32),
        entry.astype(jnp.int32)[:, None],
    )
    (ofid, ofd, ofexp, ofpass, osel, ovalid, ofids,
     ofetch, otun, ores, oexact) = out
    return FusedRound(
        frontier_ids=ofid,
        frontier_dists=ofd,
        frontier_expanded=ofexp != 0,
        frontier_passes=ofpass != 0,
        sel_ids=osel,
        valid=ovalid != 0,
        fetch_ids=ofids,
        fetch_mask=ofetch != 0,
        tunnel_mask=otun != 0,
        result_mask=ores != 0,
        exact_mask=oexact != 0,
    )


def fused_round_for_backend():
    """The search loop's fused-round callable for this process's backend.

    The Pallas kernel wherever a compiled lowering exists (TPU/GPU); its
    bit-identical jnp twin (``ref.fused_traversal_round_ref``) elsewhere.
    Interpret-mode Pallas inside ``jax.lax.while_loop`` makes CPU XLA
    compile times pathological (minutes per mode, unbounded for some mask
    configurations) — it is a kernel-debugging tool, not a serving path.
    The twin is pinned bitwise to the kernel by the parity lattice in
    ``tests/test_fused_traversal.py``, so routing through it preserves
    the fused loop's bit-identity contract on every backend.
    """
    from repro.kernels.backend import supports_compiled_pallas

    if supports_compiled_pallas():
        return fused_traversal_round
    from repro.kernels import ref

    return ref.fused_traversal_round_ref


def _at_least_one(x, fill):
    """Pallas blocks need extent >= 1: widen an (B, 0) input to (B, 1)
    dead lanes (the kernel's static ``m`` still reflects the real M)."""
    if x.shape[1] == 0:
        return jnp.full((x.shape[0], 1), fill, x.dtype)
    return x


def _at_least_one_3d(x):
    if x.shape[1] == 0:
        return jnp.zeros((x.shape[0], 1, x.shape[2]), x.dtype)
    return x
