"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) vocab=100352.

Fine-grained MoE on every layer: 16 experts, top-4, expert d_ff=10752
[hf:databricks/dbrx-base; unverified].  Full attention -> no long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,  # every FFN is MoE
    vocab_size=100_352,
    act="silu",
    pattern_unit=("moe",),
    attn_windows=(None,),
    n_experts=16,
    moe_top_k=4,
    moe_d_ff=10752,
    supports_long_context=False,
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        vocab_size=512, n_experts=4, moe_top_k=2, moe_d_ff=64,
    )
