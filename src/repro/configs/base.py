"""Model / shape / run configuration schema.

Every assigned architecture is expressed as a ``ModelConfig``; the four
benchmark shapes are ``ShapeConfig`` instances.  Configs are plain frozen
dataclasses — no framework magic — and each arch module in this package
exports ``CONFIG`` plus a reduced ``smoke_config()`` for CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

LayerKind = Literal["attn", "moe", "rglru", "mlstm", "slstm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- block pattern -----------------------------------------------------
    # The repeating unit of layer kinds; layer i has kind
    # pattern_unit[i % len(pattern_unit)].  "attn" entries may carry a
    # sliding window via attn_windows (None = global attention).
    pattern_unit: tuple[str, ...] = ("attn",)
    attn_windows: tuple[int | None, ...] = (None,)  # parallel to pattern_unit
    # --- attention ----------------------------------------------------------
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    logit_softcap: float | None = None
    # --- mlp -----------------------------------------------------------------
    act: str = "silu"  # silu (SwiGLU) | gelu (GeGLU)
    # --- moe ------------------------------------------------------------------
    n_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0  # expert hidden size (d_ff = dense-layer hidden size)
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- recurrent (rglru / xlstm) ---------------------------------------------
    lru_width: int = 0  # RG-LRU hidden width (recurrentgemma)
    conv_width: int = 4  # temporal conv in recurrent blocks
    # --- frontends ---------------------------------------------------------------
    frontend: str | None = None  # None | "audio_stub" | "vision_stub"
    n_prefix_embeds: int = 0  # stub frontend prefix length (vlm patches)
    # --- misc -----------------------------------------------------------------
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # which shapes this arch can run (long_500k needs sub-quadratic attn)
    supports_long_context: bool = False

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        unit = self.pattern_unit
        return tuple(unit[i % len(unit)] for i in range(self.n_layers))

    @property
    def layer_windows(self) -> tuple[int | None, ...]:
        w = self.attn_windows
        return tuple(w[i % len(w)] for i in range(self.n_layers))

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.pattern_unit)

    @property
    def n_leftover(self) -> int:
        return self.n_layers % len(self.pattern_unit)

    def param_count(self) -> int:
        """Total parameters (embedding included once unless tied)."""
        d, dff, v = self.d_model, self.d_ff, self.vocab_size
        qdim = self.n_heads * self.head_dim
        kvdim = self.n_kv_heads * self.head_dim
        total = 0
        for kind, _w in zip(self.layer_kinds, self.layer_windows):
            if kind == "attn":
                total += d * qdim + 2 * d * kvdim + qdim * d  # qkvo
                if self.qkv_bias:
                    total += qdim + 2 * kvdim
                total += 2 * d  # norms
                if dff:
                    total += 3 * d * dff
            elif kind == "moe":
                total += d * qdim + 2 * d * kvdim + qdim * d + 2 * d
                total += d * self.n_experts  # router
                total += self.n_experts * 3 * d * self.moe_d_ff
                total += self.n_shared_experts * 3 * d * self.moe_d_ff
            elif kind == "rglru":
                w = self.lru_width or d
                # in/out proj (x2 branches), gates, conv
                total += 2 * d * w + w * d + 3 * w + self.conv_width * w + 2 * d
                if dff:
                    total += 3 * d * dff
            elif kind == "mlstm":
                # up-proj x2, qkv over 2d, out
                total += 2 * d * 2 * d + 3 * (2 * d) * (2 * d) // max(self.n_heads, 1) * 0
                total += 2 * d * 2 * d + 4 * (2 * d) + 2 * d * d + 2 * d
                total += 3 * (2 * d) * self.head_dim * self.n_heads // max(1, self.n_heads)
            elif kind == "slstm":
                total += 4 * d * d + 4 * d * d + 2 * d  # in + recurrent
                if dff:
                    total += 2 * d * int(4 * d * 4 / 3)
        total += v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE counts top_k + shared experts)."""
        if self.n_experts == 0:
            return self.param_count()
        dense_total = self.param_count()
        moe_layers = sum(1 for k in self.layer_kinds if k == "moe")
        all_experts = moe_layers * self.n_experts * 3 * self.d_model * self.moe_d_ff
        active = moe_layers * (self.moe_top_k + self.n_shared_experts) * 3 * self.d_model * self.moe_d_ff
        return dense_total - all_experts + active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(config: ModelConfig) -> tuple[ShapeConfig, ...]:
    """long_500k only for sub-quadratic archs (DESIGN.md §4)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if config.supports_long_context:
        out.append(LONG_500K)
    return tuple(out)
