"""Architecture registry: --arch <id> -> ModelConfig (+ reduced smoke config)."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

_ARCH_MODULES = {
    "musicgen-medium": "repro.configs.musicgen_medium",
    "gemma-7b": "repro.configs.gemma_7b",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick",
    "dbrx-132b": "repro.configs.dbrx_132b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return importlib.import_module(_ARCH_MODULES[arch]).smoke_config()
