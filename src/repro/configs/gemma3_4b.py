"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.

5:1 local:global attention (window 1024), 128k context
[hf:google/gemma-3-1b-pt; unverified].  The 5:1 hybrid makes long_500k
runnable: per decoded token the global layers cost O(T) and the local
layers O(window).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262_144,
    act="gelu",
    pattern_unit=("attn",) * 6,  # 5 local + 1 global
    attn_windows=(1024, 1024, 1024, 1024, 1024, None),
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    supports_long_context=True,
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=7, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, attn_windows=(16, 16, 16, 16, 16, None),
    )
