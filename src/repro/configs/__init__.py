from repro.configs.base import ModelConfig, ShapeConfig, ALL_SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K, shapes_for
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
