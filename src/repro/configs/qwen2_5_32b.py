"""qwen2.5-32b [dense] — 64L d_model=5120 40H (GQA kv=8) d_ff=27648.

vocab=152064, QKV bias [hf:Qwen/Qwen2.5-0.5B; hf].
Full attention -> no long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152_064,
    act="silu",
    qkv_bias=True,
    supports_long_context=False,
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=160, vocab_size=512,
    )
