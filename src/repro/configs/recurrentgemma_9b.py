"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288.

vocab=256000, Griffin pattern: 2 RG-LRU blocks : 1 local-attention block
(window 2048), lru_width=4096 [arXiv:2402.19427; unverified].
Recurrent + local attention -> long_500k runnable.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    act="gelu",
    pattern_unit=("rglru", "rglru", "attn"),
    attn_windows=(None, None, 2048),
    lru_width=4096,
    conv_width=4,
    tie_embeddings=True,
    supports_long_context=True,
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512, lru_width=64,
        attn_windows=(None, None, 16),
    )
