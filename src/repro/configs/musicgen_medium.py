"""musicgen-medium [audio] — decoder-only LM over EnCodec tokens.

48L d_model=1536 24H (kv=24, MHA) d_ff=6144 vocab=2048
[arXiv:2306.05284; hf].  The EnCodec frontend is a stub per the brief:
``input_specs()`` supplies token ids (the EnCodec codes themselves) — the
transformer backbone is what we model.  Full attention -> no long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    act="gelu",
    frontend="audio_stub",
    supports_long_context=False,
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256,
    )
