"""deepseek-coder-33b [dense] — 62L d_model=7168 56H (GQA kv=8) d_ff=19200.

vocab=32256, llama-style SwiGLU [arXiv:2401.14196; hf].
Full attention -> no long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32_256,
    act="silu",
    supports_long_context=False,
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=160, vocab_size=512,
    )
