"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 vocab=50304.

Alternating mLSTM / sLSTM blocks [arXiv:2405.04517; unverified]: the
blocks carry their own up/down projections (projection factor 2 for
mLSTM, ferroelectric 4/3 FFN after sLSTM), hence d_ff=0 at the top level.
Recurrent state -> long_500k runnable.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50_304,
    pattern_unit=("mlstm", "slstm"),
    attn_windows=(None, None),
    supports_long_context=True,
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        vocab_size=512,
    )
