"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8).

MoE: 128 routed experts top-1 + 1 shared expert, expert d_ff=8192,
vocab=202048 [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

Assumption log (DESIGN.md §4): MoE on every *second* layer
(interleave step 2, as in the released Maverick config) with dense-layer
d_ff=16384; this reproduces ~400B total / ~17B active parameters implied
by the model name.  Early-fusion multimodality is out of scope for the
text backbone (the brief assigns the LM backbone only).
Full attention -> no long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,  # dense layers
    vocab_size=202_048,
    act="silu",
    pattern_unit=("attn", "moe"),
    attn_windows=(None, None),
    n_experts=128,
    moe_top_k=1,
    moe_d_ff=8192,
    n_shared_experts=1,
    supports_long_context=False,
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, n_experts=8, moe_d_ff=64,
    )
