"""gemma-7b [dense] — 28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000.

GeGLU activation, head_dim=256 (q-dim 4096 != d_model) [arXiv:2403.08295; hf].
Full attention -> no long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256_000,
    act="gelu",
    tie_embeddings=True,  # gemma ties the unembedding
    supports_long_context=False,
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=192, vocab_size=512,
    )
