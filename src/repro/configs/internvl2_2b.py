"""internvl2-2b [vlm] — InternLM2-1.8B backbone: 24L d_model=2048 16H (kv=8).

d_ff=8192 vocab=92553 [arXiv:2404.16821; hf].  The InternViT vision tower
is a stub per the brief: ``input_specs()`` supplies precomputed patch
embeddings (n_prefix_embeds x d_model) that are prepended to the token
embeddings.  Full attention -> no long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92_553,
    act="silu",
    frontend="vision_stub",
    n_prefix_embeds=256,  # one 448x448 tile -> 256 visual tokens
    supports_long_context=False,
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, n_prefix_embeds=8,
    )
