"""Serving decode step: one new token against per-layer caches.

KV caches are sharded over *sequence* on the ``model`` axis (flash-decode);
recurrent states shard over batch.  Cache shardings must round-trip
(out == in) so the serving loop can feed caches back without resharding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import Layout
from repro.models.transformer import forward_decode


def make_serve_step(cfg: ModelConfig, layout: Layout, *, greedy: bool = True):
    def serve_step(params, caches, tokens, pos):
        logits, new_caches = forward_decode(params, cfg, layout, tokens, caches, pos)
        if greedy:
            next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        else:
            next_tok = tokens  # caller samples from logits
        return {"logits": logits, "next_tokens": next_tok, "caches": new_caches}

    return serve_step


def _axes_of(layout: Layout, *names):
    return P(*[layout.act_axes(n) for n in names])


def cache_pspecs(cfg: ModelConfig, layout: Layout):
    """PartitionSpec tree matching init_caches structure."""
    from repro.models.attention import kv_cache_quantized

    specs = []
    for kind in cfg.layer_kinds:
        if kind in ("attn", "moe"):
            if kv_cache_quantized():
                specs.append({
                    "k_q": _axes_of(layout, "act_batch", "cache_seq", "kv_heads",
                                    "head_dim"),
                    "k_s": _axes_of(layout, "act_batch", "cache_seq", "kv_heads"),
                    "v_q": _axes_of(layout, "act_batch", "cache_seq", "kv_heads",
                                    "head_dim"),
                    "v_s": _axes_of(layout, "act_batch", "cache_seq", "kv_heads"),
                    "pos": P(layout.act_axes("cache_seq")),
                })
                continue
            specs.append({
                "k": _axes_of(layout, "act_batch", "cache_seq", "kv_heads", "head_dim"),
                "v": _axes_of(layout, "act_batch", "cache_seq", "kv_heads", "head_dim"),
                "pos": P(layout.act_axes("cache_seq")),
            })
        elif kind == "rglru":
            specs.append({
                "h": _axes_of(layout, "act_batch", "act_lru"),
                "conv": _axes_of(layout, "act_batch", "conv", "act_lru"),
            })
        elif kind == "mlstm":
            specs.append({
                "c": _axes_of(layout, "act_batch", "heads", "head_dim", "inner"),
                "n": _axes_of(layout, "act_batch", "heads", "head_dim"),
                "m": _axes_of(layout, "act_batch", "heads"),
                "conv": _axes_of(layout, "act_batch", "conv", "inner"),
            })
        elif kind == "slstm":
            z = _axes_of(layout, "act_batch", "heads", "head_dim")
            specs.append({"c": z, "n": z, "h": z, "m": z})
    return specs
