from repro.serve.decode import make_serve_step, cache_pspecs
from repro.serve.prefill import make_prefill_step

__all__ = ["make_serve_step", "make_prefill_step", "cache_pspecs"]
