from repro.serve.decode import make_serve_step, cache_pspecs
from repro.serve.prefill import make_prefill_step
from repro.serve.rag import RAGRequest, RAGServer
from repro.serve.server import (
    FAULT_POLICIES,
    AdmissionError,
    DeadlineExceeded,
    RequestTrace,
    ServeFrontend,
    ServeHandle,
    ServerClosed,
    TenantSpec,
)

__all__ = [
    "make_serve_step",
    "make_prefill_step",
    "cache_pspecs",
    "RAGRequest",
    "RAGServer",
    "ServeFrontend",
    "TenantSpec",
    "ServeHandle",
    "RequestTrace",
    "AdmissionError",
    "ServerClosed",
    "DeadlineExceeded",
    "FAULT_POLICIES",
]
