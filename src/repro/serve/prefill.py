"""Prefill step: full-sequence forward that emits last-token logits + KV."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.distributed.sharding import Layout
from repro.models.transformer import forward_prefill


def make_prefill_step(cfg: ModelConfig, layout: Layout):
    def prefill_step(params, batch):
        logits, caches = forward_prefill(params, cfg, layout, batch)
        return {"logits": logits, "caches": caches}

    return prefill_step
