"""Retrieval-augmented serving: GateANN filtered retrieval + LM decode.

This is the paper's technique as a first-class serving feature
(DESIGN.md §4): a request carries a query vector, a metadata predicate,
and a prompt; the engine retrieves top-K *filter-passing* passages with
graph tunneling (no fetches for non-matching nodes), splices passage
tokens into the prompt, and decodes.

The LM and the retrieval engine are independent substrates — any of the
10 assigned architectures can serve as the generator.

Serving is where the hot-node cache tier earns its keep: production
query streams concentrate on the medoid neighborhood, so build the
engine with ``EngineConfig.cache_budget_bytes`` (or re-wrap with
``engine.with_cache``) and the server's cumulative ``io_report`` shows
the fraction of record fetches that never touched the slow tier.  With
``cache_policy="adaptive"`` the server also drives the cache control
loop: after every batch it triggers the (cheap, between-batch) hot-set
refresh check, and ``io_report`` reports how the cache is adapting —
refresh count, live filter partitions, and the hit rate trend.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.core.engine import GateANNEngine
from repro.core.search import SearchConfig, SearchStats
from repro.distributed.sharding import Layout
from repro.models import transformer as tfm
from repro.store.adaptive import AdaptiveRecordCache


@dataclasses.dataclass
class RAGRequest:
    query_vec: np.ndarray  # (D,) retrieval query
    prompt_tokens: np.ndarray  # (P,) int32
    filter_kind: str | None = None
    filter_params: object = None


@dataclasses.dataclass
class RAGServer:
    engine: GateANNEngine
    cfg: ModelConfig
    params: object
    layout: Layout
    passage_tokens: np.ndarray  # (N_corpus, passage_len) token ids per vector
    search_config: SearchConfig = dataclasses.field(default_factory=SearchConfig)
    # batch-size bucketing: pad each per-kind sub-batch up to the smallest
    # canonical size, so a stream of arbitrary mixes compiles at most
    # len(bucket_sizes) traces per kind instead of one per distinct group
    # size.  Padding rows replicate a real request (so every filter kind
    # keeps well-formed params) but are EXCLUDED from the served-I/O
    # accounting — their traversal cost is surfaced separately as
    # ``padded_rows`` / ``padding_ios`` so the store's measured counters
    # still reconcile: store delta == served_ios + padding_ios.
    # () disables bucketing (groups run at their natural size).
    bucket_sizes: tuple = ()
    # cumulative per-tier I/O over the server's lifetime
    served_queries: int = 0
    served_ios: int = 0
    served_tunnels: int = 0
    served_cache_hits: int = 0
    # bucketing accounting (padding rows never count as served I/O).  A
    # padded row replicates a real request, so under a cache tier its
    # fetches can split between tiers just like the real row's: slow-tier
    # dispatches land in ``padding_ios``, cache hits in
    # ``padding_cache_hits`` — only the former consumes measured reads.
    padded_rows: int = 0
    padding_ios: int = 0
    padding_cache_hits: int = 0
    # measured reconciliation against the slow tier (disk store only):
    # per-batch records_read deltas, and the cumulative drift between the
    # measured delta and the modeled split (served_ios + padding_ios).
    # The contract is drift == 0 — any non-zero value means the modeled
    # attribution mis-credited I/O between served and padding rows.
    measured_reads: int = 0
    reconcile_drift: int = 0
    # hit rate of the most recent batch — shows cache adaptation over time
    last_batch_hit_rate: float = 0.0

    def _account(self, stats):
        # shared SearchStats arithmetic lives in obs.stats (one home for
        # the sums both serving layers used to copy)
        t = obs.stats.stats_totals(stats)
        self.served_queries += t["queries"]
        self.served_ios += t["n_ios"]
        self.served_tunnels += t["n_tunnels"]
        self.served_cache_hits += t["n_cache_hits"]
        self.last_batch_hit_rate = obs.stats.hit_rate(
            t["n_ios"], t["n_cache_hits"]
        )

    def io_report(self) -> dict:
        """Lifetime tier mix: how many record fetches the cache absorbed."""
        rep = obs.stats.tier_mix(
            queries=self.served_queries,
            ios=self.served_ios,
            cache_hits=self.served_cache_hits,
            tunnels=self.served_tunnels,
        )
        rep["last_batch_hit_rate"] = self.last_batch_hit_rate
        if self.bucket_sizes:
            rep["bucket_sizes"] = tuple(self.bucket_sizes)
            rep["padded_rows"] = self.padded_rows
            rep["padding_ios"] = self.padding_ios
            rep["padding_cache_hits"] = self.padding_cache_hits
        measured = getattr(self.engine, "io_counters", lambda: {})()
        if measured:
            rep["measured_slow_reads"] = self.measured_reads
            rep["reconcile_drift"] = self.reconcile_drift
            rep["abandoned_tokens"] = measured.get("abandoned_tokens", 0)
        store = getattr(self.engine, "record_store", None)
        if isinstance(store, AdaptiveRecordCache):
            rep["cache_policy"] = store.policy
            rep["cache_refreshes"] = store.n_refreshes
            rep["cache_partitions"] = len(store.partitions)
            rep["cache_slots"] = store.n_slots
        return rep

    def _bucket_pad(self, group_size: int) -> int:
        """Rows to pad a group of ``group_size`` up to its bucket (0 when
        bucketing is off or the group exceeds every canonical size)."""
        if not self.bucket_sizes:
            return 0
        fits = [s for s in sorted(self.bucket_sizes) if s >= group_size]
        return (fits[0] - group_size) if fits else 0

    def _empty_stats(self) -> SearchStats:
        z = np.zeros((0,), np.int32)
        return SearchStats(**{f: z for f in SearchStats._fields})

    def retrieve(self, requests: list[RAGRequest]):
        """Serve one request batch, mixed predicate kinds included.

        An empty batch returns empty ids/stats ((0, K) / (0,)-shaped) —
        production streams legitimately drain to nothing between ticks,
        and the serving loop must not crash on them.

        Requests are grouped by ``filter_kind`` (the engine's jitted loop
        takes one predicate family per call), each group is searched as a
        sub-batch, and results/stats are scattered back into request
        order — callers see one (ids, stats) pair regardless of mix.

        With ``bucket_sizes`` set, each group is padded up to the smallest
        canonical size before searching (padding rows cycle through the
        group's real requests, so the extra traversal mirrors the group's
        own distribution rather than amplifying one row), bounding jit
        retraces to ``len(bucket_sizes)`` per kind on an arbitrary mix
        stream.  The padding rows' results are discarded and their
        traversal I/O is kept OUT of the served accounting (tracked as
        ``padded_rows`` / ``padding_ios`` instead — the slow-tier store's
        measured counters include them, so reconciliation is served +
        padding).  Note the adaptive cache's visit counters DO see the
        padding rows (the engine observes the whole batch): cyclic
        padding keeps that a mild re-weighting of the group's own access
        pattern instead of a bias toward any single request.  A group
        larger than every bucket runs at its natural size.
        """
        k = self.search_config.result_k
        if not requests:
            return np.zeros((0, k), np.int32), self._empty_stats()
        groups: dict = {}
        for i, r in enumerate(requests):
            groups.setdefault(r.filter_kind, []).append(i)
        all_ids = np.full((len(requests), k), -1, np.int32)
        stat_fields = {f: np.zeros((len(requests),), np.int32)
                       for f in SearchStats._fields}
        # snapshot the slow tier's MEASURED reads so the modeled
        # served/padding split below is checked against reality, not
        # assumed — a cache tier above the disk store serves padded rows
        # from either tier and only the modeled counters say which
        measured0 = self.engine.io_counters().get("records_read")
        batch_pad_ios = 0
        for kind, idxs in groups.items():
            g = len(idxs)
            pad = self._bucket_pad(g)
            cyc = np.arange(pad) % g  # cyclic padding rows (see docstring)
            q = np.stack([requests[i].query_vec for i in idxs])
            if pad:
                q = np.concatenate([q, q[cyc]])
            params = None
            if kind is not None:
                params = jnp.stack(
                    [jnp.asarray(requests[i].filter_params) for i in idxs]
                )
                if pad:
                    params = jnp.concatenate([params, params[cyc]])
            out = self.engine.search(
                q, filter_kind=kind, filter_params=params,
                search_config=self.search_config,
            )
            all_ids[idxs] = np.asarray(out.ids)[:g, :k]
            for f in SearchStats._fields:
                stat_fields[f][idxs] = np.asarray(getattr(out.stats, f))[:g]
            if pad:
                self.padded_rows += pad
                pad_ios = int(np.sum(np.asarray(out.stats.n_ios)[g:]))
                self.padding_ios += pad_ios
                batch_pad_ios += pad_ios
                self.padding_cache_hits += int(
                    np.sum(np.asarray(out.stats.n_cache_hits)[g:])
                )
        stats = SearchStats(**stat_fields)
        self._account(stats)
        if measured0 is not None:
            # the reconciliation contract, against measured counters:
            # this batch's records_read delta must equal the modeled
            # served + padding slow-tier dispatches exactly
            delta = self.engine.io_counters()["records_read"] - measured0
            self.measured_reads += delta
            self.reconcile_drift += delta - (
                int(np.sum(stat_fields["n_ios"])) + batch_pad_ios
            )
        # adaptive cache maintenance runs between batches, off the
        # retrieval critical path (engine.search already observed counts)
        self.engine.maybe_refresh()
        return all_ids, stats

    def build_prompts(self, requests: list[RAGRequest], retrieved_ids: np.ndarray):
        """Prompt = [passage tokens for top-k hits] + [request prompt]."""
        if not requests:  # max() over an empty sequence has no identity
            return np.zeros((0, 0), np.int32)
        prompts = []
        for r, ids in zip(requests, retrieved_ids):
            chunks = [self.passage_tokens[i] for i in ids if i >= 0]
            ctx = np.concatenate(chunks) if chunks else np.zeros((0,), np.int32)
            prompts.append(np.concatenate([ctx, r.prompt_tokens]).astype(np.int32))
        # left-pad to a common length
        max_len = max(len(p) for p in prompts)
        batch = np.zeros((len(prompts), max_len), np.int32)
        for i, p in enumerate(prompts):
            batch[i, max_len - len(p):] = p
        return batch

    def generate(self, requests: list[RAGRequest], *, max_new_tokens: int = 16):
        """retrieve -> prefill -> greedy decode. Returns (tokens, stats)."""
        ids, stats = self.retrieve(requests)
        if not requests:  # nothing to decode — keep the output shapes
            return np.zeros((0, max_new_tokens), np.int32), stats
        prompts = self.build_prompts(requests, ids)
        b, p_len = prompts.shape
        total = p_len + max_new_tokens
        caches = tfm.init_caches(self.cfg, b, total, jnp.float32)
        # teacher-forced prefill through the decode path (simple + exact)
        tok = jnp.asarray(prompts[:, :1])
        decode = jax.jit(
            lambda pr, c, t, pos: tfm.forward_decode(pr, self.cfg, self.layout, t, c, pos)
        )
        out_tokens = []
        for t in range(total - 1):
            logits, caches = decode(self.params, caches, tok, jnp.int32(t))
            if t + 1 < p_len:
                tok = jnp.asarray(prompts[:, t + 1 : t + 2])
            else:
                tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
                out_tokens.append(np.asarray(tok)[:, 0])
        return np.stack(out_tokens, axis=1), stats
