"""Multi-tenant async serving front end over ``RAGServer``.

``RAGServer`` is a library loop: the caller owns batching, there is one
implicit tenant, and a slow search blocks everyone behind it.  This
module adds the serving semantics the paper's throughput claims are
quoted under — concurrent clients, admission control, and per-request
latency you can put an SLO on:

  * **tenant namespaces** — a :class:`TenantSpec` binds a tenant name to
    a filter partition (``filter_kind`` + ``filter_params``).  A
    tenant's searches are filtered searches over its namespace, so
    isolation rides on the engine's existing filter machinery (and, with
    ``cache_policy="adaptive"``, each tenant's namespace gets its own
    cache partition via ``filter_bucket``).  No new index structures.
  * **admission control** — each tenant has a bounded in-flight budget
    (``max_inflight`` covers queued + in-service requests).  ``submit``
    blocks up to ``admission_timeout_s`` for a slot and then raises
    :class:`AdmissionError`: backpressure is explicit, never an
    unbounded queue.
  * **batch formation** — ONE dispatcher thread drains the submission
    queue, waits up to ``batch_window_s`` for stragglers, and serves up
    to ``max_batch`` requests per engine call.  Padding to canonical jit
    shapes is delegated to ``RAGServer.bucket_sizes`` — the dispatcher
    only decides batch *membership*; shape discipline stays in one
    place.  The single dispatcher is load-bearing: the engine's adaptive
    cache observe/refresh loop and the measured-counter reconciliation
    in ``RAGServer.retrieve`` are between-batch mutations, safe only
    because exactly one thread runs searches.
  * **per-request tracing** — every request carries a
    :class:`RequestTrace` with queue-wait / batch-form / search / drain
    spans (``time.perf_counter`` seconds — monotonic, never corrupted
    by wall-clock steps).  Each resolved request's spans are also
    recorded into the front end's own ``obs`` tracer/registry
    (``trace.span_seconds{span=serve.*}`` histograms), and admission
    outcomes / per-tenant I/O attribution are registry counter families
    — ``io_report`` is a thin view over the registry, layered on the
    underlying ``RAGServer`` report.  Pass ``registry=`` to aggregate
    several front ends into one sink; by default each server gets a
    private, always-enabled registry so its accounting works regardless
    of the process-wide ``GATEANN_OBS`` toggle.

Failure containment: if the engine raises mid-batch, the dispatcher
abandons any pipelined disk rounds still in flight
(``engine.abandon_pending_io()`` — no leaked reader slots), fails that
batch's handles with the exception, and keeps serving later arrivals.

**SLO enforcement** (deadlines + shedding + degraded reads): a
``TenantSpec.deadline_s`` (or per-request ``submit(deadline_s=...)``)
gives each request an absolute deadline from admission.  Batch
formation sheds requests whose deadline already passed (resolved with
:class:`DeadlineExceeded`, counted in ``serve.deadline_shed``) and
orders the rest earliest-deadline-first instead of FIFO — serving a
request its client has already written off burns a batch slot for
nothing.  The ``fault_policy`` knob maps onto the disk tier's
resilience (``DiskRecordStore.configure_resilience``):

  * ``"fail"``              — historical behavior: one failed read
                              fails the batch (contained, not retried)
  * ``"degrade"``           — failed reads become tunneled nodes;
                              queries complete with bounded recall loss
  * ``"retry_then_degrade"``— bounded backoff retries first, degrade
                              only on exhaustion (production default)

Under non-``fail`` policies the dispatcher also propagates the batch's
tightest remaining deadline into the store as its per-round read
deadline, so one slow device round degrades instead of blowing the SLO.
Per-request degraded-slot counts land in ``RequestTrace.n_degraded``
and the ``serve.degraded`` counter family.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

import numpy as np

from repro import obs
from repro.serve.rag import RAGRequest, RAGServer
from repro.store.disk import RetryPolicy

# the four per-request stages; each becomes a serve.<name> span family
_SPANS = ("queue_wait", "batch_form", "search", "drain")

FAULT_POLICIES = ("fail", "degrade", "retry_then_degrade")

# shed requests get a deadline budget this small propagated as the
# store's round deadline instead of 0 (0 would DISABLE the deadline)
_MIN_ROUND_DEADLINE_S = 1e-3


class AdmissionError(RuntimeError):
    """Tenant over budget and no slot freed within the admission timeout."""


class ServerClosed(RuntimeError):
    """The request cannot be served because the server is shut down."""


class DeadlineExceeded(RuntimeError):
    """The request's deadline passed before it could be dispatched."""


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """A tenant namespace: a name, a filter partition, and an admission
    budget.  ``filter_kind=None`` serves the whole corpus (no filter)."""

    name: str
    filter_kind: str | None = None
    filter_params: object = None
    max_inflight: int = 64  # queued + in-service requests, bounded
    # per-request SLO deadline (seconds from admission; None = none).
    # Overridable per request via submit(deadline_s=...).
    deadline_s: float | None = None


@dataclasses.dataclass
class RequestTrace:
    """Per-request span breakdown (seconds, ``time.perf_counter``).

    ``queue_wait`` = submit -> picked into a batch; ``batch_form`` =
    picked -> search dispatched (request assembly); ``search`` = engine
    call; ``drain`` = results materialized -> handle resolved.
    """

    tenant: str
    batch_size: int = 0
    queue_wait: float = 0.0
    batch_form: float = 0.0
    search: float = 0.0
    drain: float = 0.0
    n_ios: int = 0
    n_cache_hits: int = 0
    n_degraded: int = 0  # result slots served degraded (failed disk reads)

    @property
    def total(self) -> float:
        return self.queue_wait + self.batch_form + self.search + self.drain


class ServeHandle:
    """The client's side of one submitted request."""

    def __init__(self, tenant: str):
        self.trace = RequestTrace(tenant=tenant)
        self._done = threading.Event()
        self._ids: np.ndarray | None = None
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: float | None = None) -> np.ndarray:
        """Block for the retrieved ids (raises what the server raised)."""
        if not self._done.wait(timeout):
            raise TimeoutError("request not served within timeout")
        if self._error is not None:
            raise self._error
        return self._ids


@dataclasses.dataclass
class _Pending:
    handle: ServeHandle
    request: RAGRequest
    tenant: TenantSpec
    t_submit: float
    deadline: float | None = None  # absolute perf_counter seconds (or None)


class ServeFrontend:
    """Async request-admission layer in front of a ``RAGServer``.

    Client threads call :meth:`submit` concurrently; one dispatcher
    thread forms batches and runs the engine.  ``close()`` (or the
    context manager) stops the dispatcher, fails undispatched requests
    with :class:`ServerClosed`, and abandons in-flight disk rounds.
    """

    def __init__(
        self,
        rag: RAGServer,
        tenants: list[TenantSpec] | tuple[TenantSpec, ...],
        *,
        max_batch: int = 32,
        batch_window_s: float = 0.002,
        admission_timeout_s: float = 1.0,
        fault_policy: str = "fail",
        registry: obs.MetricsRegistry | None = None,
    ):
        if not tenants:
            raise ValueError("a server needs at least one TenantSpec")
        if fault_policy not in FAULT_POLICIES:
            raise ValueError(
                f"fault_policy={fault_policy!r} not in {FAULT_POLICIES}"
            )
        names = [t.name for t in tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names: {names}")
        self.rag = rag
        self.tenants = {t.name: t for t in tenants}
        self.max_batch = int(max_batch)
        self.batch_window_s = float(batch_window_s)
        self.admission_timeout_s = float(admission_timeout_s)
        # fault containment: map the policy onto the measured store's
        # resilience knobs (no-op on modeled tiers, which cannot fail)
        self.fault_policy = fault_policy
        self._store = rag.engine.measured_store()
        self._base_round_deadline_s = (
            self._store.round_deadline_s if self._store is not None else 0.0
        )
        if self._store is not None and fault_policy != "fail":
            retries = 3 if fault_policy == "retry_then_degrade" else 0
            self._store.configure_resilience(
                retry=RetryPolicy(max_retries=retries, backoff_s=5e-4),
                on_error="degrade",
            )

        self._lock = threading.Lock()
        self._slot_freed = threading.Condition(self._lock)
        self._work = threading.Condition(self._lock)
        self._queue: deque[_Pending] = deque()  # guarded by _lock
        self._inflight = {t.name: 0 for t in tenants}  # guarded by _lock
        self._closed = False  # guarded by _lock
        # admission/outcome counters and span histograms live in the
        # registry (``io_report`` is a thin view over it); children are
        # created eagerly so zero-traffic tenants still report
        self.metrics = registry if registry is not None \
            else obs.MetricsRegistry(enabled=True)
        self.tracer = obs.trace.Tracer(registry=self.metrics)
        self.tracer.enable()
        self._counters = {
            key: {t.name: self.metrics.counter(f"serve.{key}", tenant=t.name)
                  for t in tenants}
            for key in ("admitted", "rejected", "completed", "failed",
                        "queries", "ios", "cache_hits",
                        "deadline_shed", "degraded")
        }
        self._c_batches = self.metrics.counter("serve.batches")
        self._g_queue = self.metrics.gauge("serve.queue_depth")
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="serve-dispatcher", daemon=True
        )
        self._dispatcher.start()

    # -- registry views (kept as attributes-in-spirit: tests and callers
    # read e.g. ``srv.rejected`` as a plain int) ---------------------------
    @property
    def admitted(self) -> int:
        return int(self.metrics.family_total("serve.admitted"))

    @property
    def rejected(self) -> int:
        return int(self.metrics.family_total("serve.rejected"))

    @property
    def completed(self) -> int:
        return int(self.metrics.family_total("serve.completed"))

    @property
    def failed(self) -> int:
        return int(self.metrics.family_total("serve.failed"))

    @property
    def batches(self) -> int:
        return int(self._c_batches.value)

    # -- client side -------------------------------------------------------
    def submit(
        self,
        tenant: str,
        query_vec: np.ndarray,
        *,
        prompt_tokens: np.ndarray | None = None,
        timeout: float | None = None,
        deadline_s: float | None = None,
    ) -> ServeHandle:
        """Admit one request into ``tenant``'s namespace.

        Blocks while the tenant is at ``max_inflight`` until a slot
        frees, up to ``timeout`` (default ``admission_timeout_s``), then
        raises :class:`AdmissionError`.  Thread-safe.

        ``deadline_s`` (default: the tenant's ``deadline_s``) starts the
        request's SLO clock at admission: a request still queued when it
        expires is shed with :class:`DeadlineExceeded` instead of served
        late, and queued requests dispatch earliest-deadline-first.
        """
        spec = self.tenants.get(tenant)
        if spec is None:
            raise KeyError(f"unknown tenant {tenant!r}; have {sorted(self.tenants)}")
        if timeout is None:
            timeout = self.admission_timeout_s
        deadline = time.perf_counter() + timeout
        with self._lock:
            while not self._closed and self._inflight[tenant] >= spec.max_inflight:
                remaining = deadline - time.perf_counter()
                if remaining <= 0 or not self._slot_freed.wait(remaining):
                    self._counters["rejected"][tenant].inc()
                    raise AdmissionError(
                        f"tenant {tenant!r} at max_inflight="
                        f"{spec.max_inflight} for {timeout:.3f}s"
                    )
            if self._closed:
                raise ServerClosed("server is closed")
            handle = ServeHandle(tenant)
            req = RAGRequest(
                query_vec=np.asarray(query_vec),
                prompt_tokens=(
                    np.zeros((0,), np.int32) if prompt_tokens is None
                    else np.asarray(prompt_tokens, np.int32)
                ),
                filter_kind=spec.filter_kind,
                filter_params=spec.filter_params,
            )
            self._inflight[tenant] += 1
            self._counters["admitted"][tenant].inc()
            now = time.perf_counter()
            dl = deadline_s if deadline_s is not None else spec.deadline_s
            self._queue.append(_Pending(
                handle, req, spec, now,
                deadline=None if dl is None else now + float(dl),
            ))
            self._g_queue.set(len(self._queue))
            self._work.notify()
        return handle

    def queue_depth(self) -> int:
        with self._lock:
            return len(self._queue)

    # -- dispatcher side ---------------------------------------------------
    def _take_batch(self) -> list[_Pending] | None:
        """Block for work; once some arrives, hold the batch open for
        ``batch_window_s`` (or until full), then form the batch with the
        SLO in charge instead of arrival order:

          1. **shed** requests whose deadline already passed — they are
             resolved with :class:`DeadlineExceeded` (counted in
             ``serve.deadline_shed``); serving them would spend a batch
             slot on an answer the client has stopped waiting for;
          2. take the rest **earliest-deadline-first** (undeadlined
             requests sort last; FIFO breaks ties, so a deadline-free
             workload keeps the historical order exactly).

        Returns None when the server closes."""
        shed: list[_Pending] = []
        with self._lock:
            while not self._queue and not self._closed:
                self._work.wait()
            if not self._queue:  # closed and drained
                return None
            if self.batch_window_s > 0 and len(self._queue) < self.max_batch:
                self._work.wait(self.batch_window_s)
            now = time.perf_counter()
            live = []
            for p in self._queue:
                if p.deadline is not None and now >= p.deadline:
                    shed.append(p)
                else:
                    live.append(p)
            order = sorted(
                range(len(live)),
                key=lambda i: (
                    live[i].deadline if live[i].deadline is not None
                    else float("inf"),
                    i,
                ),
            )
            taken = set(order[: self.max_batch])
            batch = [live[i] for i in order[: self.max_batch]]
            self._queue = deque(
                live[i] for i in range(len(live)) if i not in taken
            )
            self._g_queue.set(len(self._queue))
        for p in shed:  # resolve outside the lock (_resolve re-takes it)
            self._counters["deadline_shed"][p.tenant.name].inc()
            self._resolve(
                p, None,
                DeadlineExceeded(
                    f"deadline passed before dispatch "
                    f"(tenant {p.tenant.name!r})"
                ),
                time.perf_counter(),
            )
        if not batch:
            # close() drained the queue between wakeup and pop, or every
            # queued request was shed
            with self._lock:
                closed = self._closed
            return None if closed else []
        return batch

    def _resolve(self, p: _Pending, ids, err, t_searched: float) -> None:
        p.handle._ids = ids
        p.handle._error = err
        p.handle.trace.drain = time.perf_counter() - t_searched
        p.handle._done.set()
        name = p.tenant.name
        outcome = "completed" if err is None else "failed"
        self._counters[outcome][name].inc()
        # each resolved request publishes its four spans; percentiles and
        # means come out of trace.span_seconds{span=serve.*} histograms
        for k in _SPANS:
            self.tracer.record(f"serve.{k}", getattr(p.handle.trace, k),
                               tenant=name)
        with self._lock:
            self._inflight[name] -= 1
            self._slot_freed.notify_all()

    def _dispatch_loop(self) -> None:
        while True:
            batch = self._take_batch()
            if batch is None:
                return
            if not batch:  # spurious wakeup, nothing to serve
                continue
            t_formed = time.perf_counter()
            for p in batch:
                p.handle.trace.queue_wait = t_formed - p.t_submit
                p.handle.trace.batch_size = len(batch)
            requests = [p.request for p in batch]
            t_dispatch = time.perf_counter()
            for p in batch:
                p.handle.trace.batch_form = t_dispatch - t_formed
            # SLO propagation: under a degrading policy, give the store
            # the batch's tightest remaining deadline as its per-round
            # read budget — a slow device round then degrades the
            # affected slots instead of stalling the whole batch past
            # its deadline.  (Floored at _MIN_ROUND_DEADLINE_S: zero
            # would disable the deadline entirely.)
            budget_set = False
            if self._store is not None and self.fault_policy != "fail":
                dls = [p.deadline for p in batch if p.deadline is not None]
                if dls:
                    remaining = min(dls) - t_dispatch
                    self._store.configure_resilience(
                        round_deadline_s=max(remaining, _MIN_ROUND_DEADLINE_S)
                    )
                    budget_set = True
            try:
                ids, stats = self.rag.retrieve(requests)
                err = None
            except BaseException as e:  # noqa: BLE001 — failures are per-batch
                # a mid-search failure may strand a pipelined disk round
                # in flight; abandon it so the reader pool stays usable
                # for the next batch (engine.search also abandons on its
                # own failures — this covers retrieve-level ones too)
                self.rag.engine.abandon_pending_io()
                ids = stats = None
                err = e
            finally:
                if budget_set:  # restore the store-level default
                    self._store.configure_resilience(
                        round_deadline_s=self._base_round_deadline_s
                    )
            t_searched = time.perf_counter()
            n_ios = np.asarray(stats.n_ios) if err is None else None
            n_hits = np.asarray(stats.n_cache_hits) if err is None else None
            n_deg = np.asarray(stats.n_degraded) if err is None else None
            for i, p in enumerate(batch):
                p.handle.trace.search = t_searched - t_dispatch
                name = p.tenant.name
                self._counters["queries"][name].inc()
                if err is None:
                    p.handle.trace.n_ios = int(n_ios[i])
                    p.handle.trace.n_cache_hits = int(n_hits[i])
                    p.handle.trace.n_degraded = int(n_deg[i])
                    self._counters["ios"][name].inc(int(n_ios[i]))
                    self._counters["cache_hits"][name].inc(int(n_hits[i]))
                    if int(n_deg[i]):
                        self._counters["degraded"][name].inc(int(n_deg[i]))
                    self._resolve(p, ids[i], None, t_searched)
                else:
                    self._resolve(p, None, err, t_searched)
            self._c_batches.inc()

    # -- reporting / lifecycle ---------------------------------------------
    def io_report(self) -> dict:
        """The ``RAGServer`` report plus serving-layer aggregates:
        admission outcomes, mean span breakdown, per-tenant attribution.

        A thin view over the front end's registry — every value here is
        a family total or histogram mean; nothing is aggregated outside
        ``self.metrics``."""
        rep = self.rag.io_report()
        total = self.metrics.family_total
        done = self.completed + self.failed
        spans = {}
        for k in _SPANS:
            children = [
                c for c in self.metrics.children("trace.span_seconds")
                if c.labels.get("span") == f"serve.{k}"
            ]
            s = sum(c.sum for c in children)
            n = sum(c.count for c in children)
            spans[k] = s / max(n, 1)
        rep.update(
            tenants=sorted(self.tenants),
            admitted=self.admitted,
            rejected=self.rejected,
            completed=self.completed,
            failed=self.failed,
            batches=self.batches,
            queue_depth=self.queue_depth(),
            mean_batch_size=done / max(self.batches, 1),
            spans_mean_s=spans,
            fault_policy=self.fault_policy,
            deadline_shed=int(total("serve.deadline_shed")),
            degraded=int(total("serve.degraded")),
            per_tenant={
                name: {
                    "queries": int(total("serve.queries", tenant=name)),
                    "ios": int(total("serve.ios", tenant=name)),
                    "cache_hits": int(total("serve.cache_hits", tenant=name)),
                    "failed": int(total("serve.failed", tenant=name)),
                    "deadline_shed": int(
                        total("serve.deadline_shed", tenant=name)
                    ),
                    "degraded": int(total("serve.degraded", tenant=name)),
                }
                for name in self.tenants
            },
        )
        return rep

    def close(self) -> None:
        """Stop serving: fail queued requests, join the dispatcher,
        abandon any in-flight disk rounds.  Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            orphans = list(self._queue)
            self._queue.clear()
            self._work.notify_all()
            self._slot_freed.notify_all()
        for p in orphans:
            self._resolve(p, None, ServerClosed("server closed"),
                          time.perf_counter())
        self._dispatcher.join(timeout=30.0)
        self.rag.engine.abandon_pending_io()

    def __enter__(self) -> "ServeFrontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
