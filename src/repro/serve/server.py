"""Multi-tenant async serving front end over ``RAGServer``.

``RAGServer`` is a library loop: the caller owns batching, there is one
implicit tenant, and a slow search blocks everyone behind it.  This
module adds the serving semantics the paper's throughput claims are
quoted under — concurrent clients, admission control, and per-request
latency you can put an SLO on:

  * **tenant namespaces** — a :class:`TenantSpec` binds a tenant name to
    a filter partition (``filter_kind`` + ``filter_params``).  A
    tenant's searches are filtered searches over its namespace, so
    isolation rides on the engine's existing filter machinery (and, with
    ``cache_policy="adaptive"``, each tenant's namespace gets its own
    cache partition via ``filter_bucket``).  No new index structures.
  * **admission control** — each tenant has a bounded in-flight budget
    (``max_inflight`` covers queued + in-service requests).  ``submit``
    blocks up to ``admission_timeout_s`` for a slot and then raises
    :class:`AdmissionError`: backpressure is explicit, never an
    unbounded queue.
  * **batch formation** — ONE dispatcher thread drains the submission
    queue, waits up to ``batch_window_s`` for stragglers, and serves up
    to ``max_batch`` requests per engine call.  Padding to canonical jit
    shapes is delegated to ``RAGServer.bucket_sizes`` — the dispatcher
    only decides batch *membership*; shape discipline stays in one
    place.  The single dispatcher is load-bearing: the engine's adaptive
    cache observe/refresh loop and the measured-counter reconciliation
    in ``RAGServer.retrieve`` are between-batch mutations, safe only
    because exactly one thread runs searches.
  * **per-request tracing** — every request carries a
    :class:`RequestTrace` with queue-wait / batch-form / search / drain
    spans (``time.perf_counter`` seconds — monotonic, never corrupted
    by wall-clock steps).  Each resolved request's spans are also
    recorded into the front end's own ``obs`` tracer/registry
    (``trace.span_seconds{span=serve.*}`` histograms), and admission
    outcomes / per-tenant I/O attribution are registry counter families
    — ``io_report`` is a thin view over the registry, layered on the
    underlying ``RAGServer`` report.  Pass ``registry=`` to aggregate
    several front ends into one sink; by default each server gets a
    private, always-enabled registry so its accounting works regardless
    of the process-wide ``GATEANN_OBS`` toggle.

Failure containment: if the engine raises mid-batch, the dispatcher
abandons any pipelined disk rounds still in flight
(``engine.abandon_pending_io()`` — no leaked reader slots), fails that
batch's handles with the exception, and keeps serving later arrivals.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

import numpy as np

from repro import obs
from repro.serve.rag import RAGRequest, RAGServer

# the four per-request stages; each becomes a serve.<name> span family
_SPANS = ("queue_wait", "batch_form", "search", "drain")


class AdmissionError(RuntimeError):
    """Tenant over budget and no slot freed within the admission timeout."""


class ServerClosed(RuntimeError):
    """The request cannot be served because the server is shut down."""


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """A tenant namespace: a name, a filter partition, and an admission
    budget.  ``filter_kind=None`` serves the whole corpus (no filter)."""

    name: str
    filter_kind: str | None = None
    filter_params: object = None
    max_inflight: int = 64  # queued + in-service requests, bounded


@dataclasses.dataclass
class RequestTrace:
    """Per-request span breakdown (seconds, ``time.perf_counter``).

    ``queue_wait`` = submit -> picked into a batch; ``batch_form`` =
    picked -> search dispatched (request assembly); ``search`` = engine
    call; ``drain`` = results materialized -> handle resolved.
    """

    tenant: str
    batch_size: int = 0
    queue_wait: float = 0.0
    batch_form: float = 0.0
    search: float = 0.0
    drain: float = 0.0
    n_ios: int = 0
    n_cache_hits: int = 0

    @property
    def total(self) -> float:
        return self.queue_wait + self.batch_form + self.search + self.drain


class ServeHandle:
    """The client's side of one submitted request."""

    def __init__(self, tenant: str):
        self.trace = RequestTrace(tenant=tenant)
        self._done = threading.Event()
        self._ids: np.ndarray | None = None
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: float | None = None) -> np.ndarray:
        """Block for the retrieved ids (raises what the server raised)."""
        if not self._done.wait(timeout):
            raise TimeoutError("request not served within timeout")
        if self._error is not None:
            raise self._error
        return self._ids


@dataclasses.dataclass
class _Pending:
    handle: ServeHandle
    request: RAGRequest
    tenant: TenantSpec
    t_submit: float


class ServeFrontend:
    """Async request-admission layer in front of a ``RAGServer``.

    Client threads call :meth:`submit` concurrently; one dispatcher
    thread forms batches and runs the engine.  ``close()`` (or the
    context manager) stops the dispatcher, fails undispatched requests
    with :class:`ServerClosed`, and abandons in-flight disk rounds.
    """

    def __init__(
        self,
        rag: RAGServer,
        tenants: list[TenantSpec] | tuple[TenantSpec, ...],
        *,
        max_batch: int = 32,
        batch_window_s: float = 0.002,
        admission_timeout_s: float = 1.0,
        registry: obs.MetricsRegistry | None = None,
    ):
        if not tenants:
            raise ValueError("a server needs at least one TenantSpec")
        names = [t.name for t in tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names: {names}")
        self.rag = rag
        self.tenants = {t.name: t for t in tenants}
        self.max_batch = int(max_batch)
        self.batch_window_s = float(batch_window_s)
        self.admission_timeout_s = float(admission_timeout_s)

        self._lock = threading.Lock()
        self._slot_freed = threading.Condition(self._lock)
        self._work = threading.Condition(self._lock)
        self._queue: deque[_Pending] = deque()  # guarded by _lock
        self._inflight = {t.name: 0 for t in tenants}  # guarded by _lock
        self._closed = False  # guarded by _lock
        # admission/outcome counters and span histograms live in the
        # registry (``io_report`` is a thin view over it); children are
        # created eagerly so zero-traffic tenants still report
        self.metrics = registry if registry is not None \
            else obs.MetricsRegistry(enabled=True)
        self.tracer = obs.trace.Tracer(registry=self.metrics)
        self.tracer.enable()
        self._counters = {
            key: {t.name: self.metrics.counter(f"serve.{key}", tenant=t.name)
                  for t in tenants}
            for key in ("admitted", "rejected", "completed", "failed",
                        "queries", "ios", "cache_hits")
        }
        self._c_batches = self.metrics.counter("serve.batches")
        self._g_queue = self.metrics.gauge("serve.queue_depth")
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="serve-dispatcher", daemon=True
        )
        self._dispatcher.start()

    # -- registry views (kept as attributes-in-spirit: tests and callers
    # read e.g. ``srv.rejected`` as a plain int) ---------------------------
    @property
    def admitted(self) -> int:
        return int(self.metrics.family_total("serve.admitted"))

    @property
    def rejected(self) -> int:
        return int(self.metrics.family_total("serve.rejected"))

    @property
    def completed(self) -> int:
        return int(self.metrics.family_total("serve.completed"))

    @property
    def failed(self) -> int:
        return int(self.metrics.family_total("serve.failed"))

    @property
    def batches(self) -> int:
        return int(self._c_batches.value)

    # -- client side -------------------------------------------------------
    def submit(
        self,
        tenant: str,
        query_vec: np.ndarray,
        *,
        prompt_tokens: np.ndarray | None = None,
        timeout: float | None = None,
    ) -> ServeHandle:
        """Admit one request into ``tenant``'s namespace.

        Blocks while the tenant is at ``max_inflight`` until a slot
        frees, up to ``timeout`` (default ``admission_timeout_s``), then
        raises :class:`AdmissionError`.  Thread-safe.
        """
        spec = self.tenants.get(tenant)
        if spec is None:
            raise KeyError(f"unknown tenant {tenant!r}; have {sorted(self.tenants)}")
        if timeout is None:
            timeout = self.admission_timeout_s
        deadline = time.perf_counter() + timeout
        with self._lock:
            while not self._closed and self._inflight[tenant] >= spec.max_inflight:
                remaining = deadline - time.perf_counter()
                if remaining <= 0 or not self._slot_freed.wait(remaining):
                    self._counters["rejected"][tenant].inc()
                    raise AdmissionError(
                        f"tenant {tenant!r} at max_inflight="
                        f"{spec.max_inflight} for {timeout:.3f}s"
                    )
            if self._closed:
                raise ServerClosed("server is closed")
            handle = ServeHandle(tenant)
            req = RAGRequest(
                query_vec=np.asarray(query_vec),
                prompt_tokens=(
                    np.zeros((0,), np.int32) if prompt_tokens is None
                    else np.asarray(prompt_tokens, np.int32)
                ),
                filter_kind=spec.filter_kind,
                filter_params=spec.filter_params,
            )
            self._inflight[tenant] += 1
            self._counters["admitted"][tenant].inc()
            self._queue.append(_Pending(handle, req, spec, time.perf_counter()))
            self._g_queue.set(len(self._queue))
            self._work.notify()
        return handle

    def queue_depth(self) -> int:
        with self._lock:
            return len(self._queue)

    # -- dispatcher side ---------------------------------------------------
    def _take_batch(self) -> list[_Pending] | None:
        """Block for work; once some arrives, hold the batch open for
        ``batch_window_s`` (or until full) and take FIFO order.  Returns
        None when the server closes."""
        with self._lock:
            while not self._queue and not self._closed:
                self._work.wait()
            if not self._queue:  # closed and drained
                return None
            if self.batch_window_s > 0 and len(self._queue) < self.max_batch:
                self._work.wait(self.batch_window_s)
            batch = [self._queue.popleft()
                     for _ in range(min(len(self._queue), self.max_batch))]
            self._g_queue.set(len(self._queue))
            if not batch:
                # close() drained the queue between wakeup and pop
                return None if self._closed else []
            return batch

    def _resolve(self, p: _Pending, ids, err, t_searched: float) -> None:
        p.handle._ids = ids
        p.handle._error = err
        p.handle.trace.drain = time.perf_counter() - t_searched
        p.handle._done.set()
        name = p.tenant.name
        outcome = "completed" if err is None else "failed"
        self._counters[outcome][name].inc()
        # each resolved request publishes its four spans; percentiles and
        # means come out of trace.span_seconds{span=serve.*} histograms
        for k in _SPANS:
            self.tracer.record(f"serve.{k}", getattr(p.handle.trace, k),
                               tenant=name)
        with self._lock:
            self._inflight[name] -= 1
            self._slot_freed.notify_all()

    def _dispatch_loop(self) -> None:
        while True:
            batch = self._take_batch()
            if batch is None:
                return
            if not batch:  # spurious wakeup, nothing to serve
                continue
            t_formed = time.perf_counter()
            for p in batch:
                p.handle.trace.queue_wait = t_formed - p.t_submit
                p.handle.trace.batch_size = len(batch)
            requests = [p.request for p in batch]
            t_dispatch = time.perf_counter()
            for p in batch:
                p.handle.trace.batch_form = t_dispatch - t_formed
            try:
                ids, stats = self.rag.retrieve(requests)
                err = None
            except BaseException as e:  # noqa: BLE001 — failures are per-batch
                # a mid-search failure may strand a pipelined disk round
                # in flight; abandon it so the reader pool stays usable
                # for the next batch (engine.search also abandons on its
                # own failures — this covers retrieve-level ones too)
                self.rag.engine.abandon_pending_io()
                ids = stats = None
                err = e
            t_searched = time.perf_counter()
            n_ios = np.asarray(stats.n_ios) if err is None else None
            n_hits = np.asarray(stats.n_cache_hits) if err is None else None
            for i, p in enumerate(batch):
                p.handle.trace.search = t_searched - t_dispatch
                name = p.tenant.name
                self._counters["queries"][name].inc()
                if err is None:
                    p.handle.trace.n_ios = int(n_ios[i])
                    p.handle.trace.n_cache_hits = int(n_hits[i])
                    self._counters["ios"][name].inc(int(n_ios[i]))
                    self._counters["cache_hits"][name].inc(int(n_hits[i]))
                    self._resolve(p, ids[i], None, t_searched)
                else:
                    self._resolve(p, None, err, t_searched)
            self._c_batches.inc()

    # -- reporting / lifecycle ---------------------------------------------
    def io_report(self) -> dict:
        """The ``RAGServer`` report plus serving-layer aggregates:
        admission outcomes, mean span breakdown, per-tenant attribution.

        A thin view over the front end's registry — every value here is
        a family total or histogram mean; nothing is aggregated outside
        ``self.metrics``."""
        rep = self.rag.io_report()
        total = self.metrics.family_total
        done = self.completed + self.failed
        spans = {}
        for k in _SPANS:
            children = [
                c for c in self.metrics.children("trace.span_seconds")
                if c.labels.get("span") == f"serve.{k}"
            ]
            s = sum(c.sum for c in children)
            n = sum(c.count for c in children)
            spans[k] = s / max(n, 1)
        rep.update(
            tenants=sorted(self.tenants),
            admitted=self.admitted,
            rejected=self.rejected,
            completed=self.completed,
            failed=self.failed,
            batches=self.batches,
            queue_depth=self.queue_depth(),
            mean_batch_size=done / max(self.batches, 1),
            spans_mean_s=spans,
            per_tenant={
                name: {
                    "queries": int(total("serve.queries", tenant=name)),
                    "ios": int(total("serve.ios", tenant=name)),
                    "cache_hits": int(total("serve.cache_hits", tenant=name)),
                    "failed": int(total("serve.failed", tenant=name)),
                }
                for name in self.tenants
            },
        )
        return rep

    def close(self) -> None:
        """Stop serving: fail queued requests, join the dispatcher,
        abandon any in-flight disk rounds.  Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            orphans = list(self._queue)
            self._queue.clear()
            self._work.notify_all()
            self._slot_freed.notify_all()
        for p in orphans:
            self._resolve(p, None, ServerClosed("server closed"),
                          time.perf_counter())
        self._dispatcher.join(timeout=30.0)
        self.rag.engine.abandon_pending_io()

    def __enter__(self) -> "ServeFrontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
