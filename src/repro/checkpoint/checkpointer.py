"""Sharded checkpointing with async writes and elastic restore.

Fault-tolerance contract (DESIGN.md §5):
  * ``save``     — atomically writes a step directory (tmp + rename) with
                   one npz per pytree leaf (path-keyed) + a manifest; an
                   optional background thread makes saves non-blocking
                   (training continues while the previous step flushes).
  * ``restore``  — reads a manifest, reassembles the pytree, and
                   ``device_put``s each leaf with the *current* sharding —
                   the checkpoint is topology-free, so restarts may change
                   device count/mesh shape (elastic re-mesh) or resume on
                   CPU from a TPU run.
  * ``latest_step`` / retention — keep the last N checkpoints, delete older.

At multi-thousand-node scale each host writes only its addressable shards;
here (single host) leaves are gathered to host numpy.  The format is
deliberately dependency-free (npz + json).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    directory: str
    keep: int = 3
    async_write: bool = True


def _path_entry_str(p) -> str:
    # DictKey -> .key, SequenceKey -> .idx, GetAttrKey (dataclass /
    # NamedTuple states like TrainState) -> .name
    for attr in ("key", "idx", "name"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_entry_str(p) for p in path)
        out[key] = leaf
    return out, treedef


class Checkpointer:
    def __init__(self, config: CheckpointConfig):
        self.config = config
        os.makedirs(config.directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool | None = None):
        leaves, _ = _flatten_with_paths(tree)
        host_leaves = {k: np.asarray(v) for k, v in leaves.items()}
        blocking = (not self.config.async_write) if blocking is None else blocking
        self.wait()  # one in-flight write at a time
        if blocking:
            self._write(step, host_leaves)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves), daemon=True
            )
            self._thread.start()

    def _write(self, step: int, host_leaves: dict):
        final = os.path.join(self.config.directory, f"step_{step:010d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": {}}
        for key, arr in host_leaves.items():
            fname = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.config.keep]:
            shutil.rmtree(os.path.join(self.config.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore -------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.config.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, *, step: int | None = None,
                shardings: Any | None = None) -> Any:
        """Rebuild `template`'s pytree from disk. `shardings` (optional
        pytree of NamedSharding) re-shards onto the live topology."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.config.directory}")
        d = os.path.join(self.config.directory, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = _flatten_with_paths(template)
        flat_sh = None
        if shardings is not None:
            sh_leaves, _ = _flatten_with_paths(shardings)
            flat_sh = sh_leaves
        rebuilt = {}
        for key in leaves:
            info = manifest["leaves"][key]
            arr = np.load(os.path.join(d, info["file"]))
            if flat_sh is not None and key in flat_sh:
                rebuilt[key] = jax.device_put(arr, flat_sh[key])
            else:
                rebuilt[key] = jax.numpy.asarray(arr)
        ordered = [rebuilt[k] for k in leaves]
        return jax.tree_util.tree_unflatten(treedef, ordered)
