from repro.checkpoint.checkpointer import Checkpointer, CheckpointConfig

__all__ = ["Checkpointer", "CheckpointConfig"]
