"""Process-wide metrics registry: counters, gauges, log-scale histograms.

The telemetry backbone of the repo: every hot layer (the disk store's
host callbacks, the search loop's dispatch sites, the serving front
end's admission/queue path) publishes named metric *families* here, and
the exporters (``obs/export.py``) turn one snapshot into Prometheus text
or a JSON artifact.  Design constraints, in order:

  * **lock-cheap.** One ``threading.Lock`` per metric child; an
    increment is a guarded add (no global lock on the write path), and a
    *disabled* registry early-outs before touching any lock — the
    disabled hot path costs one attribute read and one branch, which is
    what lets the instrumented search path stay within noise of a no-op
    stub (pinned by the tier-1 overhead guard in ``tests/test_obs.py``).
  * **no samples stored.** Histograms use fixed log-scale buckets:
    p50/p99/p99.9 are interpolated from cumulative bucket counts alone,
    so memory per child is O(buckets) regardless of observation count.
    ``sum``/``count`` are tracked exactly, so means are exact even
    though percentiles are bucket-resolution (~26% relative at the
    default 10 buckets/decade).
  * **families.** A family is ``(name, kind, label names)``; children
    are label valuations (``tenant=t0``, ``mode=gate``, ``store=...``).
    Label names are fixed at family creation — mismatched label sets on
    the same name are a bug and raise.  ``name`` is reserved (it is the
    family-name parameter); pick another label key (e.g. ``span``).

Counters are monotonic for the registry's lifetime: notably,
``DiskRecordStore.reset_io_counters()`` resets only the store-local
attributes, never the registry families (reconciliation contracts that
span resets therefore compare registry totals against registry totals).

The process-default registry starts DISABLED unless ``GATEANN_OBS`` is
set to a non-empty, non-"0" value; ``obs.enable()`` flips it at runtime
(``disk_sweep``/``serve_bench`` do when asked for ``--obs-json``).
Tests swap in a private registry with ``use_registry`` instead of
mutating the shared one.
"""
from __future__ import annotations

import bisect
import contextlib
import math
import os
import threading


class Counter:
    """Monotonic counter child.  ``inc`` is the only mutator."""

    kind = "counter"
    __slots__ = ("labels", "_registry", "_lock", "_value")

    def __init__(self, registry: "MetricsRegistry", labels: dict):
        self.labels = labels
        self._registry = registry
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int | float = 1) -> None:
        if not self._registry.enabled:
            return
        with self._lock:
            self._value += n

    @property
    def value(self):
        with self._lock:
            return self._value


class Gauge:
    """Point-in-time value child (queue depth, inflight reads, ...)."""

    kind = "gauge"
    __slots__ = ("labels", "_registry", "_lock", "_value")

    def __init__(self, registry: "MetricsRegistry", labels: dict):
        self.labels = labels
        self._registry = registry
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        if not self._registry.enabled:
            return
        with self._lock:
            self._value = v

    def inc(self, n: float = 1) -> None:
        if not self._registry.enabled:
            return
        with self._lock:
            self._value += n

    def dec(self, n: float = 1) -> None:
        self.inc(-n)

    @property
    def value(self):
        with self._lock:
            return self._value


# default histogram geometry: 10^(-6)..10^6 at 10 buckets per decade
# covers both span durations in seconds (1us..11.6 days) and per-query
# integer counts (I/Os, hops) without storing a single sample
HIST_LO = 1e-6
HIST_HI = 1e6
HIST_PER_DECADE = 10


def log_bucket_edges(lo: float = HIST_LO, hi: float = HIST_HI,
                     per_decade: int = HIST_PER_DECADE) -> list[float]:
    """Upper bucket edges ``10^(k/per_decade)`` spanning [lo, hi]."""
    k0 = math.floor(math.log10(lo) * per_decade)
    k1 = math.ceil(math.log10(hi) * per_decade)
    return [10.0 ** (k / per_decade) for k in range(k0, k1 + 1)]


class Histogram:
    """Fixed log-scale-bucket histogram child.

    ``counts[i]`` counts observations with ``edges[i-1] < v <= edges[i]``
    (``counts[0]`` is the underflow bucket spanning ``(-inf, edges[0]]``,
    the final slot overflow ``> edges[-1]``).  ``sum``/``count``/``min``
    /``max`` are exact; quantiles interpolate geometrically within the
    landing bucket.
    """

    kind = "histogram"
    __slots__ = ("labels", "edges", "_registry", "_lock", "_counts",
                 "_sum", "_count", "_min", "_max")

    def __init__(self, registry: "MetricsRegistry", labels: dict,
                 edges: list[float]):
        self.labels = labels
        self.edges = edges
        self._registry = registry
        self._lock = threading.Lock()
        self._counts = [0] * (len(edges) + 1)
        self._sum = 0.0
        self._count = 0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v: float) -> None:
        if not self._registry.enabled:
            return
        v = float(v)
        i = bisect.bisect_left(self.edges, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    @property
    def mean(self) -> float:
        with self._lock:
            return self._sum / self._count if self._count else 0.0

    def quantile(self, q: float) -> float:
        """Approximate q-quantile (q in [0, 1]) from bucket counts.

        Interpolation is geometric within the landing bucket (the
        buckets are log-spaced); the underflow bucket interpolates
        linearly from 0 and the overflow bucket returns the exact
        observed max.  Worst-case relative error is one bucket ratio
        (10^(1/per_decade), ~26% at the default geometry) — tight
        enough to rank stages and watch trends, which is the job.
        """
        with self._lock:
            total = self._count
            if not total:
                return 0.0
            counts = list(self._counts)
            vmin, vmax = self._min, self._max
        target = q * total
        cum = 0.0
        for i, c in enumerate(counts):
            if not c:
                continue
            if cum + c < target:
                cum += c
                continue
            frac = min(max((target - cum) / c, 0.0), 1.0)
            if i >= len(self.edges):  # overflow bucket
                return vmax
            hi_e = self.edges[i]
            lo_e = 0.0 if i == 0 else self.edges[i - 1]
            if lo_e <= 0.0:
                v = lo_e + (hi_e - lo_e) * frac
            else:
                v = lo_e * (hi_e / lo_e) ** frac
            # never extrapolate outside the observed range
            return min(max(v, vmin), vmax)
        return vmax

    def snapshot(self) -> dict:
        with self._lock:
            counts = list(self._counts)
            out = {
                "count": self._count,
                "sum": self._sum,
                "min": self._min if self._count else 0.0,
                "max": self._max if self._count else 0.0,
            }
        out["buckets"] = [
            [le, c] for le, c in zip(self.edges + [math.inf], counts) if c
        ]
        out["p50"] = self.quantile(0.50)
        out["p99"] = self.quantile(0.99)
        out["p999"] = self.quantile(0.999)
        return out


class _Family:
    __slots__ = ("name", "kind", "label_names", "children", "edges")

    def __init__(self, name, kind, label_names, edges=None):
        self.name = name
        self.kind = kind
        self.label_names = label_names
        self.children: dict[tuple, object] = {}
        self.edges = edges


class MetricsRegistry:
    """A namespace of metric families; see the module docstring."""

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}

    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    # -- family/child resolution -------------------------------------------
    def _child(self, name: str, kind: str, labels: dict, make):
        key = tuple(sorted(labels.items()))
        fam = self._families.get(name)  # GIL-atomic read, no lock
        # the kind check must run on the fast path too — returning an
        # existing child of the wrong kind would silently hand a Counter
        # to a histogram() caller; mismatched label NAMES can't collide
        # here (a different label set implies a different child key)
        if fam is not None and fam.kind == kind:
            child = fam.children.get(key)
            if child is not None:
                return child
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = _Family(name, kind, tuple(sorted(labels)))
                self._families[name] = fam
            if fam.kind != kind:
                raise TypeError(
                    f"metric family {name!r} is a {fam.kind}, not a {kind}"
                )
            if tuple(sorted(labels)) != fam.label_names:
                raise ValueError(
                    f"family {name!r} has labels {fam.label_names}, "
                    f"got {tuple(sorted(labels))}"
                )
            child = fam.children.get(key)
            if child is None:
                child = make(fam)
                fam.children[key] = child
            return child

    def counter(self, name: str, **labels) -> Counter:
        return self._child(name, "counter", labels,
                           lambda fam: Counter(self, dict(labels)))

    def gauge(self, name: str, **labels) -> Gauge:
        return self._child(name, "gauge", labels,
                           lambda fam: Gauge(self, dict(labels)))

    def histogram(self, name: str, *, lo: float = HIST_LO, hi: float = HIST_HI,
                  per_decade: int = HIST_PER_DECADE, **labels) -> Histogram:
        # bucket geometry is fixed per family (the first creation wins —
        # children of one family must share edges so exports line up)
        def make(fam):
            if fam.edges is None:
                fam.edges = log_bucket_edges(lo, hi, per_decade)
            return Histogram(self, dict(labels), fam.edges)

        return self._child(name, "histogram", labels, make)

    # -- reads --------------------------------------------------------------
    def families(self) -> list[str]:
        with self._lock:
            return sorted(self._families)

    def children(self, name: str) -> list:
        fam = self._families.get(name)
        if fam is None:
            return []
        with self._lock:
            return list(fam.children.values())

    def family_total(self, name: str, **match_labels) -> float:
        """Sum of counter/gauge child values, optionally filtered to
        children whose labels include every ``match_labels`` item."""
        total = 0.0
        for child in self.children(name):
            if match_labels and any(
                child.labels.get(k) != v for k, v in match_labels.items()
            ):
                continue
            total += child.value
        return total

    def snapshot(self) -> dict:
        """Plain-dict view of every family (the JSON/Prometheus source).

        Each child is snapshotted under its own lock; the result is a
        consistent-per-child (not globally atomic) view — each child's
        (value) or (count, sum, buckets) tuple is internally coherent,
        which is what the mid-flight invariant checks rely on.
        """
        with self._lock:
            fams = [(f.name, f.kind, list(f.children.values()))
                    for f in self._families.values()]
        out = {}
        for name, kind, children in sorted(fams):
            rows = []
            for child in children:
                row = {"labels": dict(child.labels)}
                if kind == "histogram":
                    row.update(child.snapshot())
                else:
                    row["value"] = child.value
                rows.append(row)
            rows.sort(key=lambda r: sorted(r["labels"].items()))
            fam_out = {"kind": kind, "children": rows}
            if kind in ("counter", "gauge"):
                fam_out["total"] = sum(r["value"] for r in rows)
            out[name] = fam_out
        return out

    def reset(self) -> None:
        """Drop every family (tests / explicit restarts only)."""
        with self._lock:
            self._families.clear()


_default = MetricsRegistry(
    enabled=os.environ.get("GATEANN_OBS", "") not in ("", "0")
)


def default_registry() -> MetricsRegistry:
    return _default


def set_default_registry(reg: MetricsRegistry) -> MetricsRegistry:
    global _default
    prev = _default
    _default = reg
    return prev


@contextlib.contextmanager
def use_registry(reg: MetricsRegistry):
    """Swap the process-default registry for the block (test isolation).

    Stores built inside the block capture ``reg`` at construction, so
    their counters keep landing in it even after the block exits —
    exactly what a test wants when it asserts on the swapped registry
    after tearing the engine down.
    """
    prev = set_default_registry(reg)
    try:
        yield reg
    finally:
        set_default_registry(prev)


def enable() -> None:
    """Enable recording on the process-default registry."""
    _default.enabled = True


def disable() -> None:
    _default.enabled = False
