"""Span tracer: monotonic-clock stage timing with per-thread ring buffers.

``with trace.span("disk.preadv", store=...):`` times one stage of the
I/O path on ``time.perf_counter()`` (monotonic, high-resolution — wall
clock steps can never corrupt a duration) and publishes it two ways:

  * a per-thread **ring buffer** of the most recent spans — the raw
    material for "what did the last few requests actually do", exported
    by ``obs.export`` and rendered by ``scripts/obs_report.py``.  Rings
    are per-thread so the disk store's reader-pool threads, the serving
    dispatcher, and the client threads never contend on a shared list.
  * a ``trace.span_seconds{span=...}`` **histogram family** in the bound
    registry, so span percentiles ride the same export path as every
    other metric (span labels beyond the name stay in the ring only —
    histogram families need fixed, bounded label sets; ``name`` itself
    is reserved for the registry API).

Overhead budget (documented, and pinned by the tier-1 overhead guard):

  * **disabled** (the default): ``span()`` is one attribute read, one
    branch, and a shared no-op context manager — near-zero, safe to
    leave in the hottest host callback.
  * **enabled**: two ``perf_counter`` calls plus a ring append and one
    histogram observe per recorded span, ~1-2us on commodity CPUs —
    <2% of even a page-cache-served 4 KB ``preadv`` round, which is the
    cheapest stage we time.  The ``sample_rate`` knob (1-in-N per
    thread, deterministic) cuts it further for high-frequency spans.

Pre-measured durations (e.g. the serving dispatcher computes queue-wait
arithmetic itself) enter through ``trace.record(name, dur_s, ...)`` —
same ring, same histogram family, no double clocking.
"""
from __future__ import annotations

import threading
import time

from repro.obs import registry as regm

RING_SIZE = 512  # spans kept per thread


class _NopSpan:
    """Shared do-nothing context manager — the disabled-tracer fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOP = _NopSpan()


class _Ring:
    """Fixed-capacity overwrite-oldest span buffer (single-writer)."""

    __slots__ = ("buf", "cap", "i")

    def __init__(self, cap: int):
        self.buf: list = []
        self.cap = cap
        self.i = 0

    def push(self, item) -> None:
        if len(self.buf) < self.cap:
            self.buf.append(item)
        else:
            self.buf[self.i % self.cap] = item
        self.i += 1

    def items(self) -> list:
        if len(self.buf) < self.cap:
            return list(self.buf)
        k = self.i % self.cap
        return self.buf[k:] + self.buf[:k]


class _Span:
    __slots__ = ("_tracer", "name", "labels", "t0")

    def __init__(self, tracer: "Tracer", name: str, labels: dict):
        self._tracer = tracer
        self.name = name
        self.labels = labels

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._tracer._commit(
            self.name, self.labels, self.t0, time.perf_counter() - self.t0
        )
        return False


class Tracer:
    """One span sink: per-thread rings + a span-seconds histogram family.

    The process-default tracer (module-level ``span``/``record``/...)
    binds to whatever the process-default registry currently is; a
    serving front end creates its own ``Tracer(registry=...)`` so its
    request spans land in its own registry regardless of global state.
    """

    def __init__(self, registry: regm.MetricsRegistry | None = None,
                 ring_size: int = RING_SIZE):
        self.enabled = False
        self.sample_every = 1
        self._registry = registry
        self._ring_size = ring_size
        self._rings: dict[str, _Ring] = {}
        self._rings_lock = threading.Lock()
        self._tls = threading.local()

    def enable(self, sample_rate: float = 1.0) -> None:
        """Start recording; ``sample_rate`` keeps 1-in-round(1/rate)
        spans per thread (deterministic, counter-based — no RNG in the
        hot path).  Histogram percentiles are over the sampled spans."""
        if not 0.0 < sample_rate <= 1.0:
            raise ValueError(f"sample_rate must be in (0, 1], got {sample_rate}")
        self.sample_every = max(1, int(round(1.0 / sample_rate)))
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def _reg(self) -> regm.MetricsRegistry:
        return self._registry if self._registry is not None \
            else regm.default_registry()

    def span(self, name: str, **labels):
        if not self.enabled:
            return _NOP
        return _Span(self, name, labels)

    def record(self, name: str, duration_s: float, **labels) -> None:
        """Publish an externally measured duration as a span."""
        if not self.enabled:
            return
        self._commit(name, labels, time.perf_counter() - duration_s,
                     duration_s)

    def _commit(self, name: str, labels: dict, t0: float, dur: float) -> None:
        tls = self._tls
        ring = getattr(tls, "ring", None)
        if ring is None:
            ring = tls.ring = _Ring(self._ring_size)
            tls.n = 0
            t = threading.current_thread()
            with self._rings_lock:
                self._rings[f"{t.name}-{t.ident}"] = ring
        n = tls.n
        tls.n = n + 1
        if n % self.sample_every:
            return
        ring.push((name, labels, t0, dur))
        self._reg().histogram("trace.span_seconds", span=name).observe(dur)

    def snapshot(self) -> dict:
        """``{thread: [span dicts, oldest first]}`` across all threads."""
        with self._rings_lock:
            rings = list(self._rings.items())
        return {
            tname: [
                {"name": n, "labels": dict(l), "start": t0, "dur_s": d}
                for (n, l, t0, d) in ring.items()
            ]
            for tname, ring in rings
        }

    def reset(self) -> None:
        with self._rings_lock:
            self._rings.clear()
        self._tls = threading.local()


_tracer = Tracer()


def default_tracer() -> Tracer:
    return _tracer


def span(name: str, **labels):
    return _tracer.span(name, **labels)


def record(name: str, duration_s: float, **labels) -> None:
    _tracer.record(name, duration_s, **labels)


def enable(sample_rate: float = 1.0) -> None:
    _tracer.enable(sample_rate)


def disable() -> None:
    _tracer.disable()


def snapshot() -> dict:
    return _tracer.snapshot()
