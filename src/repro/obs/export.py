"""Exporters: Prometheus text format and JSON snapshots.

Both render the same ``MetricsRegistry.snapshot()`` dict, so a scrape
and an ``--obs-json`` artifact always agree bit-exactly (the nightly
``obs-contracts`` job checks a counter through both).  No third-party
client library — the text format is simple and the toolchain is frozen.

JSON layout (``to_json``):

    {"schema_version": 1,
     "enabled": true,
     "families": {<name>: {"kind": ..., "children": [...], "total": ...}},
     "spans": {<thread>: [{"name", "labels", "start", "dur_s"}, ...]}}

``write_obs_json`` wraps one or more of those sections into a single
artifact — benchmarks export the process registry/tracer as
``"process"`` plus any per-instance sections (the serving front end's
own registry lands as ``"serve"``).
"""
from __future__ import annotations

import json
import math

from repro.obs import registry as regm
from repro.obs import tracer as tracerm

SCHEMA_VERSION = 1

_PREFIX = "gateann_"


def to_json(registry: regm.MetricsRegistry | None = None,
            tracer: tracerm.Tracer | None = None) -> dict:
    """One registry (+ tracer) as a JSON-ready snapshot dict."""
    reg = registry if registry is not None else regm.default_registry()
    tr = tracer if tracer is not None else tracerm.default_tracer()
    return {
        "schema_version": SCHEMA_VERSION,
        "enabled": reg.enabled,
        "families": reg.snapshot(),
        "spans": tr.snapshot(),
    }


def _metric_name(name: str) -> str:
    return _PREFIX + name.replace(".", "_").replace("-", "_")


def _label_str(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(
        f'{k}="{v}"' for k, v in sorted(labels.items())
    )
    return "{" + inner + "}"


def _fmt(v) -> str:
    if isinstance(v, float):
        if math.isinf(v):
            return "+Inf" if v > 0 else "-Inf"
        return repr(v)
    return str(v)


def to_prometheus(source=None) -> str:
    """Prometheus exposition text from a registry OR a snapshot dict.

    Accepting the snapshot dict lets ``obs_report.py --prom`` re-render
    a saved ``--obs-json`` artifact identically to a live scrape.
    """
    if source is None:
        source = regm.default_registry()
    if isinstance(source, regm.MetricsRegistry):
        families = source.snapshot()
    elif isinstance(source, dict):
        families = source.get("families", source)
    else:
        raise TypeError(f"cannot export {type(source).__name__}")
    lines = []
    for name in sorted(families):
        fam = families[name]
        mname = _metric_name(name)
        lines.append(f"# TYPE {mname} {fam['kind']}")
        for child in fam["children"]:
            labels = child.get("labels", {})
            if fam["kind"] in ("counter", "gauge"):
                lines.append(
                    f"{mname}{_label_str(labels)} {_fmt(child['value'])}"
                )
                continue
            # histogram: cumulative le-buckets, then _sum/_count
            cum = 0
            buckets = list(child.get("buckets", []))
            if not buckets or not math.isinf(buckets[-1][0]):
                buckets.append([math.inf, 0])
            for le, c in buckets:
                cum += c
                lines.append(
                    f"{mname}_bucket"
                    f"{_label_str({**labels, 'le': _fmt(float(le))})} {cum}"
                )
            lines.append(
                f"{mname}_sum{_label_str(labels)} {_fmt(child['sum'])}"
            )
            lines.append(
                f"{mname}_count{_label_str(labels)} {child['count']}"
            )
    return "\n".join(lines) + "\n"


def write_obs_json(path: str, sections: dict | None = None) -> dict:
    """Write the standard ``--obs-json`` artifact.

    The process-default registry/tracer land under ``"process"``;
    ``sections`` maps extra names to ``(registry, tracer_or_None)``
    pairs (e.g. ``{"serve": (srv.metrics, srv.tracer)}``).  Returns the
    payload that was written.
    """
    payload = {"schema_version": SCHEMA_VERSION, "process": to_json()}
    for name, (reg, tr) in (sections or {}).items():
        payload[name] = to_json(reg, tr)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return payload
