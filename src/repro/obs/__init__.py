"""Unified telemetry: metrics registry, span tracer, exporters.

    from repro import obs

    obs.enable()                      # counters/gauges/histograms on
    obs.trace.enable(sample_rate=1.0) # span timing on

    reg = obs.default_registry()
    reg.counter("disk.records_read", store="idx.gann").inc(8)
    with obs.trace.span("disk.preadv", store="idx.gann"):
        ...
    print(obs.export.to_prometheus())

Recording is disabled by default (near-zero hot-path cost — see the
overhead budget in ``obs/tracer.py``); set ``GATEANN_OBS=1`` or call
``obs.enable()``.  ``disk_sweep``/``serve_bench`` enable both when run
with ``--obs-json``, and ``scripts/obs_report.py`` renders the artifact.
"""
from repro.obs import export, stats  # noqa: F401
from repro.obs import tracer as trace  # noqa: F401
from repro.obs.registry import (  # noqa: F401
    MetricsRegistry,
    default_registry,
    disable,
    enable,
    set_default_registry,
    use_registry,
)

__all__ = [
    "MetricsRegistry",
    "default_registry",
    "set_default_registry",
    "use_registry",
    "enable",
    "disable",
    "export",
    "stats",
    "trace",
]
