"""Shared ``SearchStats`` aggregation + registry recording.

One home for the summing/ratio arithmetic that ``RAGServer.io_report``
and ``ServeFrontend.io_report`` used to carry as private copies, plus
``record_search_stats`` — the single point where a materialized batch of
per-query stats becomes registry families (the fetched-vs-tunneled
split per mode is the paper's headline ratio, so it gets first-class
counters here rather than being re-derived per report).

Everything here duck-types the stats object (any NamedTuple of ``(B,)``
arrays with ``_fields``) so ``obs`` never imports ``core.search`` — the
dependency points the other way.
"""
from __future__ import annotations

import numpy as np

from repro.obs import registry as regm


def stats_totals(stats) -> dict:
    """Host-materialized integer sums of a per-query stats batch.

    Materializing forces the whole search computation (ordered
    io_callbacks included), so counters read afterwards are complete —
    same discipline as ``DiskRecordStore``'s counter notes.  Returns
    one ``"queries"`` key (the batch size) plus one key per stats field.
    """
    out = {}
    n = 0
    for f in stats._fields:
        arr = np.asarray(getattr(stats, f))
        n = int(arr.shape[0])
        out[f] = int(arr.sum())
    out["queries"] = n
    return out


def hit_rate(ios: int, cache_hits: int) -> float:
    """Cache-tier share of record fetches (0.0 when there were none)."""
    return cache_hits / max(ios + cache_hits, 1)


def tier_mix(*, queries: int, ios: int, cache_hits: int, tunnels: int) -> dict:
    """The lifetime tier-mix report head shared by both serving layers."""
    return {
        "queries": queries,
        "slow_tier_reads": ios,
        "cache_hits": cache_hits,
        "tunnels": tunnels,
        "cache_hit_rate": hit_rate(ios, cache_hits),
    }


def record_search_stats(reg: regm.MetricsRegistry, stats, *,
                        mode: str, tier: str) -> dict:
    """Fold one materialized stats batch into the registry families.

    Counters (labeled ``mode``/``tier``) carry the reconciliation
    contracts — ``search.ios{tier=disk}`` totals must equal the disk
    store's ``disk.records_read`` exactly, and
    ``search.ios + search.cache_hits`` vs ``search.tunnels`` is the
    fetched-vs-tunneled split.  Histograms carry the per-query
    distributions the report CLI renders.  Returns ``stats_totals``.
    """
    t = stats_totals(stats)
    labels = {"mode": mode, "tier": tier}
    reg.counter("search.queries", **labels).inc(t["queries"])
    reg.counter("search.ios", **labels).inc(t["n_ios"])
    reg.counter("search.cache_hits", **labels).inc(t["n_cache_hits"])
    reg.counter("search.tunnels", **labels).inc(t["n_tunnels"])
    reg.counter("search.exact", **labels).inc(t["n_exact"])
    reg.counter("search.hops", **labels).inc(t["n_hops"])
    if "n_degraded" in t:  # duck-typed stats may predate the field
        reg.counter("search.degraded", **labels).inc(t["n_degraded"])
        reg.counter("search.degraded_queries", **labels).inc(
            int((np.asarray(stats.n_degraded) > 0).sum())
        )
    h_ios = reg.histogram("search.ios_per_query", mode=mode)
    h_hops = reg.histogram("search.hops_per_query", mode=mode)
    for v in np.asarray(stats.n_ios).tolist():
        h_ios.observe(v)
    for v in np.asarray(stats.n_hops).tolist():
        h_hops.observe(v)
    return t
