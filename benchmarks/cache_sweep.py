"""Cache sweep: slow-tier I/O, hit rate, and modeled QPS vs cache budget.

Sweeps the hot-node record cache (``EngineConfig.cache_budget_bytes``)
per search mode on the standard 20k setup, then pits the **adaptive**
policy against the static one on a *skewed selective-filter* workload
(Zipfian query centers over the rare-label region, gate mode) — the
regime where a static, filter-blind hot set thrashes.  The cache is a
runtime knob (``engine.with_cache``) so the graph/PQ build is shared
across the whole sweep.  Emits the benchmark-contract CSV
``name,us_per_call,derived``:

  cache_<mode>_r<records>_ios        derived = mean slow-tier reads/query
  cache_<mode>_r<records>_hitrate    derived = hits / (hits + slow reads)
  cache_<mode>_r<records>_qps32      derived = modeled QPS at 32 threads
  cache_<mode>_ids_match             derived = 1.0 iff every budget returned
                                     ids identical to the uncached engine
  cache_skew_<policy>_r<records>_*   the skewed-workload head-to-head
  cache_skew_ids_match               derived = 1.0 iff both policies stayed
                                     bit-identical to uncached at all budgets
  cache_skew_adaptive_ge_static      derived = 1.0 iff adaptive hit rate >=
                                     static at every budget, > at >= 1

    PYTHONPATH=src python -m benchmarks.cache_sweep [--quick] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from benchmarks import common
from repro.core import SearchConfig
from repro.data import make_zipfian_queries, zipf_labels

BUDGET_RECORDS = (0, 64, 256, 1024, 4096)
RECORD_BYTES = 4096  # 32-dim, degree-32 records round to one 4 KB sector
MODES = ("gate", "post", "unfiltered")

# skewed-workload knobs: rare Zipf class (~3% selectivity), hot query centers
SKEW_ALPHA = 1.1
SKEW_CENTERS = 24
N_WARM_BATCHES = 3


def sweep_cache(ctx, *, budgets=BUDGET_RECORDS, modes=MODES, search_l=100,
                policy="visit_freq"):
    engine = ctx["engine"]
    queries = ctx["queries"]
    rows = []
    for mode in modes:
        kind = None if mode == "unfiltered" else "label"
        params = None if mode == "unfiltered" else np.zeros(common.NQ, np.int32)
        base_ids = None
        ids_match = True
        for nrec in budgets:
            eng = engine.with_cache(nrec * RECORD_BYTES, policy=policy)
            out = eng.search(
                queries, filter_kind=kind, filter_params=params,
                search_config=SearchConfig(mode=mode, search_l=search_l,
                                           beam_width=8),
            )
            ids = np.asarray(out.ids)
            if base_ids is None:
                base_ids = ids
            ids_match &= bool(np.array_equal(ids, base_ids))
            ios = float(np.mean(np.asarray(out.stats.n_ios)))
            hits = float(np.mean(np.asarray(out.stats.n_cache_hits)))
            lat = eng.modeled_latency_us(out.stats)
            rows.append(dict(name=f"cache_{mode}_r{nrec}_ios", lat1_us=lat,
                             derived=ios))
            rows.append(dict(name=f"cache_{mode}_r{nrec}_hitrate", lat1_us=lat,
                             derived=hits / max(hits + ios, 1e-9)))
            rows.append(dict(name=f"cache_{mode}_r{nrec}_qps32", lat1_us=lat,
                             derived=eng.modeled_qps(out.stats)))
        rows.append(dict(name=f"cache_{mode}_ids_match", lat1_us=0.0,
                         derived=float(ids_match)))
    return rows


def skewed_setup(seed: int = 0):
    """Zipf-labelled engine + skewed selective workload on the shared graph.

    Labels are Zipf(1.0) over 10 classes; the target is the *rarest*
    class (~3% selectivity).  Queries cluster Zipf-style around a few
    centers drawn from the rare-label region — warm and eval batches are
    independent draws from the same distribution.
    """
    corpus, graph = common.cached_graph(seed=seed)
    labels = zipf_labels(common.N, common.N_CLASSES, alpha=1.0, seed=seed)
    rare = int(np.argmin(np.bincount(labels, minlength=common.N_CLASSES)))
    mask = labels == rare
    engine = common.build_engine(corpus, graph, labels=labels)
    warm_batches = [
        make_zipfian_queries(
            corpus, common.NQ, n_centers=SKEW_CENTERS, alpha=SKEW_ALPHA,
            seed=seed + 100 + i, mask=mask,
        )
        for i in range(N_WARM_BATCHES)
    ]
    eval_queries = make_zipfian_queries(
        corpus, common.NQ, n_centers=SKEW_CENTERS, alpha=SKEW_ALPHA,
        seed=seed + 999, mask=mask,
    )
    return dict(engine=engine, labels=labels, rare=rare,
                warm_batches=warm_batches, eval_queries=eval_queries)


def sweep_adaptive_vs_static(skew, *, budgets=BUDGET_RECORDS, search_l=100):
    """Head-to-head on the skewed selective workload (gate mode).

    The adaptive engine is warmed on independent same-distribution
    batches (its counters learn the filtered fetch population), then
    both policies are measured on the eval batch.  Result ids must stay
    bit-identical to the uncached engine for every policy and budget.
    """
    engine = skew["engine"]
    eval_q = skew["eval_queries"]
    tgt = np.full(eval_q.shape[0], skew["rare"], np.int32)
    cfg = SearchConfig(mode="gate", search_l=search_l, beam_width=8)

    base = engine.search(eval_q, filter_kind="label", filter_params=tgt,
                         search_config=cfg)
    base_ids = np.asarray(base.ids)
    base_ios = np.asarray(base.stats.n_ios)

    rows = []
    ids_match = True
    hit_rates = {"static": [], "adaptive": []}
    for nrec in budgets:
        for policy in ("static", "adaptive"):
            if policy == "static":
                eng = engine.with_cache(nrec * RECORD_BYTES, policy="visit_freq")
            else:
                eng = engine.with_cache(nrec * RECORD_BYTES, policy="adaptive",
                                        refresh_every=1)
                for wq in skew["warm_batches"]:
                    wt = np.full(wq.shape[0], skew["rare"], np.int32)
                    eng.warm(wq, filter_kind="label", filter_params=wt,
                             search_config=cfg)
            out = eng.search(eval_q, filter_kind="label", filter_params=tgt,
                             search_config=cfg)
            ids_match &= bool(np.array_equal(np.asarray(out.ids), base_ids))
            ids_match &= bool(np.array_equal(
                np.asarray(out.stats.n_ios) + np.asarray(out.stats.n_cache_hits),
                base_ios))
            ios = float(np.mean(np.asarray(out.stats.n_ios)))
            hits = float(np.mean(np.asarray(out.stats.n_cache_hits)))
            rate = hits / max(hits + ios, 1e-9)
            hit_rates[policy].append(rate)
            lat = eng.modeled_latency_us(out.stats)
            rows.append(dict(name=f"cache_skew_{policy}_r{nrec}_hitrate",
                             lat1_us=lat, derived=rate))
            rows.append(dict(name=f"cache_skew_{policy}_r{nrec}_qps32",
                             lat1_us=lat, derived=eng.modeled_qps(out.stats)))
    ge = all(a >= s - 1e-12 for a, s in zip(hit_rates["adaptive"], hit_rates["static"]))
    gt = any(a > s + 1e-12 for a, s in zip(hit_rates["adaptive"], hit_rates["static"]))
    rows.append(dict(name="cache_skew_ids_match", lat1_us=0.0,
                     derived=float(ids_match)))
    rows.append(dict(name="cache_skew_adaptive_ge_static", lat1_us=0.0,
                     derived=float(ge and gt)))
    return rows


def fig19_cache_sweep(ctx):
    """Registered with benchmarks/run.py as fig19."""
    return sweep_cache(ctx)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="gate mode only, 3 budgets")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write all rows as a JSON artifact")
    args = ap.parse_args()
    ctx = common.standard_setup()
    kw = {}
    budgets = BUDGET_RECORDS
    if args.quick:
        budgets = (0, 256, 4096)
        kw = dict(budgets=budgets, modes=("gate",))
    rows = sweep_cache(ctx, **kw)
    rows += sweep_adaptive_vs_static(skewed_setup(), budgets=budgets)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['lat1_us']:.1f},{r['derived']:.4f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"benchmark": "cache_sweep", "rows": rows}, f, indent=1)
        print(f"# wrote {args.json}", file=sys.stderr)
    print("# sweep done", file=sys.stderr)


if __name__ == "__main__":
    main()
