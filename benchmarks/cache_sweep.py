"""Cache sweep: slow-tier I/O, hit rate, and modeled QPS vs cache budget.

Sweeps the hot-node record cache (``EngineConfig.cache_budget_bytes``)
per search mode on the standard 20k setup.  The cache is a runtime knob
(``engine.with_cache``) so the graph/PQ build is shared across the whole
sweep.  Emits the benchmark-contract CSV ``name,us_per_call,derived``:

  cache_<mode>_r<records>_ios      derived = mean slow-tier reads/query
  cache_<mode>_r<records>_hitrate  derived = hits / (hits + slow reads)
  cache_<mode>_r<records>_qps32    derived = modeled QPS at 32 threads
  cache_<mode>_ids_match           derived = 1.0 iff every budget returned
                                   ids identical to the uncached engine

    PYTHONPATH=src python -m benchmarks.cache_sweep [--quick]
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from benchmarks import common
from repro.core import SearchConfig

BUDGET_RECORDS = (0, 64, 256, 1024, 4096)
RECORD_BYTES = 4096  # 32-dim, degree-32 records round to one 4 KB sector
MODES = ("gate", "post", "unfiltered")


def sweep_cache(ctx, *, budgets=BUDGET_RECORDS, modes=MODES, search_l=100,
                policy="visit_freq"):
    engine = ctx["engine"]
    queries = ctx["queries"]
    rows = []
    for mode in modes:
        kind = None if mode == "unfiltered" else "label"
        params = None if mode == "unfiltered" else np.zeros(common.NQ, np.int32)
        base_ids = None
        ids_match = True
        for nrec in budgets:
            eng = engine.with_cache(nrec * RECORD_BYTES, policy=policy)
            out = eng.search(
                queries, filter_kind=kind, filter_params=params,
                search_config=SearchConfig(mode=mode, search_l=search_l,
                                           beam_width=8),
            )
            ids = np.asarray(out.ids)
            if base_ids is None:
                base_ids = ids
            ids_match &= bool(np.array_equal(ids, base_ids))
            ios = float(np.mean(np.asarray(out.stats.n_ios)))
            hits = float(np.mean(np.asarray(out.stats.n_cache_hits)))
            lat = eng.modeled_latency_us(out.stats)
            rows.append(dict(name=f"cache_{mode}_r{nrec}_ios", lat1_us=lat,
                             derived=ios))
            rows.append(dict(name=f"cache_{mode}_r{nrec}_hitrate", lat1_us=lat,
                             derived=hits / max(hits + ios, 1e-9)))
            rows.append(dict(name=f"cache_{mode}_r{nrec}_qps32", lat1_us=lat,
                             derived=eng.modeled_qps(out.stats)))
        rows.append(dict(name=f"cache_{mode}_ids_match", lat1_us=0.0,
                         derived=float(ids_match)))
    return rows


def fig19_cache_sweep(ctx):
    """Registered with benchmarks/run.py as fig19."""
    return sweep_cache(ctx)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="gate mode only, 3 budgets")
    args = ap.parse_args()
    ctx = common.standard_setup()
    kw = {}
    if args.quick:
        kw = dict(budgets=(0, 256, 4096), modes=("gate",))
    print("name,us_per_call,derived")
    for r in sweep_cache(ctx, **kw):
        print(f"{r['name']},{r['lat1_us']:.1f},{r['derived']:.4f}")
    print("# sweep done", file=sys.stderr)


if __name__ == "__main__":
    main()
