"""Shared benchmark harness: cached corpus/engine builds, L-sweeps, CSV.

Scale is CPU-budget-resized (N=20k vs the paper's 100M+) — per DESIGN.md
§8, *structural* metrics (I/O counts, recall, 1/s law, tunnel counts) are
measured for real; *device-time* metrics (latency/QPS) come from the
calibrated io_model with the paper's own constants.  The distributed
dry-run covers the 100M-scale memory/collective story.
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig, GateANNEngine, SearchConfig, recall_at_k
from repro.core.graph import VamanaGraph, build_vamana
from repro.core.io_model import DEFAULT_COST_MODEL
from repro.data import (
    filtered_ground_truth,
    make_bigann_like,
    make_queries,
    uniform_labels,
)

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
CACHE_DIR = os.path.join(REPO_ROOT, "results", "bench_cache")

# version stamp for every benchmark JSON artifact (BENCH_*.json) — bump
# on any field rename/removal so nightly consumers can fail loudly
# instead of silently reading shifted columns
BENCH_SCHEMA_VERSION = 1


def root_artifact(name: str) -> str:
    """Anchor an artifact filename at the repo root (stable across CWDs)."""
    return name if os.path.isabs(name) else os.path.join(REPO_ROOT, name)


def write_bench_json(path: str, benchmark: str, rows, extra: dict | None = None):
    """Write the standard benchmark JSON artifact (schema-versioned)."""
    import json

    doc = {"schema_version": BENCH_SCHEMA_VERSION, "benchmark": benchmark,
           "rows": rows}
    if extra:
        doc.update(extra)
    path = root_artifact(path)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, default=float)
    return path

# default benchmark scale
N, DIM, NQ, N_CLASSES = 20_000, 32, 48, 10
DEGREE, BUILD_L, PQ_CHUNKS, R_MAX = 32, 64, 8, 16
L_SWEEP = (20, 40, 60, 100, 150, 200)


def cached_graph(n: int = N, dim: int = DIM, seed: int = 0, degree: int = DEGREE,
                 build_l: int = BUILD_L, tag: str = "") -> tuple[np.ndarray, VamanaGraph]:
    os.makedirs(CACHE_DIR, exist_ok=True)
    path = os.path.join(CACHE_DIR, f"graph_{tag}{n}_{dim}_{degree}_{seed}.npz")
    corpus = make_bigann_like(n, dim, seed=seed)
    if os.path.exists(path):
        z = np.load(path)
        return corpus, VamanaGraph(
            neighbors=jnp.asarray(z["neighbors"]), medoid=jnp.int32(z["medoid"])
        )
    t0 = time.perf_counter()
    g = build_vamana(corpus, degree=degree, build_l=build_l, seed=seed)
    print(f"# built graph n={n} in {time.perf_counter()-t0:.0f}s", file=sys.stderr)
    np.savez(path, neighbors=np.asarray(g.neighbors), medoid=int(g.medoid))
    return corpus, g


def build_engine(corpus, graph, *, labels=None, attributes=None, tag_bits=None,
                 r_max: int = R_MAX) -> GateANNEngine:
    return GateANNEngine.build(
        corpus,
        config=EngineConfig(degree=graph.neighbors.shape[1], pq_chunks=PQ_CHUNKS,
                            r_max=r_max),
        labels=labels, attributes=attributes, tag_bits=tag_bits, graph=graph,
    )


def standard_setup(seed: int = 0):
    """The workhorse: 20k corpus + graph + uniform 10-class labels."""
    corpus, graph = cached_graph(seed=seed)
    labels = uniform_labels(N, N_CLASSES, seed=seed)
    queries = make_queries(corpus, NQ, seed=seed + 1)
    engine = build_engine(corpus, graph, labels=labels)
    gt = filtered_ground_truth(corpus, queries, labels == 0, k=10)
    return dict(corpus=corpus, graph=graph, labels=labels, queries=queries,
                engine=engine, gt=gt)


def sweep(engine, queries, gt, *, mode: str, l_values=L_SWEEP, beam_width: int = 8,
          filter_kind="label", filter_params=None, k: int = 10):
    """Returns rows: (L, recall, ios, tunnels, exact, lat1_us, qps32)."""
    if filter_params is None:
        filter_params = np.zeros(queries.shape[0], np.int32)
    rows = []
    for L in l_values:
        out = engine.search(
            queries, filter_kind=filter_kind, filter_params=filter_params,
            search_config=SearchConfig(mode=mode, search_l=L, result_k=k,
                                       beam_width=beam_width),
        )
        ios = float(np.mean(np.asarray(out.stats.n_ios)))
        tun = float(np.mean(np.asarray(out.stats.n_tunnels)))
        nex = float(np.mean(np.asarray(out.stats.n_exact)))
        rec = recall_at_k(out.ids, gt, k)
        lat = engine.modeled_latency_us(out.stats)
        qps = engine.modeled_qps(out.stats)
        rows.append(dict(L=L, recall=rec, ios=ios, tunnels=tun, exact=nex,
                         lat1_us=lat, qps32=qps))
    return rows


def emit(name: str, rows, derived_key: str = "recall"):
    """Print `name,us_per_call,derived` CSV lines (benchmark contract)."""
    out = []
    for r in rows:
        line = f"{name},{r.get('lat1_us', 0.0):.1f},{r[derived_key]:.4f}"
        print(line)
        out.append(line)
    return out
