"""Disk sweep: *measured* reads and syscalls vs the cost model's ``n_ios``.

Every other benchmark prices slow-tier I/O through the calibrated cost
model.  This one builds the standard engine, persists it to the
page-aligned index format, reloads it with ``store_tier="disk"`` and
compares, per search mode and per cache budget:

  * measured  — ``DiskRecordStore`` counter deltas (the host callback
                counts the sectors the loop requested AND what the
                coalesced reader physically did)
  * modeled   — ``sum(SearchStats.n_ios) * pages_per_record`` (what the
                cost model prices)

Two reconciliation contracts, both enforced nightly:

  * logical (exact): requested pages == modeled pages — cache hits and
    filter-gated nodes never reach the file.
  * physical (coalesced): ``unique_sectors_read <= sum(n_ios)`` (equality
    iff no round fetched the same record for two queries at once), and
    one vectored syscall per search round on the preadv path
    (``syscalls == read_rounds``) or one per merged range on the
    fallback (``syscalls == ranges_read``).

Emits the benchmark-contract CSV ``name,us_per_call,derived``:

  disk_<mode>_r<records>_pages_q    derived = requested pages / query
  disk_<mode>_r<records>_model_q    derived = modeled pages / query
  disk_<mode>_r<records>_reconciled derived = 1.0 iff measured == modeled
  disk_<mode>_r<records>_uniq_q     derived = unique sectors read / query
  disk_<mode>_r<records>_sys_round  derived = syscalls / read round
  disk_ids_match                    derived = 1.0 iff every disk-tier run
                                    returned ids identical to in-memory
  disk_gate_lt_post                 derived = 1.0 iff gate read strictly
                                    fewer pages than post (uncached)
  disk_unique_le_ios                derived = 1.0 iff unique <= requested
                                    sectors held in every cell
  disk_syscall_contract             derived = 1.0 iff the syscall law for
                                    the store's io_mode held in every cell

``--pipeline-depth K`` additionally sweeps the software pipeline
(SearchConfig.pipeline_depth in {1, 2, 4, ...} up to K) on the
cold-cache disk tier — page cache dropped (posix_fadvise DONTNEED)
before every timed run — and emits wall-clock-per-query columns:

  pipe_gate_d<p>_wall_q       derived = measured wall-clock us / query
  pipe_gate_d<p>_reconciled   derived = 1.0 iff pages_read == sum(n_ios)
                              * pages_per_record at this depth
  pipe_ids_match              derived = 1.0 iff every depth returned ids
                              AND dists bit-identical to depth 1
  pipe_recall_match           derived = 1.0 iff pipelined recall@K ==
                              synchronous recall@K at every depth
  pipe_unique_le_ios          derived = 1.0 iff unique <= requested held
                              under overlap at every depth
  pipe_overlap_observed       derived = 1.0 iff depth > 1 runs overlapped
                              at least one read (overlapped_rounds > 0)
  pipe_speedup_d<p>           derived = wall(depth 1) / wall(depth p)

With ``--obs-json PATH`` the process telemetry registry + tracer are
enabled for the sweep and dumped to PATH, and two more contract rows
appear (the nightly ``obs-contracts`` job asserts both == 1.0):

  obs_store_reconciled    1.0 iff every mirrored ``disk.*`` registry
                          counter == the store's measured counter,
                          bit-exact (checked before any counter reset)
  obs_search_reconciled   1.0 iff registry ``search.ios{tier=disk}`` ==
                          registry ``disk.records_read`` — the
                          cross-reset form of the logical contract

    PYTHONPATH=src python -m benchmarks.disk_sweep [--quick] [--json PATH]
        [--pipeline-depth K] [--obs-json PATH]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

from benchmarks import common
from repro import obs
from repro.core import GateANNEngine, SearchConfig, recall_at_k

BUDGET_RECORDS = (0, 256, 1024)
MODES = ("gate", "post", "unfiltered")


def index_path(tag: str = "") -> str:
    os.makedirs(common.CACHE_DIR, exist_ok=True)
    return os.path.join(common.CACHE_DIR, f"index_{tag}{common.N}_{common.DIM}.gann")


def sweep_disk(ctx, *, budgets=BUDGET_RECORDS, modes=MODES, search_l=100):
    engine = ctx["engine"]
    queries = ctx["queries"]
    nq = queries.shape[0]
    path = index_path()
    engine.save(path)
    print(f"# saved index: {os.path.getsize(path)} B", file=sys.stderr)

    # one load: all budgets re-wrap the same DiskRecordStore (same file
    # handle, same measured counters, same jit traces per mode)
    disk_engine = GateANNEngine.load(path, store_tier="disk")
    store = disk_engine.record_store
    print(f"# disk io_mode: {store.io_mode}", file=sys.stderr)

    rows = []
    ids_match = True
    unique_ok = True
    syscall_ok = True
    gate_pages = post_pages = None
    for mode in modes:
        kind = None if mode == "unfiltered" else "label"
        params = None if mode == "unfiltered" else np.zeros(nq, np.int32)
        cfg = SearchConfig(mode=mode, search_l=search_l, beam_width=8)
        mem_out = engine.search(queries, filter_kind=kind, filter_params=params,
                                search_config=cfg)
        mem_ids = np.asarray(mem_out.ids)
        for nrec in budgets:
            # budgets are in *records*; the store knows its sector size
            disk = disk_engine.with_cache(nrec * store.sector_bytes)
            before = store.io_counters()
            out = disk.search(queries, filter_kind=kind, filter_params=params,
                              search_config=cfg)
            ids = np.asarray(out.ids)  # materialize => all callbacks ran
            after = store.io_counters()
            d = {k: after[k] - before[k] for k in after}
            measured = d["pages_read"]
            modeled = int(np.sum(np.asarray(out.stats.n_ios))) * store.pages_per_record
            ids_match &= bool(np.array_equal(ids, mem_ids))
            # physical contracts: dedup never reads more than requested;
            # the preadv path spends one vectored syscall per round (per
            # touched segment), the pread fallback one per merged range
            unique_ok &= d["unique_sectors_read"] <= d["records_read"]
            if store.io_mode == "preadv":
                # == read_rounds on this (unsharded) index; a sharded one
                # may spend up to one call per touched segment per round
                syscall_ok &= (
                    d["read_rounds"] <= d["syscalls"]
                    <= d["read_rounds"] * store.n_shards
                )
            elif store.io_mode == "pread":
                syscall_ok &= d["syscalls"] == d["ranges_read"]
            else:  # gather oracle issues no explicit syscalls
                syscall_ok &= d["syscalls"] == 0
            if mode == "gate" and nrec == 0:
                gate_pages = measured
            if mode == "post" and nrec == 0:
                post_pages = measured
            lat = disk.modeled_latency_us(out.stats)
            rows.append(dict(name=f"disk_{mode}_r{nrec}_pages_q", lat1_us=lat,
                             derived=measured / nq))
            rows.append(dict(name=f"disk_{mode}_r{nrec}_model_q", lat1_us=lat,
                             derived=modeled / nq))
            rows.append(dict(name=f"disk_{mode}_r{nrec}_reconciled", lat1_us=0.0,
                             derived=float(measured == modeled)))
            rows.append(dict(name=f"disk_{mode}_r{nrec}_uniq_q", lat1_us=lat,
                             derived=d["unique_sectors_read"] / nq))
            rows.append(dict(name=f"disk_{mode}_r{nrec}_sys_round", lat1_us=0.0,
                             derived=d["syscalls"] / max(d["read_rounds"], 1)))
    rows.append(dict(name="disk_ids_match", lat1_us=0.0, derived=float(ids_match)))
    if gate_pages is not None and post_pages is not None:
        rows.append(dict(name="disk_gate_lt_post", lat1_us=0.0,
                         derived=float(gate_pages < post_pages)))
    rows.append(dict(name="disk_unique_le_ios", lat1_us=0.0,
                     derived=float(unique_ok)))
    rows.append(dict(name="disk_syscall_contract", lat1_us=0.0,
                     derived=float(syscall_ok)))
    reg = obs.default_registry()
    if reg.enabled:
        # telemetry-vs-measured contract, checked BEFORE any
        # reset_io_counters (sweep_pipeline resets per repeat; registry
        # counters are monotonic and would stop matching the store's):
        # every mirrored counter must agree bit-exactly with the store
        c = store.io_counters()
        mirrored = ("records_read", "pages_read", "bytes_read",
                    "unique_sectors_read", "ranges_read", "syscalls",
                    "fetch_rounds", "read_rounds")
        ok = all(reg.family_total(f"disk.{k}") == c[k] for k in mirrored)
        rows.append(dict(name="obs_store_reconciled", lat1_us=0.0,
                         derived=float(ok)))
    return rows


def sweep_pipeline(ctx, *, max_depth=4, search_l=100, repeats=3):
    """Software-pipeline sweep on the cold-cache disk tier.

    For each depth the page cache is dropped before every timed run, so
    each round's ``preadv`` pays a real storage read — exactly the regime
    the submit/drain overlap is built for.  Results must be bit-identical
    to depth 1 (the synchronous loop) and the logical counters must keep
    reconciling exactly; only wall-clock may change.
    """
    engine = ctx["engine"]
    queries = ctx["queries"]
    nq = queries.shape[0]
    path = index_path()
    if not os.path.exists(path):
        engine.save(path)
    disk_engine = GateANNEngine.load(path, store_tier="disk")
    store = disk_engine.record_store
    depths = [d for d in (1, 2, 4, 8, 16) if d <= max_depth]
    if max_depth not in depths:
        depths.append(max_depth)
    kind, params = "label", np.zeros(nq, np.int32)

    rows = []
    walls = {}
    ref_ids = ref_dists = None
    ids_match = recall_match = unique_ok = True
    overlap_seen = True
    for depth in depths:
        cfg = SearchConfig(mode="gate", search_l=search_l, beam_width=8,
                           pipeline_depth=depth)
        run = lambda: disk_engine.search(  # noqa: E731
            queries, filter_kind=kind, filter_params=params,
            search_config=cfg,
        )
        out = run()  # compile + warm the trace before timing
        np.asarray(out.ids)
        best = float("inf")
        for _ in range(repeats):
            store.drop_page_cache()
            store.reset_io_counters()
            t0 = time.perf_counter()
            out = run()
            ids = np.asarray(out.ids)  # materialize => all reads retired
            dists = np.asarray(out.dists)
            best = min(best, time.perf_counter() - t0)
        c = store.io_counters()
        measured = c["pages_read"]
        modeled = int(np.sum(np.asarray(out.stats.n_ios))) * store.pages_per_record
        unique_ok &= c["unique_sectors_read"] <= c["records_read"]
        if depth == 1:
            ref_ids, ref_dists = ids, dists
        else:
            ids_match &= bool(np.array_equal(ids, ref_ids))
            ids_match &= bool(np.array_equal(dists, ref_dists))
            # recall against the synchronous ids as ground truth — equality
            # of the id sets is the nightly "pipelined recall ==
            # synchronous recall" contract (bit-identity implies it; this
            # row keeps the contract explicit even if ordering ever drifts)
            recall_match &= recall_at_k(ids, ref_ids, k=10) == 1.0
            overlap_seen &= c["overlapped_rounds"] > 0
        walls[depth] = best
        wall_q = best * 1e6 / nq
        rows.append(dict(name=f"pipe_gate_d{depth}_wall_q", lat1_us=wall_q,
                         derived=wall_q))
        rows.append(dict(name=f"pipe_gate_d{depth}_reconciled", lat1_us=0.0,
                         derived=float(measured == modeled)))
        print(f"# pipeline depth {depth}: {wall_q:.0f} us/q "
              f"(inflight_max {c['inflight_depth_max']}, "
              f"overlapped {c['overlapped_rounds']})", file=sys.stderr)
    rows.append(dict(name="pipe_ids_match", lat1_us=0.0,
                     derived=float(ids_match)))
    rows.append(dict(name="pipe_recall_match", lat1_us=0.0,
                     derived=float(recall_match)))
    rows.append(dict(name="pipe_unique_le_ios", lat1_us=0.0,
                     derived=float(unique_ok)))
    rows.append(dict(name="pipe_overlap_observed", lat1_us=0.0,
                     derived=float(overlap_seen)))
    for depth in depths[1:]:
        rows.append(dict(name=f"pipe_speedup_d{depth}", lat1_us=0.0,
                         derived=walls[1] / max(walls[depth], 1e-9)))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="gate+post only, budgets (0, 256)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write all rows as a JSON artifact")
    ap.add_argument("--pipeline-depth", type=int, metavar="K", default=0,
                    help="also sweep SearchConfig.pipeline_depth up to K "
                         "on the cold-cache disk tier (0 = skip)")
    ap.add_argument("--obs-json", metavar="PATH", default=None,
                    help="enable telemetry for the sweep and dump the "
                         "registry + span rings as a JSON snapshot")
    args = ap.parse_args()
    if args.obs_json:
        obs.enable()
        obs.trace.enable()
    ctx = common.standard_setup()
    kw = {}
    if args.quick:
        kw = dict(budgets=(0, 256), modes=("gate", "post"))
    rows = sweep_disk(ctx, **kw)
    if args.pipeline_depth > 0:
        rows += sweep_pipeline(ctx, max_depth=args.pipeline_depth)
    reg = obs.default_registry()
    if reg.enabled:
        # cross-reset contract: the registry is monotonic, so the
        # search-side and store-side *registry* totals must agree even
        # though sweep_pipeline reset the store's own counters
        rows.append(dict(
            name="obs_search_reconciled", lat1_us=0.0,
            derived=float(
                reg.family_total("search.ios", tier="disk")
                == reg.family_total("disk.records_read")
            ),
        ))
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['lat1_us']:.1f},{r['derived']:.4f}")
    if args.obs_json:
        obs.export.write_obs_json(common.root_artifact(args.obs_json))
        print(f"# wrote {args.obs_json}", file=sys.stderr)
    if args.json:
        path = common.write_bench_json(args.json, "disk_sweep", rows)
        print(f"# wrote {path}", file=sys.stderr)
    print("# sweep done", file=sys.stderr)


if __name__ == "__main__":
    main()
