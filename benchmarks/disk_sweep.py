"""Disk sweep: *measured* reads and syscalls vs the cost model's ``n_ios``.

Every other benchmark prices slow-tier I/O through the calibrated cost
model.  This one builds the standard engine, persists it to the
page-aligned index format, reloads it with ``store_tier="disk"`` and
compares, per search mode and per cache budget:

  * measured  — ``DiskRecordStore`` counter deltas (the host callback
                counts the sectors the loop requested AND what the
                coalesced reader physically did)
  * modeled   — ``sum(SearchStats.n_ios) * pages_per_record`` (what the
                cost model prices)

Two reconciliation contracts, both enforced nightly:

  * logical (exact): requested pages == modeled pages — cache hits and
    filter-gated nodes never reach the file.
  * physical (coalesced): ``unique_sectors_read <= sum(n_ios)`` (equality
    iff no round fetched the same record for two queries at once), and
    one vectored syscall per search round on the preadv path
    (``syscalls == read_rounds``) or one per merged range on the
    fallback (``syscalls == ranges_read``).

Emits the benchmark-contract CSV ``name,us_per_call,derived``:

  disk_<mode>_r<records>_pages_q    derived = requested pages / query
  disk_<mode>_r<records>_model_q    derived = modeled pages / query
  disk_<mode>_r<records>_reconciled derived = 1.0 iff measured == modeled
  disk_<mode>_r<records>_uniq_q     derived = unique sectors read / query
  disk_<mode>_r<records>_sys_round  derived = syscalls / read round
  disk_ids_match                    derived = 1.0 iff every disk-tier run
                                    returned ids identical to in-memory
  disk_gate_lt_post                 derived = 1.0 iff gate read strictly
                                    fewer pages than post (uncached)
  disk_unique_le_ios                derived = 1.0 iff unique <= requested
                                    sectors held in every cell
  disk_syscall_contract             derived = 1.0 iff the syscall law for
                                    the store's io_mode held in every cell

    PYTHONPATH=src python -m benchmarks.disk_sweep [--quick] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from benchmarks import common
from repro.core import GateANNEngine, SearchConfig

BUDGET_RECORDS = (0, 256, 1024)
MODES = ("gate", "post", "unfiltered")


def index_path(tag: str = "") -> str:
    os.makedirs(common.CACHE_DIR, exist_ok=True)
    return os.path.join(common.CACHE_DIR, f"index_{tag}{common.N}_{common.DIM}.gann")


def sweep_disk(ctx, *, budgets=BUDGET_RECORDS, modes=MODES, search_l=100):
    engine = ctx["engine"]
    queries = ctx["queries"]
    nq = queries.shape[0]
    path = index_path()
    engine.save(path)
    print(f"# saved index: {os.path.getsize(path)} B", file=sys.stderr)

    # one load: all budgets re-wrap the same DiskRecordStore (same file
    # handle, same measured counters, same jit traces per mode)
    disk_engine = GateANNEngine.load(path, store_tier="disk")
    store = disk_engine.record_store
    print(f"# disk io_mode: {store.io_mode}", file=sys.stderr)

    rows = []
    ids_match = True
    unique_ok = True
    syscall_ok = True
    gate_pages = post_pages = None
    for mode in modes:
        kind = None if mode == "unfiltered" else "label"
        params = None if mode == "unfiltered" else np.zeros(nq, np.int32)
        cfg = SearchConfig(mode=mode, search_l=search_l, beam_width=8)
        mem_out = engine.search(queries, filter_kind=kind, filter_params=params,
                                search_config=cfg)
        mem_ids = np.asarray(mem_out.ids)
        for nrec in budgets:
            # budgets are in *records*; the store knows its sector size
            disk = disk_engine.with_cache(nrec * store.sector_bytes)
            before = store.io_counters()
            out = disk.search(queries, filter_kind=kind, filter_params=params,
                              search_config=cfg)
            ids = np.asarray(out.ids)  # materialize => all callbacks ran
            after = store.io_counters()
            d = {k: after[k] - before[k] for k in after}
            measured = d["pages_read"]
            modeled = int(np.sum(np.asarray(out.stats.n_ios))) * store.pages_per_record
            ids_match &= bool(np.array_equal(ids, mem_ids))
            # physical contracts: dedup never reads more than requested;
            # the preadv path spends one vectored syscall per round (per
            # touched segment), the pread fallback one per merged range
            unique_ok &= d["unique_sectors_read"] <= d["records_read"]
            if store.io_mode == "preadv":
                # == read_rounds on this (unsharded) index; a sharded one
                # may spend up to one call per touched segment per round
                syscall_ok &= (
                    d["read_rounds"] <= d["syscalls"]
                    <= d["read_rounds"] * store.n_shards
                )
            elif store.io_mode == "pread":
                syscall_ok &= d["syscalls"] == d["ranges_read"]
            else:  # gather oracle issues no explicit syscalls
                syscall_ok &= d["syscalls"] == 0
            if mode == "gate" and nrec == 0:
                gate_pages = measured
            if mode == "post" and nrec == 0:
                post_pages = measured
            lat = disk.modeled_latency_us(out.stats)
            rows.append(dict(name=f"disk_{mode}_r{nrec}_pages_q", lat1_us=lat,
                             derived=measured / nq))
            rows.append(dict(name=f"disk_{mode}_r{nrec}_model_q", lat1_us=lat,
                             derived=modeled / nq))
            rows.append(dict(name=f"disk_{mode}_r{nrec}_reconciled", lat1_us=0.0,
                             derived=float(measured == modeled)))
            rows.append(dict(name=f"disk_{mode}_r{nrec}_uniq_q", lat1_us=lat,
                             derived=d["unique_sectors_read"] / nq))
            rows.append(dict(name=f"disk_{mode}_r{nrec}_sys_round", lat1_us=0.0,
                             derived=d["syscalls"] / max(d["read_rounds"], 1)))
    rows.append(dict(name="disk_ids_match", lat1_us=0.0, derived=float(ids_match)))
    if gate_pages is not None and post_pages is not None:
        rows.append(dict(name="disk_gate_lt_post", lat1_us=0.0,
                         derived=float(gate_pages < post_pages)))
    rows.append(dict(name="disk_unique_le_ios", lat1_us=0.0,
                     derived=float(unique_ok)))
    rows.append(dict(name="disk_syscall_contract", lat1_us=0.0,
                     derived=float(syscall_ok)))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="gate+post only, budgets (0, 256)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write all rows as a JSON artifact")
    args = ap.parse_args()
    ctx = common.standard_setup()
    kw = {}
    if args.quick:
        kw = dict(budgets=(0, 256), modes=("gate", "post"))
    rows = sweep_disk(ctx, **kw)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['lat1_us']:.1f},{r['derived']:.4f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"benchmark": "disk_sweep", "rows": rows}, f, indent=1)
        print(f"# wrote {args.json}", file=sys.stderr)
    print("# sweep done", file=sys.stderr)


if __name__ == "__main__":
    main()
