"""§Perf hillclimb driver: lower one cell under a named variant, report the
three roofline terms, and append the iteration to results/perf_log.json.

Variants (composable via comma):
  baseline     — exactly what the dry-run sweep ran
  cast_early   — bf16-cast masters at the ZeRO shard before gather
                 (REPRO_CAST_EARLY=1): gathers + grad reduce-scatter in bf16
  donate       — donate the train state / decode caches (in-place updates,
                 no defensive copies)
  remat_dots   — checkpoint policy saving dot outputs (less recompute,
                 more activation memory) (REPRO_REMAT=dots)
  causal_skip  — skip fully-masked KV chunks in flash attention
                 (REPRO_CAUSAL_SKIP=1)
  kv_int8      — int8 KV cache with per-slot scales (REPRO_KV_INT8=1)

Usage:
  PYTHONPATH=src python -m benchmarks.hillclimb --arch deepseek-coder-33b \
      --shape train_4k --variant cast_early,donate
"""
import os
import sys

# must precede any jax import
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import json
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--log", default="results/perf_log.json")
    args = ap.parse_args()

    variants = set(args.variant.split(","))
    os.environ["REPRO_CAST_EARLY"] = "1" if "cast_early" in variants else "0"
    os.environ["REPRO_GRAD_SHARD"] = "1" if "grad_shard" in variants else "0"
    os.environ["REPRO_REMAT"] = "dots" if "remat_dots" in variants else "full"
    os.environ["REPRO_KV_INT8"] = "1" if "kv_int8" in variants else "0"
    os.environ["REPRO_W_INT8"] = "1" if "w_int8" in variants else "0"
    donate = "donate" in variants

    from repro.configs.base import ALL_SHAPES
    from repro.launch.dryrun import lower_cell
    from benchmarks.roofline import (
        HBM_BW, LINK_BW, PEAK_FLOPS, analytic_collective_bytes,
        model_bytes_per_device, model_flops_per_device,
    )

    shape = next(s for s in ALL_SHAPES if s.name == args.shape)
    t0 = time.perf_counter()
    _, compiled, report, hlo = lower_cell(args.arch, shape, donate=donate)
    t_c = report["flops_per_device"] / PEAK_FLOPS
    hlo_m = report["hbm_bytes_per_device"] / HBM_BW
    ana_m = model_bytes_per_device(report, variants) / HBM_BW
    t_m = min(hlo_m, ana_m)
    # collective: HLO parse is f32-normalized on the CPU backend (bf16
    # widened) — report both the parse and the dtype-corrected model
    t_x_hlo = report["collective_bytes_total"] / LINK_BW
    coll_model = analytic_collective_bytes(report, variants)
    # two corrected estimates: (a) analytic structure x logical dtypes,
    # (b) HLO-parsed structure x bf16 correction (CPU f32-normalizes all
    # compute tensors; under cast_early everything big is logically bf16).
    dtype_factor = 0.5 if "cast_early" in variants else 1.0
    t_x_corrected_parse = t_x_hlo * dtype_factor
    t_x = min(coll_model["total"] / LINK_BW, t_x_corrected_parse)
    entry = {
        "arch": args.arch,
        "shape": args.shape,
        "variant": sorted(variants),
        "t_compute_s": t_c,
        "t_memory_s": t_m,
        "t_memory_hlo_s": hlo_m,
        "t_collective_s": t_x,
        "t_collective_hlo_s": t_x_hlo,
        "collective_model_by_kind": {k: v for k, v in coll_model.items()},
        "collective_hlo_by_kind": report["collective_bytes_per_device"],
        "collective_counts": report["collective_counts"],
        "useful_ratio": model_flops_per_device(report) / max(report["flops_per_device"], 1),
        "bound_s": max(t_c, t_m, t_x),
        "wall_s": round(time.perf_counter() - t0, 1),
    }
    print(json.dumps(entry, indent=2))
    os.makedirs(os.path.dirname(args.log), exist_ok=True)
    log = []
    if os.path.exists(args.log):
        with open(args.log) as f:
            log = json.load(f)
    log.append(entry)
    with open(args.log, "w") as f:
        json.dump(log, f, indent=2)


if __name__ == "__main__":
    main()
