"""One benchmark per paper figure/table (see DESIGN.md §7 for the index).

Each ``figNN_*`` function takes the shared setup and returns CSV rows.
All structural metrics (recall, I/O, tunnels) are measured; device-time
columns are io_model-derived (constants from the paper's Table 5).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import SearchConfig, recall_at_k
from repro.core.graph import beam_search_batch, build_filtered_vamana
from repro.core.io_model import DEFAULT_COST_MODEL, GEN5_COST_MODEL, IOCostModel
from repro.data import (
    filtered_ground_truth,
    kmeans_correlated_labels,
    norm_bin_attribute,
    zipf_labels,
)
from repro.data.labels import multilabel_queries, multilabel_tags
from repro.core.filter_store import pack_tags


def fig01_motivation(ctx):
    """Post-filter plateau vs naive pre-filter recall collapse."""
    rows = []
    for mode in ("post", "pre_naive"):
        for r in common.sweep(ctx["engine"], ctx["queries"], ctx["gt"], mode=mode):
            rows.append(dict(name=f"fig01_{mode}_L{r['L']}", lat1_us=r["lat1_us"],
                             derived=r["recall"], qps32=r["qps32"]))
    return rows


def fig05_main(ctx):
    """Main tradeoff curves: DiskANN(sync W=8) / PipeANN(W=32) / GateANN."""
    rows = []
    systems = {
        "diskann": dict(mode="post", beam_width=8, pipe=1),   # sync batch: no overlap
        "pipeann": dict(mode="post", beam_width=8, pipe=32),
        "gateann": dict(mode="gate", beam_width=8, pipe=32),
    }
    for name, s in systems.items():
        for r in common.sweep(ctx["engine"], ctx["queries"], ctx["gt"], mode=s["mode"],
                              beam_width=s["beam_width"]):
            m = IOCostModel(pipeline_depth=s["pipe"])
            lat = m.latency_us(r["ios"], r["tunnels"], r["exact"])
            qps = m.qps(r["ios"], r["tunnels"], n_exact=r["exact"])
            rows.append(dict(name=f"fig05_{name}_L{r['L']}", lat1_us=lat,
                             derived=r["recall"], qps32=qps))
    return rows


def fig06_scaling(ctx):
    """Thread scaling at L=200: gate breaks the ~430K IOPS ceiling."""
    rows = []
    for mode in ("post", "gate"):
        r = common.sweep(ctx["engine"], ctx["queries"], ctx["gt"], mode=mode,
                         l_values=(200,))[0]
        for t in (1, 2, 4, 8, 16, 32):
            qps = DEFAULT_COST_MODEL.qps(r["ios"], r["tunnels"], n_threads=t,
                                         n_exact=r["exact"])
            rows.append(dict(name=f"fig06_{mode}_T{t}", lat1_us=r["lat1_us"],
                             derived=qps))
    return rows


def fig07_io(ctx):
    """Measured I/O reduction vs the 1/s expectation at s = 5/10/20%."""
    rows = []
    # (a) ios vs L — the two curves stay parallel (structural property)
    for mode in ("post", "gate"):
        for r in common.sweep(ctx["engine"], ctx["queries"], ctx["gt"], mode=mode):
            rows.append(dict(name=f"fig07a_{mode}_L{r['L']}", lat1_us=r["lat1_us"],
                             derived=r["ios"]))
    # (b) measured reduction vs expected 1/s at s = 5/10/20%
    labels = ctx["labels"]
    half = (labels == 0) & (np.arange(len(labels)) % 2 == 0)
    configs = {
        5: np.where(half, 0, 1).astype(np.int32),     # class 0 -> ~5%
        10: labels,                                    # 10 uniform classes
        20: (labels // 2).astype(np.int32),            # 5 classes of ~20%
    }
    for s_pct, labs in configs.items():
        eng = (ctx["engine"] if s_pct == 10
               else common.build_engine(ctx["corpus"], ctx["graph"], labels=labs))
        res = {}
        for mode in ("post", "gate"):
            out = eng.search(ctx["queries"], filter_kind="label",
                             filter_params=np.zeros(common.NQ, np.int32),
                             search_config=SearchConfig(mode=mode, search_l=100,
                                                        beam_width=8))
            res[mode] = float(np.mean(np.asarray(out.stats.n_ios)))
        rows.append(dict(name=f"fig07b_s{s_pct}", lat1_us=0.0,
                         derived=res["post"] / max(res["gate"], 1e-9)))
    return rows


def fig08_scale(ctx):
    """N-sweep: the I/O reduction is scale-invariant (paper: 100M -> 1B)."""
    rows = []
    for n in (5_000, 10_000, 20_000):
        corpus, graph = common.cached_graph(n=n, tag="scale")
        labels = common.uniform_labels(n, 10, seed=0)
        queries = common.make_queries(corpus, 32, seed=1)
        eng = common.build_engine(corpus, graph, labels=labels)
        got = {}
        for mode in ("post", "gate"):
            out = eng.search(queries, filter_kind="label",
                             filter_params=np.zeros(32, np.int32),
                             search_config=SearchConfig(mode=mode, search_l=100,
                                                        beam_width=8))
            got[mode] = float(np.mean(np.asarray(out.stats.n_ios)))
        rows.append(dict(name=f"fig08_n{n}", lat1_us=0.0,
                         derived=got["post"] / max(got["gate"], 1e-9)))
    return rows


def fig09_multilabel(ctx):
    """YFCC-style multi-label subset predicates, variable selectivity."""
    import jax.numpy as jnp

    n = len(ctx["labels"])
    tags = multilabel_tags(n, vocab=2048, mean_tags=6.0, seed=0)
    bits = pack_tags(tags, 2048)
    eng = common.build_engine(ctx["corpus"], ctx["graph"], tag_bits=bits)
    qtags = multilabel_queries(tags, common.NQ, n_tags=(1, 2), seed=2)
    qbits = jnp.asarray(pack_tags(qtags, 2048))
    # ground truth per query
    tagsets = [set(t) for t in tags]
    mask = np.stack([
        np.asarray([set(qt) <= ts for ts in tagsets]) for qt in qtags
    ])
    gt = filtered_ground_truth(ctx["corpus"], ctx["queries"], mask, k=10)
    sel = mask.mean()
    rows = []
    for mode in ("post", "gate"):
        for r in common.sweep(eng, ctx["queries"], gt, mode=mode,
                              filter_kind="tags", filter_params=qbits,
                              l_values=(40, 100, 200)):
            rows.append(dict(name=f"fig09_{mode}_L{r['L']}", lat1_us=r["lat1_us"],
                             derived=r["recall"], qps32=r["qps32"]))
    rows.append(dict(name="fig09_mean_selectivity", lat1_us=0.0, derived=sel))
    return rows


def fig10_vamana(ctx):
    """In-memory Vamana (full-precision post-filter) vs GateANN."""
    import jax.numpy as jnp

    rows = []
    labels = ctx["labels"]
    corpus_j = jnp.asarray(ctx["corpus"])
    queries_j = jnp.asarray(ctx["queries"])
    for L in (60, 100, 200):
        res = beam_search_batch(
            ctx["graph"].neighbors, corpus_j, ctx["graph"].medoid, queries_j,
            search_l=L, beam_width=8, max_expand=4 * L,
        )
        ids = np.asarray(res.ids)
        keep = np.where(labels[np.maximum(ids, 0)] == 0, ids, -1)
        rec = recall_at_k(jnp.asarray(keep), ctx["gt"], 10)
        n_exp = float(np.mean(np.asarray(res.n_expanded)))
        # in-memory: exact distance per expansion, no I/O
        lat = n_exp * (DEFAULT_COST_MODEL.exact_dist_us + DEFAULT_COST_MODEL.list_mgmt_us)
        rows.append(dict(name=f"fig10_vamana_L{L}", lat1_us=lat, derived=rec))
    for r in common.sweep(ctx["engine"], ctx["queries"], ctx["gt"], mode="gate",
                          l_values=(60, 100, 200)):
        rows.append(dict(name=f"fig10_gateann_L{r['L']}", lat1_us=r["lat1_us"],
                         derived=r["recall"]))
    return rows


def fig11_fdiskann(ctx):
    """F-DiskANN: label-aware FilteredVamana vs GateANN on the standard graph."""
    fg = build_filtered_vamana(ctx["corpus"], ctx["labels"], degree=common.DEGREE,
                               build_l=common.BUILD_L, batch_size=512)
    import jax.numpy as jnp
    from repro.core.graph import VamanaGraph

    eng_f = common.build_engine(
        ctx["corpus"], VamanaGraph(neighbors=fg.neighbors, medoid=fg.medoid),
        labels=ctx["labels"],
    )
    rows = []
    for r in common.sweep(eng_f, ctx["queries"], ctx["gt"], mode="post",
                          l_values=(60, 100, 200)):
        rows.append(dict(name=f"fig11_fdiskann_L{r['L']}", lat1_us=r["lat1_us"],
                         derived=r["recall"], ios=r["ios"]))
    for r in common.sweep(ctx["engine"], ctx["queries"], ctx["gt"], mode="post",
                          l_values=(60, 100, 200)):
        rows.append(dict(name=f"fig11_diskann_L{r['L']}", lat1_us=r["lat1_us"],
                         derived=r["recall"], ios=r["ios"]))
    for r in common.sweep(ctx["engine"], ctx["queries"], ctx["gt"], mode="gate",
                          l_values=(60, 100, 200)):
        rows.append(dict(name=f"fig11_gateann_L{r['L']}", lat1_us=r["lat1_us"],
                         derived=r["recall"], ios=r["ios"]))
    return rows


def fig12_selectivity(ctx):
    """Gain scales like 1/s (5/10/20%) while post is s-independent."""
    rows = []
    labels = ctx["labels"]
    half = (labels == 0) & (np.arange(len(labels)) % 2 == 0)
    configs = {
        5: np.where(half, 0, 1).astype(np.int32),
        10: labels,
        20: (labels // 2).astype(np.int32),  # merge pairs: 5 classes of ~20%
    }
    for s_pct, labs in configs.items():
        eng = (ctx["engine"] if s_pct == 10
               else common.build_engine(ctx["corpus"], ctx["graph"], labels=labs))
        gt = filtered_ground_truth(ctx["corpus"], ctx["queries"], labs == 0, k=10)
        for mode in ("post", "gate"):
            r = common.sweep(eng, ctx["queries"], gt, mode=mode, l_values=(100,))[0]
            rows.append(dict(name=f"fig12_{mode}_s{s_pct}", lat1_us=r["lat1_us"],
                             derived=r["qps32"], recall=r["recall"]))
    return rows


def fig13_rmax(ctx):
    """DRAM-performance tradeoff: sweep R_max (runtime knob, no rebuild)."""
    rows = []
    for r_max in (4, 8, 16, 32):
        eng = common.build_engine(ctx["corpus"], ctx["graph"], labels=ctx["labels"],
                                  r_max=r_max)
        r = common.sweep(eng, ctx["queries"], ctx["gt"], mode="gate", l_values=(100,))[0]
        dram = eng.neighbor_store.memory_bytes()
        rows.append(dict(name=f"fig13_rmax{r_max}", lat1_us=r["lat1_us"],
                         derived=r["recall"], qps32=r["qps32"], dram_bytes=dram))
    return rows


def fig14_zipf(ctx):
    """Zipf(1.0) labels, queries uniform over classes (mixed selectivity)."""
    labs = zipf_labels(len(ctx["labels"]), 10, alpha=1.0, seed=0)
    eng = common.build_engine(ctx["corpus"], ctx["graph"], labels=labs)
    rng = np.random.default_rng(0)
    targets = rng.integers(0, 10, common.NQ).astype(np.int32)
    mask = labs[None, :] == targets[:, None]
    gt = filtered_ground_truth(ctx["corpus"], ctx["queries"], mask, k=10)
    rows = []
    for mode in ("post", "gate"):
        for r in common.sweep(eng, ctx["queries"], gt, mode=mode,
                              filter_params=targets, l_values=(60, 100, 200)):
            rows.append(dict(name=f"fig14_{mode}_L{r['L']}", lat1_us=r["lat1_us"],
                             derived=r["recall"], qps32=r["qps32"]))
    return rows


def fig15_correlation(ctx):
    """Label–vector correlation alpha in {0, 0.5, 1.0} via k-means labels."""
    rows = []
    for alpha in (0.0, 0.5, 1.0):
        labs = kmeans_correlated_labels(ctx["corpus"], 10, alpha=alpha, seed=0)
        eng = common.build_engine(ctx["corpus"], ctx["graph"], labels=labs)
        gt = filtered_ground_truth(ctx["corpus"], ctx["queries"], labs == 0, k=10)
        for mode in ("post", "gate"):
            r = common.sweep(eng, ctx["queries"], gt, mode=mode, l_values=(150,))[0]
            rows.append(dict(name=f"fig15_{mode}_a{alpha}", lat1_us=r["lat1_us"],
                             derived=r["recall"], ios=r["ios"]))
    return rows


def fig16_range(ctx):
    """Range predicate over L2-norm equal-frequency bins (~10% selectivity)."""
    norms, edges = norm_bin_attribute(ctx["corpus"], 10)
    eng = common.build_engine(ctx["corpus"], ctx["graph"], attributes=norms)
    lo, hi = edges[4], edges[5]
    mask = (norms >= lo) & (norms <= hi)
    gt = filtered_ground_truth(ctx["corpus"], ctx["queries"], mask, k=10)
    b = common.NQ
    fp = (np.full(b, lo, np.float32), np.full(b, hi, np.float32))
    rows = []
    for mode in ("post", "gate"):
        for r in common.sweep(eng, ctx["queries"], gt, mode=mode, filter_kind="range",
                              filter_params=fp, l_values=(60, 100, 200)):
            rows.append(dict(name=f"fig16_{mode}_L{r['L']}", lat1_us=r["lat1_us"],
                             derived=r["recall"], qps32=r["qps32"]))
    return rows


def fig17_pipeline(ctx):
    """W sweep: recall invariant; modeled QPS plateaus by W=8."""
    rows = []
    for w in (1, 2, 4, 8, 16, 32):
        r = common.sweep(ctx["engine"], ctx["queries"], ctx["gt"], mode="gate",
                         beam_width=w, l_values=(100,))[0]
        m = IOCostModel(pipeline_depth=w)
        rows.append(dict(name=f"fig17_W{w}",
                         lat1_us=m.latency_us(r["ios"], r["tunnels"], r["exact"]),
                         derived=r["recall"], qps32=m.qps(r["ios"], r["tunnels"],
                                                          n_exact=r["exact"])))
    return rows


def fig18_ablation(ctx):
    """I/O elimination vs CPU-savings-only (early filter)."""
    rows = []
    for mode, label in (("post", "post"), ("early", "early"), ("gate", "pre")):
        r = common.sweep(ctx["engine"], ctx["queries"], ctx["gt"], mode=mode,
                         l_values=(100,))[0]
        rows.append(dict(name=f"fig18_{label}", lat1_us=r["lat1_us"],
                         derived=r["qps32"], recall=r["recall"]))
    return rows


def table2_memory(ctx):
    """Analytic memory overhead at paper scale (N=100M, 1B)."""
    rows = []
    for n, nm in ((100_000_000, "100m"), (1_000_000_000, "1b")):
        nbr = n * (1 + 16) * 4
        pq = n * 32
        filt = n
        rows.append(dict(name=f"table2_nbr_store_{nm}_gb", lat1_us=0.0,
                         derived=nbr / 1e9))
        rows.append(dict(name=f"table2_pq_{nm}_gb", lat1_us=0.0, derived=pq / 1e9))
        rows.append(dict(name=f"table2_filter_{nm}_gb", lat1_us=0.0,
                         derived=filt / 1e9))
    return rows


def table4_ssd_speed(ctx):
    """Gen4 vs Gen5 SSD: gate is device-speed-independent."""
    rows = []
    for mode in ("post", "gate"):
        r = common.sweep(ctx["engine"], ctx["queries"], ctx["gt"], mode=mode,
                         l_values=(100,))[0]
        g4 = DEFAULT_COST_MODEL.qps(r["ios"], r["tunnels"], n_exact=r["exact"])
        g5 = GEN5_COST_MODEL.qps(r["ios"], r["tunnels"], n_exact=r["exact"])
        rows.append(dict(name=f"table4_{mode}_gen5_over_gen4", lat1_us=0.0,
                         derived=g5 / max(g4, 1e-9)))
    return rows


def table5_breakdown(ctx):
    """Per-query time decomposition (modeled with Table-5 constants)."""
    rows = []
    m = DEFAULT_COST_MODEL
    for mode in ("post", "gate"):
        r = common.sweep(ctx["engine"], ctx["queries"], ctx["gt"], mode=mode,
                         l_values=(100,))[0]
        io_us = np.ceil(r["ios"] / m.pipeline_depth) * m.ssd_read_us \
            + r["ios"] * m.submit_poll_us
        tun_us = r["tunnels"] * m.tunnel_us
        proc_us = r["exact"] * m.exact_dist_us
        other_us = (r["ios"] + r["tunnels"]) * m.list_mgmt_us
        for comp, v in (("io", io_us), ("tunnel", tun_us), ("processing", proc_us),
                        ("other", other_us)):
            rows.append(dict(name=f"table5_{mode}_{comp}_us", lat1_us=v, derived=v))
    return rows


from benchmarks.cache_sweep import fig19_cache_sweep  # noqa: E402 — shares common

ALL_FIGURES = [
    fig01_motivation, fig05_main, fig06_scaling, fig07_io, fig08_scale,
    fig09_multilabel, fig10_vamana, fig11_fdiskann, fig12_selectivity,
    fig13_rmax, fig14_zipf, fig15_correlation, fig16_range, fig17_pipeline,
    fig18_ablation, fig19_cache_sweep, table2_memory, table4_ssd_speed,
    table5_breakdown,
]
