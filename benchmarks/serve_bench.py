"""SLO load generator: closed/open-loop multi-tenant serving benchmark.

The first end-to-end *serving* number in the repo: real concurrent
clients, Zipfian tenant skew, the disk-tier engine behind the async
``ServeFrontend``, and tail latency you can put an SLO on.  The two
loops follow the mlperf-inference convention:

  * **closed loop** — ``--clients`` threads each keep exactly one
    request in flight (submit, wait, repeat).  Measures the server's
    sustainable throughput and the latency under that self-limiting
    load.  Latency = submit -> result, measured by the client.
  * **open loop** — a Poisson arrival process at ``--qps`` submits
    regardless of completions (the "LON" in mlperf terms).  Measures
    tail behaviour under a fixed offered load, where queueing shows up
    in the tail.  Latency = *scheduled arrival* -> result, so scheduler
    lag and admission wait count against the server, not the client.

Tenants are label namespaces (tenant ``i`` -> ``label == i``) drawn
from a Zipf(``--alpha``) popularity distribution — the skew is what
makes per-tenant admission limits and the adaptive cache's per-filter
partitions earn their keep.  Requests run through the pipelined disk
path (``--pipeline-depth``, default 2), so this is also the concurrency
hammer for the submit/drain machinery.

Emits the benchmark-contract CSV ``name,us_per_call,derived`` and (by
default) the ``BENCH_serve.json`` artifact.  Contract rows nightly
asserts on:

  serve_<loop>_p50_ms / p99_ms / p999_ms   latency percentiles (ms)
  serve_<loop>_qps                         achieved completions / s
  serve_open_offered_qps                   the open loop's target rate
  serve_t<i>_ios_q                         per-tenant slow-tier reads /
                                           query (the I/O attribution)
  serve_recall_parity   1.0 iff every served result == the direct
                        ``engine.search`` ids for that (tenant, query)
  serve_reconciled      1.0 iff measured reads == served + padding
                        (``reconcile_drift == 0``) after both loops
  serve_abandoned       abandoned pipelined tokens (0.0 on happy path)
  serve_rejected        admission rejections across both loops

    PYTHONPATH=src python -m benchmarks.serve_bench [--quick]
        [--json PATH] [--obs-json PATH] [--qps F] [--clients N]
        [--requests N] [--tenants N] [--alpha F] [--pipeline-depth K]
        [--soak MINUTES] [--soak-qps F]
        [--fault-eio P] [--fault-policy POLICY]

``--soak MINUTES`` replaces the closed/open pair with a fixed-rate
(deterministic arrivals, not Poisson) open loop that runs for the
given wall time and reports a per-minute p99 series plus a drift row
(last-minute p99 vs first-minute p99) — the latency-stability soak the
nightly chaos lane runs under fault injection.  ``--fault-eio P``
attaches a ``FaultPlan(p_eio=P)`` to the disk tier and
``--fault-policy`` picks the front end's resilience mode
(``fail`` | ``degrade`` | ``retry_then_degrade``); recall parity is
only asserted (and only emitted) when no faults are injected.

``BENCH_serve.json`` is always written (repo-root-anchored, with a
``schema_version`` field); ``--obs-json`` additionally dumps the
process and serve-frontend telemetry registries.
"""
from __future__ import annotations

import argparse
import os
import sys
import threading
import time

import numpy as np

from benchmarks import common
from repro import obs
from repro.core import GateANNEngine, SearchConfig
from repro.serve import AdmissionError, RAGServer, ServeFrontend, TenantSpec
from repro.store import FaultPlan

RECORD = 4096  # one record sector


def index_path() -> str:
    os.makedirs(common.CACHE_DIR, exist_ok=True)
    return os.path.join(
        common.CACHE_DIR, f"index_{common.N}_{common.DIM}.gann"
    )


def zipf_probs(n: int, alpha: float) -> np.ndarray:
    p = (np.arange(1, n + 1, dtype=np.float64)) ** -alpha
    return p / p.sum()


def make_frontend(ctx, *, n_tenants, pipeline_depth, max_inflight=64,
                  fault_eio=0.0, fault_policy="fail", fault_seed=0):
    """Disk-tier engine + adaptive cache behind the async front end."""
    path = index_path()
    if not os.path.exists(path):
        ctx["engine"].save(path)
    faults = None
    if fault_eio > 0.0:
        faults = FaultPlan(seed=fault_seed, p_eio=fault_eio)
    engine = GateANNEngine.load(
        path, store_tier="disk", cache_budget_bytes=512 * RECORD,
        cache_policy="adaptive", refresh_every=4, faults=faults,
    )
    rag = RAGServer(
        engine=engine, cfg=None, params=None, layout=None,
        passage_tokens=np.zeros((common.N, 4), np.int32),
        search_config=SearchConfig(mode="gate", search_l=50, beam_width=8,
                                   pipeline_depth=pipeline_depth),
        bucket_sizes=(8, 16, 32),
    )
    tenants = [
        TenantSpec(f"t{i}", "label", np.int32(i), max_inflight=max_inflight)
        for i in range(n_tenants)
    ]
    srv = ServeFrontend(rag, tenants, max_batch=32, batch_window_s=0.002,
                        fault_policy=fault_policy)
    return engine, rag, srv


def run_closed(srv, queries, schedule, *, n_clients):
    """Each client keeps one request in flight; FIFO over the schedule."""
    lats, served, rejected = [], [], [0]
    lock = threading.Lock()
    cursor = [0]

    def client():
        while True:
            with lock:
                i = cursor[0]
                if i >= len(schedule):
                    return
                cursor[0] += 1
            tenant, qi = schedule[i]
            t0 = time.perf_counter()
            try:
                h = srv.submit(tenant, queries[qi], timeout=30.0)
                ids = h.result(timeout=120.0)
            except AdmissionError:
                with lock:
                    rejected[0] += 1
                continue
            lat = time.perf_counter() - t0
            with lock:
                lats.append(lat)
                served.append((tenant, qi, ids))

    t_start = time.perf_counter()
    threads = [threading.Thread(target=client) for _ in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t_start
    return np.asarray(lats), served, len(lats) / max(wall, 1e-9), rejected[0]


def run_open(srv, queries, schedule, *, qps, seed):
    """Poisson arrivals at ``qps``; latency counts from scheduled arrival."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / qps, size=len(schedule))
    arrivals = np.cumsum(gaps)
    handles, served, rejected = [], [], 0
    t_start = time.perf_counter()
    for (tenant, qi), t_arr in zip(schedule, arrivals):
        now = time.perf_counter() - t_start
        if t_arr > now:
            time.sleep(t_arr - now)
        t_sched = t_start + t_arr
        try:
            h = srv.submit(tenant, queries[qi], timeout=5.0)
        except AdmissionError:
            rejected += 1
            continue
        lag = time.perf_counter() - t_sched  # scheduler + admission wait
        handles.append((tenant, qi, h, lag))
    lats = []
    for tenant, qi, h, lag in handles:
        ids = h.result(timeout=120.0)
        served.append((tenant, qi, ids))
        lats.append(lag + h.trace.total)
    wall = time.perf_counter() - t_start
    return np.asarray(lats), served, len(lats) / max(wall, 1e-9), rejected


def run_soak(srv, queries, schedule, *, qps, minutes, seed):
    """Fixed-rate open loop for ``minutes`` of wall time: arrival i is
    scheduled at exactly ``i / qps`` seconds, latency counts from that
    scheduled instant, and completions are bucketed by arrival minute
    so tail drift over the run is visible as a series, not an average."""
    del seed  # arrivals are deterministic; the schedule carries the mix
    handles, served, rejected = [], [], 0
    horizon = minutes * 60.0
    t_start = time.perf_counter()
    i = 0
    while True:
        t_arr = i / qps
        if t_arr >= horizon:
            break
        tenant, qi = schedule[i % len(schedule)]
        now = time.perf_counter() - t_start
        if t_arr > now:
            time.sleep(t_arr - now)
        t_sched = t_start + t_arr
        try:
            h = srv.submit(tenant, queries[qi], timeout=5.0)
        except AdmissionError:
            rejected += 1
            i += 1
            continue
        lag = time.perf_counter() - t_sched
        handles.append((tenant, qi, h, lag, int(t_arr // 60)))
        i += 1
    lats, minutes_of = [], []
    for tenant, qi, h, lag, minute in handles:
        ids = h.result(timeout=120.0)
        served.append((tenant, qi, ids))
        lats.append(lag + h.trace.total)
        minutes_of.append(minute)
    wall = time.perf_counter() - t_start
    return (np.asarray(lats), np.asarray(minutes_of), served,
            len(lats) / max(wall, 1e-9), rejected)


def soak_rows(lats_s, minutes_of, qps_achieved, offered):
    rows = pctl_rows("soak", lats_s, qps_achieved)
    rows.append(dict(name="serve_soak_offered_qps", lat1_us=0.0,
                     derived=offered))
    p99s = []
    for m in range(int(minutes_of.max()) + 1 if minutes_of.size else 0):
        sel = lats_s[minutes_of == m]
        if sel.size == 0:
            continue
        p99 = float(np.percentile(sel * 1e3, 99))
        p99s.append(p99)
        rows.append(dict(name=f"serve_soak_p99_m{m}_ms", lat1_us=p99 * 1e3,
                         derived=p99))
    # drift: last-minute p99 relative to the first — flat is ~1.0; a
    # leak (queue growth, cache thrash, fd exhaustion) trends upward
    drift = p99s[-1] / max(p99s[0], 1e-9) if len(p99s) >= 2 else 1.0
    rows.append(dict(name="serve_soak_p99_drift", lat1_us=0.0,
                     derived=drift))
    return rows


def check_parity(engine, rag, queries, served) -> float:
    """Served ids vs direct ``engine.search`` for every (tenant, query)."""
    by_tenant: dict = {}
    for tenant, qi, ids in served:
        by_tenant.setdefault(tenant, {}).setdefault(qi, []).append(ids)
    ok = total = 0
    for tenant, qmap in sorted(by_tenant.items()):
        qis = sorted(qmap)
        label = np.full(len(qis), int(tenant[1:]), np.int32)
        out = engine.search(
            queries[qis], filter_kind="label", filter_params=label,
            search_config=rag.search_config,
        )
        direct = np.asarray(out.ids)[:, : rag.search_config.result_k]
        for row, qi in enumerate(qis):
            for ids in qmap[qi]:
                total += 1
                ok += int(np.array_equal(ids, direct[row]))
    return ok / max(total, 1)


def pctl_rows(tag: str, lats_s: np.ndarray, qps: float):
    p50, p99, p999 = np.percentile(lats_s * 1e3, [50, 99, 99.9])
    return [
        dict(name=f"serve_{tag}_p50_ms", lat1_us=p50 * 1e3, derived=p50),
        dict(name=f"serve_{tag}_p99_ms", lat1_us=p99 * 1e3, derived=p99),
        dict(name=f"serve_{tag}_p999_ms", lat1_us=p999 * 1e3, derived=p999),
        dict(name=f"serve_{tag}_qps", lat1_us=0.0, derived=qps),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small request counts (CI smoke)")
    ap.add_argument("--json", metavar="PATH", default="BENCH_serve.json",
                    help="artifact path (always written; relative paths "
                         "anchor at the repo root)")
    ap.add_argument("--obs-json", metavar="PATH", default=None,
                    help="also dump the telemetry registries (process + "
                         "serve sections) as a JSON snapshot")
    ap.add_argument("--qps", type=float, default=40.0,
                    help="open-loop offered load (Poisson arrivals)")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--requests", type=int, default=600,
                    help="requests per loop (closed and open)")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--alpha", type=float, default=1.1,
                    help="Zipf skew across tenants")
    ap.add_argument("--pipeline-depth", type=int, default=2)
    ap.add_argument("--soak", type=float, metavar="MINUTES", default=0.0,
                    help="run a fixed-rate soak for this many minutes "
                         "INSTEAD of the closed/open pair")
    ap.add_argument("--soak-qps", type=float, default=25.0,
                    help="the soak loop's fixed arrival rate")
    ap.add_argument("--fault-eio", type=float, default=0.0,
                    help="per-read-call EIO probability injected into the "
                         "disk tier (chaos lane)")
    ap.add_argument("--fault-policy", default="fail",
                    choices=("fail", "degrade", "retry_then_degrade"),
                    help="front-end resilience mode when faults fire")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    n_requests = 120 if args.quick else args.requests
    if args.obs_json:
        obs.enable()
        obs.trace.enable()

    ctx = common.standard_setup()
    queries = ctx["queries"]
    engine, rag, srv = make_frontend(
        ctx, n_tenants=args.tenants, pipeline_depth=args.pipeline_depth,
        fault_eio=args.fault_eio, fault_policy=args.fault_policy,
        fault_seed=args.seed,
    )
    rng = np.random.default_rng(args.seed)
    probs = zipf_probs(args.tenants, args.alpha)

    def make_schedule(n):
        ts = rng.choice(args.tenants, size=n, p=probs)
        qs = rng.integers(0, queries.shape[0], size=n)
        return [(f"t{t}", int(q)) for t, q in zip(ts, qs)]

    rows = []
    try:
        # warm the jit traces (one burst per bucket size) so compile time
        # lands here, not in the measured tails
        for burst in (8, 16, 32):
            hs = [srv.submit(f"t{i % args.tenants}", queries[i % queries.shape[0]],
                             timeout=30.0) for i in range(burst)]
            for h in hs:
                h.result(timeout=300.0)
        print("# warmup done", file=sys.stderr)

        if args.soak > 0.0:
            n_sched = max(int(args.soak_qps * args.soak * 60) + 1, 1)
            lats_s, minutes_of, served_all, qps_s, rej_total = run_soak(
                srv, queries, make_schedule(n_sched), qps=args.soak_qps,
                minutes=args.soak, seed=args.seed + 1,
            )
            print(f"# soak: {len(lats_s)} reqs over {args.soak:.2f} min, "
                  f"offered {args.soak_qps:.1f} achieved {qps_s:.1f} qps",
                  file=sys.stderr)
            rows += soak_rows(lats_s, minutes_of, qps_s, args.soak_qps)
        else:
            lats_c, served_c, qps_c, rej_c = run_closed(
                srv, queries, make_schedule(n_requests),
                n_clients=args.clients
            )
            print(f"# closed: {len(lats_c)} reqs, {qps_c:.1f} qps",
                  file=sys.stderr)
            rows += pctl_rows("closed", lats_c, qps_c)

            lats_o, served_o, qps_o, rej_o = run_open(
                srv, queries, make_schedule(n_requests), qps=args.qps,
                seed=args.seed + 1,
            )
            print(f"# open: {len(lats_o)} reqs, offered {args.qps:.1f} "
                  f"achieved {qps_o:.1f} qps", file=sys.stderr)
            rows += pctl_rows("open", lats_o, qps_o)
            rows.append(dict(name="serve_open_offered_qps", lat1_us=0.0,
                             derived=args.qps))
            served_all = served_c + served_o
            rej_total = rej_c + rej_o

        # parity vs direct search only holds fault-free: with faults
        # injected, the direct rerun draws its own (different) faults
        parity = (check_parity(engine, rag, queries, served_all)
                  if args.fault_eio == 0.0 else None)
        rep = srv.io_report()
        if args.obs_json:
            payload = obs.export.write_obs_json(
                common.root_artifact(args.obs_json),
                sections={"serve": (srv.metrics, srv.tracer)},
            )
            n_fam = len(payload["serve"]["families"])
            print(f"# wrote {args.obs_json} ({n_fam} serve families)",
                  file=sys.stderr)
    finally:
        srv.close()

    for name in sorted(rep["per_tenant"]):
        ts = rep["per_tenant"][name]
        rows.append(dict(name=f"serve_{name}_ios_q", lat1_us=0.0,
                         derived=ts["ios"] / max(ts["queries"], 1)))
        rows.append(dict(name=f"serve_{name}_share", lat1_us=0.0,
                         derived=ts["queries"] / max(rep["completed"], 1)))
    for span, mean_s in rep["spans_mean_s"].items():
        rows.append(dict(name=f"serve_span_{span}_ms", lat1_us=mean_s * 1e6,
                         derived=mean_s * 1e3))
    if parity is not None:
        rows.append(dict(name="serve_recall_parity", lat1_us=0.0,
                         derived=parity))
    rows.append(dict(name="serve_reconciled", lat1_us=0.0,
                     derived=float(rep.get("reconcile_drift", 0) == 0)))
    rows.append(dict(name="serve_abandoned", lat1_us=0.0,
                     derived=float(rep.get("abandoned_tokens", 0))))
    rows.append(dict(name="serve_rejected", lat1_us=0.0,
                     derived=float(rej_total)))
    if args.fault_eio > 0.0:
        rows.append(dict(name="serve_fault_eio", lat1_us=0.0,
                         derived=args.fault_eio))
        rows.append(dict(name="serve_degraded", lat1_us=0.0,
                         derived=float(rep.get("degraded", 0))))
        rows.append(dict(name="serve_deadline_shed", lat1_us=0.0,
                         derived=float(rep.get("deadline_shed", 0))))
        rows.append(dict(name="serve_failed", lat1_us=0.0,
                         derived=float(rep.get("failed", 0))))
    rows.append(dict(name="serve_mean_batch", lat1_us=0.0,
                     derived=rep["mean_batch_size"]))
    rows.append(dict(name="serve_cache_hit_rate", lat1_us=0.0,
                     derived=rep["cache_hit_rate"]))

    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['lat1_us']:.1f},{r['derived']:.4f}")
    # the JSON artifact is unconditional: nightly uploads BENCH_serve.json
    # from the repo root, so an empty --json falls back to the default
    path = common.write_bench_json(
        args.json or "BENCH_serve.json", "serve_bench", rows
    )
    print(f"# wrote {path}", file=sys.stderr)
    print("# serve bench done", file=sys.stderr)


if __name__ == "__main__":
    main()
