"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` runs a reduced set;
``--figure figNN`` runs one.  Builds are cached under results/bench_cache.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import common
from benchmarks import figures as F


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--figure", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="core figures only (motivation, main, io, ablation)")
    args = ap.parse_args()

    t0 = time.perf_counter()
    print("# building shared setup (cached)", file=sys.stderr)
    ctx = common.standard_setup()
    print(f"# setup ready ({time.perf_counter()-t0:.0f}s)", file=sys.stderr)

    quick_set = {"fig01_motivation", "fig05_main", "fig07_io", "fig18_ablation",
                 "table5_breakdown"}
    print("name,us_per_call,derived")
    for fn in F.ALL_FIGURES:
        if args.figure and not fn.__name__.startswith(args.figure):
            continue
        if args.quick and fn.__name__ not in quick_set:
            continue
        t1 = time.perf_counter()
        try:
            rows = fn(ctx)
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"{fn.__name__}_FAILED,0.0,0.0")
            print(f"# {fn.__name__} failed: {e}", file=sys.stderr)
            import traceback

            traceback.print_exc()
            continue
        for r in rows:
            print(f"{r['name']},{r.get('lat1_us', 0.0):.1f},{r['derived']:.4f}")
        print(f"# {fn.__name__} done ({time.perf_counter()-t1:.0f}s)", file=sys.stderr)


if __name__ == "__main__":
    main()
