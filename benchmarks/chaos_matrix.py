"""Chaos matrix: fault-rate x policy sweep asserting graceful degradation.

The nightly resilience lane.  Each cell loads the cached disk index
with a seeded probabilistic ``FaultPlan`` (EIO on the raw read calls),
runs the full filtered search, and scores recall@10 against the exact
filtered ground truth.  The sweep crosses:

  * ``p_eio``  — 0 (baseline), 0.5%, 1%, 2% per read call
  * policy     — ``degrade`` (no retries) vs ``retry_then_degrade``
                 (3 bounded retries, then degrade)
  * mode       — ``gate`` and ``post`` filtered-search modes
  * depth      — pipeline depth 1 (sync) and 2 (overlapped)

Faults degrade failed read groups to tunneled records (+inf sentinel,
adjacency-sidecar neighbors), so the contract is *graceful decline*,
not parity: recall may drop with fault rate but must do so smoothly
and stay bounded.  Contract rows nightly asserts on:

  chaos_recall_floor    min recall@10 over every faulted cell
  chaos_drop_p1         worst (baseline - faulted) recall drop at 1%
                        EIO — the "no mode loses more than 0.05" gate
  chaos_monotone        1.0 iff recall declines (near-)monotonically in
                        p_eio for every (mode, depth, policy) series
  chaos_no_token_leak   1.0 iff abandoned_tokens == 0 after every cell
  chaos_reconciled      1.0 iff records_read == sum(n_ios) in every
                        cell (requested-records accounting under faults)
  chaos_degraded_total  degraded record slots across the whole matrix
  chaos_serve_ok        1.0 iff the serve hammer under 1% EIO with
                        retry_then_degrade completes every request

    PYTHONPATH=src python -m benchmarks.chaos_matrix [--quick]
        [--json PATH] [--seed N]

Writes ``BENCH_chaos.json`` (repo-root-anchored).  Deterministic for a
fixed ``--seed``: every injector decision is a pure function of
(seed, call index), so a red nightly replays exactly.
"""
from __future__ import annotations

import argparse
import os
import sys

import numpy as np

from benchmarks import common
from repro.core import GateANNEngine, SearchConfig, recall_at_k
from repro.store import FaultPlan

RECORD = 4096

P_EIO = (0.0, 0.005, 0.01, 0.02)
POLICIES = ("degrade", "retry_then_degrade")
MODES = ("gate", "post")
DEPTHS = (1, 2)

# probabilistic faults jitter recall cell-to-cell; "monotone" means no
# big recovery at a higher fault rate, not strict ordering of noise
MONOTONE_TOL = 0.02


def index_path() -> str:
    os.makedirs(common.CACHE_DIR, exist_ok=True)
    return os.path.join(
        common.CACHE_DIR, f"index_{common.N}_{common.DIM}.gann"
    )


def load_cell_engine(path: str, *, p_eio: float, policy: str, seed: int):
    faults = FaultPlan(seed=seed, p_eio=p_eio) if p_eio > 0 else None
    return GateANNEngine.load(
        path, store_tier="disk", faults=faults,
        io_on_error="degrade",
        io_retries=3 if policy == "retry_then_degrade" else 0,
        io_retry_backoff_s=5e-4,
    )


def run_cell(path, queries, gt, *, mode, depth, p_eio, policy, seed,
             search_l=100):
    eng = load_cell_engine(path, p_eio=p_eio, policy=policy, seed=seed)
    store = eng.record_store
    cfg = SearchConfig(mode=mode, search_l=search_l, beam_width=8,
                       pipeline_depth=depth)
    # one search per query, not one batched call: reads for a batch
    # coalesce into a handful of preadv calls, so per-call fault
    # probabilities would barely fire and a single EIO would degrade a
    # whole round for every query at once.  Per-query searches give
    # ~fetch_rounds calls *per query* (the serving-path granularity)
    # and keep each degraded group one query's beam.
    ids = []
    n_ios = n_deg = 0
    for q in np.asarray(queries):
        out = eng.search(q[None, :], filter_kind="label",
                         filter_params=np.zeros(1, np.int32),
                         search_config=cfg)
        ids.append(np.asarray(out.ids)[0])
        # materialize stats before reading counters: the ordered
        # io_callbacks only complete when the stats arrays do
        n_ios += int(np.asarray(out.stats.n_ios).sum())
        n_deg += int(np.asarray(out.stats.n_degraded).sum())
    rec = recall_at_k(np.stack(ids), gt, 10)
    d = store.io_counters()
    f = store.fault_counters()
    cell = dict(
        recall=float(rec), n_ios=n_ios, n_degraded=n_deg,
        records_read=d["records_read"], abandoned=d["abandoned_tokens"],
        degraded_records=d["degraded_records"],
        retried=d["retried_ios"], exhausted=d["retry_exhausted"],
        read_calls=f.get("read_calls", 0), faults=f.get("faults_injected", 0),
    )
    store.close()
    return cell


def serve_hammer(ctx, *, p_eio, seed, n_requests=64):
    """The serving front end under probabilistic faults: every request
    must complete (retry_then_degrade absorbs what retries cannot)."""
    from benchmarks.serve_bench import make_frontend

    queries = ctx["queries"]
    engine, rag, srv = make_frontend(
        ctx, n_tenants=2, pipeline_depth=2,
        fault_eio=p_eio, fault_policy="retry_then_degrade",
        fault_seed=seed,
    )
    try:
        handles = [
            srv.submit(f"t{i % 2}", queries[i % queries.shape[0]],
                       timeout=30.0)
            for i in range(n_requests)
        ]
        results = [h.result(timeout=300.0) for h in handles]
        rep = srv.io_report()
    finally:
        srv.close()
    ok = (all(r is not None for r in results)
          and rep["failed"] == 0
          and rep["completed"] == n_requests
          and rep.get("abandoned_tokens", 0) == 0)
    return float(ok), rep


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small matrix (CI smoke): gate mode, depth 1, "
                         "p in {0, 0.01}")
    ap.add_argument("--json", metavar="PATH", default="BENCH_chaos.json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--search-l", type=int, default=100)
    args = ap.parse_args()

    p_eio = (0.0, 0.01) if args.quick else P_EIO
    modes = ("gate",) if args.quick else MODES
    depths = (1,) if args.quick else DEPTHS

    ctx = common.standard_setup()
    queries, gt = ctx["queries"], ctx["gt"]
    path = index_path()
    if not os.path.exists(path):
        ctx["engine"].save(path)

    rows = []
    series: dict = {}
    no_leak = reconciled = True
    degraded_total = 0
    floor = 1.0
    drop_p1 = 0.0
    for mode in modes:
        for depth in depths:
            for policy in POLICIES:
                baseline = None
                for p in p_eio:
                    cell = run_cell(
                        path, queries, gt, mode=mode, depth=depth,
                        p_eio=p, policy=policy, seed=args.seed,
                        search_l=args.search_l,
                    )
                    tag = (f"chaos_{mode}_d{depth}_{policy}_"
                           f"p{p:g}".replace(".", "_"))
                    rows.append(dict(name=tag, lat1_us=0.0,
                                     derived=cell["recall"]))
                    print(f"# {tag}: recall={cell['recall']:.4f} "
                          f"calls={cell['read_calls']} "
                          f"faults={cell['faults']} "
                          f"degraded={cell['degraded_records']} "
                          f"retried={cell['retried']}", file=sys.stderr)
                    series.setdefault((mode, depth, policy), []).append(
                        (p, cell["recall"]))
                    no_leak &= cell["abandoned"] == 0
                    reconciled &= cell["records_read"] == cell["n_ios"]
                    degraded_total += cell["degraded_records"]
                    if p == 0.0:
                        baseline = cell["recall"]
                    else:
                        floor = min(floor, cell["recall"])
                    if p == 0.01 and baseline is not None:
                        drop_p1 = max(drop_p1, baseline - cell["recall"])

    monotone = True
    for pts in series.values():
        pts = sorted(pts)
        for (p0, r0), (p1, r1) in zip(pts, pts[1:]):
            # a higher fault rate may not *gain* recall beyond noise
            monotone &= r1 <= r0 + MONOTONE_TOL

    serve_ok, rep = serve_hammer(ctx, p_eio=0.01, seed=args.seed + 1,
                                 n_requests=32 if args.quick else 64)
    print(f"# serve hammer: ok={serve_ok} completed={rep['completed']} "
          f"degraded={rep.get('degraded', 0)}", file=sys.stderr)

    rows.append(dict(name="chaos_recall_floor", lat1_us=0.0, derived=floor))
    rows.append(dict(name="chaos_drop_p1", lat1_us=0.0, derived=drop_p1))
    rows.append(dict(name="chaos_monotone", lat1_us=0.0,
                     derived=float(monotone)))
    rows.append(dict(name="chaos_no_token_leak", lat1_us=0.0,
                     derived=float(no_leak)))
    rows.append(dict(name="chaos_reconciled", lat1_us=0.0,
                     derived=float(reconciled)))
    rows.append(dict(name="chaos_degraded_total", lat1_us=0.0,
                     derived=float(degraded_total)))
    rows.append(dict(name="chaos_serve_ok", lat1_us=0.0, derived=serve_ok))

    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['lat1_us']:.1f},{r['derived']:.4f}")
    out = common.write_bench_json(args.json or "BENCH_chaos.json",
                                  "chaos_matrix", rows)
    print(f"# wrote {out}", file=sys.stderr)
    print("# chaos matrix done", file=sys.stderr)


if __name__ == "__main__":
    main()
