"""Roofline analysis from the dry-run artifacts (deliverable (g)).

For every (arch x shape x mesh) cell this derives the three terms:

  compute    = HLO_FLOPs_per_device / peak_FLOPs        (197 TF/s bf16, v5e)
  memory     = HLO_bytes_per_device / HBM_bw            (819 GB/s)
  collective = collective_bytes_per_device / link_bw    (50 GB/s/link, 1 link
                                                         conservative)

HLO_FLOPs / bytes / collective bytes come from the loop-aware parse of the
compiled partitioned HLO (repro.launch.hlo_analysis) — XLA's own
cost_analysis counts while bodies once and is reported alongside as "raw".

Also reported per cell: MODEL_FLOPS (6·N_active·D train / 2·N_active·D
inference), the MODEL_FLOPS/HLO_FLOPs usefulness ratio, the dominant term,
and a one-line action that would move it.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline [--dryrun-dir results/dryrun]
      [--format md|csv]
  PYTHONPATH=src python -m benchmarks.roofline --kernels [--json kernels.json]

``--kernels`` runs the stage-A kernel sweep instead: fused Pallas
traversal round (kernels.fused_traversal) vs the unfused op chain
(best_unexpanded + filter masks + ADC + frontier insert), checked
bitwise against the jnp reference twin and placed against the roofline
(ADC contraction FLOPs vs the VMEM-resident working set).  Emits
``fused_parity`` / ``fused_speedup`` / ``fused_compiled`` contract rows
for the nightly job.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link (conservative single-link)


def _param_split(cfg):
    """(dense_params, routed_expert_params) — EP shards only the latter."""
    total = cfg.param_count()
    if cfg.n_experts == 0:
        return total, 0
    moe_layers = sum(1 for k in cfg.layer_kinds if k == "moe")
    experts = moe_layers * cfg.n_experts * 3 * cfg.d_model * cfg.moe_d_ff
    return total - experts, experts


def model_bytes_per_device(rep: dict, variants: set | None = None) -> float:
    """Analytic HBM-traffic model (fusion-independent cross-check).

    train    — replicated-compute layers: each chip reads full gathered
               bf16 weights ~4x (fwd, remat-fwd, dgrad, wgrad); EP experts
               1/16; optimizer rw at the ZeRO shard; stored activations.
    prefill  — one weight pass + activation stream + emitted KV.
    decode   — TP weight shard (1/16) + this chip's KV-cache slice.
    """
    from repro.configs import get_config
    from repro.configs.base import ALL_SHAPES

    variants = variants or set()
    if rep["arch"].startswith("gateann"):
        return rep.get("hbm_bytes_per_device", 0.0)
    cfg = get_config(rep["arch"])
    shape = next(s for s in ALL_SHAPES if s.name == rep["shape"])
    n_dev = rep["n_devices"]
    tp = 16
    dense_p, expert_p = _param_split(cfg)
    d = cfg.d_model
    # int8 KV: 1 B codes + f32 scale per (slot, kv head) => ~0.53x of bf16
    kv_factor = (1.0 + 4.0 / cfg.head_dim) / 2.0 if "kv_int8" in variants else 1.0
    w_factor = 0.52 if "w_int8" in variants else 1.0  # int8 + per-channel scales

    def cache_bytes_total(batch, length):
        total = 0
        for kind, win in zip(cfg.layer_kinds, cfg.layer_windows):
            if kind in ("attn", "moe"):
                l_eff = min(win, length) if win else length
                total += batch * l_eff * cfg.n_kv_heads * cfg.head_dim * 2 * 2 * kv_factor
            elif kind == "rglru":
                total += batch * (cfg.lru_width or d) * 4
            elif kind in ("mlstm", "slstm"):
                total += batch * 2 * d * max(cfg.head_dim, 1) // 64 * 4
        return total

    if shape.kind == "train":
        b_loc = shape.global_batch / (n_dev / tp)
        t_loc = shape.seq_len / tp
        w = 4 * 2 * (dense_p + expert_p / tp)
        opt = 2 * 12 * cfg.param_count() / n_dev
        act = 40 * b_loc * t_loc * d * 2 * cfg.n_layers
        return w + opt + act
    if shape.kind == "prefill":
        b_loc = shape.global_batch / (n_dev / tp)
        t_loc = shape.seq_len / tp
        w = 2 * (dense_p + expert_p / tp)
        act = 20 * b_loc * t_loc * d * 2 * cfg.n_layers
        kv = cache_bytes_total(shape.global_batch, shape.seq_len) / n_dev
        return w + act + kv
    # decode / long
    w = 2 * (dense_p + expert_p) / tp * w_factor
    kv = cache_bytes_total(shape.global_batch, shape.seq_len) / n_dev
    return w + kv


def analytic_collective_bytes(rep: dict, variants: set | None = None) -> dict:
    """Variant-aware collective model with *logical* dtypes.

    The CPU backend float-normalizes bf16 to f32 before partitioning
    (verified on a micro-case, EXPERIMENTS §Perf), so parsed HLO bytes
    overstate bf16 traffic 2x and cannot show bf16-vs-fp32 deltas.  This
    model reproduces the HLO's op *structure* (which the parse does
    verify: per-layer forward+backward weight gathers, K/V gathers, one
    full-gradient reduction per layer, MoE dispatch/combine) with the
    dtype each tensor logically carries.

    Ring traffic per device: all-gather/reduce-scatter ~ bytes x (g-1)/g;
    all-reduce ~ 2x that.
    """
    from repro.configs import get_config
    from repro.configs.base import ALL_SHAPES

    variants = variants or set()
    if rep["arch"].startswith("gateann"):
        return {"total": rep.get("collective_bytes_total", 0.0)}
    cfg = get_config(rep["arch"])
    shape = next(s for s in ALL_SHAPES if s.name == rep["shape"])
    n_dev = rep["n_devices"]
    tp = 16
    dense_p, expert_p = _param_split(cfg)
    cast_early = "cast_early" in variants
    grad_shard = "grad_shard" in variants
    w_bytes = 2 if cast_early else 4  # gathered compute weights
    g_bytes = 2 if cast_early else 4  # reduced gradients
    ring = lambda b, g: b * (g - 1) / max(g, 1)

    out = {}
    if shape.kind == "train":
        b_loc = shape.global_batch / (n_dev / tp)
        # per-layer weight gathers: fwd + remat-recomputed bwd (2 passes)
        out["ag_params"] = 2 * ring((dense_p + expert_p / tp) * w_bytes, n_dev)
        # K/V all-gather over `model` per attn layer, fwd + bwd recompute
        n_attn = sum(1 for k in cfg.layer_kinds if k in ("attn", "moe"))
        kv = b_loc * shape.seq_len * cfg.n_kv_heads * cfg.head_dim * 2 * 2
        out["ag_kv"] = 2 * ring(kv * n_attn, tp)
        # gradient reduction: all-reduce (2x) vs reduce-scatter (1x).
        # Expert grads are born EP-sharded (verified in HLO: group=16
        # reductions) — they reduce over `data` only at 1/tp size.
        red = ring(dense_p * g_bytes, n_dev) + ring(
            (expert_p / tp) * g_bytes, n_dev // tp)
        out["grad_reduce"] = red if grad_shard else 2 * red
        # MoE dispatch/combine all-to-alls (bf16 tokens), fwd + bwd
        n_moe = sum(1 for k in cfg.layer_kinds if k == "moe")
        if n_moe:
            tok = b_loc * (shape.seq_len / tp) * cfg.d_model * 2
            out["moe_a2a"] = 2 * 2 * 2 * ring(tok * n_moe, tp)
        if rep.get("multi_pod"):
            out["pod_allreduce"] = 2 * ring(cfg.param_count() * g_bytes / (n_dev // 2), 2)
    elif shape.kind == "prefill":
        out["ag_params"] = ring((dense_p + expert_p / tp) * 2, n_dev)
        n_attn = sum(1 for k in cfg.layer_kinds if k in ("attn", "moe"))
        b_loc = shape.global_batch / (n_dev / tp)
        kv = b_loc * shape.seq_len * cfg.n_kv_heads * cfg.head_dim * 2 * 2
        out["ag_kv"] = ring(kv * n_attn, tp)
    else:  # decode: per-layer activation psums (tiny) + distributed softmax
        b_loc = max(shape.global_batch / (n_dev / tp), 1)
        per_layer = b_loc * (cfg.d_model + cfg.n_heads * cfg.head_dim) * 4 * 4
        out["act_psums"] = 2 * per_layer * cfg.n_layers
    out["total"] = sum(v for k, v in out.items())
    return out


def model_flops_per_device(rep: dict) -> float:
    from repro.configs import get_config
    from repro.configs.base import ALL_SHAPES

    if rep["arch"].startswith("gateann"):
        return 0.0
    cfg = get_config(rep["arch"])
    shape = next(s for s in ALL_SHAPES if s.name == rep["shape"])
    n_act = cfg.active_param_count()
    n_dev = rep["n_devices"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens / n_dev
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens / n_dev
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch / n_dev


def suggestion(dom: str, rep: dict) -> str:
    kind = rep.get("layout", "")
    if dom == "collective":
        return "cut gather volume (reshard params/KV; overlap behind layer compute)"
    if dom == "memory":
        if kind in ("decode", "long"):
            return "quantize weights+KV (int8) or raise per-chip batch to amortize weight reads"
        return "reduce remat traffic / fuse optimizer update"
    return "compute-bound: improve MFU (block-causal attention, remat policy)"


def analyze_cell(rep: dict) -> dict:
    t_c = rep["flops_per_device"] / PEAK_FLOPS
    # memory: min(parsed-HLO bytes, analytic model) — the parse is an upper
    # bound because CPU-backend fusion is weaker than TPU's (EXPERIMENTS §R)
    hlo_m = rep.get("hbm_bytes_per_device", 0.0) / HBM_BW
    ana_m = model_bytes_per_device(rep) / HBM_BW
    t_m = min(hlo_m, ana_m) if ana_m else hlo_m
    # dtype-corrected collective model (CPU HLO is f32-normalized); the
    # HLO parse bounds it from above and verifies the op structure.
    t_x_model = analytic_collective_bytes(rep)["total"] / LINK_BW
    t_x_hlo = rep.get("collective_bytes_total", 0.0) / LINK_BW
    t_x = min(t_x_model, t_x_hlo) if t_x_model else t_x_hlo
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    mf = model_flops_per_device(rep)
    bound = max(terms.values())
    return {
        "arch": rep["arch"],
        "shape": rep["shape"],
        "mesh": "x".join(map(str, rep["mesh"])),
        "t_compute_s": t_c,
        "t_memory_s": t_m,
        "t_memory_hlo_s": hlo_m,
        "t_memory_analytic_s": ana_m,
        "t_collective_s": t_x,
        "t_collective_hlo_s": t_x_hlo,
        "bottleneck": dom,
        "model_flops_per_dev": mf,
        "useful_ratio": (mf / rep["flops_per_device"]) if rep["flops_per_device"] else 0.0,
        "roofline_fraction": (t_c / bound) if bound else 0.0,
        "mfu_bound": (mf / PEAK_FLOPS / bound) if bound and mf else 0.0,
        "suggestion": suggestion(dom, rep),
    }


# ---------------------------------------------------------------------------
# --kernels: fused vs unfused stage-A traversal round
# ---------------------------------------------------------------------------


def _kernel_round_state(b, l, w, m, c, k, n, seed=0):
    """Random mid-search round state (frontier + candidate batch)."""
    import jax.numpy as jnp
    import numpy as np

    rng = np.random.default_rng(seed)
    fid = rng.choice(n, size=(b, l), replace=False if l <= n else True).astype(np.int32)
    fid[:, l // 2:] = -1  # half the frontier dead, like a mid-search round
    fd = np.where(fid >= 0, rng.random((b, l)).astype(np.float32) * 8,
                  np.float32(3.4e38))
    fexp = (rng.random((b, l)) < 0.4) & (fid >= 0)
    fpass = rng.random((b, l)) < 0.6
    nid = rng.integers(-1, n, size=(b, m)).astype(np.int32)
    ncodes = rng.integers(0, k, size=(b, m, c)).astype(np.int32)
    npass = rng.random((b, m)) < 0.6
    lut = (rng.normal(size=(b, c, k)).astype(np.float32)) ** 2
    entry = fid[:, 0].copy()
    return tuple(
        jnp.asarray(x)
        for x in (fid, fd, fexp, fpass, nid, ncodes, npass, lut, entry)
    )


def _unfused_stage(state, width):
    """The op-chain stage A the kernel fuses: ADC reference + dedup/insert
    (stable argsort) + best-unexpanded select + mode masks — i.e. the jnp
    reference twin, which is exactly the unfused building blocks."""
    from repro.kernels import ref as kref

    return kref.fused_traversal_round_ref(*state, mode="gate", width=width)


def kernels_sweep(args) -> int:
    import jax
    import numpy as np

    from repro.kernels import fused_traversal as ft
    from repro.kernels.backend import supports_compiled_pallas

    b, l, w = args.batch, args.search_l, args.beam
    r, r_max = args.degree, args.r_max
    c, k = args.pq_chunks, args.pq_k
    m = w * (r + r_max)
    n = 100_000
    state = _kernel_round_state(b, l, w, m, c, k, n)
    compiled = supports_compiled_pallas()

    fused = lambda: ft.fused_traversal_round(*state, mode="gate", width=w)
    unfused = jax.jit(lambda s: _unfused_stage(s, w))

    # parity: every output field of the fused kernel bitwise-equal to the
    # jnp reference twin (= the unfused op chain)
    got, want = fused(), unfused(state)
    parity = all(
        np.array_equal(np.asarray(getattr(got, f)), np.asarray(getattr(want, f)))
        for f in got._fields
    )

    def bench(fn):
        fn()[0].block_until_ready()  # compile
        t0 = time.perf_counter()
        for _ in range(args.repeats):
            out = fn()
        jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
        return (time.perf_counter() - t0) / args.repeats

    t_fused = bench(fused)
    t_unfused = bench(lambda: unfused(state))
    speedup = t_unfused / t_fused if t_fused > 0 else 0.0

    # roofline placement: ADC one-hot contraction dominates FLOPs
    # (B·C·M·K MACs); the working set is the VMEM-resident round state
    flops = 2.0 * b * c * m * k
    bytes_rt = 4.0 * b * (
        l * 4 + m * (2 + c) + c * k  # frontier + candidates/codes + lut
    )
    t_c, t_m = flops / PEAK_FLOPS, bytes_rt / HBM_BW
    rows = [
        {"name": "fused_parity", "derived": 1.0 if parity else 0.0},
        {"name": "fused_speedup", "derived": speedup},
        {"name": "fused_compiled", "derived": 1.0 if compiled else 0.0},
        {"name": "fused_us", "derived": t_fused * 1e6},
        {"name": "unfused_us", "derived": t_unfused * 1e6},
        {"name": "stage_flops", "derived": flops},
        {"name": "stage_bytes", "derived": bytes_rt},
        {"name": "stage_intensity", "derived": flops / bytes_rt},
        {"name": "stage_roofline_bound_us",
         "derived": max(t_c, t_m) * 1e6},
    ]
    print("| metric | value |")
    print("|---|---|")
    for row in rows:
        print(f"| {row['name']} | {row['derived']:.6g} |")
    print(
        f"# shapes: B={b} L={l} W={w} M={m} C={c} K={k} "
        f"backend={jax.default_backend()} "
        f"mode={'compiled' if compiled else 'interpret'}"
    )
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows, "shape": {
                "b": b, "l": l, "w": w, "m": m, "c": c, "k": k,
                "backend": jax.default_backend(),
            }}, f, indent=1)
    return 0 if parity else 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--format", default="md", choices=["md", "csv"])
    ap.add_argument("--mesh", default="16x16", help="16x16 | 2x16x16 | all")
    ap.add_argument("--kernels", action="store_true",
                    help="run the fused-vs-unfused stage-A kernel sweep")
    ap.add_argument("--json", default="",
                    help="(--kernels) write contract rows to this JSON file")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--search-l", type=int, default=64)
    ap.add_argument("--beam", type=int, default=8)
    ap.add_argument("--degree", type=int, default=32)
    ap.add_argument("--r-max", type=int, default=16)
    ap.add_argument("--pq-chunks", type=int, default=8)
    ap.add_argument("--pq-k", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=20)
    args = ap.parse_args()

    if args.kernels:
        sys.exit(kernels_sweep(args))

    rows = []
    for path in sorted(glob.glob(os.path.join(args.dryrun_dir, "*.json"))):
        with open(path) as f:
            rep = json.load(f)
        mesh = "x".join(map(str, rep["mesh"]))
        if args.mesh != "all" and mesh != args.mesh:
            continue
        rows.append(analyze_cell(rep))

    if args.format == "csv":
        cols = ["arch", "shape", "mesh", "t_compute_s", "t_memory_s",
                "t_collective_s", "bottleneck", "useful_ratio",
                "roofline_fraction", "mfu_bound"]
        print(",".join(cols))
        for r in rows:
            print(",".join(
                f"{r[c]:.4g}" if isinstance(r[c], float) else str(r[c]) for c in cols
            ))
        return

    print("| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
          "| bottleneck | useful | roofline frac | MFU bound | next move |")
    print("|---|---|---|---|---|---|---|---|---|---|---|"[: -4] + "|")
    for r in rows:
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3f} | {r['t_memory_s']:.3f} "
            f"| {r['t_collective_s']:.3f} | **{r['bottleneck']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['mfu_bound']:.2f} | {r['suggestion']} |"
        )


if __name__ == "__main__":
    main()
