"""Diagnose graph quality: unfiltered + filtered recall vs L."""
import sys, time
import numpy as np

sys.path.insert(0, "src")
from repro.core import EngineConfig, GateANNEngine, SearchConfig, recall_at_k
from repro.data import make_bigann_like, make_queries, uniform_labels, filtered_ground_truth

N, D, B = 5000, 32, 32
corpus = make_bigann_like(N, D, seed=0)
labels = uniform_labels(N, 10, seed=0)
queries = make_queries(corpus, B, seed=1)

t0 = time.perf_counter()
eng = GateANNEngine.build(
    corpus, config=EngineConfig(degree=32, build_l=64, pq_chunks=8, r_max=16), labels=labels
)
print(f"build: {time.perf_counter()-t0:.1f}s")

gt_all = filtered_ground_truth(corpus, queries, np.ones(N, bool), k=10)
gt_f = filtered_ground_truth(corpus, queries, np.asarray(labels) == 0, k=10)
tgt = np.zeros(B, dtype=np.int32)

for L in [16, 32, 64, 128]:
    out_u = eng.search(queries, search_config=SearchConfig(mode="unfiltered", search_l=L, result_k=10, beam_width=4))
    r_u = recall_at_k(out_u.ids, gt_all, 10)
    out_g = eng.search(queries, filter_kind="label", filter_params=tgt,
                       search_config=SearchConfig(mode="gate", search_l=L, result_k=10, beam_width=4))
    r_g = recall_at_k(out_g.ids, gt_f, 10)
    out_p = eng.search(queries, filter_kind="label", filter_params=tgt,
                       search_config=SearchConfig(mode="post", search_l=L, result_k=10, beam_width=4))
    r_p = recall_at_k(out_p.ids, gt_f, 10)
    print(
        f"L={L:4d} unfilt={r_u:.3f} (ios {float(np.mean(out_u.stats.n_ios)):5.1f}) | "
        f"gate={r_g:.3f} (ios {float(np.mean(out_g.stats.n_ios)):5.1f}, tun {float(np.mean(out_g.stats.n_tunnels)):6.1f}) | "
        f"post={r_p:.3f} (ios {float(np.mean(out_p.stats.n_ios)):5.1f})"
    )
