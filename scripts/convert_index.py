"""convert_index — build, inspect, and verify persistent GateANN indexes.

    # build an index file from an .npy corpus (+ optional labels/attributes)
    PYTHONPATH=src python scripts/convert_index.py build \
        --corpus corpus.npy [--labels labels.npy] [--attributes attrs.npy] \
        --out index.gann [--degree 32] [--build-l 64] [--pq-chunks 16]

    # print the header: version, geometry, section table, shard manifest
    PYTHONPATH=src python scripts/convert_index.py inspect --index index.gann

    # load the index disk-tier, run a search smoke, reconcile measured I/O
    PYTHONPATH=src python scripts/convert_index.py verify --index index.gann

    # split the record sectors into one segment file per model-axis shard
    PYTHONPATH=src python scripts/convert_index.py shard \
        --index index.gann --out sharded.gann --shards 4

    # fold a sharded index back into a monolithic records section
    PYTHONPATH=src python scripts/convert_index.py merge \
        --index sharded.gann --out merged.gann
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def cmd_build(args) -> int:
    from repro.core import EngineConfig, GateANNEngine

    corpus = np.load(args.corpus).astype(np.float32)
    labels = np.load(args.labels) if args.labels else None
    attributes = np.load(args.attributes) if args.attributes else None
    print(f"building index: n={corpus.shape[0]} dim={corpus.shape[1]} "
          f"degree={args.degree}", file=sys.stderr)
    engine = GateANNEngine.build(
        corpus,
        config=EngineConfig(degree=args.degree, build_l=args.build_l,
                            pq_chunks=args.pq_chunks, r_max=args.r_max,
                            seed=args.seed),
        labels=labels,
        attributes=attributes,
    )
    engine.save(args.out)
    print(f"wrote {args.out}: {os.path.getsize(args.out)} B", file=sys.stderr)
    return cmd_inspect(argparse.Namespace(index=args.out))


def cmd_inspect(args) -> int:
    from repro.store import read_header

    print(read_header(args.index).describe())
    return 0


def _rewrite(index: str, out: str, shards: int) -> int:
    """Re-shard an existing index: same records/graph/PQ/filters/config,
    different record-segment layout (1 == monolithic)."""
    from repro.store import read_index, write_index

    idx = read_index(index)
    h = idx.header
    print(f"rewriting {index} ({h.n_shards} shard(s)) -> {out} "
          f"({shards} shard(s))", file=sys.stderr)
    write_index(
        out,
        vectors=idx.vectors(),
        neighbors=idx.neighbors(),
        pq_books=idx.pq_books(),
        pq_codes=idx.pq_codes(),
        medoid=h.medoid,
        config=h.config,
        filters={k: idx.filter_array(k) for k in idx.filter_kinds()},
        shards=shards,
    )
    return cmd_inspect(argparse.Namespace(index=out))


def cmd_shard(args) -> int:
    if args.shards < 2:
        print("shard: --shards must be >= 2 (use merge for 1)", file=sys.stderr)
        return 2
    return _rewrite(args.index, args.out, args.shards)


def cmd_merge(args) -> int:
    return _rewrite(args.index, args.out, 1)


def cmd_verify(args) -> int:
    """Disk-tier load + search smoke: ids must match the in-memory load
    and measured page reads must reconcile with ``SearchStats.n_ios``."""
    from repro.core import GateANNEngine, SearchConfig

    mem = GateANNEngine.load(args.index)
    disk = GateANNEngine.load(args.index, store_tier="disk")
    store = disk.record_store
    rng = np.random.default_rng(0)
    picks = rng.integers(0, mem.vectors.shape[0], size=args.nq)
    queries = np.asarray(mem.vectors)[picks] + rng.normal(
        0.0, 0.05, size=(args.nq, mem.vectors.shape[1])
    ).astype(np.float32)
    kind = "label" if "label" in disk.filters else None
    params = np.zeros(args.nq, np.int32) if kind else None
    ok = True
    for mode in ("gate", "post") if kind else ("unfiltered",):
        cfg = SearchConfig(mode=mode, search_l=args.search_l, beam_width=4)
        before = store.io_counters()
        out_d = disk.search(queries, filter_kind=kind, filter_params=params,
                            search_config=cfg)
        ids_d = np.asarray(out_d.ids)  # materialize => callbacks done
        after = store.io_counters()
        d = {k: after[k] - before[k] for k in after}
        measured = d["pages_read"]
        modeled = int(np.sum(np.asarray(out_d.stats.n_ios))) * store.pages_per_record
        out_m = mem.search(queries, filter_kind=kind, filter_params=params,
                           search_config=cfg)
        same = bool(np.array_equal(ids_d, np.asarray(out_m.ids)))
        reconciled = measured == modeled
        coalesced = d["unique_sectors_read"] <= d["records_read"]
        ok &= same and reconciled and coalesced
        print(f"{mode:10s} ids_match={same} pages_read={measured} "
              f"modeled={modeled} reconciled={reconciled} "
              f"unique={d['unique_sectors_read']} syscalls={d['syscalls']} "
              f"rounds={d['read_rounds']} [{store.io_mode}, "
              f"{store.n_shards} shard(s)]")
    print("verify:", "OK" if ok else "FAILED")
    return 0 if ok else 1


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    b = sub.add_parser("build", help="build + save an index from .npy arrays")
    b.add_argument("--corpus", required=True, help="(N, D) float .npy")
    b.add_argument("--labels", default=None, help="(N,) int .npy (equality filter)")
    b.add_argument("--attributes", default=None, help="(N,) float .npy (range filter)")
    b.add_argument("--out", required=True)
    b.add_argument("--degree", type=int, default=32)
    b.add_argument("--build-l", type=int, default=64)
    b.add_argument("--pq-chunks", type=int, default=16)
    b.add_argument("--r-max", type=int, default=16)
    b.add_argument("--seed", type=int, default=0)
    b.set_defaults(fn=cmd_build)

    i = sub.add_parser("inspect", help="print the index header")
    i.add_argument("--index", required=True)
    i.set_defaults(fn=cmd_inspect)

    v = sub.add_parser("verify", help="disk-tier search smoke + I/O reconcile")
    v.add_argument("--index", required=True)
    v.add_argument("--nq", type=int, default=8)
    v.add_argument("--search-l", type=int, default=48)
    v.set_defaults(fn=cmd_verify)

    s = sub.add_parser("shard", help="split records into per-shard segments")
    s.add_argument("--index", required=True)
    s.add_argument("--out", required=True)
    s.add_argument("--shards", type=int, required=True)
    s.set_defaults(fn=cmd_shard)

    m = sub.add_parser("merge", help="fold segments back into one records section")
    m.add_argument("--index", required=True)
    m.add_argument("--out", required=True)
    m.set_defaults(fn=cmd_merge)

    args = ap.parse_args()
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
