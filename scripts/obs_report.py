"""Render an ``--obs-json`` telemetry snapshot as a human-readable report.

Input is the artifact ``obs.export.write_obs_json`` produces (what
``disk_sweep --obs-json`` / ``serve_bench --obs-json`` write): one
section per registry (``process``, plus e.g. ``serve`` for the front
end's private registry).  For each section this renders:

  * the span table — count / total / mean / p50 / p99 / p99.9 per
    ``trace.span_seconds`` child (the I/O-path stage timings)
  * the per-query latency breakdown — traversal vs submit vs drain-wait
    vs preadv, each as us/query over ``search.queries``.  The preadv
    stage runs on reader-pool threads and *overlaps* traversal under
    the pipelined path, so the rows are attributed thread time, not a
    disjoint partition of wall-clock; "traversal+kernel" is the
    residual of ``engine.search`` minus the dispatcher-thread stages.
  * I/O counters — every ``disk.*`` family total
  * the per-mode search split — fetched (slow reads + cache hits) vs
    tunneled, the paper's headline ratio, from the ``search.*`` families

``--prom`` instead re-renders the snapshot as Prometheus exposition
text, byte-identical to a live scrape of the same registry state (the
nightly ``obs-contracts`` job diffs a counter through both paths).

    python scripts/obs_report.py OBS.json [--section NAME] [--prom]
"""
from __future__ import annotations

import argparse
import json
import sys

sys.path.insert(0, "src")


def _fmt_s(v: float) -> str:
    """Seconds, scaled to a readable unit."""
    if v >= 1.0:
        return f"{v:8.2f} s"
    if v >= 1e-3:
        return f"{v * 1e3:8.2f} ms"
    return f"{v * 1e6:8.1f} us"


def _labels(ch: dict) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(ch["labels"].items()))


def _counter_total(fams: dict, name: str, **match) -> float:
    fam = fams.get(name)
    if not fam:
        return 0.0
    total = 0.0
    for ch in fam["children"]:
        if any(ch["labels"].get(k) != v for k, v in match.items()):
            continue
        total += ch["value"]
    return total


def render_spans(fams: dict, out) -> dict:
    """Span table; returns {span_name: total_seconds} for the breakdown."""
    fam = fams.get("trace.span_seconds")
    totals = {}
    if not fam or not fam["children"]:
        return totals
    print("  spans (trace.span_seconds):", file=out)
    print(f"    {'span':24s} {'count':>8s} {'total':>11s} {'mean':>11s}"
          f" {'p50':>11s} {'p99':>11s} {'p99.9':>11s}", file=out)
    for ch in fam["children"]:
        name = ch["labels"].get("span", _labels(ch))
        totals[name] = ch["sum"]
        mean = ch["sum"] / max(ch["count"], 1)
        print(f"    {name:24s} {ch['count']:8d} {_fmt_s(ch['sum']):>11s}"
              f" {_fmt_s(mean):>11s} {_fmt_s(ch['p50']):>11s}"
              f" {_fmt_s(ch['p99']):>11s} {_fmt_s(ch['p999']):>11s}",
              file=out)
    return totals


def render_breakdown(fams: dict, span_totals: dict, out) -> None:
    queries = _counter_total(fams, "search.queries")
    if not queries or "engine.search" not in span_totals:
        return
    submit = span_totals.get("disk.submit", 0.0)
    drain = span_totals.get("disk.drain_wait", 0.0)
    preadv = span_totals.get("disk.preadv", 0.0)
    search = span_totals["engine.search"]
    # preadv runs on reader threads (overlapping traversal when
    # pipelined), so the residual subtracts only dispatcher-thread time
    traversal = max(search - submit - drain, 0.0)
    print(f"  per-query breakdown ({int(queries)} queries):", file=out)
    rows = [("traversal+kernel", traversal), ("disk.submit", submit),
            ("disk.drain_wait", drain), ("disk.preadv (readers)", preadv)]
    for name, tot in rows:
        print(f"    {name:24s} {_fmt_s(tot / queries):>11s}/q"
              f"   total {_fmt_s(tot)}", file=out)


def render_io(fams: dict, out) -> None:
    disk = sorted(n for n in fams if n.startswith("disk."))
    if not disk:
        return
    print("  I/O counters:", file=out)
    for name in disk:
        fam = fams[name]
        if fam["kind"] == "gauge":
            v = sum(ch["value"] for ch in fam["children"])
            print(f"    {name:28s} {v:>14.0f}  (gauge)", file=out)
        else:
            print(f"    {name:28s} {fam['total']:>14.0f}", file=out)


def render_split(fams: dict, out) -> None:
    fam = fams.get("search.queries")
    if not fam:
        return
    modes = sorted({ch["labels"].get("mode", "?") for ch in fam["children"]})
    print("  per-mode search split (fetched vs tunneled):", file=out)
    print(f"    {'mode':12s} {'queries':>8s} {'slow_reads':>11s}"
          f" {'cache_hits':>11s} {'fetched':>9s} {'tunneled':>9s}"
          f" {'hit_rate':>9s}", file=out)
    for mode in modes:
        q = _counter_total(fams, "search.queries", mode=mode)
        ios = _counter_total(fams, "search.ios", mode=mode)
        hits = _counter_total(fams, "search.cache_hits", mode=mode)
        tun = _counter_total(fams, "search.tunnels", mode=mode)
        fetched = ios + hits
        print(f"    {mode:12s} {int(q):8d} {int(ios):11d} {int(hits):11d}"
              f" {int(fetched):9d} {int(tun):9d}"
              f" {hits / max(fetched, 1):9.3f}", file=out)


def render_section(name: str, doc: dict, out) -> None:
    fams = doc.get("families", {})
    print(f"== section {name!r} (enabled={doc.get('enabled')},"
          f" {len(fams)} families) ==", file=out)
    span_totals = render_spans(fams, out)
    render_breakdown(fams, span_totals, out)
    render_io(fams, out)
    render_split(fams, out)
    print(file=out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", help="obs JSON artifact (write_obs_json output)")
    ap.add_argument("--section", default=None,
                    help="render only this section (default: all)")
    ap.add_argument("--prom", action="store_true",
                    help="emit Prometheus text instead of the report")
    args = ap.parse_args()
    with open(args.path) as f:
        payload = json.load(f)
    sections = {
        k: v for k, v in payload.items()
        if isinstance(v, dict) and "families" in v
    }
    if args.section:
        if args.section not in sections:
            sys.exit(f"no section {args.section!r}; have {sorted(sections)}")
        sections = {args.section: sections[args.section]}
    if args.prom:
        from repro.obs import export

        for name, doc in sections.items():
            sys.stdout.write(export.to_prometheus(doc))
        return
    for name, doc in sections.items():
        render_section(name, doc, sys.stdout)


if __name__ == "__main__":
    main()
