#!/usr/bin/env python
"""gatelint — run the repo's static analysis rules over a tree.

Usage:
    python scripts/gatelint.py src/                      # lint, exit 1 on findings
    python scripts/gatelint.py src/ tests/ --json        # machine-readable output
    python scripts/gatelint.py src/ --baseline analysis_baseline.json
    python scripts/gatelint.py --explain token-leak      # rule rationale
    python scripts/gatelint.py --list-rules

Pure AST — no jax/numpy import, suitable for a <30 s CI gate.
Exit codes: 0 clean, 1 unsuppressed findings, 2 usage error.
"""
import argparse
import json
import os
import sys
import textwrap

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
)

from repro.analysis import core  # noqa: E402


def _explain(rule_id: str) -> int:
    rule = core.RULES.get(rule_id)
    if rule is None:
        print(f"unknown rule: {rule_id}", file=sys.stderr)
        print("known rules: " + ", ".join(sorted(core.RULES)), file=sys.stderr)
        return 2
    print(f"{rule.id}  [{rule.family}]")
    print(f"  {rule.summary}\n")
    print(textwrap.fill(rule.rationale, width=78,
                        initial_indent="  ", subsequent_indent="  "))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="gatelint",
        description="project-specific static analysis: lock discipline, "
                    "trace hygiene, timing policy, I/O-token lifecycle",
    )
    ap.add_argument("paths", nargs="*", help="files or directories to lint")
    ap.add_argument("--baseline", metavar="FILE",
                    help="findings baseline (analysis_baseline.json)")
    ap.add_argument("--json", action="store_true", dest="json_out",
                    help="emit findings as JSON on stdout")
    ap.add_argument("--explain", metavar="RULE",
                    help="print the rationale for one rule and exit")
    ap.add_argument("--list-rules", action="store_true",
                    help="list rule ids and summaries and exit")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print suppressed/baselined findings")
    args = ap.parse_args(argv)

    if args.explain:
        return _explain(args.explain)
    if args.list_rules:
        for rule in sorted(core.RULES.values(), key=lambda r: r.id):
            print(f"{rule.id:28s} [{rule.family}] {rule.summary}")
        return 0
    if not args.paths:
        ap.print_usage(sys.stderr)
        print("gatelint: error: no paths given", file=sys.stderr)
        return 2

    findings = core.lint_paths(args.paths)
    if args.baseline:
        core.apply_baseline(findings, core.load_baseline(args.baseline))

    live = [f for f in findings if not f.suppressed and not f.baselined]
    if args.json_out:
        doc = {
            "findings": [f.to_json() for f in
                         (findings if args.show_suppressed else live)],
            "summary": core.summarize(findings),
        }
        print(json.dumps(doc, indent=2))
    else:
        shown = findings if args.show_suppressed else live
        for f in shown:
            tag = ""
            if f.suppressed:
                tag = f"  [suppressed: {f.suppress_reason or 'NO REASON'}]"
            elif f.baselined:
                tag = "  [baselined]"
            print(f.render() + tag)
        s = core.summarize(findings)
        print(f"gatelint: {s['live']} finding(s) "
              f"({s['suppressed']} suppressed, {s['baselined']} baselined) "
              f"across {len(args.paths)} path(s)")
    return 1 if live else 0


if __name__ == "__main__":
    sys.exit(main())
