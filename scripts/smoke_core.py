"""Fast end-to-end smoke of the GateANN core on a tiny corpus."""
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.core import EngineConfig, GateANNEngine, SearchConfig, recall_at_k
from repro.data import make_bigann_like, make_queries, uniform_labels, filtered_ground_truth

t0 = time.perf_counter()
N, D, B = 3000, 32, 16
corpus = make_bigann_like(N, D, seed=0)
labels = uniform_labels(N, 10, seed=0)
queries = make_queries(corpus, B, seed=1)
print(f"data: {time.perf_counter()-t0:.1f}s")

t0 = time.perf_counter()
eng = GateANNEngine.build(
    corpus,
    config=EngineConfig(degree=24, build_l=48, pq_chunks=8, r_max=12),
    labels=labels,
)
print(f"build: {time.perf_counter()-t0:.1f}s; mem={eng.memory_report()}")

target = np.zeros(B, dtype=np.int32)  # filter to label 0 (~10% selectivity)
gt = filtered_ground_truth(corpus, queries, np.asarray(labels) == 0, k=10)

for mode in ["gate", "post", "early", "pre_naive"]:
    t0 = time.perf_counter()
    out = eng.search(
        queries,
        filter_kind="label",
        filter_params=target,
        search_config=SearchConfig(mode=mode, search_l=48, beam_width=4),
    )
    r = recall_at_k(out.ids, gt, 10)
    ios = float(np.mean(np.asarray(out.stats.n_ios)))
    tun = float(np.mean(np.asarray(out.stats.n_tunnels)))
    hops = float(np.mean(np.asarray(out.stats.n_hops)))
    print(
        f"{mode:10s} recall@10={r:.3f} ios/q={ios:6.1f} tunnels/q={tun:6.1f} "
        f"hops={hops:5.1f} wall={time.perf_counter()-t0:.1f}s qps32={eng.modeled_qps(out.stats):.0f}"
    )
