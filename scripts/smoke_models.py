"""Per-arch smoke: reduced config, train loss + one decode step, no NaNs."""
import sys, time, traceback

sys.path.insert(0, "src")
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.distributed.sharding import NULL_LAYOUT
from repro.models import transformer as tfm
from repro.models import zoo
from repro.configs.base import ShapeConfig

ok = True
for arch in ARCH_IDS:
    t0 = time.perf_counter()
    try:
        cfg = get_smoke_config(arch)
        import dataclasses
        cfg = dataclasses.replace(cfg, dtype="float32")
        params, axes = tfm.init_model(jax.random.PRNGKey(0), cfg)
        shape = ShapeConfig("smoke", 32, 2, "train")
        batch = zoo.make_concrete_batch(cfg, shape)
        loss = jax.jit(lambda p, b: tfm.lm_loss(p, cfg, NULL_LAYOUT, b))(params, batch)
        assert jnp.isfinite(loss), f"{arch}: loss not finite: {loss}"
        # grads
        g = jax.jit(jax.grad(lambda p, b: tfm.lm_loss(p, cfg, NULL_LAYOUT, b)))(params, batch)
        gnorm = jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(g)))
        assert jnp.isfinite(gnorm), f"{arch}: grad norm not finite"
        # decode
        caches = tfm.init_caches(cfg, 2, 32, jnp.float32)
        tok = jnp.zeros((2, 1), jnp.int32)
        logits, caches = jax.jit(
            lambda p, c, t, pos: tfm.forward_decode(p, cfg, NULL_LAYOUT, t, c, pos)
        )(params, caches, tok, jnp.int32(0))
        assert jnp.all(jnp.isfinite(logits)), f"{arch}: decode logits not finite"
        n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        print(f"OK   {arch:28s} loss={float(loss):7.3f} gnorm={float(gnorm):9.3f} "
              f"params={n_params:,} ({time.perf_counter()-t0:.1f}s)")
    except Exception as e:
        ok = False
        print(f"FAIL {arch}: {e}")
        traceback.print_exc()
print("ALL OK" if ok else "FAILURES")
