"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the deepseek-coder family config scaled to ~100M params (the brief's
"train ~100M model for a few hundred steps" deliverable), the production
train_step (ZeRO specs no-op on one device), deterministic token stream,
and async checkpointing with restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointConfig, Checkpointer
from repro.configs.base import ModelConfig
from repro.data.tokens import TokenStreamConfig, batch_at_step
from repro.distributed.sharding import NULL_LAYOUT
from repro.models import transformer as tfm
from repro.optim import OptConfig, opt_init
from repro.train.train_step import TrainHParams, TrainState, make_train_step

# ~100M params: 12L x 512 with a 32k vocab
CFG = ModelConfig(
    name="repro-110m", family="dense", n_layers=12, d_model=512, n_heads=8,
    n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32_768, act="silu",
    dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt", default="results/ckpt_train_lm")
    args = ap.parse_args()

    print(f"params: {CFG.param_count()/1e6:.1f}M")
    hp = TrainHParams(peak_lr=3e-4, warmup=20, total_steps=args.steps,
                      opt=OptConfig(name="adamw", weight_decay=0.01))
    params, _ = tfm.init_model(jax.random.PRNGKey(0), CFG)
    state = TrainState(params=params, opt=opt_init(params, hp.opt),
                       step=jnp.zeros((), jnp.int32))
    ckpt = Checkpointer(CheckpointConfig(directory=args.ckpt, keep=2))
    if ckpt.latest_step() is not None:
        state = ckpt.restore(state)
        print(f"resumed at step {int(state.step)}")

    step_fn = jax.jit(make_train_step(CFG, NULL_LAYOUT, hp))
    ds = TokenStreamConfig(vocab_size=CFG.vocab_size, seq_len=args.seq_len,
                           global_batch=args.batch, seed=0)
    t0 = time.perf_counter()
    first = None
    start_step = int(state.step)  # snapshot: state is reassigned in the loop
    if start_step >= args.steps:
        print(f"already trained to step {start_step}; nothing to do")
        return
    for step in range(start_step, args.steps):
        batch = jax.tree.map(jnp.asarray, batch_at_step(ds, step))
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        first = loss if first is None else first
        if step % 20 == 0 or step == args.steps - 1:
            tput = args.batch * args.seq_len / max((time.perf_counter() - t0) / (step - start_step + 1), 1e-9)
            print(f"step {step:4d}  loss {loss:.4f}  gnorm "
                  f"{float(metrics['grad_norm']):7.2f}  lr {float(metrics['lr']):.2e}",
                  flush=True)
        if step and step % 100 == 0:
            ckpt.save(step, state)  # async
    ckpt.save(args.steps, state, blocking=True)
    print(f"done: loss {first:.3f} -> {loss:.3f} in {time.perf_counter()-t0:.0f}s")
    assert loss < first, "loss did not improve"


if __name__ == "__main__":
    main()
