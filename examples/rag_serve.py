"""Serve batched RAG requests: GateANN filtered retrieval + LM decode.

Each request carries a query vector, a metadata predicate (document
category), and prompt tokens.  Retrieval runs in 'gate' mode — record
fetches happen only for predicate-passing passages; the generator is a
reduced gemma3-family model decoding greedily with ring-buffer caches.

    PYTHONPATH=src python examples/rag_serve.py
"""
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import EngineConfig, GateANNEngine, SearchConfig
from repro.data import make_bigann_like, make_queries, uniform_labels
from repro.distributed.sharding import NULL_LAYOUT
from repro.models import transformer as tfm
from repro.serve.rag import RAGRequest, RAGServer

# --- corpus of "passages": vectors + category metadata + token payloads
N, DIM = 4_000, 32
corpus = make_bigann_like(N, DIM, seed=0)
labels = uniform_labels(N, 10, seed=0)
rng = np.random.default_rng(0)

cfg = dataclasses.replace(get_smoke_config("gemma3-4b"), dtype="float32")
passage_tokens = rng.integers(0, cfg.vocab_size, size=(N, 8)).astype(np.int32)

print("building retrieval index ...")
engine = GateANNEngine.build(
    corpus,
    config=EngineConfig(degree=24, build_l=48, pq_chunks=8, r_max=12,
                        # adaptive hot-node record cache: 256 records stay
                        # device-resident; online visit counters re-learn
                        # the hot set from live traffic after every batch,
                        # with a per-filter partition per category
                        cache_budget_bytes=256 * 4096,
                        cache_policy="adaptive", refresh_every=1),
    labels=labels,
)
params, _ = tfm.init_model(jax.random.PRNGKey(0), cfg)
server = RAGServer(
    engine=engine, cfg=cfg, params=params, layout=NULL_LAYOUT,
    passage_tokens=passage_tokens,
    search_config=SearchConfig(mode="gate", search_l=48, result_k=3, beam_width=4),
)

# --- a batch of requests, all filtered to category 3
reqs = [
    RAGRequest(
        query_vec=make_queries(corpus, 1, seed=10 + i)[0],
        prompt_tokens=rng.integers(0, cfg.vocab_size, size=6).astype(np.int32),
        filter_kind="label",
        filter_params=np.int32(3),
    )
    for i in range(4)
]

t0 = time.perf_counter()
tokens, stats = server.generate(reqs, max_new_tokens=8)
ios = float(np.mean(np.asarray(stats.n_ios)))
tun = float(np.mean(np.asarray(stats.n_tunnels)))
hits = float(np.mean(np.asarray(stats.n_cache_hits)))
print(f"retrieval: {ios:.1f} slow-tier reads/query, {hits:.1f} cache hits/query, "
      f"{tun:.1f} tunnels/query (all retrieved passages satisfy category==3)")
print(f"server io_report: {server.io_report()}")
# a second retrieval pass of the same workload: the adaptive cache has
# refreshed its hot set from the first batch's visit counters
server.retrieve(reqs)
rep = server.io_report()
print(f"after adaptation: hit rate {rep['last_batch_hit_rate']:.2f} "
      f"(refreshes={rep['cache_refreshes']}, partitions={rep['cache_partitions']})")
print(f"generated {tokens.shape[1]} tokens per request in {time.perf_counter()-t0:.0f}s:")
for i, row in enumerate(tokens):
    print(f"  request {i}: {row.tolist()}")
