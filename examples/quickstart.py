"""Quickstart: build a GateANN index and run filtered search in 4 modes.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, time

sys.path.insert(0, "src")

import numpy as np

from repro.core import EngineConfig, GateANNEngine, SearchConfig, recall_at_k
from repro.data import (
    filtered_ground_truth,
    make_bigann_like,
    make_queries,
    uniform_labels,
)

# 1. A BigANN-style corpus with 10-class metadata (paper Table 3, scaled).
N, DIM, NQ = 8_000, 32, 32
corpus = make_bigann_like(N, DIM, seed=0)
labels = uniform_labels(N, 10, seed=0)
queries = make_queries(corpus, NQ, seed=1)

# 2. Build once: Vamana graph + PQ codes + neighbor store + filter store.
t0 = time.perf_counter()
engine = GateANNEngine.build(
    corpus,
    config=EngineConfig(degree=32, build_l=64, pq_chunks=8, r_max=16),
    labels=labels,
)
print(f"built index for N={N} in {time.perf_counter()-t0:.0f}s")
print("memory:", engine.memory_report())

# 3. Search with a 10%-selectivity equality predicate, in every mode.
target = np.zeros(NQ, np.int32)  # "category == 0"
gt = filtered_ground_truth(corpus, queries, labels == 0, k=10)

print(f"\n{'mode':12s} {'recall@10':>9s} {'ios/q':>8s} {'tunnels/q':>9s} "
      f"{'lat(model)':>10s} {'qps@32T':>9s}")
for mode in ("post", "early", "pre_naive", "gate"):
    out = engine.search(
        queries, filter_kind="label", filter_params=target,
        search_config=SearchConfig(mode=mode, search_l=100, beam_width=8),
    )
    r = recall_at_k(out.ids, gt, 10)
    ios = float(np.mean(np.asarray(out.stats.n_ios)))
    tun = float(np.mean(np.asarray(out.stats.n_tunnels)))
    print(f"{mode:12s} {r:9.3f} {ios:8.1f} {tun:9.1f} "
          f"{engine.modeled_latency_us(out.stats):9.0f}us "
          f"{engine.modeled_qps(out.stats):9.0f}")

print("\nGateANN ('gate') matches post-filter recall with ~10x fewer record "
      "fetches — the paper's headline, reproduced structurally.")

# 4. Add the hot-node cache tier (a runtime knob, no rebuild): the hot
#    records near the medoid are served from device memory, killing the
#    slow-tier reads tunneling can't (the filter-passing hot nodes).
print(f"\n{'cache':>12s} {'ios/q':>8s} {'hits/q':>8s} {'qps@32T':>9s}")
for n_records in (0, 256, 1024):
    cached = engine.with_cache(n_records * 4096)
    out = cached.search(
        queries, filter_kind="label", filter_params=target,
        search_config=SearchConfig(mode="gate", search_l=100, beam_width=8),
    )
    ios = float(np.mean(np.asarray(out.stats.n_ios)))
    hits = float(np.mean(np.asarray(out.stats.n_cache_hits)))
    print(f"{n_records:9d} rec {ios:8.1f} {hits:8.1f} "
          f"{cached.modeled_qps(out.stats):9.0f}")

# 5. Persist the index and serve it from disk: save() writes one
#    page-aligned file (4 KB record sectors + PQ/graph/filter sidecars);
#    load() restores without rebuilding the graph or retraining PQ, and
#    store_tier="disk" serves records straight off the file with
#    *measured* (not modeled) page reads.
import os, tempfile

path = os.path.join(tempfile.mkdtemp(), "quickstart.gann")
t0 = time.perf_counter()
engine.save(path)
print(f"\nsaved index -> {path} ({os.path.getsize(path)//1024} KiB) "
      f"in {time.perf_counter()-t0:.1f}s")

disk = GateANNEngine.load(path, store_tier="disk")  # no rebuild, no retrain
store = disk.record_store
print(f"{'mode':12s} {'pages/q':>8s} {'ios/q':>8s} {'uniq/q':>8s} "
      f"{'sys/round':>9s} {'ids==mem':>9s}")
for mode in ("post", "gate"):
    before = store.io_counters()
    out = disk.search(
        queries, filter_kind="label", filter_params=target,
        search_config=SearchConfig(mode=mode, search_l=100, beam_width=8),
    )
    ids = np.asarray(out.ids)  # materialize => measured counters final
    ref = engine.search(
        queries, filter_kind="label", filter_params=target,
        search_config=SearchConfig(mode=mode, search_l=100, beam_width=8),
    )
    match = bool(np.array_equal(ids, np.asarray(ref.ids)))
    d = {k: v - before[k] for k, v in store.io_counters().items()}
    ios = float(np.mean(np.asarray(out.stats.n_ios)))
    print(f"{mode:12s} {d['pages_read']/NQ:8.1f} {ios:8.1f} "
          f"{d['unique_sectors_read']/NQ:8.1f} "
          f"{d['syscalls']/max(d['read_rounds'],1):9.1f} {str(match):>9s}")

print("\nThe disk tier *measures* the paper's central quantity: gate mode "
      "reads a fraction of post's 4 KB sectors, now counted off a real file —\n"
      f"and each round's beam coalesces into ONE {store.io_mode} submission "
      "(sorted, deduplicated, range-merged).")
