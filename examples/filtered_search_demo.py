"""Every predicate family on one index, no rebuilds (paper §3.2).

Equality, range over a continuous attribute, multi-label subset, and a
conjunction — plus an R_max sweep showing the runtime DRAM knob.

    PYTHONPATH=src python examples/filtered_search_demo.py
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig, GateANNEngine, SearchConfig
from repro.core.filter_store import AndFilter, pack_tags
from repro.core.neighbor_store import NeighborStore
from repro.data import make_bigann_like, make_queries, uniform_labels
from repro.data.labels import multilabel_queries, multilabel_tags, norm_bin_attribute

N, DIM, NQ = 6_000, 32, 16
corpus = make_bigann_like(N, DIM, seed=0)
labels = uniform_labels(N, 10, seed=0)
norms, edges = norm_bin_attribute(corpus, 10)
tags = multilabel_tags(N, vocab=512, mean_tags=5.0, seed=0)

engine = GateANNEngine.build(
    corpus,
    config=EngineConfig(degree=28, build_l=56, pq_chunks=8, r_max=14),
    labels=labels,
    attributes=norms,
    tag_bits=pack_tags(tags, 512),
)
queries = make_queries(corpus, NQ, seed=1)
cfg = SearchConfig(mode="gate", search_l=80, beam_width=8)


def report(name, out, check):
    ids = np.asarray(out.ids)
    ok = all(check(int(i)) for row in ids for i in row if i >= 0)
    ios = float(np.mean(np.asarray(out.stats.n_ios)))
    tun = float(np.mean(np.asarray(out.stats.n_tunnels)))
    print(f"{name:28s} predicate-clean={ok}  ios/q={ios:6.1f} tunnels/q={tun:6.1f}")


# 1. equality
out = engine.search(queries, filter_kind="label",
                    filter_params=np.zeros(NQ, np.int32), search_config=cfg)
report("equality (label==0)", out, lambda i: labels[i] == 0)

# 2. range over the norm attribute (one equal-frequency bin, ~10%)
lo, hi = float(edges[3]), float(edges[4])
out = engine.search(queries, filter_kind="range",
                    filter_params=(np.full(NQ, lo, np.float32),
                                   np.full(NQ, hi, np.float32)),
                    search_config=cfg)
report(f"range (norm in [{lo:.0f},{hi:.0f}])", out,
       lambda i: lo <= norms[i] <= hi)

# 3. multi-label subset (YFCC semantics)
qtags = multilabel_queries(tags, NQ, n_tags=(1, 2), seed=2)
qbits = jnp.asarray(pack_tags(qtags, 512))
out = engine.search(queries, filter_kind="tags", filter_params=qbits,
                    search_config=cfg)
ok = all(
    set(qtags[q]) <= set(tags[int(i)])
    for q, row in enumerate(np.asarray(out.ids)) for i in row if i >= 0
)
print(f"{'subset (tags ⊆ node.tags)':28s} predicate-clean={ok}  "
      f"ios/q={float(np.mean(np.asarray(out.stats.n_ios))):6.1f} "
      f"tunnels/q={float(np.mean(np.asarray(out.stats.n_tunnels))):6.1f}")

# 4. conjunction: label==0 AND norm-bin — swap the filter store, same index
conj = AndFilter((engine.filters["label"], engine.filters["range"]))
check = conj.bind(np.zeros(NQ, np.int32),
                  (np.full(NQ, lo, np.float32), np.full(NQ, hi, np.float32)))
from repro.core import search as searchm
from repro.core import pq as pqm

out = searchm.filtered_search(
    fetch=engine.record_store.fetch_fn(), neighbor_store=engine.neighbor_store,
    filter_check=check, lut=pqm.build_lut(engine.codec, jnp.asarray(queries)),
    codes=engine.codes, entry=engine.medoid, queries=jnp.asarray(queries),
    config=cfg,
)
report("conjunction (label AND range)", out,
       lambda i: labels[i] == 0 and lo <= norms[i] <= hi)

# 5. R_max is a runtime knob — rebuild the neighbor store, never the graph
print("\nR_max sweep (no index rebuild):")
for r_max in (4, 8, 16):
    engine.neighbor_store = NeighborStore.from_graph(
        engine.record_store.neighbors, r_max)
    out = engine.search(queries, filter_kind="label",
                        filter_params=np.zeros(NQ, np.int32), search_config=cfg)
    print(f"  R_max={r_max:3d}: dram={engine.neighbor_store.memory_bytes()/1e3:7.0f}KB "
          f"ios/q={float(np.mean(np.asarray(out.stats.n_ios))):6.1f}")
