"""Distributed correctness on 8 simulated host devices (subprocess-isolated
so the main pytest process keeps its single-device view)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, n_dev: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_record_store_matches_inmemory():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.store.vector_store import ShardedRecordStore, InMemoryRecordStore

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    n, d, r = 64, 8, 4
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    nbrs = rng.integers(-1, n, size=(n, r)).astype(np.int32)
    v_p, g_p, rows = ShardedRecordStore.shard_arrays(vecs, nbrs, 4)
    store = ShardedRecordStore(
        local_vectors=None, local_neighbors=None, rows_per_shard=rows)

    ids = rng.integers(-1, n, size=(6, 3)).astype(np.int32)

    def run(lv, ln, ids):
        s = ShardedRecordStore(local_vectors=lv, local_neighbors=ln,
                               rows_per_shard=rows)
        return s.fetch_fn()(ids)

    mapped = shard_map(run, mesh=mesh,
        in_specs=(P("model", None), P("model", None), P(None, None)),
        out_specs=(P(None, None, None), P(None, None, None)), check_rep=False)
    got_v, got_n = jax.jit(mapped)(jnp.asarray(v_p), jnp.asarray(g_p), jnp.asarray(ids))
    ref = InMemoryRecordStore(vectors=jnp.asarray(vecs), neighbors=jnp.asarray(nbrs))
    want_v, want_n = ref.fetch_fn()(jnp.asarray(ids))
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_n), np.asarray(want_n))
    print("sharded fetch OK")
    """)


def test_distributed_retrieve_step_runs_and_filters():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.distributed_search import DistSearchConfig, make_retrieve_step
    from repro.core import pq as pqm
    from repro.core.graph import build_vamana, find_medoid
    from repro.data import make_bigann_like, make_queries, uniform_labels

    # mesh (data=2, model=4) — mirrors the production layout shape
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    n, d = 800, 16
    corpus = make_bigann_like(n, d, seed=0)
    labels = uniform_labels(n, 5, seed=0)
    g = build_vamana(corpus, degree=12, build_l=24, batch_size=256)
    codec = pqm.train_pq(jnp.asarray(corpus), n_chunks=8, iters=4)
    codes = pqm.encode_pq(codec, jnp.asarray(corpus))
    queries = make_queries(corpus, 8, seed=1)
    lut = pqm.build_lut(codec, jnp.asarray(queries))

    rows = -(-n // 4)
    import numpy as _np
    v_p = _np.pad(corpus, ((0, rows*4-n), (0, 0)))
    g_p = _np.pad(_np.asarray(g.neighbors), ((0, rows*4-n), (0, 0)), constant_values=-1)

    cfg = DistSearchConfig(search_l=32, beam_width=4, n_hops=24, visited_cap=512)
    step = make_retrieve_step(mesh, cfg, rows_per_shard=rows)
    out = step(jnp.asarray(queries), lut, codes,
               jnp.asarray(_np.asarray(g.neighbors)[:, :8]),
               jnp.asarray(labels), jnp.asarray(v_p), jnp.asarray(g_p),
               g.medoid, jnp.zeros((8,), jnp.int32))
    ids = np.asarray(out["ids"])
    valid = ids[ids >= 0]
    assert len(valid) > 0
    assert (np.asarray(labels)[valid] == 0).all(), "filter violated"
    assert float(np.mean(np.asarray(out["n_tunnels"]))) > 0
    # I/O reduction vs post mode
    step_post = make_retrieve_step(mesh, DistSearchConfig(
        search_l=32, beam_width=4, n_hops=24, visited_cap=512, mode="post"),
        rows_per_shard=rows)
    out_post = step_post(jnp.asarray(queries), lut, codes,
               jnp.asarray(_np.asarray(g.neighbors)[:, :8]),
               jnp.asarray(labels), jnp.asarray(v_p), jnp.asarray(g_p),
               g.medoid, jnp.zeros((8,), jnp.int32))
    r = float(np.mean(np.asarray(out["n_ios"]))) / max(
        float(np.mean(np.asarray(out_post["n_ios"]))), 1e-9)
    assert r < 0.5, f"io ratio {r}"
    print("distributed retrieve OK, io ratio", r)
    """)


@pytest.mark.parametrize("mode", ["gate", "post"])
def test_distributed_matches_single_host_oracle(mode):
    """Oracle parity for core/distributed_search.py: on a tiny CPU mesh the
    sharded fixed-hop loop must return the same ids/distances and I/O
    counters as the single-host ``filtered_search`` (which is itself
    pinned to the NumPy oracle of Algorithm 1 in test_search_oracle)."""
    _run(f"""
    import jax, jax.numpy as jnp, numpy as np
    from jax.tree_util import Partial
    from repro.core.distributed_search import DistSearchConfig, make_retrieve_step
    from repro.core import pq as pqm
    from repro.core.search import SearchConfig, filtered_search
    from repro.core.filter_store import EqualityFilter
    from repro.core.neighbor_store import NeighborStore
    from repro.core.graph import build_vamana
    from repro.data import make_bigann_like, make_queries, uniform_labels
    from repro.store.vector_store import InMemoryRecordStore

    mode = {mode!r}
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    n, d, r_max, L, W, K = 600, 16, 8, 32, 4, 10
    corpus = make_bigann_like(n, d, seed=3)
    labels = uniform_labels(n, 5, seed=3)
    g = build_vamana(corpus, degree=12, build_l=24, batch_size=256, seed=3)
    codec = pqm.train_pq(jnp.asarray(corpus), n_chunks=8, iters=4)
    codes = pqm.encode_pq(codec, jnp.asarray(corpus))
    queries = make_queries(corpus, 8, seed=4)
    lut = pqm.build_lut(codec, jnp.asarray(queries))
    targets = jnp.zeros((8,), jnp.int32)

    # single-host reference: the oracle-pinned Algorithm 1 loop
    store = InMemoryRecordStore(vectors=jnp.asarray(corpus),
                                neighbors=jnp.asarray(g.neighbors))
    ref = filtered_search(
        fetch=store.fetch_fn(),
        neighbor_store=NeighborStore.from_graph(g.neighbors, r_max),
        filter_check=EqualityFilter(jnp.asarray(labels)).bind(targets),
        lut=lut, codes=codes, entry=g.medoid, queries=jnp.asarray(queries),
        config=SearchConfig(mode=mode, search_l=L, beam_width=W, result_k=K),
    )

    # distributed run: generous hop budget + visited capacity so the
    # frontier fully drains and the ring buffer never overwrites
    rows = -(-n // 4)
    v_p = np.pad(corpus, ((0, rows*4-n), (0, 0)))
    g_p = np.pad(np.asarray(g.neighbors), ((0, rows*4-n), (0, 0)),
                 constant_values=-1)
    cfg = DistSearchConfig(search_l=L, beam_width=W, result_k=K,
                           n_hops=96, visited_cap=4096, mode=mode)
    step = make_retrieve_step(mesh, cfg, rows_per_shard=rows)
    out = step(jnp.asarray(queries), lut, codes,
               jnp.asarray(np.asarray(g.neighbors)[:, :r_max]),
               jnp.asarray(labels), jnp.asarray(v_p), jnp.asarray(g_p),
               g.medoid, targets)

    ids_ref = np.asarray(ref.ids)
    ids_dist = np.asarray(out["ids"])
    np.testing.assert_array_equal(ids_dist, ids_ref)
    valid = ids_ref >= 0
    np.testing.assert_allclose(np.asarray(out["dists"])[valid],
                               np.asarray(ref.dists)[valid], rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(out["n_ios"]),
                                  np.asarray(ref.stats.n_ios))
    np.testing.assert_array_equal(np.asarray(out["n_tunnels"]),
                                  np.asarray(ref.stats.n_tunnels))
    print("distributed oracle parity OK:", mode)
    """)


def test_retrieve_step_from_disk_segments():
    """The record tier fed from per-shard on-disk segments: save(shards=4),
    load each shard's rows off its own segment file only, and the mesh
    retrieve step must match single-host ``filtered_search`` exactly —
    the persisted sharded layout serves the production mesh unchanged."""
    _run("""
    import os, tempfile
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import EngineConfig, GateANNEngine
    from repro.core import pq as pqm
    from repro.core.distributed_search import (
        DistSearchConfig, load_shard_records, load_sharded_record_arrays,
        make_retrieve_step)
    from repro.core.search import SearchConfig, filtered_search
    from repro.data import make_bigann_like, make_queries, uniform_labels

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    n, d, L, W, K = 400, 16, 32, 4, 10
    corpus = make_bigann_like(n, d, seed=5)
    labels = uniform_labels(n, 5, seed=5)
    eng = GateANNEngine.build(
        corpus, config=EngineConfig(degree=12, build_l=24, pq_chunks=8, r_max=8),
        labels=labels)
    path = os.path.join(tempfile.mkdtemp(), "dist.gann")
    eng.save(path, shards=4)

    # per-host path: each shard opens ONLY its own segment file
    v0, n0, rows = load_shard_records(path, 0)
    assert v0.shape == (rows, d) and n0.shape[0] == rows
    v_p, g_p, rows2 = load_sharded_record_arrays(path)
    assert rows2 == rows and v_p.shape[0] == rows * 4

    queries = make_queries(corpus, 8, seed=6)
    lut = pqm.build_lut(eng.codec, jnp.asarray(queries))
    targets = jnp.zeros((8,), jnp.int32)
    ref = eng.search(queries, filter_kind="label", filter_params=targets,
                     search_config=SearchConfig(mode="gate", search_l=L,
                                                beam_width=W, result_k=K))
    cfg = DistSearchConfig(search_l=L, beam_width=W, result_k=K,
                           n_hops=96, visited_cap=4096, mode="gate")
    step = make_retrieve_step(mesh, cfg, rows_per_shard=rows)
    out = step(jnp.asarray(queries), lut, eng.codes,
               eng.neighbor_store.neighbors, jnp.asarray(labels),
               jnp.asarray(v_p), jnp.asarray(g_p),
               eng.medoid, targets)
    np.testing.assert_array_equal(np.asarray(out["ids"]), np.asarray(ref.ids))
    np.testing.assert_array_equal(np.asarray(out["n_ios"]),
                                  np.asarray(ref.stats.n_ios))
    print("segment-fed retrieve parity OK")
    """)


@pytest.mark.slow  # jits a sharded model train step on 8 emulated devices
def test_train_step_sharded_2x4():
    _run("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.distributed.sharding import make_layout, tree_pspecs
    from repro.models import transformer as tfm, zoo
    from repro.optim import OptConfig, opt_init
    from repro.train.train_step import (TrainHParams, TrainState,
        make_train_state_specs, make_train_step)

    cfg = dataclasses.replace(get_smoke_config("deepseek-coder-33b"),
                              dtype="float32")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    layout = make_layout("train", mesh)
    params, axes = tfm.init_model(jax.random.PRNGKey(0), cfg)
    hp = TrainHParams(opt=OptConfig(name="adamw"))
    state = TrainState(params=params, opt=opt_init(params, hp.opt),
                       step=jnp.zeros((), jnp.int32))
    specs = make_train_state_specs(params, axes, layout, "adamw")
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                      is_leaf=lambda s: isinstance(s, P))
    state = jax.device_put(state, sh)
    b, t = 4, 32
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32),
             "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32)}
    bsh = {"tokens": NamedSharding(mesh, P("data", "model")),
           "targets": NamedSharding(mesh, P("data", "model"))}
    batch = jax.device_put(batch, bsh)
    step = jax.jit(make_train_step(cfg, layout, hp),
                   in_shardings=(sh, bsh), out_shardings=(sh, None))
    l0 = None
    for i in range(4):
        state, metrics = step(state, batch)
        l = float(metrics["loss"])
        assert np.isfinite(l)
        l0 = l if l0 is None else l0
    assert l < l0, (l0, l)  # same batch -> loss must drop
    print("sharded train OK", l0, "->", l)
    """)


@pytest.mark.slow  # two full model forwards (sharded + replicated) in subprocesses
def test_sharded_equals_single_device():
    """Numerical parity: the sharded loss equals the unsharded loss."""
    _run("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.distributed.sharding import NULL_LAYOUT, make_layout
    from repro.models import transformer as tfm

    cfg = dataclasses.replace(get_smoke_config("gemma3-4b"), dtype="float32")
    params, _ = tfm.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    b, t = 4, 32
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32),
             "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32)}
    l_single = float(jax.jit(lambda p, bt: tfm.lm_loss(p, cfg, NULL_LAYOUT, bt))(params, batch))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    layout = make_layout("train", mesh)
    l_shard = float(jax.jit(lambda p, bt: tfm.lm_loss(p, cfg, layout, bt))(params, batch))
    np.testing.assert_allclose(l_shard, l_single, rtol=2e-4)
    print("parity OK", l_single, l_shard)
    """)
