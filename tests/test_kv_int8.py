"""int8 KV cache (REPRO_KV_INT8) — decode parity within quantization error."""
import dataclasses
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_int8_cache_decode_close_to_fp():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["REPRO_KV_INT8"] = "1"
    code = """
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke_config
    from repro.distributed.sharding import NULL_LAYOUT
    from repro.models import transformer as tfm

    cfg = dataclasses.replace(get_smoke_config("deepseek-coder-33b"), dtype="float32")
    b, t = 2, 16
    params, _ = tfm.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (b, t)), jnp.int32)
    hidden, _, _ = tfm.forward_train(params, cfg, NULL_LAYOUT,
                                     {"tokens": tokens}, remat=False)
    w = tfm.unembed_matrix(params, cfg).astype(hidden.dtype)
    full = jax.lax.dot_general(hidden, w, (((2,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    caches = tfm.init_caches(cfg, b, t, jnp.float32)
    assert "k_q" in caches[0], "int8 cache not active"
    step = jax.jit(lambda p, c, tok, pos: tfm.forward_decode(
        p, cfg, NULL_LAYOUT, tok, c, pos))
    outs = []
    for i in range(t):
        logits, caches = step(params, caches, tokens[:, i:i+1], jnp.int32(i))
        outs.append(logits[:, 0, :])
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec - full)))
    scale = float(jnp.max(jnp.abs(full)))
    assert err < 0.05 * scale + 0.3, (err, scale)
    # ranking mostly preserved
    agree = float(jnp.mean(jnp.argmax(dec, -1) == jnp.argmax(full, -1)))
    assert agree > 0.9, agree
    print("int8 KV parity OK", err, agree)
    """
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:{out.stdout}\nSTDERR:{out.stderr}"
