"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

# Pallas interpret mode on CPU takes >10 min for the full sweep — not tier-1.
pytestmark = pytest.mark.slow

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("b", [1, 3])
@pytest.mark.parametrize("m", [1, 7, 128, 300])
@pytest.mark.parametrize("c,k", [(4, 256), (8, 16), (16, 256)])
def test_pq_lookup_gathered(b, m, c, k):
    lut = jnp.asarray(RNG.normal(size=(b, c, k)), jnp.float32)
    codes = jnp.asarray(RNG.integers(0, k, size=(b, m, c)), jnp.int32)
    got = ops.pq_lookup_gathered(lut, codes)
    want = ref.pq_lookup_gathered_ref(lut, codes)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", [5, 512, 1000])
@pytest.mark.parametrize("c", [4, 32])
def test_pq_scan(n, c):
    k = 256
    lut = jnp.asarray(RNG.normal(size=(2, c, k)), jnp.float32)
    codes = jnp.asarray(RNG.integers(0, k, size=(n, c)), jnp.int32)
    got = ops.pq_scan(lut, codes)
    want = ref.pq_scan_ref(lut, codes)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,w,d", [(1, 1, 8), (4, 12, 64), (2, 33, 128)])
def test_l2_dist(b, w, d, dtype):
    q = jnp.asarray(RNG.normal(size=(b, d)), dtype)
    x = jnp.asarray(RNG.normal(size=(b, w, d)), dtype)
    got = ops.l2_dist(q, x)
    want = ref.l2_dist_ref(q, x)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_pq_lookup_padding_is_inert():
    """Rows padded up to the block boundary emit +INF inside the kernel —
    a fused consumer selecting over the raw block can never pick one.
    M=300 with block_m=128 leaves 84 padded lanes."""
    b, m, c, k = 2, 300, 4, 16
    lut = jnp.asarray(RNG.normal(size=(b, c, k)), jnp.float32)
    codes = jnp.asarray(RNG.integers(0, k, size=(b, m, c)), jnp.int32)
    from repro.kernels import pq_lookup as pq

    full = pq.pq_lookup_gathered(lut, codes, keep_padding=True)
    assert full.shape == (b, 384)  # padded to the 128-row block
    assert np.all(np.asarray(full[:, m:]) == np.float32(3.4e38))
    np.testing.assert_allclose(full[:, :m], ref.pq_lookup_gathered_ref(lut, codes),
                               rtol=1e-5, atol=1e-5)
    scan = pq.pq_scan(lut, jnp.asarray(RNG.integers(0, k, size=(300, c)),
                                       jnp.int32), block_n=128,
                      keep_padding=True)
    assert scan.shape == (b, 384)
    assert np.all(np.asarray(scan[:, 300:]) == np.float32(3.4e38))


def test_topk_merge_duplicate_distances_deterministic():
    """Distance ties break by ascending id — kernel and oracle must agree
    exactly (ids included), even on a batch that is mostly ties."""
    b, m, k = 3, 64, 16
    d = jnp.asarray(RNG.integers(0, 4, size=(b, m)), jnp.float32)  # heavy ties
    i = jnp.asarray(RNG.permutation(10 * m)[: b * m].reshape(b, m), jnp.int32)
    gd, gi = ops.topk_merge(d, i, k)
    wd, wi = ref.topk_merge_ref(d, i, k)
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


def test_interpret_mode_resolution():
    """Kernel wrappers run compiled wherever a lowering exists; interpret
    is the resolved fallback (CPU), never a silent default elsewhere."""
    from repro.kernels.backend import resolve_interpret, supports_compiled_pallas

    assert ops._interpret() == (not supports_compiled_pallas())
    assert resolve_interpret(None) == ops._interpret()
    assert supports_compiled_pallas("tpu") and supports_compiled_pallas("gpu")
    assert not supports_compiled_pallas("cpu")
    assert resolve_interpret(False) is False  # explicit opt-out wins


@pytest.mark.parametrize("m,k", [(8, 4), (50, 10), (128, 128), (100, 200)])
def test_topk_merge(m, k):
    b = 3
    d = jnp.asarray(RNG.normal(size=(b, m)), jnp.float32)
    i = jnp.asarray(RNG.integers(0, 10_000, size=(b, m)), jnp.int32)
    gd, gi = ops.topk_merge(d, i, k)
    kk = min(k, m)  # beyond m the kernel returns INF/-1 padding
    wd, wi = ref.topk_merge_ref(d, i, kk)
    np.testing.assert_allclose(gd[:, :kk], wd, rtol=1e-6)
    # ids must agree where distances are unique (ties may reorder)
    uniq = np.diff(np.asarray(wd), axis=1) > 1e-9
    agree = np.asarray(gi)[:, 1:kk][uniq] == np.asarray(wi)[:, 1:][uniq]
    assert agree.all()
    if k > m:  # padding is inert
        assert np.all(np.asarray(gi)[:, m:] == -1)


def test_adc_matches_decoded_distance():
    """ADC with exact LUT == true squared distance to decoded vectors."""
    from repro.core import pq as pqm

    x = jnp.asarray(RNG.normal(size=(500, 32)), jnp.float32)
    codec = pqm.train_pq(x, n_chunks=8, iters=4)
    codes = pqm.encode_pq(codec, x)
    q = jnp.asarray(RNG.normal(size=(4, 32)), jnp.float32)
    lut = pqm.build_lut(codec, q)
    adc = pqm.adc_lookup_ref(lut, codes)
    decoded = pqm.decode_pq(codec, codes)
    true = ((q[:, None, :] - decoded[None, :, :]) ** 2).sum(-1)
    np.testing.assert_allclose(adc, true, rtol=2e-4, atol=2e-3)
