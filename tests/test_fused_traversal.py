"""Fused stage-A traversal kernel: kernel-vs-twin parity + engine lattice.

Contract under test (kernels/fused_traversal.py + core/search.py):

  * ``fused_traversal_round`` (one Pallas pass: ADC lookup, dedup kill,
    bitonic frontier merge, beam selection, mode masks) is **bit-identical**
    to its jnp reference twin ``ref.fused_traversal_round_ref`` on every
    output field — including adversarial batches (duplicate ids, all
    candidates filtered out, M=0 round-0 calls, M not a power of two).
  * ``SearchConfig.use_fused_kernel=True`` produces bit-identical search
    output (ids, dists, every stat) to the unfused loop in all five modes,
    both cache tiers, and every pipeline depth — the flag is a perf knob,
    never a correctness one.
  * ``fused_supported`` gates the silent fallback on shape/backend limits.

Interpret-mode Pallas builds are expensive on CPU, so tier-1 keeps one
mode per lattice axis on a micro index; the full sweep is slow-marked.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import EngineConfig, GateANNEngine, SearchConfig
from repro.core import search as searchm
from repro.kernels import fused_traversal as ft
from repro.kernels import ref as kref
from repro.kernels.backend import resolve_interpret, supports_compiled_pallas

MODES = ("gate", "post", "early", "pre_naive", "unfiltered")

# one fixed kernel shape per M so the jitted pallas build is paid once per
# (mode, M) and every adversarial variant below reuses it
B, L, W, C, K, N_IDS = 2, 8, 2, 4, 16, 50
RNG = np.random.default_rng(7)


def _round_inputs(m, *, dup_ids=False, all_filtered=False, seed=None):
    """A plausible mid-search round state (plus adversarial knobs)."""
    rng = np.random.default_rng(RNG.integers(1 << 31) if seed is None else seed)
    fid = rng.choice(N_IDS, size=(B, L), replace=False).astype(np.int32)
    fid[:, L - 2:] = -1  # a couple of empty slots, like a young frontier
    fd = np.where(fid >= 0, rng.random((B, L)).astype(np.float32) * 4,
                  np.float32(3.4e38)).astype(np.float32)
    fexp = (rng.random((B, L)) < 0.3) & (fid >= 0)
    fpas = rng.random((B, L)) < 0.5
    nid = rng.integers(-1, N_IDS, size=(B, m)).astype(np.int32)
    if dup_ids and m >= 2:
        nid[:, 1] = nid[:, 0]  # exact duplicate inside the batch
        nid[:, m - 1] = fid[:, 0]  # and a frontier/candidate collision
    nc = rng.integers(0, K, size=(B, m, C)).astype(np.int32)
    npas = np.zeros((B, m), bool) if all_filtered else rng.random((B, m)) < 0.5
    lut = (rng.random((B, C, K)).astype(np.float32)) * 2
    entry = fid[:, 0].copy()
    return tuple(jnp.asarray(x)
                 for x in (fid, fd, fexp, fpas, nid, nc, npas, lut, entry))


def _assert_round_equal(got, want, ctx):
    for f in got._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, f)), np.asarray(getattr(want, f)),
            err_msg=f"{ctx}: FusedRound.{f}",
        )


def _kernel_vs_ref(mode, m, **knobs):
    state = _round_inputs(m, **knobs)
    got = ft.fused_traversal_round(*state, mode=mode, width=W)
    want = kref.fused_traversal_round_ref(*state, mode=mode, width=W)
    _assert_round_equal(got, want, (mode, m, knobs))


@pytest.mark.parametrize("case", ["plain", "dup_ids", "all_filtered"])
def test_kernel_matches_twin_gate(case):
    """Gate mode (the mode with tunnels — every mask populated), main
    shape: plain plus the two adversarial batches that stress the dedup
    kill and the all-tunnel path.  One pallas build serves all three."""
    _kernel_vs_ref("gate", 8, dup_ids=(case == "dup_ids"),
                   all_filtered=(case == "all_filtered"))


@pytest.mark.slow
def test_kernel_round0_m_zero():
    """The pre-loop call: M=0 merges nothing and just selects the first
    beam from the entry-seeded frontier."""
    _kernel_vs_ref("gate", 0)


@pytest.mark.slow
def test_kernel_m_not_power_of_two():
    """L+M=14 exercises the (+INF, -1, seq>=real) pad lanes of the
    bitonic network — pads must sort strictly after real INF entries."""
    _kernel_vs_ref("gate", 6, dup_ids=True)


@pytest.mark.slow
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("case", ["plain", "dup_ids", "all_filtered",
                                  "m_zero", "m_odd"])
def test_kernel_matches_twin_all_modes(mode, case):
    """Nightly: the full mode x adversarial-case product."""
    m = {"m_zero": 0, "m_odd": 6}.get(case, 8)
    _kernel_vs_ref(mode, m, dup_ids=(case == "dup_ids"),
                   all_filtered=(case == "all_filtered"))


def test_fused_supported_limits():
    """The silent-fallback predicate: shape/VMEM ceilings and backends."""
    ok = dict(l=16, width=2, m=24, c=4, k=256)
    assert ft.fused_supported(**ok)
    assert not ft.fused_supported(**{**ok, "l": 4000, "m": 200})  # sort pad
    assert not ft.fused_supported(**{**ok, "c": 64, "k": 1024})  # ADC bytes
    assert not ft.fused_supported(**{**ok, "width": 0})
    assert not ft.fused_supported(**{**ok, "m": -1})
    assert not ft.fused_supported(**ok, backend="weird")
    assert ft.fused_supported(**ok, backend="tpu")


def test_interpret_resolution():
    """interpret=None resolves from the backend; explicit bools win."""
    assert supports_compiled_pallas("tpu")
    assert supports_compiled_pallas("gpu")
    assert not supports_compiled_pallas("cpu")
    assert resolve_interpret(None) == (not supports_compiled_pallas())
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False


# ---------------------------------------------------------------------------
# end-to-end engine parity (micro index: interpret-mode builds stay small)
# ---------------------------------------------------------------------------

MICRO_N, MICRO_D = 600, 16


@pytest.fixture(scope="module")
def micro_corpus():
    rng = np.random.default_rng(11)
    vecs = rng.normal(size=(MICRO_N, MICRO_D)).astype(np.float32)
    labels = rng.integers(0, 4, size=MICRO_N).astype(np.int32)
    queries = rng.normal(size=(4, MICRO_D)).astype(np.float32)
    return vecs, labels, queries


@pytest.fixture(scope="module")
def micro_engine(micro_corpus):
    vecs, labels, _ = micro_corpus
    return GateANNEngine.build(
        vecs, labels=labels,
        # shapes chosen so the padded bitonic width stays at 32 lanes
        # (L=12 + W*(degree+r_max)=18 -> 30): interpret-mode pallas build
        # time scales with the network, and this engine serves tier-1
        config=EngineConfig(degree=6, build_l=20, pq_chunks=4, r_max=3),
    )


@pytest.fixture(scope="module")
def micro_index_path(micro_engine, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("fused") / "micro.gann")
    micro_engine.save(path)
    return path


def _cfg(mode, *, fused, depth=1):
    return SearchConfig(mode=mode, search_l=12, beam_width=2,
                        pipeline_depth=depth, use_fused_kernel=fused)


def _filter_for(mode, queries):
    if mode == "unfiltered":
        return None, None
    return "label", np.full(queries.shape[0], 1, np.int32)


def _assert_same(got, want, ctx):
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids),
                                  err_msg=str(ctx))
    np.testing.assert_array_equal(np.asarray(got.dists),
                                  np.asarray(want.dists), err_msg=str(ctx))
    for f in want.stats._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(got.stats, f)),
            np.asarray(getattr(want.stats, f)),
            err_msg=f"{ctx}: stats.{f}",
        )


def test_engine_fused_parity_gate(micro_engine, micro_corpus, monkeypatch):
    """Fused gate search == unfused bit-for-bit, and the fused round
    genuinely ran (trace-time call count — guards a silent fallback)."""
    _, _, queries = micro_corpus
    kind, params = _filter_for("gate", queries)
    calls = []
    real_dispatch = ft.fused_round_for_backend

    def counting_dispatch():
        real = real_dispatch()

        def counting(*args, **kwargs):
            calls.append(1)
            return real(*args, **kwargs)

        return counting

    monkeypatch.setattr(searchm.ftk, "fused_round_for_backend",
                        counting_dispatch)
    want = micro_engine.search(queries, filter_kind=kind, filter_params=params,
                               search_config=_cfg("gate", fused=False))
    assert not calls  # the unfused loop never touches the kernel
    got = micro_engine.search(queries, filter_kind=kind, filter_params=params,
                              search_config=_cfg("gate", fused=True))
    assert calls  # traced through the fused path, no silent fallback
    _assert_same(got, want, ("gate", "fused", "memory-tier"))


def test_engine_config_plumbs_fused_default(micro_index_path, micro_corpus,
                                            monkeypatch):
    """EngineConfig.use_fused_kernel survives save/load and becomes the
    SearchConfig default only when the caller passes no config (an
    explicit search_config always wins).  Captured at the filtered_search
    boundary — no search actually runs."""
    import dataclasses

    _, _, queries = micro_corpus
    eng = GateANNEngine.load(micro_index_path)
    assert eng.config.use_fused_kernel is False  # default survived the disk
    fused_eng = dataclasses.replace(
        eng, config=dataclasses.replace(eng.config, use_fused_kernel=True)
    )
    seen = []

    def capture(**kwargs):
        seen.append(kwargs["config"])
        raise RuntimeError("captured")

    monkeypatch.setattr(searchm, "filtered_search", capture)
    kind, params = _filter_for("gate", queries)
    for engine, explicit, want_flag in (
        (eng, None, False),  # engine default off
        (fused_eng, None, True),  # engine default on -> SearchConfig on
        (fused_eng, _cfg("gate", fused=False), False),  # explicit cfg wins
    ):
        with pytest.raises(RuntimeError, match="captured"):
            engine.search(queries, filter_kind=kind, filter_params=params,
                          search_config=explicit)
        assert seen[-1].use_fused_kernel is want_flag


@pytest.mark.slow
def test_engine_fused_lattice_disk(micro_index_path, micro_corpus):
    """Nightly: 5 modes x pipeline_depth {1, 2, 4} on the disk tier —
    fused pinned bit-identical to unfused everywhere."""
    _, _, queries = micro_corpus
    eng = GateANNEngine.load(micro_index_path, store_tier="disk")
    for mode in MODES:
        kind, params = _filter_for(mode, queries)
        want = eng.search(queries, filter_kind=kind, filter_params=params,
                          search_config=_cfg(mode, fused=False))
        for depth in (1, 2, 4):
            got = eng.search(queries, filter_kind=kind, filter_params=params,
                             search_config=_cfg(mode, fused=True, depth=depth))
            _assert_same(got, want, (mode, depth, "disk"))
    eng.record_store.close()


@pytest.mark.slow
@pytest.mark.parametrize("policy", ("visit_freq", "adaptive"))
@pytest.mark.parametrize("mode", ("gate", "post"))
def test_engine_fused_lattice_cache(micro_index_path, micro_corpus, mode,
                                    policy):
    """Nightly: fused parity through both cache tiers (the cached-mask
    split runs outside the kernel — stats must still reconcile exactly)."""
    _, _, queries = micro_corpus
    eng = GateANNEngine.load(micro_index_path, store_tier="disk")
    cached = eng.with_cache(24 * 4096, policy=policy, refresh_every=0)
    kind, params = _filter_for(mode, queries)
    want = cached.search(queries, filter_kind=kind, filter_params=params,
                         search_config=_cfg(mode, fused=False))
    for depth in (1, 4):
        got = cached.search(queries, filter_kind=kind, filter_params=params,
                            search_config=_cfg(mode, fused=True, depth=depth))
        _assert_same(got, want, (mode, policy, depth, "cached"))
    eng.record_store.close()
