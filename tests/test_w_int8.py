"""w8a16 weight quantization: decode parity within quantization error."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.distributed.sharding import NULL_LAYOUT
from repro.models import transformer as tfm
from repro.models.layers import quantize_axes, quantize_tree


def test_quantized_decode_close_to_fp():
    cfg = dataclasses.replace(get_smoke_config("qwen2.5-32b"), dtype="float32")
    b, t = 2, 12
    params, axes = tfm.init_model(jax.random.PRNGKey(0), cfg)
    qparams = quantize_tree(params, axes)
    qaxes = quantize_axes(axes)
    assert jax.tree.structure(qaxes) != jax.tree.structure(axes)  # transformed

    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (b, t)), jnp.int32)
    hidden, _, _ = tfm.forward_train(params, cfg, NULL_LAYOUT,
                                     {"tokens": tokens}, remat=False)
    w = tfm.unembed_matrix(params, cfg).astype(hidden.dtype)
    full = jax.lax.dot_general(hidden, w, (((2,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)

    caches = tfm.init_caches(cfg, b, t, jnp.float32)
    step = jax.jit(lambda p, c, tok, pos: tfm.forward_decode(
        p, cfg, NULL_LAYOUT, tok, c, pos))
    outs = []
    for i in range(t):
        logits, caches = step(qparams, caches, tokens[:, i : i + 1], jnp.int32(i))
        outs.append(logits[:, 0, :])
    dec = jnp.stack(outs, axis=1)
    scale = float(jnp.max(jnp.abs(full)))
    err = float(jnp.max(jnp.abs(dec - full)))
    assert err < 0.08 * scale + 0.5, (err, scale)
    agree = float(jnp.mean(jnp.argmax(dec, -1) == jnp.argmax(full, -1)))
    assert agree > 0.85, agree


def test_stacked_scale_shapes():
    """Per-layer scales for stacked (scanned) weights."""
    cfg = dataclasses.replace(get_smoke_config("gemma-7b"), dtype="float32")
    params, axes = tfm.init_model(jax.random.PRNGKey(1), cfg)
    q = quantize_tree(params, axes)
    unit0 = q["units"]["0"]
    wq = unit0["attn"]["wq"]
    assert wq["w_q"].dtype == jnp.int8
    # stacked (n_units, in, H, dh) -> scales (n_units, H, dh)
    assert wq["w_s"].shape == (wq["w_q"].shape[0],) + wq["w_q"].shape[2:]
