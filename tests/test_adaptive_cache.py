"""Adaptive cache subsystem: counters, refresh, partitions, serving loop.

The invariant that matters everywhere: adaptivity changes *accounting
only*.  Result ids/dists are bit-identical to the uncached engine at any
budget, refresh cadence, or partition state — the hot set may move under
the search loop between batches but never inside one.
"""
import numpy as np
import pytest

from repro.core import SearchConfig
from repro.store import AdaptiveRecordCache, CachedRecordStore, filter_bucket

RECORD = 4096


def _search(engine, queries, mode="gate", L=64, W=4, target=0):
    tgt = np.full(queries.shape[0], target, np.int32)
    return engine.search(
        queries, filter_kind="label", filter_params=tgt,
        search_config=SearchConfig(mode=mode, search_l=L, beam_width=W),
    )


@pytest.fixture()
def adaptive_engine(tiny_engine):
    """Fresh adaptive engine per test — the cache is stateful."""
    return tiny_engine.with_cache(128 * RECORD, policy="adaptive",
                                  refresh_every=2)


def test_visit_counts_conserve_fetches(adaptive_engine, tiny_engine, tiny_corpus):
    """The loop-carried counters count exactly the fetch-path dispatches:
    sum(counts) == sum(n_ios + n_cache_hits), and (in gate mode) only
    filter-passing nodes are ever counted."""
    corpus, labels, queries = tiny_corpus
    out = _search(adaptive_engine, queries)
    counts = np.asarray(adaptive_engine.record_store.counts)
    fetched = int(np.sum(np.asarray(out.stats.n_ios))) + int(
        np.sum(np.asarray(out.stats.n_cache_hits))
    )
    assert int(counts.sum()) == fetched
    assert (np.asarray(labels)[counts > 0] == 0).all()


def test_adaptive_ids_identical_across_batches(adaptive_engine, tiny_engine,
                                               tiny_corpus):
    """Refreshes between batches must never change results — only move
    fetches between the slow tier and the cache tier."""
    _, _, queries = tiny_corpus
    base = _search(tiny_engine, queries)
    base_ios = np.asarray(base.stats.n_ios)
    # refresh_every=2, refresh runs lazily at search entry: batches 3 and
    # 5 find the cadence due, so 5 batches cross two refresh boundaries
    for batch in range(5):
        out = _search(adaptive_engine, queries)
        np.testing.assert_array_equal(
            np.asarray(out.ids), np.asarray(base.ids), err_msg=f"batch={batch}"
        )
        np.testing.assert_allclose(
            np.asarray(out.dists), np.asarray(base.dists), rtol=1e-6
        )
        np.testing.assert_array_equal(
            np.asarray(out.stats.n_ios) + np.asarray(out.stats.n_cache_hits),
            base_ios, err_msg=f"batch={batch}",
        )
    assert adaptive_engine.record_store.n_refreshes == 2


def test_adaptation_beats_static_on_repeated_workload(tiny_engine, tiny_corpus):
    """After warming on the live workload, the adaptive hot set must hit
    at least as often as the static filter-blind one at the same budget
    (and strictly more on this selective repeated workload)."""
    _, _, queries = tiny_corpus
    static = tiny_engine.with_cache(128 * RECORD, policy="visit_freq")
    adapt = tiny_engine.with_cache(128 * RECORD, policy="adaptive")
    adapt.warm(queries, filter_kind="label",
               filter_params=np.zeros(queries.shape[0], np.int32),
               search_config=SearchConfig(mode="gate", search_l=64, beam_width=4))
    out_s = _search(static, queries)
    out_a = _search(adapt, queries)
    hits_s = int(np.sum(np.asarray(out_s.stats.n_cache_hits)))
    hits_a = int(np.sum(np.asarray(out_a.stats.n_cache_hits)))
    assert hits_a > hits_s, (hits_a, hits_s)
    np.testing.assert_array_equal(np.asarray(out_a.ids), np.asarray(out_s.ids))


def test_refresh_keeps_shapes_stable(adaptive_engine, tiny_corpus):
    """Every refresh must re-materialize identically-shaped cache blocks,
    otherwise each refresh would retrace the jitted search loop."""
    _, _, queries = tiny_corpus
    store = adaptive_engine.record_store
    shape0 = tuple(store.global_store.cache_vectors.shape)
    slot0 = tuple(store.global_store.slot_of.shape)
    for _ in range(3):
        _search(adaptive_engine, queries)
        store.refresh()
        assert tuple(store.global_store.cache_vectors.shape) == shape0
        assert tuple(store.global_store.slot_of.shape) == slot0
        for part in store.partitions.values():
            assert tuple(part.store.cache_vectors.shape) == shape0
    assert store.n_cached <= store.n_slots


def test_per_filter_partitions_and_lru(tiny_engine, tiny_corpus):
    """Each filter bucket gets its own partition; the LRU keeps only the
    most recent ``cache_partitions`` of them."""
    _, _, queries = tiny_corpus
    eng = tiny_engine.with_cache(64 * RECORD, policy="adaptive",
                                 refresh_every=0, cache_partitions=2)
    store = eng.record_store
    for target in (0, 1, 2, 3):
        _search(eng, queries[:4], target=target)
    assert set(store.partitions) == {("label", 2), ("label", 3)}
    store.refresh()
    # a partition's learned hot set is drawn from ITS fetch population:
    # in gate mode only filter-passing nodes are fetched, so every node
    # with a live counter passes that partition's predicate
    _, labels, _ = tiny_corpus
    for (kind, tgt), part in store.partitions.items():
        counts = np.asarray(part.counts)
        assert (np.asarray(labels)[counts > 0] == tgt).all()
        assert isinstance(part.store, CachedRecordStore)
    assert store.last_refresh_sets == 3  # global + both dirty partitions
    store.refresh()
    assert store.last_refresh_sets == 1  # idle partitions keep their snapshot


def test_partition_snapshot_served_after_refresh(tiny_engine, tiny_corpus):
    _, _, queries = tiny_corpus
    eng = tiny_engine.with_cache(64 * RECORD, policy="adaptive", refresh_every=0)
    store = eng.record_store
    _search(eng, queries, target=0)
    bucket = filter_bucket("label", np.zeros(4, np.int32))
    assert store.store_for(bucket) is store.global_store  # not materialized yet
    store.refresh()
    assert store.store_for(bucket) is store.partitions[bucket].store


def test_filter_bucket_keys():
    assert filter_bucket(None, None) is None
    assert filter_bucket("label", np.asarray([3, 3, 1])) == ("label", 3)
    lo = np.asarray([0.5, 0.5]); hi = np.asarray([1.5, 1.5])
    assert filter_bucket("range", np.stack([lo, hi])) == ("range", 0.5, 1.5)
    b1 = filter_bucket("tags", np.asarray([[3, 0]], np.uint32))
    b2 = filter_bucket("tags", np.asarray([[3, 0]], np.uint32))
    assert b1 == b2 and b1[0] == "tags"


def test_wrap_pads_to_fixed_slots(tiny_engine):
    """The adaptive refresh path: wrap(n_slots=...) must pad the block to
    a fixed shape while mapping only the real hot ids."""
    backing = tiny_engine.record_store
    vecs, nbrs = tiny_engine.vectors, backing.neighbors
    store = CachedRecordStore.wrap(
        backing, vectors=vecs, neighbors=nbrs,
        hot_ids=np.asarray([5, 9], np.int32), policy="adaptive", n_slots=16,
    )
    assert store.cache_vectors.shape == (16, vecs.shape[1])
    assert store.n_cached == 2  # only the real hot ids are mapped
    assert store.hot_ids().tolist() == [5, 9]
    # truncation side: more hot ids than slots keeps the first n_slots
    store2 = CachedRecordStore.wrap(
        backing, vectors=vecs, neighbors=nbrs,
        hot_ids=np.arange(32, dtype=np.int32), policy="adaptive", n_slots=16,
    )
    assert store2.n_cached == 16
    assert store2.cache_vectors.shape == (16, vecs.shape[1])


def test_sub_record_budget_leaves_adaptive_off(tiny_engine, tiny_corpus):
    _, _, queries = tiny_corpus
    eng = tiny_engine.with_cache(100, policy="adaptive")
    assert not isinstance(eng.record_store, AdaptiveRecordCache)
    out = _search(eng, queries[:4])
    np.testing.assert_array_equal(np.asarray(out.stats.n_cache_hits), 0)


def test_modeled_cost_prices_refresh(tiny_engine, tiny_corpus):
    """Adaptive latency includes the amortized refresh term, so at equal
    stats it must price >= the static engine, and the term must shrink
    with a slower cadence."""
    _, _, queries = tiny_corpus
    fast = tiny_engine.with_cache(128 * RECORD, policy="adaptive",
                                  refresh_every=1)
    slow = tiny_engine.with_cache(128 * RECORD, policy="adaptive",
                                  refresh_every=8)
    static = tiny_engine.with_cache(128 * RECORD)
    out = _search(static, queries)
    lat_static = static.modeled_latency_us(out.stats)
    lat_fast = fast.modeled_latency_us(out.stats)
    lat_slow = slow.modeled_latency_us(out.stats)
    assert lat_fast > lat_slow > lat_static


def test_load_starts_counters_reset(tiny_engine, tiny_corpus, tmp_path):
    """save()/load() must never carry EMA counters implicitly: the loaded
    adaptive tier starts from the cold-start seed (zero counts, no
    partitions) even when the saved engine had a learned workload."""
    from repro.core.engine import GateANNEngine

    _, _, queries = tiny_corpus
    warm = tiny_engine.with_cache(128 * RECORD, policy="adaptive",
                                  refresh_every=1)
    _search(warm, queries)
    assert float(np.asarray(warm.record_store.counts).sum()) > 0
    path = str(tmp_path / "adaptive.gann")
    warm.save(path)
    eng = GateANNEngine.load(
        path, cache_budget_bytes=128 * RECORD, cache_policy="adaptive",
        refresh_every=1,
    )
    store = eng.record_store
    assert float(np.asarray(store.counts).sum()) == 0.0
    assert len(store.partitions) == 0
    assert store.batches_since_refresh == 0
    # cold hot set == the seed, and results match the saved engine exactly
    np.testing.assert_array_equal(store.hot_ids(), store.seed_hot_ids)
    out = _search(eng, queries)
    base = _search(tiny_engine, queries)
    np.testing.assert_array_equal(np.asarray(out.ids), np.asarray(base.ids))


def test_export_restore_carries_workload_across_save_load(
    tiny_engine, tiny_corpus, tmp_path
):
    """The explicit persist-and-remap path: export_state before save,
    restore_state after load → the first post-restore search already
    serves the learned hot set (no re-warm), with identical results."""
    from repro.core.engine import GateANNEngine

    _, _, queries = tiny_corpus
    warm = tiny_engine.with_cache(128 * RECORD, policy="adaptive",
                                  refresh_every=0)
    for _ in range(3):
        _search(warm, queries)
    warm.record_store.refresh()
    state = warm.record_store.export_state()
    warm_hits = int(np.sum(np.asarray(_search(warm, queries).stats.n_cache_hits)))
    path = str(tmp_path / "adaptive.gann")
    warm.save(path)
    eng = GateANNEngine.load(
        path, cache_budget_bytes=128 * RECORD, cache_policy="adaptive",
        refresh_every=0,
    )
    store = eng.record_store
    eng.record_store.restore_state(state)
    np.testing.assert_allclose(
        np.asarray(store.counts), np.asarray(state["counts"]), rtol=1e-6
    )
    assert set(store.partitions) == {k for k, _ in state["partitions"]}
    # restore_state refreshes immediately: partition snapshots are live
    for part in store.partitions.values():
        assert part.store is not None
    out = _search(eng, queries)
    hits = int(np.sum(np.asarray(out.stats.n_cache_hits)))
    assert hits == warm_hits  # same hot set → same hit pattern, no re-warm
    base = _search(tiny_engine, queries)
    np.testing.assert_array_equal(np.asarray(out.ids), np.asarray(base.ids))


def test_restore_state_rejects_mismatched_corpus(adaptive_engine, tiny_corpus):
    _, _, queries = tiny_corpus
    _search(adaptive_engine, queries[:4])
    store = adaptive_engine.record_store
    state = store.export_state()
    bad = dict(state, n=state["n"] + 1)
    with pytest.raises(ValueError, match="keyed to node ids"):
        store.restore_state(bad)
    bad2 = dict(state, counts=state["counts"][:-1])
    with pytest.raises(ValueError, match="keyed to node ids"):
        store.restore_state(bad2)


def test_reset_counters_forgets_workload(adaptive_engine, tiny_corpus):
    _, _, queries = tiny_corpus
    for _ in range(3):
        _search(adaptive_engine, queries)
    store = adaptive_engine.record_store
    assert float(np.asarray(store.counts).sum()) > 0
    store.reset_counters()
    assert float(np.asarray(store.counts).sum()) == 0.0
    assert len(store.partitions) == 0
    assert store.batches_since_refresh == 0
    np.testing.assert_array_equal(store.hot_ids(), store.seed_hot_ids)
    out = _search(adaptive_engine, queries)
    base = _search(adaptive_engine.with_cache(0), queries)
    np.testing.assert_array_equal(np.asarray(out.ids), np.asarray(base.ids))


def test_rag_server_drives_the_control_loop(tiny_engine, tiny_corpus):
    """RAGServer.retrieve refreshes the adaptive cache between batches and
    io_report surfaces the adaptation state."""
    from repro.serve.rag import RAGRequest, RAGServer

    corpus, _, queries = tiny_corpus
    eng = tiny_engine.with_cache(128 * RECORD, policy="adaptive",
                                 refresh_every=1)
    server = RAGServer(
        engine=eng, cfg=None, params=None, layout=None,
        passage_tokens=np.zeros((corpus.shape[0], 4), np.int32),
        search_config=SearchConfig(mode="gate", search_l=64, beam_width=4),
    )
    reqs = [
        RAGRequest(query_vec=q, prompt_tokens=np.zeros(4, np.int32),
                   filter_kind="label", filter_params=np.int32(0))
        for q in queries[:8]
    ]
    server.retrieve(reqs)
    first_rate = server.last_batch_hit_rate
    server.retrieve(reqs)  # same batch again — now served from the hot set
    rep = server.io_report()
    assert rep["cache_policy"] == "adaptive"
    assert rep["cache_refreshes"] >= 2
    assert rep["cache_partitions"] == 1
    assert rep["last_batch_hit_rate"] > first_rate
    assert rep["cache_hits"] > 0
    assert 0.0 <= rep["cache_hit_rate"] <= 1.0
