"""gatelint analyzer tests — every rule proven to fire and to stay quiet.

Fixture snippets are parsed, never executed, so they can reference jax /
np freely.  The whole-tree test at the bottom makes tier-1 itself the
lint gate: a new unsuppressed finding anywhere in ``src/`` fails the
suite, not just the CI lint job.
"""
import json
import os
import subprocess
import sys
import threading
from pathlib import Path

import pytest

from repro.analysis import core
from repro.analysis.lockdep import LockOrderRecorder

REPO = Path(__file__).resolve().parents[1]


def live(source, rule=None):
    """Unsuppressed findings for a snippet, optionally one rule only."""
    out = [f for f in core.lint_source(source, "fixture.py")
           if not f.suppressed]
    if rule is not None:
        out = [f for f in out if f.rule == rule]
    return out


# ---------------------------------------------------------------- locks --
LOCK_VIOLATION = """
import threading

class Store:
    def __init__(self):
        self._lock = threading.Lock()
        self._pending = {}  # guarded by _lock
        with self._lock:
            self._reset_counters_locked()

    def _reset_counters_locked(self):
        self.reads = 0
        self.rounds = 0

    def fetch(self, k):
        self.reads += 1            # RMW outside the lock
        self.rounds = self.rounds + 1  # ditto, plain-assign form
        self._pending[k] = object()    # container store outside the lock
        self._pending.pop(k)           # mutator call outside the lock
"""

LOCK_CLEAN = """
import threading

class Store:
    def __init__(self):
        self._lock = threading.Lock()
        self._pending = {}  # guarded by _lock
        self.generation = 0
        with self._lock:
            self._reset_counters_locked()

    def _reset_counters_locked(self):
        self.reads = 0

    def _bump_locked(self):
        self.reads += 1  # caller holds the lock by convention

    def fetch(self, k):
        with self._lock:
            self.reads += 1
            self._pending[k] = object()
            del self._pending[k]
        self.generation = 7  # plain overwrite of an unguarded attr

    def close(self):
        self.reads_done = True  # unguarded attr: no finding
"""


def test_lock_rule_fires():
    findings = live(LOCK_VIOLATION, "lock-guarded-write")
    assert len(findings) == 4, [f.render() for f in findings]
    messages = " | ".join(f.message for f in findings)
    assert "self.reads" in messages
    assert "self._pending" in messages
    assert all("_lock" in f.message for f in findings)


def test_lock_rule_negative():
    assert live(LOCK_CLEAN, "lock-guarded-write") == []


def test_lock_rule_annotation_names_other_locks():
    src = """
class Seg:
    fd: int = -1  # guarded by _open_lock

    def reopen(self):
        self.fd += 1
"""
    (f,) = live(src, "lock-guarded-write")
    assert "_open_lock" in f.message


# ---------------------------------------------------------------- trace --
TRACE_BRANCH_VIOLATION = """
import jax

def run(init):
    def cond(state):
        return state[0] > 0

    def body(state):
        x, acc = state
        if x > 3:            # host branch on a traced carry
            acc = acc + 1
        while acc > 0:       # host while on a traced value
            acc = acc - 1
        return (x - 1, acc)

    return jax.lax.while_loop(cond, body, init)
"""

TRACE_BRANCH_CLEAN = """
import functools
import jax

def run(init, cfg):
    def body(state):
        x, acc = state
        if cfg is None:            # `is None` compare: trace-static
            acc = acc + 1
        if x.ndim == 0:            # shape metadata: trace-static
            acc = acc + 2
        track = cfg is not None
        if track:                  # derived from an is-compare: static
            acc = acc + 3
        return (x - 1, acc)

    return jax.lax.while_loop(lambda s: s[0] > 0, body, init)

@functools.partial(jax.jit, static_argnames=("mode",))
def dispatch(x, mode):
    if mode == "gate":             # static_argnames param: trace-static
        return x + 1
    return x
"""


def test_trace_host_branch_fires():
    findings = live(TRACE_BRANCH_VIOLATION, "trace-host-branch")
    assert len(findings) == 2, [f.render() for f in findings]
    assert any("`if`" in f.message for f in findings)
    assert any("`while`" in f.message for f in findings)


def test_trace_host_branch_negative():
    assert live(TRACE_BRANCH_CLEAN, "trace-host-branch") == []


def test_trace_dynamic_shape_fires_and_negative():
    bad = """
import jax, jax.numpy as jnp

def f(carry, x):
    hits = jnp.nonzero(x > 0)      # no size=
    idx = jnp.where(x > 0)         # one-argument where
    return carry, hits

out = jax.lax.scan(f, 0, xs)
"""
    findings = live(bad, "trace-dynamic-shape")
    assert len(findings) == 2, [f.render() for f in findings]

    good = """
import jax, jax.numpy as jnp

def f(carry, x):
    hits = jnp.nonzero(x > 0, size=8, fill_value=-1)
    masked = jnp.where(x > 0, x, 0.0)
    return carry, (hits, masked)

out = jax.lax.scan(f, 0, xs)

def host_path(x):
    return jnp.nonzero(x)  # not a traced context: fine
"""
    assert live(good, "trace-dynamic-shape") == []


def test_trace_rng_fires_and_negative():
    bad = """
import jax
import numpy as np

def body(i, val):
    noise = np.random.rand(4)      # baked in at trace time
    return val + noise

out = jax.lax.fori_loop(0, 8, body, v0)
"""
    (f,) = live(bad, "trace-unseeded-rng")
    assert "np.random" in f.message

    good = """
import jax
import numpy as np

def body(i, val):
    key = jax.random.fold_in(base_key, i)
    return val + jax.random.normal(key, (4,))

out = jax.lax.fori_loop(0, 8, body, v0)

rng = np.random.default_rng(0)  # host-side, outside any traced context
"""
    assert live(good, "trace-unseeded-rng") == []


# --------------------------------------------------------------- timing --
def test_timing_rule_fires():
    bad = """
import time

def span():
    t0 = time.time()
    work()
    dt = time.time() - t0
    return dt

def mono():
    t0 = time.monotonic()
    work()
    hist.observe(time.monotonic() - t0)
"""
    findings = live(bad, "timing-wallclock")
    assert len(findings) >= 2, [f.render() for f in findings]


def test_timing_rule_honors_import_aliases():
    bad = """
from time import time as now

def span():
    t0 = now()
    work()
    return now() - t0
"""
    assert live(bad, "timing-wallclock")

    # aliasing perf_counter *onto* the name `time` must stay clean
    good = """
from time import perf_counter as time

def span():
    t0 = time()
    work()
    return time() - t0
"""
    assert live(good, "timing-wallclock") == []


def test_timing_rule_negative():
    good = """
import time

def span():
    t0 = time.perf_counter()
    work()
    return time.perf_counter() - t0

def stamp_only():
    started_at = time.time()   # absolute timestamp, no duration math
    log(started_at)
    deadline = started_at + 30.0  # addition is not a duration
    return deadline
"""
    assert live(good, "timing-wallclock") == []


# --------------------------------------------------------------- tokens --
def test_token_rule_fires_on_discard_and_never_drained():
    bad = """
def discard(store, ids):
    store.submit(ids)

def forget(store, ids):
    token, nbrs = store.submit(ids)
    return nbrs
"""
    findings = live(bad, "token-leak")
    assert len(findings) == 2, [f.render() for f in findings]
    assert any("discarded" in f.message for f in findings)
    assert any("never drained" in f.message for f in findings)


def test_token_rule_fires_on_partial_paths_and_exception_edge():
    bad = """
def one_branch(store, ids, ok):
    token, nbrs = store.submit(ids)
    if ok:
        store.drain(token)
    return nbrs

def exception_edge(store, ids):
    token, nbrs = store.submit(ids)
    risky_transform(nbrs)
    return store.drain(token)
"""
    findings = live(bad, "token-leak")
    assert len(findings) == 2, [f.render() for f in findings]
    assert any("every path" in f.message for f in findings)
    assert any("may raise" in f.message for f in findings)


def test_token_rule_negative():
    good = """
def straight(store, ids):
    token, nbrs = store.submit(ids)
    return store.drain(token)

def both_branches(store, ids, ok):
    token, nbrs = store.submit(ids)
    if ok:
        store.drain(token)
    else:
        store.abandon_pending(token)
    return nbrs

def protected(store, ids):
    token, nbrs = store.submit(ids)
    try:
        risky_transform(nbrs)
    finally:
        store.drain(token)

def ownership_transfer(store, pending, ids):
    token, nbrs = store.submit(ids)
    pending[token] = ids       # the pending map now owns the token
    return nbrs

def executor(self, fn):
    self._pool.submit(fn)      # Future, not an I/O token

def expected_to_raise(store):
    import pytest
    with pytest.raises(ValueError):
        store.submit(None)     # raises before a token exists
"""
    assert live(good, "token-leak") == []


def test_token_rule_loop_body_reuse_counts():
    good = """
def pipelined(store, rounds, ids):
    pending = []
    for _ in range(rounds):
        token, nbrs = store.submit(ids)
        pending.append(token)
        ids = nbrs
    for token in pending:
        store.drain(token)
"""
    assert live(good, "token-leak") == []


# -------------------------------------------------------- silent-except --
def test_silent_except_fires_on_broad_swallows():
    src = """
import os

def sweep(paths):
    for p in paths:
        try:
            os.remove(p)
        except OSError:
            pass

def drain(q):
    while True:
        try:
            q.get_nowait()
        except Exception:
            continue

def teardown(self):
    try:
        self.close()
    except:
        ...
"""
    findings = live(src, "silent-except")
    assert len(findings) == 3
    assert {f.line for f in findings} == {8, 15, 21}
    assert any("bare except" in f.message for f in findings)


def test_silent_except_negative():
    # narrow catches, handled errors, and re-raises are all fine
    src = """
import errno, os

def read(fd):
    try:
        return os.pread(fd, 10, 0)
    except OSError as e:
        if e.errno != errno.EIO:
            raise
        self.warm_errors += 1
        return b""

def lookup(d, k):
    try:
        return d[k]
    except KeyError:
        pass  # narrow catch: expected control flow

def logged(fn):
    try:
        fn()
    except Exception as e:
        print("failed:", e)
"""
    assert live(src, "silent-except") == []


# --------------------------------------- suppressions, baseline, meta --
def test_suppression_with_reason_silences_and_records():
    src = """
import time

def span():
    t0 = time.time()
    return time.time() - t0  # gatelint: disable=timing-wallclock — fixture: proving pragmas work
"""
    findings = core.lint_source(src, "fixture.py")
    assert [f for f in findings if not f.suppressed] == []
    (sup,) = [f for f in findings if f.suppressed]
    assert sup.rule == "timing-wallclock"
    assert "pragmas work" in sup.suppress_reason


def test_suppression_without_reason_is_itself_a_finding():
    # the pragma is assembled at runtime so linting THIS file doesn't
    # see a reasonless marker in its raw source
    pragma = "# gate" + "lint: disable=timing-wallclock"
    src = (
        "import time\n\n"
        "def span():\n"
        "    t0 = time.time()\n"
        f"    return time.time() - t0  {pragma}\n"
    )
    findings = core.lint_source(src, "fixture.py")
    rules = [f.rule for f in findings if not f.suppressed]
    assert rules == ["suppression-missing-reason"]


def test_suppression_unknown_rule_is_flagged():
    pragma = "# gate" + "lint: disable=no-such-rule — because"
    findings = core.lint_source(f"x = 1  {pragma}\n", "fixture.py")
    (f,) = findings
    assert f.rule == "suppression-missing-reason"
    assert "no-such-rule" in f.message


def test_baseline_absorbs_up_to_count():
    src = """
def a(store, ids):
    store.submit(ids)

def b(store, ids):
    store.submit(ids)
"""
    findings = core.lint_source(src, "fixture.py")
    assert len(findings) == 2
    core.apply_baseline(findings, [
        {"path": "fixture.py", "rule": "token-leak", "count": 1,
         "reason": "fixture"},
    ])
    assert sum(f.baselined for f in findings) == 1
    assert sum(not f.baselined for f in findings) == 1


def test_parse_error_is_a_finding():
    findings = core.lint_source("def broken(:\n", "fixture.py")
    assert [f.rule for f in findings] == ["parse-error"]


def test_every_rule_has_an_explanation():
    for rule in core.RULES.values():
        assert rule.summary and len(rule.rationale) > 80, rule.id


# ------------------------------------------------------------- lockdep --
def test_lockdep_clean_ordering():
    rec = LockOrderRecorder()
    a = rec.wrap(threading.Lock(), "A")
    b = rec.wrap(threading.Lock(), "B")

    def worker():
        for _ in range(50):
            with a:
                with b:
                    pass

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert ("A", "B") in rec.edges()
    assert rec.inversions() == []
    rec.assert_no_inversions()


def test_lockdep_detects_inversion():
    rec = LockOrderRecorder()
    a = rec.wrap(threading.Lock(), "A")
    b = rec.wrap(threading.Lock(), "B")
    # sequential opposite-order nesting: never deadlocks here, but two
    # concurrent threads doing this would — exactly what lockdep catches
    with a:
        with b:
            pass
    with b:
        with a:
            pass
    assert rec.inversions() == [("A", "B")]
    with pytest.raises(AssertionError, match="lock-order inversions"):
        rec.assert_no_inversions()


def test_lockdep_self_edge_same_name_instances():
    rec = LockOrderRecorder()
    s1 = rec.wrap(threading.Lock(), "Seg._open_lock")
    s2 = rec.wrap(threading.Lock(), "Seg._open_lock")
    with s1:
        with s2:
            pass
    assert rec.inversions() == [("Seg._open_lock", "Seg._open_lock")]


# ------------------------------------------------------------------ CLI --
def _run_cli(args, cwd=None):
    return subprocess.run(
        [sys.executable, str(REPO / "scripts" / "gatelint.py"), *args],
        capture_output=True, text=True, cwd=cwd or str(REPO),
    )


def test_cli_seeded_violation_fails_build(tmp_path):
    bad = tmp_path / "seeded.py"
    bad.write_text(
        "import time\n\n"
        "def span():\n"
        "    t0 = time.time()\n"
        "    return time.time() - t0\n"
    )
    proc = _run_cli([str(bad)])
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "timing-wallclock" in proc.stdout

    proc_json = _run_cli([str(bad), "--json"])
    assert proc_json.returncode == 1
    doc = json.loads(proc_json.stdout)
    assert doc["summary"]["live"] == 1
    (finding,) = doc["findings"]
    assert finding["rule"] == "timing-wallclock"
    assert finding["line"] == 5
    assert finding["file"].endswith("seeded.py")


def test_cli_clean_file_exits_zero(tmp_path):
    good = tmp_path / "clean.py"
    good.write_text(
        "import time\n\n"
        "def span():\n"
        "    t0 = time.perf_counter()\n"
        "    return time.perf_counter() - t0\n"
    )
    proc = _run_cli([str(good)])
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_explain_and_list_rules():
    proc = _run_cli(["--explain", "token-leak"])
    assert proc.returncode == 0
    assert "reader-pool slot" in proc.stdout
    assert _run_cli(["--explain", "bogus"]).returncode == 2
    listing = _run_cli(["--list-rules"])
    assert listing.returncode == 0
    for rule_id in core.RULES:
        assert rule_id in listing.stdout


# --------------------------------------------------------- whole tree --
def test_whole_tree_src_is_clean(monkeypatch):
    """The gate itself: zero unsuppressed findings on src/ — with no
    baseline, so src stays clean outright."""
    monkeypatch.chdir(REPO)
    findings = core.lint_paths(["src"])
    livef = [f for f in findings if not f.suppressed]
    assert livef == [], "\n".join(f.render() for f in livef)


def test_whole_tree_with_tests_and_baseline(monkeypatch):
    """Extended (nightly) coverage: src + tests + benchmarks + scripts
    must be clean modulo the checked-in baseline allowances."""
    monkeypatch.chdir(REPO)
    findings = core.lint_paths(["src", "tests", "benchmarks", "scripts"])
    core.apply_baseline(findings, core.load_baseline("analysis_baseline.json"))
    livef = [f for f in findings if not f.suppressed and not f.baselined]
    assert livef == [], "\n".join(f.render() for f in livef)


def test_suppressions_in_tree_all_carry_reasons(monkeypatch):
    monkeypatch.chdir(REPO)
    findings = core.lint_paths(["src", "tests", "benchmarks", "scripts"])
    assert not any(f.rule == "suppression-missing-reason" for f in findings)
