"""System behaviour: the paper's core claims on a small corpus.

These are the structural invariants that transfer exactly from the paper:
  * gate recall ≈ post recall at equal L (tunneling preserves connectivity)
  * gate I/O ≈ selectivity x post I/O  (the 1/s law, Fig. 7)
  * naive pre-filtering recalls less at equal L (connectivity collapse)
  * early-filter pays the same I/O as post (Fig. 18)
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SearchConfig, recall_at_k
from repro.data import filtered_ground_truth


def _search(engine, queries, mode, L=96, target=0):
    tgt = np.full(queries.shape[0], target, np.int32)
    return engine.search(
        queries, filter_kind="label", filter_params=tgt,
        search_config=SearchConfig(mode=mode, search_l=L, beam_width=4),
    )


@pytest.fixture(scope="module")
def runs(tiny_engine, tiny_corpus):
    corpus, labels, queries = tiny_corpus
    gt = filtered_ground_truth(corpus, queries, np.asarray(labels) == 0, k=10)
    outs = {m: _search(tiny_engine, queries, m) for m in
            ("gate", "post", "early", "pre_naive")}
    return outs, gt


def _mean(x):
    return float(np.mean(np.asarray(x)))


def test_gate_matches_post_recall(runs):
    outs, gt = runs
    r_gate = recall_at_k(outs["gate"].ids, gt)
    r_post = recall_at_k(outs["post"].ids, gt)
    assert r_gate >= r_post - 0.05, (r_gate, r_post)


def test_io_reduction_tracks_selectivity(runs):
    """~10% selectivity -> gate issues ~10% of post's I/Os (paper Fig. 7)."""
    outs, _ = runs
    ratio = _mean(outs["gate"].stats.n_ios) / max(_mean(outs["post"].stats.n_ios), 1e-9)
    assert 0.03 < ratio < 0.3, ratio


def test_gate_results_all_pass_filter(runs, tiny_corpus):
    _, labels, _ = tiny_corpus
    outs, _ = runs
    ids = np.asarray(outs["gate"].ids)
    got = ids[ids >= 0]
    assert (np.asarray(labels)[got] == 0).all()


def test_naive_prefilter_loses_recall(runs):
    outs, gt = runs
    r_naive = recall_at_k(outs["pre_naive"].ids, gt)
    r_gate = recall_at_k(outs["gate"].ids, gt)
    assert r_naive < r_gate, (r_naive, r_gate)


def test_early_filter_pays_full_io(runs):
    outs, _ = runs
    assert _mean(outs["early"].stats.n_ios) == pytest.approx(
        _mean(outs["post"].stats.n_ios), rel=1e-6
    )
    # ... but computes far fewer exact distances
    assert _mean(outs["early"].stats.n_exact) < 0.5 * _mean(outs["post"].stats.n_exact)


def test_tunnels_only_in_gate_mode(runs):
    outs, _ = runs
    assert _mean(outs["gate"].stats.n_tunnels) > 0
    for m in ("post", "early", "pre_naive"):
        assert _mean(outs[m].stats.n_tunnels) == 0


def test_stats_invariants(runs):
    """Dispatches are bounded by hops x W; fetches+tunnels == dispatches in gate."""
    outs, _ = runs
    for mode, out in outs.items():
        ios = np.asarray(out.stats.n_ios)
        tun = np.asarray(out.stats.n_tunnels)
        hops = np.asarray(out.stats.n_hops)
        assert (ios + tun <= hops * 4).all(), mode
        assert (ios >= 0).all() and (tun >= 0).all()


def test_range_predicate(tiny_engine, tiny_corpus):
    corpus, _, queries = tiny_corpus
    norms = np.linalg.norm(corpus, axis=1)
    lo, hi = np.quantile(norms, [0.4, 0.5])
    gt = filtered_ground_truth(corpus, queries, (norms >= lo) & (norms <= hi), k=10)
    b = queries.shape[0]
    out = tiny_engine.search(
        queries, filter_kind="range",
        filter_params=(np.full(b, lo, np.float32), np.full(b, hi, np.float32)),
        search_config=SearchConfig(mode="gate", search_l=96, beam_width=4),
    )
    ids = np.asarray(out.ids)
    got = ids[ids >= 0]
    assert ((norms[got] >= lo) & (norms[got] <= hi)).all()
    assert recall_at_k(out.ids, gt) > 0.3


def test_unfiltered_high_recall(tiny_engine, tiny_corpus):
    corpus, _, queries = tiny_corpus
    gt = filtered_ground_truth(corpus, queries, np.ones(len(corpus), bool), k=10)
    out = tiny_engine.search(
        queries, search_config=SearchConfig(mode="unfiltered", search_l=64, beam_width=4)
    )
    assert recall_at_k(out.ids, gt) > 0.9
