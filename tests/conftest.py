import os
import sys

# Tests see the real device count (1 CPU) — the 512-device flag is ONLY for
# the dry-run launcher. Distributed tests spawn subprocesses with their own
# XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


def pytest_configure(config):
    # registered in pytest.ini too; kept here so a bare `pytest tests/...`
    # from another rootdir still knows the marker
    config.addinivalue_line(
        "markers", "slow: builds big graphs or jits large shapes; not tier-1"
    )


# Canonical tiny setup — plain functions so non-pytest callers (e.g. the
# recall-pin regenerator in test_recall_regression.py) build the *same*
# corpus/engine the session fixtures use and can never drift from them.
def make_tiny_corpus():
    from repro.data import make_bigann_like, make_queries, uniform_labels

    n, d = 2000, 24
    corpus = make_bigann_like(n, d, seed=0)
    labels = uniform_labels(n, 10, seed=0)
    queries = make_queries(corpus, 16, seed=1)
    return corpus, labels, queries


def make_tiny_engine(corpus, labels):
    from repro.core import EngineConfig, GateANNEngine

    return GateANNEngine.build(
        corpus,
        config=EngineConfig(degree=20, build_l=40, pq_chunks=8, r_max=10),
        labels=labels,
        attributes=np.linalg.norm(corpus, axis=1).astype(np.float32),
    )


@pytest.fixture(scope="session")
def tiny_corpus():
    return make_tiny_corpus()


@pytest.fixture(scope="session")
def tiny_engine(tiny_corpus):
    """One engine for every module — the Vamana build dominates tier-1
    setup time, so it runs once per session (N/D/L/W kept small)."""
    corpus, labels, _ = tiny_corpus
    return make_tiny_engine(corpus, labels)


@pytest.fixture(scope="session")
def tiny_cached_engine(tiny_engine):
    """The same engine with a 128-record hot-node cache in front of the
    slow tier (shares graph/PQ/filters with ``tiny_engine``)."""
    return tiny_engine.with_cache(128 * 4096)
