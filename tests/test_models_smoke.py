"""Per-arch smoke: reduced config, one train step + one decode step on CPU,
asserting output shapes and no NaNs (deliverable (f))."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, shapes_for
from repro.configs.base import ShapeConfig
from repro.distributed.sharding import NULL_LAYOUT
from repro.models import transformer as tfm
from repro.models import zoo

# 10 archs x (train + decode) jits ~2 min of large shapes — not tier-1.
pytestmark = pytest.mark.slow


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    params, _ = tfm.init_model(jax.random.PRNGKey(0), cfg)
    shape = ShapeConfig("smoke", 32, 2, "train")
    batch = zoo.make_concrete_batch(cfg, shape)
    loss, grads = jax.jit(
        jax.value_and_grad(lambda p: tfm.lm_loss(p, cfg, NULL_LAYOUT, batch))
    )(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(l))) for l in leaves)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_smoke(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    params, _ = tfm.init_model(jax.random.PRNGKey(0), cfg)
    caches = tfm.init_caches(cfg, 2, 16, jnp.float32)
    logits, new_caches = jax.jit(
        lambda p, c, t, pos: tfm.forward_decode(p, cfg, NULL_LAYOUT, t, c, pos)
    )(params, caches, jnp.zeros((2, 1), jnp.int32), jnp.int32(0))
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_consistency(arch):
    """Full config matches the assigned spec (layer counts, dims, vocab)."""
    cfg = get_config(arch)
    assert cfg.n_layers == len(cfg.layer_kinds)
    assert cfg.d_model % 16 == 0  # decode TP divisibility
    if cfg.d_ff:
        assert cfg.d_ff % 16 == 0
    shapes = shapes_for(cfg)
    names = [s.name for s in shapes]
    assert "train_4k" in names and "decode_32k" in names
    assert ("long_500k" in names) == cfg.supports_long_context


def test_param_counts_plausible():
    """Declared param counts should be near the models' nameplates."""
    expect = {
        "gemma-7b": (7e9, 10e9),
        "deepseek-coder-33b": (30e9, 36e9),
        "qwen2.5-32b": (29e9, 36e9),
        "dbrx-132b": (110e9, 145e9),
        "llama4-maverick-400b-a17b": (350e9, 450e9),
        "recurrentgemma-9b": (7.5e9, 11e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e}, {hi:.1e}]"
    active = get_config("llama4-maverick-400b-a17b").active_param_count()
    assert 12e9 <= active <= 25e9, active


def test_vision_stub_prefix():
    cfg = dataclasses.replace(get_smoke_config("internvl2-2b"), dtype="float32")
    params, _ = tfm.init_model(jax.random.PRNGKey(0), cfg)
    b, t = 2, 24
    batch = {
        "tokens": jnp.zeros((b, t - cfg.n_prefix_embeds), jnp.int32),
        "targets": jnp.zeros((b, t - cfg.n_prefix_embeds), jnp.int32),
        "prefix_embeds": jnp.asarray(
            np.random.default_rng(0).normal(size=(b, cfg.n_prefix_embeds, cfg.d_model))
            * 0.02, jnp.float32),
    }
    loss = jax.jit(lambda p: tfm.lm_loss(p, cfg, NULL_LAYOUT, batch))(params)
    assert np.isfinite(float(loss))
