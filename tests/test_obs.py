"""Telemetry subsystem: registry, histograms, tracer, exports, contracts.

Tier-1 coverage for ``src/repro/obs``:

  * registry semantics — families are (name, kind, labels); mismatched
    kinds/label sets raise; ``name`` is a reserved label key; a
    DISABLED registry's write path is an early-out (pinned structurally
    and by the overhead guard below).
  * histogram percentiles — log-bucket p50/p99 land within one bucket
    ratio of the exact sample percentiles; sum/count/mean are exact.
  * exporters — Prometheus text renders identically from the live
    registry and from its JSON snapshot (the scrape-vs-artifact
    bit-exactness the nightly ``obs-contracts`` job relies on).
  * tracer — perf_counter spans land in per-thread rings and the
    ``trace.span_seconds`` histogram family; sampling keeps 1-in-N;
    disabled tracing returns the shared no-op context manager.
  * store reconciliation — a disk-tier search's registry families
    agree bit-exactly with ``DiskRecordStore.io_counters()`` and with
    the summed ``SearchStats``.
  * monotonic timing (satellite) — serving-path span math never reads
    ``time.time()``: a wall-clock step backwards mid-request cannot
    produce a negative span.
  * overhead guard (satellite) — with telemetry disabled, the
    instrumented search path must stay within noise of a no-op stub:
    the stats-recording hook is proven unreachable, and the disabled
    counter/span primitives stay within an order of magnitude of an
    empty call (generous bound — CI timing noise, not a benchmark).
"""
import json
import math
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.obs import export, registry as regm, tracer as tracerm


# ---------------------------------------------------------------- registry
def test_counter_gauge_families():
    reg = obs.MetricsRegistry(enabled=True)
    reg.counter("req.total", tenant="a").inc()
    reg.counter("req.total", tenant="a").inc(2)
    reg.counter("req.total", tenant="b").inc(5)
    assert reg.counter("req.total", tenant="a").value == 3
    assert reg.family_total("req.total") == 8
    assert reg.family_total("req.total", tenant="b") == 5
    g = reg.gauge("depth")
    g.set(4)
    g.inc()
    g.dec(2)
    assert g.value == 3
    # same name, different kind or label set => error
    with pytest.raises(TypeError, match="is a counter"):
        reg.gauge("req.total", tenant="a")
    with pytest.raises(ValueError, match="has labels"):
        reg.counter("req.total", shard="0")
    # the `name` label key collides with the positional family name —
    # reserved by the API (use another key, e.g. `span`)
    with pytest.raises(TypeError):
        reg.counter("x", name="y")


def test_disabled_registry_records_nothing():
    reg = obs.MetricsRegistry(enabled=False)
    c = reg.counter("n")
    h = reg.histogram("h")
    c.inc(100)
    h.observe(1.0)
    assert c.value == 0 and h.count == 0
    reg.enable()
    c.inc(1)
    assert c.value == 1
    reg.disable()
    c.inc(1)
    assert c.value == 1


def test_registry_snapshot_shape():
    reg = obs.MetricsRegistry(enabled=True)
    reg.counter("a.b", mode="gate").inc(7)
    reg.histogram("lat").observe(0.5)
    snap = reg.snapshot()
    assert snap["a.b"]["kind"] == "counter"
    assert snap["a.b"]["total"] == 7
    assert snap["a.b"]["children"][0]["labels"] == {"mode": "gate"}
    h = snap["lat"]
    assert h["kind"] == "histogram"
    child = h["children"][0]
    assert child["count"] == 1 and child["sum"] == 0.5
    assert child["min"] == child["max"] == 0.5
    json.dumps(snap)  # JSON-serializable as-is


# -------------------------------------------------------------- histograms
def test_histogram_percentiles_within_bucket_error():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=-5.0, sigma=1.0, size=20_000)
    reg = obs.MetricsRegistry(enabled=True)
    h = reg.histogram("lat")
    for v in samples:
        h.observe(v)
    assert h.count == samples.size
    assert h.sum == pytest.approx(float(samples.sum()))
    assert h.mean == pytest.approx(float(samples.mean()))
    # worst-case relative error is one bucket ratio (~26% at 10/decade);
    # allow a bit of slack for the interpolation at the bucket ends
    ratio = 10 ** (1 / regm.HIST_PER_DECADE)
    for q in (0.50, 0.99, 0.999):
        exact = float(np.quantile(samples, q))
        got = h.quantile(q)
        assert exact / (ratio * 1.1) <= got <= exact * (ratio * 1.1), \
            f"q={q}: got {got}, exact {exact}"
    # quantiles never extrapolate outside the observed range
    assert h.quantile(0.0) >= float(samples.min())
    assert h.quantile(1.0) <= float(samples.max())


def test_histogram_concurrent_observe_exact_count():
    reg = obs.MetricsRegistry(enabled=True)
    h = reg.histogram("lat")
    n_threads, per = 8, 2000

    def work():
        for i in range(per):
            h.observe(1e-4 * (1 + i % 7))

    ts = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert h.count == n_threads * per


# --------------------------------------------------------------- exporters
def test_prometheus_identical_from_registry_and_snapshot():
    reg = obs.MetricsRegistry(enabled=True)
    reg.counter("disk.records_read", store="x.gann").inc(42)
    reg.gauge("disk.inflight_depth", store="x.gann").set(3)
    h = reg.histogram("trace.span_seconds", span="disk.preadv")
    for v in (1e-4, 2e-4, 5e-3):
        h.observe(v)
    live = export.to_prometheus(reg)
    snap = export.to_json(reg, tracerm.Tracer())
    again = export.to_prometheus(snap)
    assert live == again
    assert 'gateann_disk_records_read{store="x.gann"} 42' in live
    assert "# TYPE gateann_trace_span_seconds histogram" in live
    # cumulative buckets end at +Inf == count
    assert 'le="+Inf"' in live
    assert "gateann_trace_span_seconds_count" in live
    doc = export.to_json(reg, tracerm.Tracer())
    assert doc["schema_version"] == export.SCHEMA_VERSION
    assert doc["families"]["disk.records_read"]["total"] == 42


def test_write_obs_json_sections(tmp_path):
    reg = obs.MetricsRegistry(enabled=True)
    reg.counter("serve.admitted", tenant="t0").inc(5)
    path = tmp_path / "obs.json"
    payload = export.write_obs_json(
        str(path), sections={"serve": (reg, tracerm.Tracer())}
    )
    on_disk = json.loads(path.read_text())
    assert on_disk == json.loads(json.dumps(payload))
    assert on_disk["serve"]["families"]["serve.admitted"]["total"] == 5
    assert "process" in on_disk


# ------------------------------------------------------------------ tracer
def test_tracer_spans_ring_and_histogram():
    reg = obs.MetricsRegistry(enabled=True)
    tr = tracerm.Tracer(registry=reg)
    assert tr.span("x") is tracerm._NOP  # disabled => shared no-op
    tr.enable()
    with tr.span("stage.a", k="v"):
        pass
    tr.record("stage.b", 0.25)
    snap = tr.snapshot()
    spans = [s for ring in snap.values() for s in ring]
    names = sorted(s["name"] for s in spans)
    assert names == ["stage.a", "stage.b"]
    for s in spans:
        assert s["dur_s"] >= 0
    b = next(s for s in spans if s["name"] == "stage.b")
    assert b["dur_s"] == 0.25
    hist = reg.children("trace.span_seconds")
    assert {c.labels["span"] for c in hist} == {"stage.a", "stage.b"}


def test_tracer_sampling_keeps_one_in_n():
    reg = obs.MetricsRegistry(enabled=True)
    tr = tracerm.Tracer(registry=reg)
    tr.enable(sample_rate=0.25)  # keep 1 in 4 per thread
    for _ in range(100):
        with tr.span("s"):
            pass
    kept = reg.histogram("trace.span_seconds", span="s").count
    assert kept == 25
    with pytest.raises(ValueError, match="sample_rate"):
        tr.enable(sample_rate=0.0)


def test_tracer_ring_overwrites_oldest():
    tr = tracerm.Tracer(ring_size=4)
    tr.enable()
    for i in range(10):
        tr.record(f"s{i}", 0.0)
    spans = [s for ring in tr.snapshot().values() for s in ring]
    assert [s["name"] for s in spans] == ["s6", "s7", "s8", "s9"]


# ----------------------------------------------- store/search reconciliation
def test_disk_search_reconciles_registry(tiny_engine, tiny_corpus, tmp_path):
    """Registry families == measured store counters == summed SearchStats,
    bit-exact, for a real disk-tier search."""
    from repro.core import GateANNEngine, SearchConfig

    _, _, queries = tiny_corpus
    path = str(tmp_path / "obs.gann")
    tiny_engine.save(path)
    reg = obs.MetricsRegistry(enabled=True)
    with obs.use_registry(reg):
        engine = GateANNEngine.load(path, store_tier="disk")
        out = engine.search(
            queries, filter_kind="label",
            filter_params=np.zeros(queries.shape[0], np.int32),
            search_config=SearchConfig(mode="gate", search_l=32, beam_width=4),
        )
        ios = int(np.sum(np.asarray(out.stats.n_ios)))
    store = engine.measured_store()
    c = store.io_counters()
    # three-way: registry == measured == modeled
    assert reg.family_total("disk.records_read") == c["records_read"] == ios
    for key in ("pages_read", "bytes_read", "unique_sectors_read",
                "ranges_read", "syscalls", "fetch_rounds", "read_rounds"):
        assert reg.family_total(f"disk.{key}") == c[key], key
    assert reg.family_total("search.ios", tier="disk", mode="gate") == ios
    assert reg.family_total("search.queries") == queries.shape[0]
    # the per-query histogram saw every row
    h = reg.histogram("search.ios_per_query", mode="gate")
    assert h.count == queries.shape[0]
    assert h.sum == pytest.approx(float(ios))
    # fetched-vs-tunneled split is non-trivial in gate mode
    assert reg.family_total("search.tunnels", mode="gate") > 0
    # a store-side reset must NOT reset the registry (monotonic families)
    store.reset_io_counters()
    assert store.io_counters()["records_read"] == 0
    assert reg.family_total("disk.records_read") == ios
    store.close()


# ------------------------------------------------------- monotonic timing
def test_serving_spans_immune_to_wall_clock_steps(tiny_engine, tiny_corpus,
                                                  monkeypatch):
    """Satellite: span math uses perf_counter, so a wall clock stepping
    BACKWARDS mid-request cannot produce a negative span.  time.time is
    patched to run backwards; any timing code still reading it would go
    negative."""
    from repro.serve import RAGServer, ServeFrontend, TenantSpec
    from repro.core import SearchConfig

    # serving-layer sources must not read the wall clock at all
    import inspect
    from repro.serve import server as server_mod
    from repro.obs import tracer as tracer_mod
    for mod in (server_mod, tracer_mod):
        assert "time.time(" not in inspect.getsource(mod), mod.__name__

    t0 = time.time()
    steps = [0.0]

    def backwards():
        steps[0] -= 60.0  # one minute back per read
        return t0 + steps[0]

    monkeypatch.setattr(time, "time", backwards)
    _, _, queries = tiny_corpus
    rag = RAGServer(
        engine=tiny_engine, cfg=None, params=None, layout=None,
        passage_tokens=np.zeros((int(tiny_engine.vectors.shape[0]), 4),
                                np.int32),
        search_config=SearchConfig(mode="gate", search_l=32, beam_width=4),
    )
    with ServeFrontend(rag, [TenantSpec("t0", "label", np.int32(0))],
                       max_batch=4, batch_window_s=0.0) as srv:
        hs = [srv.submit("t0", queries[i]) for i in range(4)]
        for h in hs:
            h.result(timeout=120.0)
        rep = srv.io_report()
    for h in hs:
        tr = h.trace
        for k in ("queue_wait", "batch_form", "search", "drain"):
            assert getattr(tr, k) >= 0.0, k
        assert tr.search > 0.0
    for k, v in rep["spans_mean_s"].items():
        assert v >= 0.0, k


# ---------------------------------------------------------- overhead guard
def test_disabled_telemetry_is_structurally_off(tiny_engine, tiny_corpus,
                                                monkeypatch):
    """With the registry disabled, the stats-recording hook on the search
    path must be UNREACHABLE — not just cheap.  Raising from it proves
    the guarded branch never runs."""
    from repro.core import SearchConfig

    def boom(*a, **k):  # pragma: no cover - reaching it is the failure
        raise AssertionError("record_search_stats ran with obs disabled")

    monkeypatch.setattr(obs.stats, "record_search_stats", boom)
    _, _, queries = tiny_corpus
    reg = obs.MetricsRegistry(enabled=False)
    with obs.use_registry(reg):
        out = tiny_engine.search(
            queries[:4], filter_kind="label",
            filter_params=np.zeros(4, np.int32),
            search_config=SearchConfig(mode="gate", search_l=32,
                                       beam_width=4),
        )
    assert np.asarray(out.ids).shape[0] == 4
    assert reg.families() in ([], ["search.dispatch"])  # counters stayed 0
    assert reg.family_total("search.dispatch") == 0


def test_disabled_primitives_overhead_guard():
    """Tier-1 overhead guard: the disabled counter/span fast path stays
    within an order of magnitude of a no-op stub (min-of-N timing — this
    pins the early-out structure, not absolute speed)."""
    reg = obs.MetricsRegistry(enabled=False)
    c = reg.counter("hot")
    tr = tracerm.Tracer(registry=reg)  # disabled

    def stub():
        pass

    n = 20_000

    def best_of(fn, reps=5):
        best = math.inf
        for _ in range(reps):
            t0 = time.perf_counter()
            for _ in range(n):
                fn()
            best = min(best, time.perf_counter() - t0)
        return best

    t_stub = best_of(stub)
    t_inc = best_of(lambda: c.inc())
    t_span = best_of(lambda: tr.span("s"))
    # generous 10x bound over an empty python call: the disabled paths
    # are one attribute read + branch (plus arg passing).  A lock or
    # histogram touch on the disabled path would blow far past this.
    assert t_inc < 10 * t_stub + 0.05, (t_inc, t_stub)
    assert t_span < 10 * t_stub + 0.05, (t_span, t_stub)
