"""NumPy oracle of Algorithm 1 — mode parity and stats invariants.

``oracle_search`` re-implements the jitted loop of ``core/search.py`` in
plain Python/NumPy: sorted L-frontier with eviction, W-wide best-first
dispatch, per-mode fetch/tunnel/result masks, visited set, exact-ranked
result list.  The jitted loop must match it — ids exactly, distances to
float tolerance, I/O counters exactly — in all five ``SearchConfig``
modes.  PQ and exact distances are taken from the same jax computations
the engine uses, so the oracle checks the *loop logic*, not float
summation order.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SearchConfig
from repro.core import pq as pqm
from repro.core import search as searchm

MODES = searchm.MODES
INF = np.float32(3.4e38)


@dataclasses.dataclass
class OracleOut:
    ids: np.ndarray  # (B, K)
    dists: np.ndarray  # (B, K)
    n_ios: np.ndarray  # (B,)
    n_tunnels: np.ndarray
    n_exact: np.ndarray
    n_hops: np.ndarray
    n_cache_hits: np.ndarray
    n_expansions: np.ndarray  # valid dispatches (not a SearchStats field)


def oracle_search(
    *,
    pq_dist,  # (B, N) PQ priority distances
    exact_dist,  # (B, N) exact squared L2
    passes,  # (N,) bool — filter predicate per node
    full_nbrs,  # (N, R) slow-tier adjacency
    mem_nbrs,  # (N, R_max) neighbor-store adjacency
    entry: int,
    mode: str,
    L: int,
    W: int,
    K: int,
    max_hops: int = 512,
    cached=None,  # optional (N,) bool — cache-resident records
) -> OracleOut:
    b = pq_dist.shape[0]
    cached = np.zeros(passes.shape[0], bool) if cached is None else cached
    out = OracleOut(*[None] * 8)
    out.ids = np.full((b, K), -1, np.int32)
    out.dists = np.full((b, K), INF, np.float32)
    for f in ("n_ios", "n_tunnels", "n_exact", "n_hops", "n_cache_hits",
              "n_expansions"):
        setattr(out, f, np.zeros((b,), np.int32))

    per_query_rounds = np.zeros((b,), np.int64)
    for q in range(b):
        # frontier entries: [dist, id, expanded, seq] — seq breaks sort ties
        # exactly like the stable argsort over [old slots, new candidates]
        frontier = [[pq_dist[q, entry], entry, False, 0]]
        seq = 1
        visited = {entry}
        results: list[tuple[float, int]] = []
        rounds = 0
        while any(not e[2] for e in frontier) and rounds < max_hops:
            rounds += 1
            frontier.sort(key=lambda e: (e[0], e[3]))
            sel = [e for e in frontier if not e[2]][:W]
            for e in sel:
                e[2] = True
            out.n_expansions[q] += len(sel)

            fetched, tunneled, result_nodes, exact_nodes = [], [], [], []
            for e in sel:
                i = e[1]
                p = bool(passes[i])
                if mode == "unfiltered":
                    f_, t_, r_, x_ = True, False, True, True
                elif mode == "post":
                    f_, t_, r_, x_ = True, False, p, True
                elif mode == "early":
                    f_, t_, r_, x_ = True, False, p, p
                elif mode == "pre_naive":
                    f_ = p or (i == entry)
                    t_, r_, x_ = False, p, f_
                else:  # gate
                    f_, t_, r_, x_ = p, not p, p, p
                if f_:
                    fetched.append(i)
                    if cached[i]:
                        out.n_cache_hits[q] += 1
                    else:
                        out.n_ios[q] += 1
                if t_:
                    tunneled.append(i)
                    out.n_tunnels[q] += 1
                if r_:
                    result_nodes.append(i)
                if x_:
                    out.n_exact[q] += 1

            for i in result_nodes:
                if all(i != rid for _, rid in results):
                    results.append((float(exact_dist[q, i]), i))

            # candidate neighbors in the loop's concatenation order:
            # all fetched rows first (full adjacency), then tunnel rows
            cand = [j for i in fetched for j in full_nbrs[i] if j >= 0]
            if mode == "gate":
                cand += [j for i in tunneled for j in mem_nbrs[i] if j >= 0]
            fresh, seen_round = [], set()
            for j in cand:
                j = int(j)
                if j in visited or j in seen_round:
                    continue  # visited-set check + within-round first-occurrence
                seen_round.add(j)
                fresh.append(j)
            visited.update(seen_round)
            for j in fresh:
                frontier.append([float(pq_dist[q, j]), j, False, seq])
                seq += 1
            frontier.sort(key=lambda e: (e[0], e[3]))
            del frontier[L:]  # eviction: dropped nodes stay visited forever
        per_query_rounds[q] = rounds

        results.sort(key=lambda t: t[0])
        for k, (d_, i) in enumerate(results[:K]):
            out.ids[q, k] = i
            out.dists[q, k] = d_

    # n_hops increments globally: every query counts every round until the
    # slowest query's frontier drains
    out.n_hops[:] = per_query_rounds.max(initial=0)
    return out


@pytest.fixture(scope="module")
def oracle_setup(tiny_engine, tiny_corpus):
    corpus, labels, queries = tiny_corpus
    queries = queries[:6]
    eng = tiny_engine
    b = queries.shape[0]
    n = corpus.shape[0]
    q = jnp.asarray(queries, jnp.float32)
    lut = pqm.build_lut(eng.codec, q)
    all_ids = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (b, n))
    pq_d = np.asarray(searchm._adc_ids(lut, eng.codes, all_ids, False))
    vecs = jnp.broadcast_to(eng.vectors[None], (b, n, corpus.shape[1]))
    exact_d = np.asarray(searchm._exact_dist(q, vecs, False))
    return dict(
        engine=eng,
        queries=queries,
        labels=np.asarray(labels),
        pq_dist=pq_d,
        exact_dist=exact_d,
        full_nbrs=np.asarray(eng.record_store.neighbors),
        mem_nbrs=np.asarray(eng.neighbor_store.neighbors),
        entry=int(eng.medoid),
    )


def _run_mode(s, mode, L=32, W=4, K=8):
    eng = s["engine"]
    kind, params = (None, None)
    if mode != "unfiltered":
        kind = "label"
        params = np.zeros(s["queries"].shape[0], np.int32)
    out = eng.search(
        s["queries"], filter_kind=kind, filter_params=params,
        search_config=SearchConfig(mode=mode, search_l=L, beam_width=W, result_k=K),
    )
    passes = (s["labels"] == 0) if mode != "unfiltered" else np.ones(
        len(s["labels"]), bool
    )
    ora = oracle_search(
        pq_dist=s["pq_dist"], exact_dist=s["exact_dist"], passes=passes,
        full_nbrs=s["full_nbrs"], mem_nbrs=s["mem_nbrs"], entry=s["entry"],
        mode=mode, L=L, W=W, K=K,
    )
    return out, ora


@pytest.mark.parametrize("mode", MODES)
def test_mode_matches_numpy_oracle(oracle_setup, mode):
    out, ora = _run_mode(oracle_setup, mode)
    np.testing.assert_array_equal(np.asarray(out.ids), ora.ids, err_msg=mode)
    got_d = np.asarray(out.dists)
    valid = ora.ids >= 0
    np.testing.assert_allclose(got_d[valid], ora.dists[valid], rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(out.stats.n_ios), ora.n_ios)
    np.testing.assert_array_equal(np.asarray(out.stats.n_tunnels), ora.n_tunnels)
    np.testing.assert_array_equal(np.asarray(out.stats.n_exact), ora.n_exact)
    np.testing.assert_array_equal(np.asarray(out.stats.n_cache_hits), 0)
    np.testing.assert_array_equal(np.asarray(out.stats.n_hops), ora.n_hops)


def test_gate_expansion_conservation(oracle_setup):
    """Gate: every dispatched node is either fetched or tunneled."""
    out, ora = _run_mode(oracle_setup, "gate")
    ios = np.asarray(out.stats.n_ios)
    tun = np.asarray(out.stats.n_tunnels)
    np.testing.assert_array_equal(ios + tun, ora.n_expansions)


def test_post_and_early_have_equal_ios(oracle_setup):
    out_p, _ = _run_mode(oracle_setup, "post")
    out_e, _ = _run_mode(oracle_setup, "early")
    np.testing.assert_array_equal(
        np.asarray(out_p.stats.n_ios), np.asarray(out_e.stats.n_ios)
    )


def test_unfiltered_has_zero_tunnels(oracle_setup):
    out, _ = _run_mode(oracle_setup, "unfiltered")
    np.testing.assert_array_equal(np.asarray(out.stats.n_tunnels), 0)
    # ... and every dispatch is an I/O
    _, ora = _run_mode(oracle_setup, "unfiltered")
    np.testing.assert_array_equal(np.asarray(out.stats.n_ios), ora.n_expansions)
