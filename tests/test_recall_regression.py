"""Recall regression pins — quality can't silently drift.

The oracle tests pin the search *loop* node-for-node; these pin the
end-to-end *quality* of the whole stack (Vamana build + PQ + loop) on a
seeded synthetic dataset: recall@10 per search mode must stay within
±0.01 of the values stored in ``tests/baselines/recall_at10.json``.
A legitimate quality change (better build, different PQ) regenerates
the pins explicitly:

    PYTHONPATH=src python tests/test_recall_regression.py --regen

The setup mirrors the session fixtures in conftest.py (same corpus,
labels, queries, engine config), so tier-1 reuses the shared engine
build and the pins stay meaningful for every oracle/property test that
runs against the same fixture.
"""
import json
import os

import numpy as np
import pytest

from repro.core import SearchConfig, recall_at_k
from repro.data import filtered_ground_truth

BASELINE_PATH = os.path.join(
    os.path.dirname(__file__), "baselines", "recall_at10.json"
)
MODES = ("gate", "post", "early", "pre_naive", "unfiltered")
TOLERANCE = 0.01
SEARCH_L, BEAM_W, K = 64, 8, 10


def compute_recalls(engine, corpus, labels, queries) -> dict:
    """recall@10 per mode: label==0 predicate (unfiltered: no predicate)."""
    out = {}
    for mode in MODES:
        if mode == "unfiltered":
            kind, params = None, None
            mask = np.ones(corpus.shape[0], bool)
        else:
            kind = "label"
            params = np.zeros(queries.shape[0], np.int32)
            mask = np.asarray(labels) == 0
        gt = filtered_ground_truth(corpus, queries, mask, k=K)
        res = engine.search(
            queries, filter_kind=kind, filter_params=params,
            search_config=SearchConfig(mode=mode, search_l=SEARCH_L,
                                       beam_width=BEAM_W, result_k=K),
        )
        out[mode] = round(float(recall_at_k(res.ids, gt, K)), 4)
    return out


@pytest.fixture(scope="module")
def measured(tiny_engine, tiny_corpus):
    corpus, labels, queries = tiny_corpus
    return compute_recalls(tiny_engine, corpus, labels, queries)


@pytest.fixture(scope="module")
def baselines():
    assert os.path.exists(BASELINE_PATH), (
        f"missing {BASELINE_PATH} — regenerate with "
        "`PYTHONPATH=src python tests/test_recall_regression.py --regen`"
    )
    with open(BASELINE_PATH) as f:
        return json.load(f)


@pytest.mark.parametrize("mode", MODES)
def test_recall_within_pin(measured, baselines, mode):
    got = measured[mode]
    want = baselines[mode]
    assert abs(got - want) <= TOLERANCE, (
        f"{mode}: recall@10 {got:.4f} drifted from pinned {want:.4f} "
        f"(±{TOLERANCE}); if intentional, regenerate the baselines"
    )


def test_mode_quality_ordering(measured):
    """Structural sanity on the pins themselves: gate must not lose recall
    vs post at the same L (the paper's central claim), and the naive
    pre-filter must be the worst filtered mode (broken connectivity)."""
    assert measured["gate"] >= measured["post"] - TOLERANCE
    assert measured["pre_naive"] <= min(
        measured["gate"], measured["post"], measured["early"]
    ) + TOLERANCE


def _regen():
    # the same builders the session fixtures use (tests/conftest.py), so
    # regenerated pins always match what tier-1 measures
    from conftest import make_tiny_corpus, make_tiny_engine

    corpus, labels, queries = make_tiny_corpus()
    engine = make_tiny_engine(corpus, labels)
    recalls = compute_recalls(engine, corpus, labels, queries)
    os.makedirs(os.path.dirname(BASELINE_PATH), exist_ok=True)
    with open(BASELINE_PATH, "w") as f:
        json.dump(recalls, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {BASELINE_PATH}: {recalls}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--regen", action="store_true",
                    help="recompute and overwrite the recall pins")
    args = ap.parse_args()
    if args.regen:
        _regen()
    else:
        ap.print_help()
