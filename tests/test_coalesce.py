"""The coalesced disk reader: parity, counters, concurrency.

Contract under test (store/disk.py):

  * All three io_modes — ``preadv`` (one vectored syscall per round,
    gap-bridged), ``pread`` (one syscall per merged range), ``gather``
    (the legacy per-record memmap fancy-gather, kept as the oracle) —
    return byte-identical records for any beam, duplicates and -1 pads
    included, so search output is bit-identical across them.  (The
    default disk engine is already pinned against the in-memory engine
    across all five modes in test_persist; here the gather oracle pins
    the other read paths at the fetch level, where parity is
    mode-independent, plus full-search spot checks.)
  * Logical counters (``records_read``/``pages_read``/``bytes_read``)
    count what the loop requested; physical counters
    (``unique_sectors_read``/``ranges_read``/``syscalls``/
    ``gap_sectors_read``) count what the reader did.
    ``unique_sectors_read <= records_read`` with equality iff the round
    had no duplicates; preadv spends ``syscalls == read_rounds`` (per
    segment), pread ``syscalls == ranges_read``, gather 0.
  * Counters are guarded by a lock — concurrent fetches through one
    shared store must not lose updates, and reset is atomic.
"""
import shutil
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GateANNEngine, SearchConfig
from repro.store import DiskRecordStore, is_lazy_host, merge_ranges

RECORD = 4096  # tiny-corpus records round up to one 4 KB sector
IO_MODES = ("preadv", "pread", "gather")


@pytest.fixture(scope="module")
def index_path(tiny_engine, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("coalesce") / "tiny.gann")
    tiny_engine.save(path)
    return path


@pytest.fixture(scope="module")
def stores(index_path):
    return {m: DiskRecordStore.open(index_path, io_mode=m) for m in IO_MODES}


def _beam(n, rng, b=7, w=9):
    """A duplicate-heavy beam: repeats within rows, across rows, -1 pads,
    an all-invalid row, and both boundary ids."""
    ids = rng.integers(-1, n, size=(b, w)).astype(np.int32)
    ids[:, 1] = ids[:, 0]  # intra-row duplicate
    ids[1] = ids[0]  # whole-row duplicate (cross-query, same round)
    ids[2] = -1  # a query with nothing dispatched
    ids[3, :3] = (0, n - 1, 0)  # boundary sectors, duplicated again
    return ids


def test_merge_ranges_unit():
    got = merge_ranges(np.asarray([0, 1, 2, 5, 7, 8, 9]))
    np.testing.assert_array_equal(got, [[0, 3], [5, 1], [7, 3]])
    assert merge_ranges(np.asarray([], np.int64)).shape == (0, 2)
    np.testing.assert_array_equal(merge_ranges(np.asarray([4])), [[4, 1]])


@pytest.mark.parametrize("io_mode", IO_MODES)
def test_duplicate_heavy_fetch_parity_and_counters(stores, tiny_engine, io_mode):
    store = stores[io_mode]
    ref_fetch = tiny_engine.record_store.fetch_fn()
    rng = np.random.default_rng(7)
    for trial in range(4):
        ids = _beam(store.n, rng)
        before = store.io_counters()
        vecs, nbrs = store._host_fetch(ids)
        after = store.io_counters()
        want_v, want_n = ref_fetch(jnp.asarray(ids))
        np.testing.assert_array_equal(vecs, np.asarray(want_v), err_msg=io_mode)
        np.testing.assert_array_equal(nbrs, np.asarray(want_n), err_msg=io_mode)
        d = {k: after[k] - before[k] for k in after}
        m = int((ids >= 0).sum())
        u = int(np.unique(ids[ids >= 0]).size)
        assert d["records_read"] == m
        assert d["pages_read"] == m * store.pages_per_record
        assert d["bytes_read"] == m * store.sector_bytes
        assert d["unique_sectors_read"] == u < m  # the beam is dup-heavy
        assert d["fetch_rounds"] == 1 and d["read_rounds"] == 1
        if io_mode == "preadv":
            assert d["syscalls"] == 1  # ONE vectored read for the round
        elif io_mode == "pread":
            assert d["syscalls"] == d["ranges_read"]
        else:
            assert d["syscalls"] == 0 and d["gap_sectors_read"] == 0


def test_unique_equals_requested_without_duplicates(stores):
    store = stores["preadv"]
    ids = np.asarray([[3, 9, 27, 81, -1]], np.int32)  # no dups
    before = store.io_counters()
    store._host_fetch(ids)
    d = {k: v - before[k] for k, v in store.io_counters().items()}
    assert d["unique_sectors_read"] == d["records_read"] == 4


def test_all_invalid_beam_reads_nothing(stores):
    for io_mode, store in stores.items():
        before = store.io_counters()
        vecs, nbrs = store._host_fetch(np.full((3, 4), -1, np.int32))
        d = {k: v - before[k] for k, v in store.io_counters().items()}
        assert (vecs == 0).all() and (nbrs == -1).all()
        assert d["records_read"] == d["syscalls"] == d["unique_sectors_read"] == 0
        assert d["fetch_rounds"] == 1 and d["read_rounds"] == 0, io_mode


@pytest.mark.parametrize("io_mode", ("pread", "gather"))
def test_search_bit_identical_across_io_modes(index_path, tiny_corpus, io_mode):
    """Full loop: the non-default read paths return the same search output
    as the default (preadv) disk engine, uncached and cached."""
    import dataclasses

    _, _, queries = tiny_corpus
    base = GateANNEngine.load(index_path, store_tier="disk")
    alt = dataclasses.replace(
        base, record_store=DiskRecordStore.open(index_path, io_mode=io_mode)
    )
    cfg = SearchConfig(mode="gate", search_l=48, beam_width=4)
    tgt = np.zeros(queries.shape[0], np.int32)
    out_b = base.search(queries, filter_kind="label", filter_params=tgt,
                        search_config=cfg)
    out_a = alt.search(queries, filter_kind="label", filter_params=tgt,
                       search_config=cfg)
    np.testing.assert_array_equal(np.asarray(out_a.ids), np.asarray(out_b.ids))
    np.testing.assert_array_equal(np.asarray(out_a.dists), np.asarray(out_b.dists))
    for f in out_b.stats._fields:
        np.testing.assert_array_equal(np.asarray(getattr(out_a.stats, f)),
                                      np.asarray(getattr(out_b.stats, f)))
    # and with a cache tier in front: the file only sees the misses
    cached = alt.with_cache(48 * RECORD)
    out_c = cached.search(queries, filter_kind="label", filter_params=tgt,
                          search_config=cfg)
    np.testing.assert_array_equal(np.asarray(out_c.ids), np.asarray(out_b.ids))
    np.testing.assert_array_equal(
        np.asarray(out_c.stats.n_ios) + np.asarray(out_c.stats.n_cache_hits),
        np.asarray(out_b.stats.n_ios))


def test_counters_locked_under_concurrency(index_path):
    """Concurrent fetches through one shared store lose no counter
    updates (two engines sharing a store do exactly this)."""
    store = DiskRecordStore.open(index_path)
    rng = np.random.default_rng(11)
    beams = [rng.integers(-1, store.n, size=(4, 6)).astype(np.int32)
             for _ in range(8)]
    n_threads, iters = 8, 12
    errs = []

    def hammer(tid):
        try:
            for i in range(iters):
                store._host_fetch(beams[(tid + i) % len(beams)])
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=hammer, args=(t,)) for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    per_pass = sum(int((b >= 0).sum()) for b in beams) // len(beams)
    want = sum(int((beams[(t + i) % len(beams)] >= 0).sum())
               for t in range(n_threads) for i in range(iters))
    c = store.io_counters()
    assert c["records_read"] == want, (c["records_read"], want, per_pass)
    assert c["fetch_rounds"] == n_threads * iters
    assert c["bytes_read"] == want * store.sector_bytes
    store.reset_io_counters()
    assert all(v == 0 for v in store.io_counters().values())


def test_max_gap_sectors_bounds_bridging(index_path, stores):
    """The gap-bridging bound trades syscalls for read amplification:
    unbounded = one vectored call, all gaps bridged; 0 = one call per
    merged range, zero over-read; a finite bound bridges only gaps <= it.
    All three return byte-identical records."""
    ref_v, ref_n = stores["gather"]._host_fetch(
        np.asarray([[0, 2, 10, -1]], np.int32))
    # ranges (0,1) (2,1) (10,1): gaps of 1 and 7 sectors
    cases = {
        None: dict(syscalls=1, gap=8),   # bridge everything, one preadv
        7: dict(syscalls=1, gap=8),      # bound == widest gap: still one
        2: dict(syscalls=2, gap=1),      # bridge the 1-gap, split at the 7
        0: dict(syscalls=3, gap=0),      # never bridge: one call per range
    }
    for bound, want in cases.items():
        store = DiskRecordStore.open(index_path, io_mode="preadv",
                                     max_gap_sectors=bound)
        vecs, nbrs = store._host_fetch(np.asarray([[0, 2, 10, -1]], np.int32))
        c = store.io_counters()
        np.testing.assert_array_equal(vecs, ref_v, err_msg=str(bound))
        np.testing.assert_array_equal(nbrs, ref_n, err_msg=str(bound))
        assert c["syscalls"] == want["syscalls"], (bound, c)
        assert c["gap_sectors_read"] == want["gap"], (bound, c)
        assert c["ranges_read"] == 3, (bound, c)
        store.close()
    # negative = unbounded (the EngineConfig encoding of None)
    assert DiskRecordStore.open(index_path, max_gap_sectors=-1).max_gap_sectors is None


def test_max_gap_search_parity(index_path, tiny_corpus):
    """Full loop at the zero-bridge extreme: identical search output, and
    every bridged gap stays within the bound (here: no gaps at all)."""
    import dataclasses

    _, _, queries = tiny_corpus
    base = GateANNEngine.load(index_path, store_tier="disk")
    tight = dataclasses.replace(
        base,
        record_store=DiskRecordStore.open(index_path, max_gap_sectors=0),
    )
    cfg = SearchConfig(mode="gate", search_l=48, beam_width=4)
    tgt = np.zeros(queries.shape[0], np.int32)
    out_b = base.search(queries, filter_kind="label", filter_params=tgt,
                        search_config=cfg)
    out_t = tight.search(queries, filter_kind="label", filter_params=tgt,
                         search_config=cfg)
    np.testing.assert_array_equal(np.asarray(out_t.ids), np.asarray(out_b.ids))
    c = tight.record_store.io_counters()
    assert c["gap_sectors_read"] == 0
    assert c["syscalls"] == c["ranges_read"]  # one call per merged range
    tight.record_store.close()


def test_warm_repopulates_page_cache_counter(index_path):
    """warm() sequentially re-reads every segment file: warmed_bytes ends
    at the full on-disk footprint (foreground), the background variant
    reaches the same count, and close() mid-warm neither blocks nor
    crashes (the warmer reads through its own fds)."""
    store = DiskRecordStore.open(index_path)
    total = store.index_bytes()
    store.warm(background=False)
    assert store.warmed_bytes == total
    store.reset_io_counters()
    store.warm(background=True, chunk_bytes=1 << 16)
    assert store.warm_wait(timeout=30.0)
    assert store.warmed_bytes == total
    # re-entrant warm: an overlapping call stops+joins the live warmer
    # first, so warmed_bytes never double-counts past one full pass + a
    # fresh one (the first pass is cut short, never duplicated)
    store.reset_io_counters()
    store.warm(background=True, chunk_bytes=1 << 12)
    store.warm(background=True, chunk_bytes=1 << 16)
    assert store.warm_wait(timeout=30.0)
    assert total <= store.warmed_bytes < 2 * total
    # non-blocking close path: closing mid-warm just signals the thread
    store.reset_io_counters()
    store.warm(background=True, chunk_bytes=1 << 12)
    store.close()
    assert store.warm_wait(timeout=30.0)  # stops promptly, no EBADF
    assert store.warmed_bytes <= total
    # engine.load(warm_disk=True) wires it up after a disk-tier load
    eng = GateANNEngine.load(index_path, store_tier="disk", warm_disk=True)
    assert eng.record_store.warm_wait(timeout=30.0)
    assert eng.record_store.warmed_bytes == eng.record_store.index_bytes()
    assert eng.memory_report()["disk_warmed_bytes"] == eng.record_store.warmed_bytes
    eng.record_store.close()


def test_lazy_vectors_view(stores, tiny_engine):
    """The vectors passthrough is a host memmap view — never a device
    array, and equal to the corpus byte-for-byte."""
    store = stores["preadv"]
    v = store.vectors
    assert isinstance(v, np.ndarray) and not isinstance(v, jax.Array)
    assert is_lazy_host(v)
    np.testing.assert_array_equal(np.asarray(v),
                                  np.asarray(tiny_engine.vectors, np.float32))
    # the explicit debug path is the only device transfer
    dv = store.device_vectors()
    assert isinstance(dv, jax.Array)
    np.testing.assert_array_equal(np.asarray(dv), np.asarray(v))
