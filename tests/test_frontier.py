"""Frontier invariants — the sorted candidate list both GateANN paths
feed into (§3.3).  Seeded-parametrize randomized tests (pure pytest; the
original hypothesis dependency is gone so collection never breaks)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import frontier as fr


@pytest.mark.parametrize("L", [2, 8])  # L=2 exercises heavy truncation
@pytest.mark.parametrize("seed", range(10))
def test_insert_keeps_sorted_unique_best(seed, L):
    """Distances are a deterministic function of node id (PQ distance), as
    in the real system — duplicates always carry the same key.

    Shapes are held to two cases across seeds (only values vary) so XLA
    compiles each op once — randomized coverage without per-case compile
    cost."""
    rng = np.random.default_rng(seed)
    n_new = 14
    ids0 = rng.integers(-1, 31, size=L).tolist()
    new_ids = rng.integers(-1, 31, size=n_new).tolist()
    key_seed = int(rng.integers(0, 2**31))
    dist_of = lambda i: float(np.random.default_rng(key_seed + i).uniform(0, 10))

    f = fr.make_frontier(1, L)
    d0 = np.asarray([dist_of(i) if i >= 0 else np.inf for i in ids0], np.float32)
    f = fr.insert(f, jnp.asarray([ids0], jnp.int32), jnp.asarray([d0]))
    nd = np.asarray([dist_of(i) if i >= 0 else np.inf for i in new_ids], np.float32)
    f2 = fr.insert(f, jnp.asarray([new_ids], jnp.int32), jnp.asarray([nd]))

    ids = np.asarray(f2.ids)[0]
    dists = np.asarray(f2.dists)[0]
    valid = ids >= 0
    # sorted ascending
    vd = dists[valid]
    assert (np.diff(vd) >= -1e-6).all()
    # unique ids
    assert len(set(ids[valid].tolist())) == valid.sum()
    # contains the L globally-best candidates
    all_ids = {i for i in ids0 + new_ids if i >= 0}
    want = sorted(all_ids, key=dist_of)[:L]
    got = ids[valid].tolist()
    assert got == want


@pytest.mark.parametrize("l", [1, 4, 8])
@pytest.mark.parametrize("w", [1, 6])
def test_best_unexpanded_marks_and_excludes(l, w):
    rng = np.random.default_rng(l * 7 + w)
    f = fr.make_frontier(1, l)
    ids = rng.permutation(20)[:l].astype(np.int32)
    d = rng.uniform(0, 1, l).astype(np.float32)
    f = fr.insert(f, jnp.asarray([ids]), jnp.asarray([d]))
    sel, slots, valid = fr.best_unexpanded(f, w)
    f2 = fr.mark_expanded(f, slots, valid)
    sel2, _, valid2 = fr.best_unexpanded(f2, w)
    # second selection must not repeat the first
    s1 = set(np.asarray(sel)[0][np.asarray(valid)[0]].tolist())
    s2 = set(np.asarray(sel2)[0][np.asarray(valid2)[0]].tolist())
    assert not (s1 & s2)
    # first selection is the w smallest distances
    order = np.argsort(d)[: min(w, l)]
    assert s1 == set(ids[order].tolist())


def test_results_insert_dedups():
    r = fr.make_results(1, 4)
    r = fr.results_insert(
        r, jnp.asarray([[5, 5, 7]], jnp.int32), jnp.asarray([[1.0, 0.5, 2.0]])
    )
    ids = np.asarray(r.ids)[0]
    assert (ids >= 0).sum() == 2  # 5 deduped
    assert ids[0] == 5
