"""Optimizers, checkpointing, compression, token pipeline, io model."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointConfig, Checkpointer
from repro.data.tokens import TokenStreamConfig, batch_at_step
from repro.distributed.compression import (
    EFState,
    dequantize_int8,
    ef_compress_decompress,
    ef_init,
    quantize_int8,
)
from repro.optim import OptConfig, clip_by_global_norm, opt_init, opt_update


def _quad_problem():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3,)), "b": jnp.zeros((2, 3))}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum((p["b"] - 0.5) ** 2)

    return params, loss


@pytest.mark.parametrize("name", ["adamw", "adafactor", "adamw8bit"])
def test_optimizer_reduces_loss(name):
    params, loss = _quad_problem()
    cfg = OptConfig(name=name, weight_decay=0.0)
    state = opt_init(params, cfg)
    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = opt_update(g, state, params, 0.05, cfg)
    assert float(loss(params)) < 0.05 * l0


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(CheckpointConfig(directory=str(tmp_path), keep=2))
    tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 3), jnp.int32)}}
    ck.save(1, tree, blocking=True)
    ck.save(7, jax.tree.map(lambda x: x * 2, tree), blocking=True)
    assert ck.latest_step() == 7
    got = ck.restore(tree)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(5.0) * 2)
    got1 = ck.restore(tree, step=1)
    np.testing.assert_array_equal(np.asarray(got1["a"]), np.arange(5.0))


def test_checkpoint_retention_and_async(tmp_path):
    ck = Checkpointer(CheckpointConfig(directory=str(tmp_path), keep=2))
    tree = {"x": jnp.zeros((4,))}
    for s in (1, 2, 3, 4):
        ck.save(s, tree)  # async
    ck.wait()
    assert ck.all_steps() == [3, 4]


def test_int8_quant_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)) * 3.0, jnp.float32)
    q, s, n = quantize_int8(x)
    back = dequantize_int8(q, s, n, x.shape)
    err = np.abs(np.asarray(back - x))
    # max error is one quantization step = scale = max|block|/127
    assert err.max() <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


def test_error_feedback_preserves_signal():
    """Sum of EF-compressed grads converges to sum of true grads."""
    rng = np.random.default_rng(1)
    grads = {"w": jnp.asarray(rng.normal(size=(256,)) * 1e-3, jnp.float32)}
    state = ef_init(grads)
    total_true = np.zeros(256)
    total_sent = np.zeros(256)
    for _ in range(50):
        g = {"w": jnp.asarray(rng.normal(size=(256,)) * 1e-3, jnp.float32)}
        sent, state = ef_compress_decompress(g, state)
        total_true += np.asarray(g["w"])
        total_sent += np.asarray(sent["w"])
    resid = np.abs(np.asarray(state.residual["w"]))
    np.testing.assert_allclose(total_sent + np.asarray(state.residual["w"]),
                               total_true, rtol=1e-4, atol=1e-6)
    assert resid.max() < 1e-3  # residual stays bounded (EF doesn't diverge)


def test_token_stream_deterministic():
    cfg = TokenStreamConfig(vocab_size=1000, seq_len=32, global_batch=4, seed=3)
    a = batch_at_step(cfg, 17)
    b = batch_at_step(cfg, 17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = batch_at_step(cfg, 18)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # targets are next-token shifted
    assert a["tokens"].shape == a["targets"].shape == (4, 32)


def test_io_cost_model_orderings():
    from repro.core.io_model import DEFAULT_COST_MODEL as M

    # fewer I/Os -> strictly higher modeled QPS at saturation
    assert M.qps(20, 180) > M.qps(200, 0)
    # early-filter (same ios, fewer exact) barely helps at 32T (paper Fig 18)
    post = M.qps(200, 0, n_exact=200)
    early = M.qps(200, 0, n_exact=20)
    assert early / post < 1.15
    # gen5 halves device latency but not CPU-side cost (paper Table 4)
    from repro.core.io_model import GEN5_COST_MODEL as G
    gain = M.latency_us(100, 0) / G.latency_us(100, 0)
    assert gain < 1.4
