"""Vamana build + beam search correctness."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import (
    beam_search_batch,
    build_filtered_vamana,
    build_vamana,
    find_medoid,
    robust_prune_batch,
)
from repro.data import make_bigann_like, uniform_labels


@pytest.fixture(scope="module")
def small_graph():
    corpus = make_bigann_like(600, 16, seed=3)
    g = build_vamana(corpus, degree=16, build_l=32, batch_size=128, seed=0)
    return corpus, g


def test_graph_shape_and_padding(small_graph):
    corpus, g = small_graph
    n = corpus.shape[0]
    nbrs = np.asarray(g.neighbors)
    assert nbrs.shape == (n, 16)
    assert (nbrs < n).all()
    # no self loops among valid entries
    rows = np.arange(n)[:, None]
    valid = nbrs >= 0
    assert not (nbrs[valid] == np.broadcast_to(rows, nbrs.shape)[valid]).any()


def test_medoid_is_most_central(small_graph):
    corpus, g = small_graph
    med = int(g.medoid)
    cen = corpus.mean(0)
    d = ((corpus - cen) ** 2).sum(1)
    assert d[med] == pytest.approx(d.min())


def test_beam_search_exact_recall(small_graph):
    corpus, g = small_graph
    queries = jnp.asarray(corpus[:8])  # corpus points: NN = themselves
    res = beam_search_batch(
        g.neighbors, jnp.asarray(corpus), g.medoid, queries,
        search_l=32, beam_width=4,
    )
    top1 = np.asarray(res.ids)[:, 0]
    assert (top1 == np.arange(8)).mean() >= 0.9


def test_robust_prune_degree_and_dedup():
    corpus = jnp.asarray(make_bigann_like(100, 8, seed=1))
    cands = jnp.asarray(
        np.random.default_rng(0).integers(0, 100, size=(4, 30)), jnp.int32
    )
    out = np.asarray(robust_prune_batch(
        jnp.asarray([0, 1, 2, 3], jnp.int32), cands, corpus, alpha=1.2, degree=8
    ))
    assert out.shape == (4, 8)
    for row, p in zip(out, range(4)):
        vals = row[row >= 0]
        assert len(set(vals.tolist())) == len(vals)  # no dup edges
        assert p not in vals  # no self edge


def test_filtered_vamana_has_label_medoids():
    corpus = make_bigann_like(400, 8, seed=2)
    labels = uniform_labels(400, 4, seed=0)
    fg = build_filtered_vamana(corpus, labels, degree=12, build_l=24, batch_size=128)
    meds = np.asarray(fg.label_medoids)
    assert meds.shape == (4,)
    for lab in range(4):
        assert labels[meds[lab]] == lab
