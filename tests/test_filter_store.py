"""Filter store predicates, including hypothesis property tests."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.filter_store import (
    AndFilter,
    EqualityFilter,
    RangeFilter,
    SubsetFilter,
    match_all,
    pack_tags,
)


def test_equality_basic():
    labels = jnp.asarray([0, 1, 2, 0, 1], jnp.int32)
    f = EqualityFilter(labels).bind(jnp.asarray([0, 1], jnp.int32))
    ids = jnp.asarray([[0, 1, 3], [1, 4, -1]], jnp.int32)
    got = np.asarray(f(ids))
    assert got.tolist() == [[True, False, True], [True, True, False]]


def test_range_basic():
    vals = jnp.asarray([0.1, 0.5, 0.9], jnp.float32)
    f = RangeFilter(vals).bind(jnp.asarray([0.2]), jnp.asarray([0.8]))
    got = np.asarray(f(jnp.asarray([[0, 1, 2]], jnp.int32)))
    assert got.tolist() == [[False, True, False]]


@settings(max_examples=50, deadline=None)
@given(st.data())
def test_subset_property(data):
    """(q & node) == q  <=>  q's tags ⊆ node's tags — for random tag sets."""
    vocab = 70
    node_tags = data.draw(st.lists(
        st.lists(st.integers(0, vocab - 1), max_size=8), min_size=1, max_size=6,
    ))
    q_tags = data.draw(st.lists(st.integers(0, vocab - 1), max_size=4))
    bits = pack_tags([sorted(set(t)) for t in node_tags], vocab)
    qbits = pack_tags([sorted(set(q_tags))], vocab)
    f = SubsetFilter(jnp.asarray(bits)).bind(jnp.asarray(qbits))
    ids = jnp.arange(len(node_tags), dtype=jnp.int32)[None, :]
    got = np.asarray(f(ids))[0]
    want = [set(q_tags) <= set(t) for t in node_tags]
    assert got.tolist() == want


@settings(max_examples=30, deadline=None)
@given(
    labels=st.lists(st.integers(0, 4), min_size=4, max_size=40),
    target=st.integers(0, 4),
)
def test_equality_property(labels, target):
    arr = jnp.asarray(labels, jnp.int32)
    f = EqualityFilter(arr).bind(jnp.asarray([target], jnp.int32))
    ids = jnp.arange(len(labels), dtype=jnp.int32)[None, :]
    got = np.asarray(f(ids))[0]
    assert got.tolist() == [l == target for l in labels]


def test_conjunction():
    labels = jnp.asarray([0, 0, 1, 1], jnp.int32)
    vals = jnp.asarray([0.0, 1.0, 0.0, 1.0], jnp.float32)
    f = AndFilter((EqualityFilter(labels), RangeFilter(vals))).bind(
        jnp.asarray([0], jnp.int32), (jnp.asarray([0.5]), jnp.asarray([1.5]))
    )
    got = np.asarray(f(jnp.asarray([[0, 1, 2, 3]], jnp.int32)))[0]
    assert got.tolist() == [False, True, False, False]


def test_match_all_rejects_invalid_ids():
    f = match_all()
    got = np.asarray(f(jnp.asarray([[0, -1, 5]], jnp.int32)))[0]
    assert got.tolist() == [True, False, True]


def test_memory_accounting():
    n = 1000
    eq = EqualityFilter(jnp.zeros((n,), jnp.int32))
    assert eq.memory_bytes() == n  # 1 B/node logical (paper Table 2)
    sub = SubsetFilter(jnp.zeros((n, 4), jnp.uint32))
    assert sub.memory_bytes() == n * 16
