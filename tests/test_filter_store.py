"""Filter store predicates, including seeded randomized property tests.

The property tests were originally hypothesis-based; they are rewritten
as seeded-parametrize pure-pytest tests so collection never depends on
an optional package.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.filter_store import (
    AndFilter,
    EqualityFilter,
    RangeFilter,
    SubsetFilter,
    match_all,
    pack_tags,
)


def test_equality_basic():
    labels = jnp.asarray([0, 1, 2, 0, 1], jnp.int32)
    f = EqualityFilter(labels).bind(jnp.asarray([0, 1], jnp.int32))
    ids = jnp.asarray([[0, 1, 3], [1, 4, -1]], jnp.int32)
    got = np.asarray(f(ids))
    assert got.tolist() == [[True, False, True], [True, True, False]]


def test_range_basic():
    vals = jnp.asarray([0.1, 0.5, 0.9], jnp.float32)
    f = RangeFilter(vals).bind(jnp.asarray([0.2]), jnp.asarray([0.8]))
    got = np.asarray(f(jnp.asarray([[0, 1, 2]], jnp.int32)))
    assert got.tolist() == [[False, True, False]]


@pytest.mark.parametrize("seed", range(12))
def test_subset_property(seed):
    """(q & node) == q  <=>  q's tags ⊆ node's tags — for random tag sets."""
    rng = np.random.default_rng(seed)
    vocab = 70
    n_nodes = 6  # fixed shape across seeds — one XLA compile, many value draws
    node_tags = [
        sorted(set(rng.integers(0, vocab, size=rng.integers(0, 9)).tolist()))
        for _ in range(n_nodes)
    ]
    q_tags = rng.integers(0, vocab, size=rng.integers(0, 5)).tolist()
    bits = pack_tags(node_tags, vocab)
    qbits = pack_tags([sorted(set(q_tags))], vocab)
    f = SubsetFilter(jnp.asarray(bits)).bind(jnp.asarray(qbits))
    ids = jnp.arange(n_nodes, dtype=jnp.int32)[None, :]
    got = np.asarray(f(ids))[0]
    want = [set(q_tags) <= set(t) for t in node_tags]
    assert got.tolist() == want


@pytest.mark.parametrize("seed", range(10))
def test_equality_property(seed):
    rng = np.random.default_rng(seed + 100)
    labels = rng.integers(0, 5, size=24).tolist()  # fixed shape, varied values
    target = int(rng.integers(0, 5))
    arr = jnp.asarray(labels, jnp.int32)
    f = EqualityFilter(arr).bind(jnp.asarray([target], jnp.int32))
    ids = jnp.arange(len(labels), dtype=jnp.int32)[None, :]
    got = np.asarray(f(ids))[0]
    assert got.tolist() == [l == target for l in labels]


def test_conjunction():
    labels = jnp.asarray([0, 0, 1, 1], jnp.int32)
    vals = jnp.asarray([0.0, 1.0, 0.0, 1.0], jnp.float32)
    f = AndFilter((EqualityFilter(labels), RangeFilter(vals))).bind(
        jnp.asarray([0], jnp.int32), (jnp.asarray([0.5]), jnp.asarray([1.5]))
    )
    got = np.asarray(f(jnp.asarray([[0, 1, 2, 3]], jnp.int32)))[0]
    assert got.tolist() == [False, True, False, False]


def test_match_all_rejects_invalid_ids():
    f = match_all()
    got = np.asarray(f(jnp.asarray([[0, -1, 5]], jnp.int32)))[0]
    assert got.tolist() == [True, False, True]


def test_memory_accounting():
    n = 1000
    eq = EqualityFilter(jnp.zeros((n,), jnp.int32))
    assert eq.memory_bytes() == n  # 1 B/node logical (paper Table 2)
    sub = SubsetFilter(jnp.zeros((n, 4), jnp.uint32))
    assert sub.memory_bytes() == n * 16
