"""Cache tier: hot-node record cache correctness and accounting.

The cache must be *invisible* to results (identical ids/dists) and only
move fetches between the slow tier (``n_ios``) and the cache tier
(``n_cache_hits``) — I/O conservation.  Hit counts must be monotone in
cache size, and the selection policies must put the medoid neighborhood
in even the smallest cache.
"""
import numpy as np
import pytest

from repro.core import SearchConfig
from repro.store import CachedRecordStore, bfs_hot_set, select_hot_set
from repro.store.cache import record_nbytes

RECORD = 4096  # tiny-corpus records round up to one 4 KB sector


def _search(engine, queries, mode="gate", L=64, W=4):
    tgt = np.zeros(queries.shape[0], np.int32)
    return engine.search(
        queries, filter_kind="label", filter_params=tgt,
        search_config=SearchConfig(mode=mode, search_l=L, beam_width=W),
    )


@pytest.fixture(scope="module")
def cache_runs(tiny_engine, tiny_corpus):
    _, _, queries = tiny_corpus
    budgets = (0, 32 * RECORD, 128 * RECORD, 512 * RECORD)
    outs = {
        bud: _search(tiny_engine.with_cache(bud), queries) for bud in budgets
    }
    return outs, queries


def test_cached_results_identical(cache_runs):
    outs, _ = cache_runs
    base = outs[0]
    for bud, out in outs.items():
        np.testing.assert_array_equal(
            np.asarray(out.ids), np.asarray(base.ids), err_msg=f"budget={bud}"
        )
        np.testing.assert_allclose(
            np.asarray(out.dists), np.asarray(base.dists), rtol=1e-6
        )


def test_io_conservation(cache_runs):
    """Every cache hit is exactly one slow-tier read saved."""
    outs, _ = cache_runs
    base_ios = np.asarray(outs[0].stats.n_ios)
    np.testing.assert_array_equal(np.asarray(outs[0].stats.n_cache_hits), 0)
    for bud, out in outs.items():
        ios = np.asarray(out.stats.n_ios)
        hits = np.asarray(out.stats.n_cache_hits)
        np.testing.assert_array_equal(ios + hits, base_ios, err_msg=f"budget={bud}")


def test_hits_monotone_in_cache_size(cache_runs):
    outs, _ = cache_runs
    budgets = sorted(outs)
    total_hits = [int(np.sum(np.asarray(outs[b].stats.n_cache_hits))) for b in budgets]
    assert total_hits == sorted(total_hits), dict(zip(budgets, total_hits))
    assert total_hits[-1] > 0  # a 512-record cache on a 2k corpus must hit


def test_tunnels_and_recall_untouched(cache_runs):
    """The cache only affects the fetch path — tunnels are unchanged."""
    outs, _ = cache_runs
    base = np.asarray(outs[0].stats.n_tunnels)
    for out in outs.values():
        np.testing.assert_array_equal(np.asarray(out.stats.n_tunnels), base)


@pytest.mark.parametrize("policy", ["visit_freq", "bfs"])
def test_policies_cache_the_medoid(tiny_engine, policy):
    eng = tiny_engine.with_cache(16 * RECORD, policy=policy)
    store = eng.record_store
    assert isinstance(store, CachedRecordStore)
    assert store.n_cached == 16
    assert int(eng.medoid) in set(store.hot_ids().tolist())


def test_cache_serves_correct_records(tiny_cached_engine):
    """A cached fetch must return the same bytes as the backing store."""
    import jax.numpy as jnp

    store = tiny_cached_engine.record_store
    ids = jnp.asarray([np.r_[store.hot_ids()[:4], [0, 1, -1, 1999]]], jnp.int32)
    vecs_c, nbrs_c = store.fetch_fn()(ids)
    vecs_b, nbrs_b = store.backing.fetch_fn()(ids)
    np.testing.assert_array_equal(np.asarray(vecs_c), np.asarray(vecs_b))
    np.testing.assert_array_equal(np.asarray(nbrs_c), np.asarray(nbrs_b))


def test_cached_mask_matches_hot_set(tiny_cached_engine):
    import jax.numpy as jnp

    store = tiny_cached_engine.record_store
    hot = set(store.hot_ids().tolist())
    probe = np.r_[store.hot_ids()[:3], [5, 7, -1]].astype(np.int32)
    got = np.asarray(store.cached_mask_fn()(jnp.asarray(probe[None])))[0]
    want = [int(i) in hot and i >= 0 for i in probe]
    assert got.tolist() == want


def test_bfs_hot_set_order_and_bounds():
    nbrs = np.asarray([[1, 2], [3, -1], [3, 4], [-1, -1], [0, -1]], np.int32)
    assert bfs_hot_set(nbrs, 0, 3).tolist() == [0, 1, 2]
    assert bfs_hot_set(nbrs, 0, 99).tolist() == [0, 1, 2, 3, 4]
    assert bfs_hot_set(nbrs, 0, 0).tolist() == []


def test_sub_record_budget_leaves_tier_off(tiny_engine, tiny_corpus):
    """A budget that fits zero records must not wrap (and must not crash
    the jit-side gather with an empty cache operand)."""
    _, _, queries = tiny_corpus
    eng = tiny_engine.with_cache(100)
    assert not isinstance(eng.record_store, CachedRecordStore)
    out = _search(eng, queries[:4])
    np.testing.assert_array_equal(np.asarray(out.stats.n_cache_hits), 0)


def test_empty_wrap_is_safe(tiny_engine, tiny_corpus):
    """Directly wrapping an empty hot set serves everything from backing."""
    import jax.numpy as jnp

    backing = tiny_engine.record_store
    store = CachedRecordStore.wrap(
        backing, vectors=tiny_engine.vectors, neighbors=backing.neighbors,
        hot_ids=np.zeros((0,), np.int32),
    )
    assert store.n_cached == 0
    assert store.cache_bytes() == 0
    ids = jnp.asarray([[0, 5, -1]], jnp.int32)
    vecs_c, nbrs_c = store.fetch_fn()(ids)
    vecs_b, nbrs_b = backing.fetch_fn()(ids)
    np.testing.assert_array_equal(np.asarray(vecs_c), np.asarray(vecs_b))
    np.testing.assert_array_equal(np.asarray(nbrs_c), np.asarray(nbrs_b))


def test_select_hot_set_respects_budget(tiny_engine):
    nbrs = np.asarray(tiny_engine.record_store.neighbors)
    dim = tiny_engine.vectors.shape[1]
    per = record_nbytes(dim, nbrs.shape[1])
    hot = select_hot_set(
        neighbors=nbrs, medoid=int(tiny_engine.medoid),
        budget_bytes=10 * per + per // 2, policy="bfs",
    )
    assert hot.size == 10  # the half record does not fit


def test_memory_report_has_cache_lines(tiny_engine):
    rep = tiny_engine.with_cache(64 * RECORD).memory_report()
    assert rep["cache_nodes"] == 64
    assert rep["cache_bytes"] == 64 * RECORD
    assert rep["cache_policy"] == "visit_freq"
    assert 0 < rep["cache_device_bytes"] < rep["cache_bytes"]
    assert "record_tier_bytes" in rep  # backing tier still reported
    assert "cache_nodes" not in tiny_engine.memory_report()  # uncached engine


def test_modeled_qps_improves_with_cache(tiny_engine, tiny_corpus):
    """Cache hits are priced at the fast-tier rate — modeled QPS must rise."""
    _, _, queries = tiny_corpus
    out0 = _search(tiny_engine, queries)
    out1 = _search(tiny_engine.with_cache(512 * RECORD), queries)
    assert tiny_engine.modeled_qps(out1.stats) > tiny_engine.modeled_qps(out0.stats)
    # without read overlap (W=1) every avoided slow read is ~100 us saved
    assert tiny_engine.modeled_latency_us(
        out1.stats, pipeline_depth=1
    ) < tiny_engine.modeled_latency_us(out0.stats, pipeline_depth=1)


# The I/O-conservation property, extended to the adaptive policy: for
# every (budget, policy, refresh cadence, mode), n_ios + n_cache_hits per
# query equals the uncached engine's n_ios, and result ids/dists are
# bit-identical — across batches, so adaptive refreshes happening *between*
# batches are covered too.  Seeded-parametrize (no hypothesis), tier-1 fast.
CONSERVATION_GRID = [
    # (policy, budget_records, refresh_every, mode, n_batches)
    ("visit_freq", 32, 0, "gate", 1),
    ("visit_freq", 512, 0, "post", 1),
    ("bfs", 128, 0, "gate", 1),
    ("adaptive", 32, 1, "gate", 3),
    ("adaptive", 128, 2, "gate", 3),
    ("adaptive", 128, 1, "post", 2),
    ("adaptive", 512, 4, "unfiltered", 2),
]


@pytest.mark.parametrize("policy,nrec,refresh_every,mode,n_batches",
                         CONSERVATION_GRID)
def test_io_conservation_every_policy(tiny_engine, tiny_corpus, policy, nrec,
                                      refresh_every, mode, n_batches):
    _, _, queries = tiny_corpus
    if mode == "unfiltered":
        base = tiny_engine.search(
            queries, search_config=SearchConfig(mode=mode, search_l=64,
                                                beam_width=4))
    else:
        base = _search(tiny_engine, queries, mode=mode)
    base_ios = np.asarray(base.stats.n_ios)
    eng = tiny_engine.with_cache(nrec * RECORD, policy=policy,
                                 refresh_every=refresh_every)
    for batch in range(n_batches):
        if mode == "unfiltered":
            out = eng.search(
                queries, search_config=SearchConfig(mode=mode, search_l=64,
                                                    beam_width=4))
        else:
            out = _search(eng, queries, mode=mode)
        msg = f"policy={policy} nrec={nrec} batch={batch}"
        np.testing.assert_array_equal(
            np.asarray(out.ids), np.asarray(base.ids), err_msg=msg)
        np.testing.assert_allclose(
            np.asarray(out.dists), np.asarray(base.dists), rtol=1e-6,
            err_msg=msg)
        np.testing.assert_array_equal(
            np.asarray(out.stats.n_ios) + np.asarray(out.stats.n_cache_hits),
            base_ios, err_msg=msg)
        np.testing.assert_array_equal(
            np.asarray(out.stats.n_tunnels), np.asarray(base.stats.n_tunnels),
            err_msg=msg)


def test_cached_gate_matches_oracle(tiny_engine, tiny_corpus):
    """Full-loop check: the cached engine matches the NumPy oracle with the
    same hot set, including the n_ios / n_cache_hits split."""
    import jax.numpy as jnp

    from repro.core import pq as pqm
    from repro.core import search as searchm
    from tests.test_search_oracle import oracle_search

    corpus, labels, queries = tiny_corpus
    queries = queries[:4]
    eng = tiny_engine.with_cache(128 * RECORD)
    out = _search(eng, queries)

    n = corpus.shape[0]
    b = queries.shape[0]
    q = jnp.asarray(queries, jnp.float32)
    lut = pqm.build_lut(eng.codec, q)
    all_ids = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (b, n))
    pq_d = np.asarray(searchm._adc_ids(lut, eng.codes, all_ids, False))
    vecs = jnp.broadcast_to(eng.vectors[None], (b, n, corpus.shape[1]))
    exact_d = np.asarray(searchm._exact_dist(q, vecs, False))
    cached = np.asarray(eng.record_store.slot_of) >= 0
    ora = oracle_search(
        pq_dist=pq_d, exact_dist=exact_d, passes=np.asarray(labels) == 0,
        full_nbrs=np.asarray(eng.record_store.neighbors),
        mem_nbrs=np.asarray(eng.neighbor_store.neighbors),
        entry=int(eng.medoid), mode="gate", L=64, W=4, K=10, cached=cached,
    )
    np.testing.assert_array_equal(np.asarray(out.ids), ora.ids)
    np.testing.assert_array_equal(np.asarray(out.stats.n_ios), ora.n_ios)
    np.testing.assert_array_equal(np.asarray(out.stats.n_cache_hits), ora.n_cache_hits)
