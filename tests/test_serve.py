"""Multi-tenant serving front end: admission, batching, tracing, attribution.

Contract under test (serve/server.py + serve/rag.py):

  * Served results are bit-identical to direct ``engine.search`` over the
    tenant's namespace — batching, bucketing, and padding never change
    what a request retrieves (the recall-parity contract nightly also
    enforces end-to-end via serve_bench).
  * Admission is bounded and explicit: ``max_inflight`` covers queued +
    in-service requests, waits time out into ``AdmissionError``, and
    ``close()`` fails undispatched requests with ``ServerClosed`` instead
    of hanging their handles.
  * A mid-batch engine failure fails THAT batch's handles, abandons any
    in-flight pipelined disk round, and leaves the server serving.
  * Accounting stays exact under concurrency: the measured slow-tier
    delta reconciles against served + padding dispatches (drift == 0),
    per-tenant attribution sums to the store totals, and the physical
    counter families (``unique_sectors_read <= records_read``,
    ``syscalls`` vs ``read_rounds``) hold with many clients in flight.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import GateANNEngine, SearchConfig
from repro.serve import (
    AdmissionError,
    RAGServer,
    ServeFrontend,
    ServerClosed,
    TenantSpec,
)

RECORD = 4096


def _rag(engine, *, bucket_sizes=(4,), depth=1):
    return RAGServer(
        engine=engine, cfg=None, params=None, layout=None,
        passage_tokens=np.zeros((int(engine.vectors.shape[0]), 4), np.int32),
        search_config=SearchConfig(mode="gate", search_l=32, beam_width=4,
                                   pipeline_depth=depth),
        bucket_sizes=bucket_sizes,
    )


def _tenants(n=2, max_inflight=32):
    return [TenantSpec(f"t{i}", "label", np.int32(i), max_inflight=max_inflight)
            for i in range(n)]


@pytest.fixture(scope="module")
def serve_index(tiny_engine, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("serve") / "tiny.gann")
    tiny_engine.save(path)
    return path


def test_served_results_match_direct_search(tiny_engine, tiny_corpus):
    _, _, queries = tiny_corpus
    rag = _rag(tiny_engine)
    with ServeFrontend(rag, _tenants(), max_batch=4,
                       batch_window_s=0.005) as srv:
        handles = [(i % 2, i, srv.submit(f"t{i % 2}", queries[i]))
                   for i in range(8)]
        got = {(t, qi): h.result(timeout=120.0) for t, qi, h in handles}
        rep = srv.io_report()
    for tenant in (0, 1):
        qis = [qi for t, qi in got if t == tenant]
        out = tiny_engine.search(
            queries[qis], filter_kind="label",
            filter_params=np.full(len(qis), tenant, np.int32),
            search_config=rag.search_config,
        )
        direct = np.asarray(out.ids)[:, : rag.search_config.result_k]
        for row, qi in enumerate(qis):
            np.testing.assert_array_equal(got[(tenant, qi)], direct[row])
    # traces are populated and the report's families agree
    for _, _, h in handles:
        tr = h.trace
        assert h.done() and tr.batch_size >= 1
        assert tr.queue_wait >= 0 and tr.search > 0 and tr.total > 0
        assert tr.n_ios + tr.n_cache_hits > 0
    assert rep["admitted"] == rep["completed"] == 8
    assert rep["failed"] == rep["rejected"] == 0
    assert sum(t["queries"] for t in rep["per_tenant"].values()) == 8
    assert set(rep["spans_mean_s"]) == {"queue_wait", "batch_form",
                                        "search", "drain"}


def test_tenant_validation(tiny_engine, tiny_corpus):
    _, _, queries = tiny_corpus
    with pytest.raises(ValueError, match="at least one TenantSpec"):
        ServeFrontend(_rag(tiny_engine), [])
    with pytest.raises(ValueError, match="duplicate tenant"):
        ServeFrontend(_rag(tiny_engine), [TenantSpec("a"), TenantSpec("a")])
    with ServeFrontend(_rag(tiny_engine), _tenants()) as srv:
        with pytest.raises(KeyError, match="unknown tenant"):
            srv.submit("nope", queries[0])


def test_admission_timeout_backpressure(tiny_engine, tiny_corpus):
    """max_inflight=1: while one request is in service, the next submit
    must block and then reject with AdmissionError, not queue unbounded."""
    _, _, queries = tiny_corpus
    rag = _rag(tiny_engine)
    inner = rag.retrieve
    gate = threading.Event()

    def slow_retrieve(reqs):
        gate.wait(timeout=10.0)
        return inner(reqs)

    rag.retrieve = slow_retrieve
    srv = ServeFrontend(rag, _tenants(max_inflight=1), max_batch=4,
                        batch_window_s=0.0)
    try:
        h = srv.submit("t0", queries[0])
        t0 = time.perf_counter()
        with pytest.raises(AdmissionError, match="max_inflight"):
            srv.submit("t0", queries[1], timeout=0.05)
        assert time.perf_counter() - t0 < 5.0  # timed out, didn't hang
        assert srv.rejected == 1
        # the OTHER tenant's budget is untouched by t0's backpressure
        h2 = srv.submit("t1", queries[2], timeout=0.05)
        gate.set()
        assert h.result(timeout=120.0) is not None
        assert h2.result(timeout=120.0) is not None
    finally:
        gate.set()
        srv.close()


def test_close_fails_queued_requests(tiny_engine, tiny_corpus):
    _, _, queries = tiny_corpus
    rag = _rag(tiny_engine)
    inner = rag.retrieve
    gate = threading.Event()

    def slow_retrieve(reqs):
        gate.wait(timeout=10.0)
        return inner(reqs)

    rag.retrieve = slow_retrieve
    srv = ServeFrontend(rag, _tenants(), max_batch=1, batch_window_s=0.0)
    first = srv.submit("t0", queries[0])
    time.sleep(0.05)  # let the dispatcher take the first into service
    queued = [srv.submit("t0", queries[i]) for i in range(1, 4)]
    # close() drains the queue immediately, then blocks joining the
    # dispatcher (still gated inside the first batch's retrieve)
    closer = threading.Thread(target=srv.close)
    closer.start()
    for h in queued:
        with pytest.raises(ServerClosed):
            h.result(timeout=10.0)
    gate.set()  # release the in-service batch so close() can finish
    closer.join(timeout=30.0)
    assert not closer.is_alive()
    assert first.result(timeout=120.0) is not None  # in-service: completes
    with pytest.raises(ServerClosed):
        srv.submit("t0", queries[0])
    srv.close()  # idempotent


def test_batch_failure_contained(tiny_engine, tiny_corpus):
    """An engine failure fails that batch's handles with the original
    exception and the server keeps serving the next batch."""
    _, _, queries = tiny_corpus
    rag = _rag(tiny_engine)
    inner = rag.retrieve
    # fail the first TWO requests regardless of how the dispatcher
    # batched them (one batch of 2 or two of 1 — both are legal timings)
    fail_budget = [2]

    def flaky_retrieve(reqs):
        if fail_budget[0] > 0:
            fail_budget[0] -= len(reqs)
            raise RuntimeError("injected mid-search failure")
        return inner(reqs)

    rag.retrieve = flaky_retrieve
    with ServeFrontend(rag, _tenants(), max_batch=4,
                       batch_window_s=0.005) as srv:
        bad = [srv.submit("t0", queries[i]) for i in range(2)]
        for h in bad:
            with pytest.raises(RuntimeError, match="injected"):
                h.result(timeout=120.0)
        good = srv.submit("t0", queries[0])
        assert good.result(timeout=120.0) is not None
        rep = srv.io_report()
    assert rep["failed"] == 2 and rep["completed"] == 1
    assert rep["per_tenant"]["t0"]["failed"] == 2
    # memory tier: abandon is a no-op that must still be callable
    assert tiny_engine.abandon_pending_io() == 0


def test_empty_and_unfiltered_tenants(tiny_engine, tiny_corpus):
    """A filter-less tenant serves the whole corpus; empty batches never
    reach the engine (close with nothing submitted is clean)."""
    _, _, queries = tiny_corpus
    rag = _rag(tiny_engine)
    with ServeFrontend(rag, [TenantSpec("all")], max_batch=4) as srv:
        h = srv.submit("all", queries[0])
        ids = h.result(timeout=120.0)
        out = tiny_engine.search(queries[:1], search_config=rag.search_config)
        np.testing.assert_array_equal(
            ids, np.asarray(out.ids)[0, : rag.search_config.result_k]
        )
    with ServeFrontend(_rag(tiny_engine), _tenants()) as srv:
        pass  # no traffic: close() must not hang or call the engine


def test_padding_reconciles_measured_disk_adaptive(serve_index, tiny_corpus):
    """Satellite regression: cache tier (adaptive) above the disk store +
    bucketed batches — the modeled served/padding split must reconcile
    EXACTLY against the store's measured records_read, batch after batch."""
    _, _, queries = tiny_corpus
    engine = GateANNEngine.load(
        serve_index, store_tier="disk", cache_budget_bytes=48 * RECORD,
        cache_policy="adaptive", refresh_every=1,
    )
    rag = _rag(engine, bucket_sizes=(4, 8), depth=2)
    from repro.serve.rag import RAGRequest

    def reqs(idxs, tenant=0):
        return [RAGRequest(query_vec=queries[i],
                           prompt_tokens=np.zeros(4, np.int32),
                           filter_kind="label",
                           filter_params=np.int32(tenant)) for i in idxs]

    # odd group sizes force padding; repeated batches move rows between
    # tiers as the adaptive hot set refreshes after every batch
    for batch in ([0, 1, 2], [3, 4, 5, 6, 7], [1, 2], [0, 1, 2, 3, 4]):
        rag.retrieve(reqs(batch))
    assert rag.padded_rows > 0
    assert rag.reconcile_drift == 0
    assert rag.measured_reads == rag.served_ios + rag.padding_ios
    rep = rag.io_report()
    assert rep["measured_slow_reads"] == rag.measured_reads
    assert rep["reconcile_drift"] == 0
    assert rep["abandoned_tokens"] == 0
    assert rep["padding_cache_hits"] >= 0
    engine.measured_store().close()


def test_concurrent_hammer_pipelined_disk(serve_index, tiny_corpus):
    """Satellite: mixed-tenant client threads through the pipelined disk
    path.  Results stay correct, every counter family holds under
    concurrency, and MID-FLIGHT registry snapshots (taken by a sampler
    thread while searches are in progress) satisfy the physical
    invariants — counter-snapshot atomicity, not just final totals.

    The whole run executes under the lockdep recorder: the store's
    counter lock and every segment's fd-open lock are proxy-wrapped, and
    the end of the test asserts no lock-order inversion was observed
    between ``_lock`` and ``_open_lock`` (the store's no-nesting
    invariant: fd opening happens before counter accounting)."""
    from repro import obs
    from repro.analysis import LockOrderRecorder, instrument_disk_store

    _, _, queries = tiny_corpus
    reg = obs.MetricsRegistry(enabled=True)
    with obs.use_registry(reg):
        engine = GateANNEngine.load(
            serve_index, store_tier="disk", cache_budget_bytes=48 * RECORD,
            cache_policy="adaptive", refresh_every=2,
        )
    store = engine.measured_store()
    lockdep = LockOrderRecorder()
    instrument_disk_store(lockdep, store)
    rag = _rag(engine, bucket_sizes=(4, 8), depth=2)
    n_threads, per_thread = 6, 4
    results, errs = {}, []
    snaps, stop = [], threading.Event()

    def client(tid):
        try:
            for j in range(per_thread):
                tenant = (tid + j) % 2
                qi = (tid * per_thread + j) % queries.shape[0]
                h = srv.submit(f"t{tenant}", queries[qi], timeout=30.0)
                results[(tid, j, tenant, qi)] = h.result(timeout=120.0)
        except Exception as e:  # pragma: no cover
            errs.append(e)

    def sampler():
        while not stop.is_set():
            snaps.append(reg.snapshot())
            time.sleep(0.01)

    with obs.use_registry(reg), \
            ServeFrontend(rag, _tenants(), max_batch=8,
                          batch_window_s=0.002) as srv:
        threads = [threading.Thread(target=client, args=(t,))
                   for t in range(n_threads)]
        smp = threading.Thread(target=sampler)
        smp.start()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stop.set()
        smp.join(timeout=10.0)
        assert not errs, errs
        rep = srv.io_report()
    assert rep["completed"] == n_threads * per_thread
    assert rep["failed"] == 0
    # accounting invariants under concurrency
    assert rep["reconcile_drift"] == 0
    assert rep["abandoned_tokens"] == 0
    assert rag.measured_reads == rag.served_ios + rag.padding_ios
    c = store.io_counters()
    assert c["unique_sectors_read"] <= c["records_read"]

    # snapshot atomicity: EVERY mid-flight snapshot (registry state with
    # reads in flight) keeps the physical invariant — never more unique
    # sectors than requested records
    def fam_total(snap, name):
        fam = snap.get(name)
        return fam["total"] if fam else 0

    assert snaps, "sampler took no snapshots"
    for snap in snaps:
        assert fam_total(snap, "disk.unique_sectors_read") <= \
            fam_total(snap, "disk.records_read")
    # final registry totals reconcile bit-exactly with the store's own
    # measured counters (no reset ran, so the monotonic families match)
    for key in ("records_read", "pages_read", "unique_sectors_read",
                "syscalls", "read_rounds"):
        assert reg.family_total(f"disk.{key}") == c[key], key
    assert reg.family_total("disk.abandoned_tokens") == 0
    # registry search-side total == store-side total (drift == 0 in
    # registry form: slow-tier dispatches are exactly the records read)
    assert reg.family_total("search.ios", tier="disk") == c["records_read"]
    if store.io_mode == "preadv":
        assert (c["read_rounds"] <= c["syscalls"]
                <= c["read_rounds"] * store.n_shards)
    assert sum(t["queries"] for t in rep["per_tenant"].values()) == \
        rep["completed"]
    # served ids match direct filtered search for every request
    for (tid, j, tenant, qi), ids in sorted(results.items()):
        out = engine.search(
            queries[qi][None], filter_kind="label",
            filter_params=np.asarray([tenant], np.int32),
            search_config=rag.search_config,
        )
        np.testing.assert_array_equal(
            ids, np.asarray(out.ids)[0, : rag.search_config.result_k],
            err_msg=str((tid, j, tenant, qi)),
        )
    store.close()
    # lock-order hygiene across the whole hammer (including close):
    # the counter lock and the segment open locks never nest in either
    # direction, so no inversion — and therefore no deadlock — is possible
    lockdep.assert_no_inversions()
    edges = lockdep.edges()
    counter, seg = "DiskRecordStore._lock", "_Segment._open_lock"
    assert (counter, seg) not in edges and (seg, counter) not in edges, \
        f"unexpected _lock/_open_lock nesting: {edges}"
