"""End-to-end behaviour tests for the GateANN system (engine-level)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SearchConfig, recall_at_k
from repro.core.io_model import DEFAULT_COST_MODEL


def test_engine_memory_report(tiny_engine):
    rep = tiny_engine.memory_report()
    n = rep["n"]
    assert rep["pq_bytes"] == n * 8  # 8 chunks
    assert rep["neighbor_store_bytes"] == n * (1 + 10) * 4  # Eq. (1)
    assert rep["filter_store_bytes"]["label"] == n
    assert rep["record_tier_bytes"] >= n * 4096  # 4 KB-aligned records


def test_neighbor_store_is_prefix_of_graph(tiny_engine):
    full = np.asarray(tiny_engine.record_store.neighbors)
    mem = np.asarray(tiny_engine.neighbor_store.neighbors)
    np.testing.assert_array_equal(mem, full[:, : mem.shape[1]])


def test_modeled_throughput_ordering(tiny_engine, tiny_corpus):
    """gate's modeled QPS must beat post's at the same recall operating
    point — the paper's headline (7.6x at s=10%)."""
    _, _, queries = tiny_corpus
    tgt = np.zeros(queries.shape[0], np.int32)
    out_g = tiny_engine.search(queries, filter_kind="label", filter_params=tgt,
                               search_config=SearchConfig(mode="gate", search_l=96))
    out_p = tiny_engine.search(queries, filter_kind="label", filter_params=tgt,
                               search_config=SearchConfig(mode="post", search_l=96))
    q_g = tiny_engine.modeled_qps(out_g.stats)
    q_p = tiny_engine.modeled_qps(out_p.stats)
    assert q_g > 2.0 * q_p, (q_g, q_p)


def test_rmax_is_runtime_knob(tiny_corpus):
    """Rebuilding the neighbor store at a different R_max must not touch
    the graph (paper §3.4: runtime parameter, no index rebuild)."""
    from repro.core import EngineConfig, GateANNEngine
    from repro.core.neighbor_store import NeighborStore

    corpus, labels, queries = tiny_corpus
    eng = GateANNEngine.build(
        corpus, config=EngineConfig(degree=20, build_l=40, pq_chunks=8, r_max=10),
        labels=labels,
    )
    graph_before = np.asarray(eng.record_store.neighbors).copy()
    eng.neighbor_store = NeighborStore.from_graph(eng.record_store.neighbors, 4)
    assert eng.neighbor_store.r_max == 4
    np.testing.assert_array_equal(np.asarray(eng.record_store.neighbors), graph_before)
    tgt = np.zeros(queries.shape[0], np.int32)
    out = eng.search(queries, filter_kind="label", filter_params=tgt,
                     search_config=SearchConfig(mode="gate", search_l=64))
    ids = np.asarray(out.ids)
    assert (np.asarray(labels)[ids[ids >= 0]] == 0).all()


def test_with_cache_threads_neighbors_explicitly(tiny_engine):
    """with_cache must not require a ``neighbors`` attribute on the
    backing — the sharded tier only exposes ``local_neighbors`` (and a
    regression here broke every non-in-memory backing)."""
    import dataclasses

    from repro.store import CachedRecordStore, ShardedRecordStore

    backing = ShardedRecordStore(
        local_vectors=tiny_engine.vectors,
        local_neighbors=tiny_engine.record_store.neighbors,
        rows_per_shard=int(tiny_engine.vectors.shape[0]),
    )
    eng = dataclasses.replace(tiny_engine, record_store=backing)
    cached = eng.with_cache(32 * 4096)
    assert isinstance(cached.record_store, CachedRecordStore)
    assert cached.record_store.backing is backing
    assert cached.record_store.n_cached == 32
    # and budget 0 unwraps back to the bare backing without touching it
    assert cached.with_cache(0).record_store is backing
    # a *partial* shard (local rows != corpus rows) must be rejected
    # loudly — its adjacency is locally indexed, not global
    half = int(tiny_engine.vectors.shape[0]) // 2
    partial = ShardedRecordStore(
        local_vectors=tiny_engine.vectors[:half],
        local_neighbors=tiny_engine.record_store.neighbors[:half],
        rows_per_shard=half,
    )
    eng_partial = dataclasses.replace(tiny_engine, record_store=partial)
    with pytest.raises(ValueError, match="partial"):
        eng_partial.with_cache(32 * 4096)


def test_recall_at_k_matches_reference():
    """The broadcast recall must equal the old per-row set loop exactly."""

    def reference(result_ids, gt_ids, k=10):
        res = np.asarray(result_ids)[:, :k]
        hits = denom = 0
        for r, g in zip(res, np.asarray(gt_ids)[:, :k]):
            gset = set(int(x) for x in g if x >= 0)
            if not gset:
                continue
            hits += len(gset & set(int(x) for x in r if x >= 0))
            denom += len(gset)
        return hits / max(denom, 1)

    rng = np.random.default_rng(0)
    for trial in range(20):
        b, k = int(rng.integers(1, 12)), int(rng.integers(1, 12))
        res = rng.integers(-1, 40, size=(b, k + 2))  # dup ids + -1 pads
        gt = np.full((b, k), -1, np.int64)
        for row in range(b):  # unique ids per gt row, variable fill
            fill = int(rng.integers(0, k + 1))
            gt[row, :fill] = rng.choice(40, size=fill, replace=False)
        got = recall_at_k(res, gt, k)
        want = reference(res, gt, k)
        assert got == pytest.approx(want), (trial, got, want)
    assert recall_at_k(np.full((3, 5), -1), np.full((3, 5), -1), 5) == 0.0


def test_rag_mixed_predicate_batch(tiny_engine, tiny_corpus):
    """retrieve() must serve a batch mixing predicate kinds (grouped by
    kind, results merged in request order) instead of asserting."""
    from repro.serve.rag import RAGRequest, RAGServer

    _, _, queries = tiny_corpus
    n = int(tiny_engine.vectors.shape[0])
    server = RAGServer(
        engine=tiny_engine, cfg=None, params=None, layout=None,
        passage_tokens=np.zeros((n, 2), np.int32),
        search_config=SearchConfig(mode="gate", search_l=48, beam_width=4),
    )
    reqs = []
    for i in range(6):
        if i % 3 == 0:  # unfiltered request
            reqs.append(RAGRequest(query_vec=queries[i], prompt_tokens=np.zeros(2, np.int32)))
        else:  # equality predicate, two different targets
            reqs.append(RAGRequest(
                query_vec=queries[i], prompt_tokens=np.zeros(2, np.int32),
                filter_kind="label", filter_params=np.int32(i % 2),
            ))
    ids, stats = server.retrieve(reqs)
    assert ids.shape == (6, server.search_config.result_k)
    assert np.asarray(stats.n_ios).shape == (6,)
    # per-request rows must equal the homogeneous sub-batch runs
    for kind, idxs in (("label", [1, 2, 4, 5]), (None, [0, 3])):
        sub = [reqs[i] for i in idxs]
        sub_ids, sub_stats = server.retrieve(sub)
        np.testing.assert_array_equal(ids[idxs], sub_ids)
        np.testing.assert_array_equal(
            np.asarray(stats.n_ios)[idxs], np.asarray(sub_stats.n_ios))
    assert server.served_queries == 6 + 6  # both retrieve calls accounted


def test_rag_empty_batch(tiny_engine):
    """An empty request batch must serve empty ids/stats, not crash —
    production streams legitimately drain to nothing between ticks."""
    from repro.core.search import SearchStats
    from repro.serve.rag import RAGServer

    n = int(tiny_engine.vectors.shape[0])
    server = RAGServer(
        engine=tiny_engine, cfg=None, params=None, layout=None,
        passage_tokens=np.zeros((n, 2), np.int32),
        search_config=SearchConfig(mode="gate", search_l=48, beam_width=4),
    )
    ids, stats = server.retrieve([])
    assert ids.shape == (0, server.search_config.result_k)
    assert ids.dtype == np.int32
    for f in SearchStats._fields:
        assert np.asarray(getattr(stats, f)).shape == (0,), f
    assert server.build_prompts([], ids).shape == (0, 0)
    tokens, gstats = server.generate([], max_new_tokens=4)
    assert tokens.shape == (0, 4)
    assert np.asarray(gstats.n_ios).shape == (0,)
    # nothing was accounted and the report still renders
    assert server.served_queries == 0 and server.served_ios == 0
    assert server.io_report()["queries"] == 0


def test_rag_batch_bucketing(tiny_engine, tiny_corpus):
    """bucket_sizes pads mixed-kind sub-batches to canonical sizes: the
    jitted loop only ever sees bucket-sized batches (bounded retraces),
    results match the unbucketed server exactly, and the padding rows are
    excluded from the served-I/O accounting (surfaced as padded_rows /
    padding_ios instead)."""
    from repro.serve.rag import RAGRequest, RAGServer

    _, _, queries = tiny_corpus
    n = int(tiny_engine.vectors.shape[0])

    def make_server(bucket_sizes):
        return RAGServer(
            engine=tiny_engine, cfg=None, params=None, layout=None,
            passage_tokens=np.zeros((n, 2), np.int32),
            search_config=SearchConfig(mode="gate", search_l=48, beam_width=4),
            bucket_sizes=bucket_sizes,
        )

    reqs = []
    for i in range(7):  # 3 unfiltered + 4 label rows -> buckets 4 and 4
        if i % 2 == 0 and i < 6:
            reqs.append(RAGRequest(query_vec=queries[i],
                                   prompt_tokens=np.zeros(2, np.int32)))
        else:
            reqs.append(RAGRequest(
                query_vec=queries[i], prompt_tokens=np.zeros(2, np.int32),
                filter_kind="label", filter_params=np.int32(0),
            ))
    plain = make_server(())
    bucketed = make_server((4, 8))
    seen_sizes = []
    real_search = tiny_engine.search

    def spy(q, **kw):
        seen_sizes.append(int(np.asarray(q).shape[0]))
        return real_search(q, **kw)

    import dataclasses

    bucketed.engine = dataclasses.replace(tiny_engine)
    bucketed.engine.search = spy  # instance attr shadows the method
    ids_p, stats_p = plain.retrieve(reqs)
    ids_b, stats_b = bucketed.retrieve(reqs)
    # identical results and identical *served* accounting row-for-row
    np.testing.assert_array_equal(ids_b, ids_p)
    np.testing.assert_array_equal(np.asarray(stats_b.n_ios),
                                  np.asarray(stats_p.n_ios))
    assert plain.served_ios == bucketed.served_ios
    assert plain.served_queries == bucketed.served_queries == 7
    # every sub-batch ran at a canonical size; padding was accounted apart
    assert set(seen_sizes) <= {4, 8}, seen_sizes
    assert bucketed.padded_rows == (4 - 3) + (4 - 4)
    assert bucketed.padding_ios >= 0
    rep = bucketed.io_report()
    assert rep["padded_rows"] == bucketed.padded_rows
    assert rep["padding_ios"] == bucketed.padding_ios
    assert "padded_rows" not in plain.io_report()
    # a group larger than every bucket runs at its natural size
    big = make_server((2,))
    big_ids, _ = big.retrieve(reqs)
    np.testing.assert_array_equal(big_ids, ids_p)
    assert big.padded_rows == 0


def test_multilabel_subset_search(tiny_corpus):
    from repro.core import EngineConfig, GateANNEngine
    from repro.core.filter_store import pack_tags
    from repro.data.labels import multilabel_tags, multilabel_queries

    corpus, _, queries = tiny_corpus
    n = corpus.shape[0]
    tags = multilabel_tags(n, vocab=64, mean_tags=4.0, seed=0)
    bits = pack_tags(tags, 64)
    eng = GateANNEngine.build(
        corpus, config=EngineConfig(degree=20, build_l=40, pq_chunks=8, r_max=10),
        tag_bits=bits,
    )
    qtags = multilabel_queries(tags, queries.shape[0], n_tags=(1, 1), seed=2)
    qbits = pack_tags(qtags, 64)
    out = eng.search(queries, filter_kind="tags", filter_params=jnp.asarray(qbits),
                     search_config=SearchConfig(mode="gate", search_l=64))
    ids = np.asarray(out.ids)
    for row, qt in zip(ids, qtags):
        for i in row[row >= 0]:
            assert set(qt) <= set(tags[int(i)])
