"""End-to-end behaviour tests for the GateANN system (engine-level)."""
import jax.numpy as jnp
import numpy as np

from repro.core import SearchConfig, recall_at_k
from repro.core.io_model import DEFAULT_COST_MODEL


def test_engine_memory_report(tiny_engine):
    rep = tiny_engine.memory_report()
    n = rep["n"]
    assert rep["pq_bytes"] == n * 8  # 8 chunks
    assert rep["neighbor_store_bytes"] == n * (1 + 10) * 4  # Eq. (1)
    assert rep["filter_store_bytes"]["label"] == n
    assert rep["record_tier_bytes"] >= n * 4096  # 4 KB-aligned records


def test_neighbor_store_is_prefix_of_graph(tiny_engine):
    full = np.asarray(tiny_engine.record_store.neighbors)
    mem = np.asarray(tiny_engine.neighbor_store.neighbors)
    np.testing.assert_array_equal(mem, full[:, : mem.shape[1]])


def test_modeled_throughput_ordering(tiny_engine, tiny_corpus):
    """gate's modeled QPS must beat post's at the same recall operating
    point — the paper's headline (7.6x at s=10%)."""
    _, _, queries = tiny_corpus
    tgt = np.zeros(queries.shape[0], np.int32)
    out_g = tiny_engine.search(queries, filter_kind="label", filter_params=tgt,
                               search_config=SearchConfig(mode="gate", search_l=96))
    out_p = tiny_engine.search(queries, filter_kind="label", filter_params=tgt,
                               search_config=SearchConfig(mode="post", search_l=96))
    q_g = tiny_engine.modeled_qps(out_g.stats)
    q_p = tiny_engine.modeled_qps(out_p.stats)
    assert q_g > 2.0 * q_p, (q_g, q_p)


def test_rmax_is_runtime_knob(tiny_corpus):
    """Rebuilding the neighbor store at a different R_max must not touch
    the graph (paper §3.4: runtime parameter, no index rebuild)."""
    from repro.core import EngineConfig, GateANNEngine
    from repro.core.neighbor_store import NeighborStore

    corpus, labels, queries = tiny_corpus
    eng = GateANNEngine.build(
        corpus, config=EngineConfig(degree=20, build_l=40, pq_chunks=8, r_max=10),
        labels=labels,
    )
    graph_before = np.asarray(eng.record_store.neighbors).copy()
    eng.neighbor_store = NeighborStore.from_graph(eng.record_store.neighbors, 4)
    assert eng.neighbor_store.r_max == 4
    np.testing.assert_array_equal(np.asarray(eng.record_store.neighbors), graph_before)
    tgt = np.zeros(queries.shape[0], np.int32)
    out = eng.search(queries, filter_kind="label", filter_params=tgt,
                     search_config=SearchConfig(mode="gate", search_l=64))
    ids = np.asarray(out.ids)
    assert (np.asarray(labels)[ids[ids >= 0]] == 0).all()


def test_multilabel_subset_search(tiny_corpus):
    from repro.core import EngineConfig, GateANNEngine
    from repro.core.filter_store import pack_tags
    from repro.data.labels import multilabel_tags, multilabel_queries

    corpus, _, queries = tiny_corpus
    n = corpus.shape[0]
    tags = multilabel_tags(n, vocab=64, mean_tags=4.0, seed=0)
    bits = pack_tags(tags, 64)
    eng = GateANNEngine.build(
        corpus, config=EngineConfig(degree=20, build_l=40, pq_chunks=8, r_max=10),
        tag_bits=bits,
    )
    qtags = multilabel_queries(tags, queries.shape[0], n_tags=(1, 1), seed=2)
    qbits = pack_tags(qtags, 64)
    out = eng.search(queries, filter_kind="tags", filter_params=jnp.asarray(qbits),
                     search_config=SearchConfig(mode="gate", search_l=64))
    ids = np.asarray(out.ids)
    for row, qt in zip(ids, qtags):
        for i in row[row >= 0]:
            assert set(qt) <= set(tags[int(i)])
