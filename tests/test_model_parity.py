"""Decode-vs-train parity: stepping the cached decode path token-by-token
must reproduce the training forward's logits (validates KV caches, ring
buffers, RoPE positions, recurrent states — the serving correctness core).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.distributed.sharding import NULL_LAYOUT
from repro.models import transformer as tfm
from repro.models.layers import rms_norm

PARITY_ARCHS = [
    "deepseek-coder-33b",   # GQA full attention
    "gemma3-4b",            # local/global mix with ring-buffer caches
    "recurrentgemma-9b",    # RG-LRU recurrence + local attention
    "xlstm-350m",           # mLSTM parallel-vs-recurrent + sLSTM scan
]


def _train_logits(params, cfg, batch):
    hidden, _, _ = tfm.forward_train(params, cfg, NULL_LAYOUT, batch, remat=False)
    w = tfm.unembed_matrix(params, cfg).astype(hidden.dtype)
    return jax.lax.dot_general(
        hidden, w, (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@pytest.mark.slow  # jits a full train forward + T decode steps per arch
@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_decode_matches_train(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    b, t = 2, 24
    params, _ = tfm.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (b, t)), jnp.int32
    )
    full = _train_logits(params, cfg, {"tokens": tokens})  # (B, T, V)

    caches = tfm.init_caches(cfg, b, t, jnp.float32)
    step = jax.jit(
        lambda p, c, tok, pos: tfm.forward_decode(p, cfg, NULL_LAYOUT, tok, c, pos)
    )
    outs = []
    for i in range(t):
        logits, caches = step(params, caches, tokens[:, i : i + 1], jnp.int32(i))
        outs.append(logits[:, 0, :])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-3, atol=2e-3)


def test_window_ring_buffer_wraps_correctly():
    """Sequence longer than the window: ring cache must equal train masking."""
    arch_cfg = dataclasses.replace(
        get_smoke_config("gemma3-4b"), dtype="float32", n_layers=6,
    )
    b, t = 1, 40  # window is 16 in the smoke config -> 2.5 wraps
    params, _ = tfm.init_model(jax.random.PRNGKey(1), arch_cfg)
    tokens = jnp.asarray(
        np.random.default_rng(1).integers(0, arch_cfg.vocab_size, (b, t)), jnp.int32
    )
    full = _train_logits(params, arch_cfg, {"tokens": tokens})
    caches = tfm.init_caches(arch_cfg, b, t, jnp.float32)
    step = jax.jit(
        lambda p, c, tok, pos: tfm.forward_decode(p, arch_cfg, NULL_LAYOUT, tok, c, pos)
    )
    outs = []
    for i in range(t):
        logits, caches = step(params, caches, tokens[:, i : i + 1], jnp.int32(i))
        outs.append(logits[:, 0, :])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-3, atol=2e-3)


def test_chunked_xent_matches_dense():
    rng = np.random.default_rng(0)
    b, t, d, v = 2, 6, 16, 97
    hidden = jnp.asarray(rng.normal(size=(b, t, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, v)), jnp.float32)
    targets = jnp.asarray(rng.integers(0, v, (b, t)), jnp.int32)
    targets = targets.at[0, 0].set(-1)  # ignored position
    loss_sum, n = tfm.chunked_xent(hidden, w, targets, chunk_v=32)
    logits = hidden @ w
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, jnp.maximum(targets, 0)[..., None], -1)[..., 0]
    want = jnp.where(targets != -1, logz - picked, 0.0).sum()
    np.testing.assert_allclose(float(loss_sum), float(want), rtol=1e-5)
    assert int(n) == b * t - 1


def test_flash_attention_matches_dense():
    """attn_train's chunked flash == plain softmax attention."""
    from repro.models import attention as attn

    cfg = dataclasses.replace(
        get_smoke_config("deepseek-coder-33b"), dtype="float32"
    )
    b, t = 2, 16
    params, _ = attn.init_attention(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(b, t, cfg.d_model)) * 0.3,
                    jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(t), (b, t))
    out_flash, _ = attn.attn_train(params, x, pos, cfg, NULL_LAYOUT,
                                   window=None, kv_chunk=4)
    out_plain, _ = attn.attn_train(params, x, pos, cfg, NULL_LAYOUT,
                                   window=None, kv_chunk=t)
    np.testing.assert_allclose(np.asarray(out_flash), np.asarray(out_plain),
                               rtol=1e-4, atol=1e-5)
