"""Persistence: save/load roundtrip, the disk tier, and measured I/O.

The contract under test:

  * ``save`` -> ``load`` returns an engine whose search output (ids,
    dists, every stats counter) is bit-identical to the freshly built
    in-memory engine, in all five modes, for both the memory and the
    disk record tier — load never rebuilds the graph or retrains PQ.
  * The disk tier *measures* its reads: ``DiskRecordStore.pages_read``
    deltas reconcile exactly with summed ``SearchStats.n_ios`` (x pages
    per record), gate reads strictly fewer pages than post on a
    selective filter, and the cache tier composes on top unchanged.
  * The format rejects bad magic, newer versions, and truncated files.
"""
import os
import shutil

import numpy as np
import pytest

from repro.core import GateANNEngine, SearchConfig
from repro.store import (
    FORMAT_VERSION,
    PAGE_BYTES,
    DiskRecordStore,
    IndexFormatError,
    read_header,
    read_index,
)
from repro.store.format import pack_records, record_sector_bytes

MODES = ("gate", "post", "early", "pre_naive", "unfiltered")
RECORD = 4096  # tiny-corpus records round up to one 4 KB sector


def _search(engine, queries, mode, L=64, W=4):
    kind = None if mode == "unfiltered" else "label"
    params = None if mode == "unfiltered" else np.zeros(queries.shape[0], np.int32)
    return engine.search(
        queries, filter_kind=kind, filter_params=params,
        search_config=SearchConfig(mode=mode, search_l=L, beam_width=W),
    )


@pytest.fixture(scope="module")
def index_path(tiny_engine, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("index") / "tiny.gann")
    tiny_engine.save(path)
    return path


@pytest.fixture(scope="module")
def mem_engine(index_path):
    return GateANNEngine.load(index_path)


@pytest.fixture(scope="module")
def disk_engine(index_path):
    return GateANNEngine.load(index_path, store_tier="disk")


# -- roundtrip --------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_roundtrip_bit_identical(tiny_engine, tiny_corpus, mem_engine,
                                 disk_engine, mode):
    """Loaded engines (both tiers) match the freshly built one exactly."""
    _, _, queries = tiny_corpus
    base = _search(tiny_engine, queries, mode)
    for name, eng in (("memory", mem_engine), ("disk", disk_engine)):
        out = _search(eng, queries, mode)
        msg = f"tier={name} mode={mode}"
        np.testing.assert_array_equal(np.asarray(out.ids),
                                      np.asarray(base.ids), err_msg=msg)
        np.testing.assert_array_equal(np.asarray(out.dists),
                                      np.asarray(base.dists), err_msg=msg)
        for f in base.stats._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(out.stats, f)),
                np.asarray(getattr(base.stats, f)), err_msg=f"{msg} stats.{f}")


def test_load_never_rebuilds(index_path, monkeypatch):
    """load must not touch the graph builder or the PQ trainer."""
    from repro.core import engine as enginem

    def boom(*a, **k):
        raise AssertionError("load rebuilt index state")

    monkeypatch.setattr(enginem.graphm, "build_vamana", boom)
    monkeypatch.setattr(enginem.pqm, "train_pq", boom)
    eng = GateANNEngine.load(index_path)
    assert eng.codes.shape[0] == eng.vectors.shape[0]


def test_loaded_components_match(tiny_engine, mem_engine):
    np.testing.assert_array_equal(np.asarray(mem_engine.vectors),
                                  np.asarray(tiny_engine.vectors))
    np.testing.assert_array_equal(np.asarray(mem_engine.codes),
                                  np.asarray(tiny_engine.codes))
    np.testing.assert_array_equal(np.asarray(mem_engine.codec.books),
                                  np.asarray(tiny_engine.codec.books))
    np.testing.assert_array_equal(
        np.asarray(mem_engine.neighbor_store.neighbors),
        np.asarray(tiny_engine.neighbor_store.neighbors))
    assert int(mem_engine.medoid) == int(tiny_engine.medoid)
    assert set(mem_engine.filters) == set(tiny_engine.filters)
    assert mem_engine.config == tiny_engine.config


def test_load_config_overrides(index_path):
    eng = GateANNEngine.load(index_path, r_max=4)
    assert eng.neighbor_store.r_max == 4
    eng2 = GateANNEngine.load(index_path, {"r_max": 6})
    assert eng2.neighbor_store.r_max == 6
    # misspelled overrides must raise, not silently no-op
    with pytest.raises(ValueError, match="cache_budget"):
        GateANNEngine.load(index_path, cache_budget=1 << 20)


def test_save_over_live_disk_engine(index_path, tmp_path, tiny_corpus):
    """Re-saving onto the file backing a live disk engine must not corrupt
    the mapping mid-search (write-then-rename keeps the old inode)."""
    _, _, queries = tiny_corpus
    path = str(tmp_path / "live.gann")
    shutil.copyfile(index_path, path)
    disk = GateANNEngine.load(path, store_tier="disk")
    base = _search(disk, queries[:4], "gate")
    disk.save(path)  # overwrites the very file the memmap is backed by
    out = _search(disk, queries[:4], "gate")
    np.testing.assert_array_equal(np.asarray(out.ids), np.asarray(base.ids))
    # and a fresh load of the re-saved file agrees too
    out2 = _search(GateANNEngine.load(path, store_tier="disk"), queries[:4], "gate")
    np.testing.assert_array_equal(np.asarray(out2.ids), np.asarray(base.ids))


# -- measured I/O -----------------------------------------------------------

def test_disk_pages_reconcile_and_gate_lt_post(disk_engine, tiny_corpus):
    """Measured sector reads == modeled n_ios; tunneling saves real pages."""
    _, _, queries = tiny_corpus
    store = disk_engine.record_store
    assert isinstance(store, DiskRecordStore)
    pages = {}
    for mode in ("gate", "post"):
        before = store.pages_read
        out = _search(disk_engine, queries, mode)
        ids = np.asarray(out.ids)  # materialize => all callbacks ran
        assert ids.shape[0] == queries.shape[0]
        measured = store.pages_read - before
        modeled = int(np.sum(np.asarray(out.stats.n_ios))) * store.pages_per_record
        assert measured == modeled, mode
        pages[mode] = measured
    assert pages["gate"] < pages["post"]
    assert store.bytes_read == store.pages_read * PAGE_BYTES
    assert store.records_read * store.pages_per_record == store.pages_read


def test_cache_tier_composes_on_disk(disk_engine, tiny_corpus):
    """A cache in front of the disk tier: identical ids, I/O conservation,
    and the file only ever sees the misses (measured)."""
    _, _, queries = tiny_corpus
    store = disk_engine.record_store
    base = _search(disk_engine, queries, "gate")
    base_ids = np.asarray(base.ids)
    base_ios = np.asarray(base.stats.n_ios)
    cached = disk_engine.with_cache(64 * RECORD)
    before = store.pages_read
    out = _search(cached, queries, "gate")
    ids = np.asarray(out.ids)
    measured = store.pages_read - before
    np.testing.assert_array_equal(ids, base_ids)
    ios = np.asarray(out.stats.n_ios)
    hits = np.asarray(out.stats.n_cache_hits)
    np.testing.assert_array_equal(ios + hits, base_ios)
    assert int(hits.sum()) > 0
    assert measured == int(ios.sum()) * store.pages_per_record


def test_adaptive_cache_composes_on_disk(disk_engine, tiny_corpus):
    _, _, queries = tiny_corpus
    base = _search(disk_engine, queries, "gate")
    eng = disk_engine.with_cache(64 * RECORD, policy="adaptive", refresh_every=1)
    for _ in range(2):
        out = _search(eng, queries, "gate")
        np.testing.assert_array_equal(np.asarray(out.ids), np.asarray(base.ids))
        np.testing.assert_array_equal(
            np.asarray(out.stats.n_ios) + np.asarray(out.stats.n_cache_hits),
            np.asarray(base.stats.n_ios))


def test_memory_report_disk_lines(disk_engine, index_path):
    rep = disk_engine.memory_report()
    assert rep["record_tier"] == "disk"
    assert rep["disk_path"] == index_path
    assert rep["disk_index_bytes"] == os.path.getsize(index_path)
    assert rep["record_tier_bytes"] == rep["n"] * rep["disk_sector_bytes"]
    assert rep["disk_pages_read"] >= 0
    assert rep["disk_bytes_read"] == rep["disk_pages_read"] * PAGE_BYTES


# -- the format itself ------------------------------------------------------

def test_header_layout(index_path, tiny_engine):
    h = read_header(index_path)
    n, d = tiny_engine.vectors.shape
    assert h.version == FORMAT_VERSION
    assert (h.n, h.dim) == (n, d)
    assert h.medoid == int(tiny_engine.medoid)
    assert h.sector_bytes == record_sector_bytes(h.dim, h.degree)
    assert h.config["r_max"] == tiny_engine.config.r_max
    for name, s in h.sections.items():
        assert s["offset"] % PAGE_BYTES == 0, name
        assert s["offset"] + s["nbytes"] <= h.file_bytes, name
    for expect in ("records", "neighbors", "pq_books", "pq_codes",
                   "filter_label", "filter_range"):
        assert expect in h.sections
    assert "tiny.gann" in h.describe()


def test_record_sectors_page_aligned(tiny_engine):
    vecs = np.asarray(tiny_engine.vectors[:5])
    nbrs = np.asarray(tiny_engine.record_store.neighbors[:5])
    rec = pack_records(vecs, nbrs)
    assert rec.dtype.itemsize % PAGE_BYTES == 0
    np.testing.assert_array_equal(rec["vec"], vecs.astype("<f4"))
    np.testing.assert_array_equal(rec["nbrs"], nbrs.astype("<i4"))
    np.testing.assert_array_equal(rec["deg"], (nbrs >= 0).sum(1))


def test_disk_fetch_matches_memory(disk_engine, tiny_engine):
    """The host callback returns the same bytes as the in-memory store."""
    import jax.numpy as jnp

    ids = jnp.asarray([[0, 1, 7, -1, 1999]], jnp.int32)
    vecs_d, nbrs_d = disk_engine.record_store.fetch_fn()(ids)
    vecs_m, nbrs_m = tiny_engine.record_store.fetch_fn()(ids)
    np.testing.assert_array_equal(np.asarray(vecs_d), np.asarray(vecs_m))
    np.testing.assert_array_equal(np.asarray(nbrs_d), np.asarray(nbrs_m))


def test_bad_magic_rejected(index_path, tmp_path):
    bad = str(tmp_path / "bad_magic.gann")
    shutil.copyfile(index_path, bad)
    with open(bad, "r+b") as f:
        f.write(b"NOPE")
    with pytest.raises(IndexFormatError, match="magic"):
        read_header(bad)
    with pytest.raises(IndexFormatError):
        GateANNEngine.load(bad)


def test_newer_version_rejected(index_path, tmp_path):
    bad = str(tmp_path / "vnext.gann")
    shutil.copyfile(index_path, bad)
    with open(bad, "r+b") as f:
        f.seek(4)
        f.write(np.uint32(FORMAT_VERSION + 1).tobytes())
    with pytest.raises(IndexFormatError, match="version"):
        GateANNEngine.load(bad)


def test_truncated_file_rejected(index_path, tmp_path):
    bad = str(tmp_path / "trunc.gann")
    shutil.copyfile(index_path, bad)
    h = read_header(index_path)
    os.truncate(bad, h.file_bytes // 2)
    with pytest.raises(IndexFormatError, match="truncat"):
        read_header(bad)
    with pytest.raises(IndexFormatError):
        GateANNEngine.load(bad, store_tier="disk")


def _write_raw_header(path, meta, pad_bytes=0):
    """A syntactically valid header with arbitrary (possibly bogus) meta."""
    import json

    from repro.store.format import HEADER_PAGES, _PRELUDE, FORMAT_MAGIC

    blob = json.dumps(meta).encode()
    prelude = np.zeros((), dtype=_PRELUDE)
    prelude["magic"] = FORMAT_MAGIC
    prelude["version"] = FORMAT_VERSION
    prelude["json_len"] = len(blob)
    with open(path, "wb") as f:
        f.write(prelude.tobytes())
        f.write(blob)
        f.write(b"\0" * (HEADER_PAGES * PAGE_BYTES - _PRELUDE.itemsize - len(blob)))
        f.write(b"\0" * pad_bytes)


@pytest.mark.parametrize("meta", [
    {},  # everything missing
    {"n": 4, "dim": 2, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {"records": {"offset": 16384}}},  # section missing nbytes
    {"n": 4, "dim": 2, "degree": 2, "sector_bytes": 0, "medoid": 0,
     "sections": {}},  # zero sector size (would div-by-zero downstream)
    {"n": 4, "dim": -1, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {}},  # nonsensical geometry
    {"n": "lots", "dim": 2, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {}},  # ill-typed field
    {"n": 100000, "dim": 2, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {"records": {"offset": 16384, "nbytes": 4096,
                              "dtype": "record", "shape": [1]}}},
    # ^ lying records shape: nbytes fits the file but not n x sector
    {"n": 4, "dim": 2, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {"pq_codes": {"offset": 16384, "nbytes": 99,
                               "dtype": "<i4", "shape": [4, 8]}}},
    # ^ dtype x shape inconsistent with nbytes (would mmap wrong bytes)
    {"n": 4, "dim": 2, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {"neighbors": {"offset": 16384, "nbytes": -5000,
                                "dtype": "<i4", "shape": [4, 2]}}},
    # ^ negative section size
    {"n": 4, "dim": 2000, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {}},
    # ^ sector_bytes inconsistent with dim/degree (record dtype would
    #   read past the section at the wrong pages_per_record)
    {"n": 4, "dim": 2, "degree": 2, "sector_bytes": 4096, "medoid": 10 ** 9,
     "sections": {}},  # medoid out of [0, n)
    {"n": 4, "dim": 2, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {"pq_codes": {"offset": 0, "nbytes": 0,
                               "dtype": "<i4", "shape": [0, 0]}}},
    # ^ section claiming the header pages as data
    {"n": 4, "dim": 2, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {"pq_codes": {"offset": 16384, "nbytes": 4096,
                               "dtype": "<u1", "shape": [4096]},
                  "neighbors": {"offset": 16384, "nbytes": 4096,
                                "dtype": "<u1", "shape": [4096]}}},
    # ^ overlapping sections
])
def test_corrupt_parseable_header_rejected(tmp_path, meta):
    """JSON that parses but lies must still come out as IndexFormatError."""
    p = str(tmp_path / "corrupt.gann")
    _write_raw_header(p, meta, pad_bytes=8192)
    with pytest.raises(IndexFormatError):
        read_header(p)


def test_not_an_index_rejected(tmp_path):
    p = str(tmp_path / "tiny.gann")
    with open(p, "wb") as f:
        f.write(b"hello world")
    with pytest.raises(IndexFormatError):
        read_header(p)
    with pytest.raises(IndexFormatError):
        read_index(os.path.join(str(tmp_path), "missing.gann"))
